"""repro.obs — the telemetry subsystem (trackers, spans, search stats).

One ``Tracker`` protocol (``log_metrics`` + ``span``), three
implementations (``NoopTracker``/``InMemoryTracker``/``JsonlTracker``), and
the ``SearchStats`` aggregator that folds per-query search signals into
scanning rate / hash saturation / comps histograms at host sync boundaries.
Event schema and reading guide: docs/observability.md.
"""

from repro.obs.stats import SearchStats
from repro.obs.tracker import (
    NOOP,
    InMemoryTracker,
    JsonlTracker,
    NoopTracker,
    Span,
    Tracker,
    load_events,
    span_tree,
)

__all__ = [
    "Tracker",
    "Span",
    "NoopTracker",
    "InMemoryTracker",
    "JsonlTracker",
    "SearchStats",
    "NOOP",
    "load_events",
    "span_tree",
]
