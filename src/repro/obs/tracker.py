"""Trackers: the one metrics/span interface every layer reports through.

The repo's observability story used to be ad-hoc benchmark prints: each
bench computed its own percentiles and threw the per-query signals away.
This module is the levanter-tracker-shaped abstraction the ROADMAP asked
for — a tiny protocol with three implementations:

  * ``NoopTracker``     — the default everywhere; zero overhead, never syncs;
  * ``InMemoryTracker`` — events held in a list (tests, notebooks);
  * ``JsonlTracker``    — append-only event log on disk, one JSON object per
    line, flushed per event so a crash loses at most the line being written.

Two event kinds flow through a tracker:

  * **metrics** — ``log_metrics({...}, step=...)``: a flat dict of host
    scalars.  Callers convert device values themselves (``int(counter)``,
    ``float(x)``) because *that conversion is a host sync* and the standing
    policy is sync-boundary-only capture: metrics are logged where the code
    already synchronized (after ``block_until_ready``, inside a wave
    callback, after a ``device_get``), never from inside a jitted path.
  * **spans** — ``with tracker.span(name) as sp: ...; sp.sync(out)``:
    wall-clock timing of a scoped operation.  JAX dispatch is async, so a
    span that closes without a sync measures *dispatch*, not device work;
    ``sp.sync(tree)`` calls ``jax.block_until_ready`` on the tree and marks
    the span ``synced`` — the event schema records which kind of time each
    span holds, so a reader never mistakes enqueue time for execution time.
    Under ``NoopTracker`` the ``sync`` is a passthrough (no block): turning
    telemetry OFF must remove every sync it introduced.

Spans nest (a ``serve/step`` span contains an ``index/flush`` span and an
``index/search`` span); the tracker maintains the active-span stack and
stamps each span event with its ``depth`` and ``parent`` so the JSONL
round-trips back into a tree.

Trackers never change results: they only read host scalars and timestamps.
``tests/test_obs.py`` pins that searching with a tracker attached is
bit-identical to searching without one (fp32).
"""

from __future__ import annotations

import json
import os
import time
from typing import Iterator, List, Mapping, Optional

import numpy as np

__all__ = [
    "Tracker",
    "Span",
    "NoopTracker",
    "InMemoryTracker",
    "JsonlTracker",
    "load_events",
    "span_tree",
]


def _host_scalar(v):
    """Coerce a value to a JSON-able host scalar.

    Accepts python numbers, strings, bools, numpy scalars and 0-d arrays.
    Device arrays reaching this point mean the caller logged from a
    non-sync boundary; ``np.asarray`` will sync them — correct but against
    policy, so keep conversions at the call site.
    """
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    arr = np.asarray(v)
    if arr.ndim == 0:
        return arr.item()
    return arr.tolist()


class Span:
    """One live span: created by ``Tracker.span``, closed by the context
    manager.  ``sync(tree)`` blocks on the tree's device buffers (so the
    elapsed time covers device work, not dispatch) and returns the tree
    unchanged, letting call sites write ``res = sp.sync(res)``."""

    __slots__ = ("name", "t0", "synced", "_tracker")

    def __init__(self, name: str, tracker: "Tracker"):
        self.name = name
        self.t0 = time.perf_counter()
        self.synced = False
        self._tracker = tracker

    def sync(self, tree):
        import jax

        jax.block_until_ready(tree)
        self.synced = True
        return tree


class _NoopSpan:
    """Span stand-in for ``NoopTracker``: no clock read, and — critically —
    ``sync`` does NOT block: telemetry off means no telemetry-introduced
    host syncs anywhere.  ``synced`` accepts (and discards) writes so call
    sites that annotate an existing sync (``sp.synced = True``) need no
    tracker-kind branch."""

    __slots__ = ()
    name = "<noop>"

    @property
    def synced(self) -> bool:
        return False

    @synced.setter
    def synced(self, _v) -> None:
        pass

    def sync(self, tree):
        return tree


_NOOP_SPAN = _NoopSpan()


class Tracker:
    """The protocol + the span-stack machinery shared by real trackers.

    Subclasses implement ``_emit(event: dict)``; everything else —
    ``log_metrics``, the ``span`` context manager, nesting bookkeeping,
    ``finish`` — lives here so the three implementations cannot drift on
    schema.
    """

    def __init__(self):
        self._stack: List[str] = []
        self._t_origin = time.perf_counter()

    # -- subclass surface ----------------------------------------------------

    def _emit(self, event: dict) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    # -- protocol ------------------------------------------------------------

    def log_metrics(
        self, metrics: Mapping[str, object], *, step: Optional[int] = None
    ) -> None:
        """Record a flat dict of host scalars (see module doc for the
        sync-boundary policy).  ``step`` is an optional monotonic ordinal
        (wave index, serving round) for time-series readers."""
        ev = {
            "event": "metrics",
            "t": time.perf_counter() - self._t_origin,
            "metrics": {k: _host_scalar(v) for k, v in metrics.items()},
        }
        if step is not None:
            ev["step"] = int(step)
        if self._stack:
            ev["span"] = self._stack[-1]
        self._emit(ev)

    def span(self, name: str):
        """Context manager timing a scoped operation; yields a ``Span``
        whose ``sync(tree)`` makes the measurement cover device work."""
        return _SpanCtx(self, name)

    def finish(self) -> None:
        """Flush/close; further events are a caller bug (real trackers may
        raise or drop)."""

    # -- internals shared with _SpanCtx --------------------------------------

    def _close_span(self, sp: Span) -> None:
        depth = len(self._stack) - 1
        ev = {
            "event": "span",
            "name": sp.name,
            "t": sp.t0 - self._t_origin,
            "dur_s": time.perf_counter() - sp.t0,
            "depth": depth,
            "synced": sp.synced,
        }
        if depth > 0:
            ev["parent"] = self._stack[depth - 1]
        self._emit(ev)


class _SpanCtx:
    __slots__ = ("_tracker", "_name", "_span")

    def __init__(self, tracker: Tracker, name: str):
        self._tracker = tracker
        self._name = name
        self._span: Optional[Span] = None

    def __enter__(self) -> Span:
        self._tracker._stack.append(self._name)
        self._span = Span(self._name, self._tracker)
        return self._span

    def __exit__(self, exc_type, exc, tb):
        try:
            self._tracker._close_span(self._span)
        finally:
            self._tracker._stack.pop()
        return False


class NoopTracker(Tracker):
    """The default: accepts everything, records nothing, syncs nothing.

    ``span`` skips the stack and the clock entirely, so instrumented code
    paths cost a single attribute check when telemetry is off.
    """

    def log_metrics(self, metrics, *, step=None) -> None:
        pass

    def span(self, name: str):
        return _NOOP_CTX

    def _emit(self, event: dict) -> None:
        pass


class _NoopCtx:
    __slots__ = ()

    def __enter__(self):
        return _NOOP_SPAN

    def __exit__(self, exc_type, exc, tb):
        return False


_NOOP_CTX = _NoopCtx()

#: module-level shared no-op instance — instrumented code uses
#: ``tracker or NOOP`` so the hot path never branches on None twice
NOOP = NoopTracker()


class InMemoryTracker(Tracker):
    """Events in a host list — the test/notebook tracker.

    ``events`` is the raw chronological record; ``metrics_events`` /
    ``span_events`` are filtered views; ``spans(name)`` collects the
    durations of one span name.
    """

    def __init__(self):
        super().__init__()
        self.events: List[dict] = []

    def _emit(self, event: dict) -> None:
        self.events.append(event)

    @property
    def metrics_events(self) -> List[dict]:
        return [e for e in self.events if e["event"] == "metrics"]

    @property
    def span_events(self) -> List[dict]:
        return [e for e in self.events if e["event"] == "span"]

    def spans(self, name: str) -> List[dict]:
        return [e for e in self.span_events if e["name"] == name]


class JsonlTracker(Tracker):
    """Append-only on-disk event log: one JSON object per line.

    Crash-safety contract: the file is opened in append mode and flushed
    (+ fsync'd on ``finish``) per event, so an interrupted run loses at most
    its final partially-written line — and ``load_events`` skips lines that
    fail to parse, so a log with a torn tail still round-trips every
    complete event.  Multiple runs may append to one file; each tracker
    writes a ``run`` header event at open (run metadata: jax/backend
    provenance via ``benchmarks.common``-style dicts or the caller's own),
    so readers can split the log into runs.
    """

    def __init__(self, path: str, run_meta: Optional[dict] = None):
        super().__init__()
        self.path = path
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self._f = open(path, "a", encoding="utf-8")
        header = {
            "event": "run",
            "wall_time_utc": time.strftime(
                "%Y-%m-%dT%H:%M:%SZ", time.gmtime()
            ),
            "pid": os.getpid(),
        }
        if run_meta:
            header["meta"] = {k: _host_scalar(v) for k, v in run_meta.items()}
        self._emit(header)

    def _emit(self, event: dict) -> None:
        if self._f is None:
            return  # post-finish emit: drop rather than crash the host loop
        self._f.write(json.dumps(event, sort_keys=True) + "\n")
        self._f.flush()

    def finish(self) -> None:
        if self._f is not None:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
            self._f = None

    def __del__(self):  # best-effort close on GC
        try:
            self.finish()
        except Exception:
            pass


def load_events(path: str) -> List[dict]:
    """Parse a JSONL event log back into event dicts.

    Torn tails (a crash mid-write) and blank lines are skipped, not fatal —
    the crash-safety contract is that every *complete* line round-trips.
    """
    events = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                continue  # torn tail / partial write
            if isinstance(ev, dict):
                events.append(ev)
    return events


def span_tree(events: List[dict]) -> Iterator[str]:
    """Render span events as an indented tree (depth-stamped at emit time);
    a quick human view of a JSONL log — see docs/observability.md."""
    for e in events:
        if e.get("event") != "span":
            continue
        pad = "  " * int(e.get("depth", 0))
        sync = "" if e.get("synced") else "  [dispatch-only]"
        yield f"{pad}{e['name']}: {e['dur_s'] * 1e3:.2f}ms{sync}"
