"""SearchStats: fold per-query search signals into serving telemetry.

Every ``SearchResult`` already carries exact per-lane accounting — ``n_comps``
(each distance evaluation, charged under the scanning-rate-honesty policy),
``hash_full`` (the visited hash could no longer record), ``n_iters`` and
``converged`` — but serving used to throw them away.  ``SearchStats`` is the
host-side aggregator: feed it results at existing sync boundaries (after
``block_until_ready``, inside ``device_get`` paths) and it maintains

  * total/mean comparisons per query and a power-of-two **histogram** of
    comps/query (bucket b counts queries with n_comps in [2^b, 2^{b+1})),
    from which approximate p50/p99 comps fall out;
  * the serving **scanning rate** — Eq. 2 extended to reads: mean distance
    evaluations per query divided by the live catalog size, i.e. the
    fraction of the dataset one query touches;
  * the **hash-saturation ratio** — share of queries whose ``hash_full``
    flag fired (their comps may overcount and their recall may be silently
    degraded; a rising ratio is the signal to grow ``hash_slots``);
  * the convergence ratio (lanes stopped by the ``max_iters`` straggler cap
    rather than the paper's no-improvement rule).

No device syncs happen inside this module beyond the ``np.asarray`` calls in
``update`` — which is exactly the point: ``update`` IS the sync boundary,
and callers place it where a sync already exists (the serving loop syncs on
``res.ids`` for latency anyway; build stats are read at the wave-callback
stride).  Nothing here is ever called from a jitted path.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["SearchStats"]

_N_BUCKETS = 32  # comps/query < 2^32 by construction (int32 counters)


class SearchStats:
    """Running aggregate over many ``SearchResult`` batches (see module doc).

    ``n_items`` may be pinned at construction or passed per ``update`` (a
    churning catalog changes size); the scanning rate uses the comps-weighted
    live size so interleaved churn stays honest.
    """

    def __init__(self, n_items: Optional[int] = None):
        self.default_n_items = n_items
        self.n_queries = 0
        self.total_comps = 0
        self.total_iters = 0
        self.hash_full_queries = 0
        self.capped_queries = 0  # stopped by max_iters, not convergence
        self.max_comps = 0
        self.hist = np.zeros(_N_BUCKETS, np.int64)
        # sum over queries of (live catalog size at serve time): the scanning
        # rate denominator under churn is the mean catalog each query saw
        self._n_items_weighted = 0

    # -- folding -------------------------------------------------------------

    def update(self, res, n_items: Optional[int] = None) -> "SearchStats":
        """Fold one batch's ``SearchResult`` (or any object with ``n_comps``,
        ``hash_full``, ``n_iters``, ``converged`` per-lane arrays).  This is
        a host sync — call it only at existing sync boundaries."""
        comps = np.asarray(res.n_comps).reshape(-1).astype(np.int64)
        full = np.asarray(res.hash_full).reshape(-1)
        iters = np.asarray(res.n_iters).reshape(-1).astype(np.int64)
        conv = np.asarray(res.converged).reshape(-1)
        B = comps.shape[0]
        n_live = self.default_n_items if n_items is None else int(n_items)

        self.n_queries += B
        self.total_comps += int(comps.sum())
        self.total_iters += int(iters.sum())
        self.hash_full_queries += int(np.count_nonzero(full))
        self.capped_queries += int(np.count_nonzero(~conv))
        if B:
            self.max_comps = max(self.max_comps, int(comps.max()))
        # pow2 bucket index: floor(log2(c)) with c=0 landing in bucket 0
        b = np.zeros_like(comps)
        pos = comps > 0
        b[pos] = np.floor(np.log2(comps[pos])).astype(np.int64)
        np.add.at(self.hist, np.clip(b, 0, _N_BUCKETS - 1), 1)
        if n_live is not None:
            self._n_items_weighted += B * int(n_live)
        return self

    def merge(self, other: "SearchStats") -> "SearchStats":
        """Fold another aggregator in (per-shard stats -> router totals)."""
        self.n_queries += other.n_queries
        self.total_comps += other.total_comps
        self.total_iters += other.total_iters
        self.hash_full_queries += other.hash_full_queries
        self.capped_queries += other.capped_queries
        self.max_comps = max(self.max_comps, other.max_comps)
        self.hist += other.hist
        self._n_items_weighted += other._n_items_weighted
        return self

    def reset(self) -> None:
        """Zero every accumulator (warm-up rounds are folded then reset)."""
        self.__init__(self.default_n_items)

    # -- derived views -------------------------------------------------------

    @property
    def comps_per_query(self) -> float:
        return self.total_comps / max(self.n_queries, 1)

    @property
    def scanning_rate(self) -> float:
        """Serving Eq.-2: mean comps per query over the mean live catalog
        size those queries were served against (0 when size is unknown)."""
        if self._n_items_weighted == 0:
            return 0.0
        return self.total_comps / self._n_items_weighted

    @property
    def hash_saturation_ratio(self) -> float:
        return self.hash_full_queries / max(self.n_queries, 1)

    @property
    def capped_ratio(self) -> float:
        return self.capped_queries / max(self.n_queries, 1)

    def comps_percentile(self, pct: float) -> float:
        """Approximate percentile of comps/query from the pow2 histogram
        (upper bucket edge at the crossing — a <=2x overestimate, consistent
        across runs; exact percentiles would need per-query retention)."""
        if self.n_queries == 0:
            return 0.0
        target = self.n_queries * (pct / 100.0)
        cum = np.cumsum(self.hist)
        b = int(np.searchsorted(cum, target, side="left"))
        b = min(b, _N_BUCKETS - 1)
        return float(min(2.0 ** (b + 1), self.max_comps or 2.0 ** (b + 1)))

    def as_metrics(self, prefix: str = "search") -> dict:
        """Flat host-scalar dict for ``Tracker.log_metrics``."""
        return {
            f"{prefix}/n_queries": self.n_queries,
            f"{prefix}/comps_per_query": self.comps_per_query,
            f"{prefix}/comps_p50": self.comps_percentile(50),
            f"{prefix}/comps_p99": self.comps_percentile(99),
            f"{prefix}/scanning_rate": self.scanning_rate,
            f"{prefix}/hash_saturation_ratio": self.hash_saturation_ratio,
            f"{prefix}/capped_ratio": self.capped_ratio,
        }

    def __repr__(self) -> str:
        return (
            f"SearchStats(n_queries={self.n_queries}, "
            f"comps/q={self.comps_per_query:.1f}, "
            f"scan={self.scanning_rate:.5f}, "
            f"hash_sat={self.hash_saturation_ratio:.3f})"
        )
