from repro.serve import retrieval  # noqa: F401
