"""ANN retrieval serving: the paper's LGD graph as a production index.

This is the paper's own deployment story (§IV-C e-shopping scenario) wired
to the MIND recsys arch (DESIGN.md §5): candidate item embeddings are
indexed once with online LGD construction; at serve time each user's
interest vectors (from MIND's capsule encoder) query the graph with
EHC search under the inner-product metric; results from the K interests are
deduped and re-ranked.

Because construction is online, catalog churn (new items listed, stale items
withdrawn) maps to ``core.dynamic.insert``/``remove`` — no index rebuilds,
which is precisely the capability the paper contributes over offline
builders (NN-Descent / DPG / HNSW).

The index object here is ``repro.index.OnlineIndex`` — the lifecycle facade
that owns capacity (auto-growth instead of the old hard assert), recycles
removed rows (free-slot ledger + compaction), coalesces small inserts, and
snapshots to disk.  ``RetrievalIndex`` remains as an alias for existing
callers.  The entry points below keep their functional contract: they
``clone()`` (O(fields); jax buffers are immutable) and mutate the copy.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import brute, construct, segments
from repro.index.lifecycle import OnlineIndex

Array = jax.Array

# legacy name — the serving index IS the lifecycle facade now
RetrievalIndex = OnlineIndex

#: metrics where the underlying "distance" is a negated similarity, so the
#: serving score flips sign to restore "higher = better"
SIMILARITY_METRICS = ("ip", "cosine")


def score_from_dist(dist: Array, metric: str) -> Array:
    """Serving score convention, one place for every metric.

    Similarity metrics (inner product, cosine) surface scores where higher =
    better; true distance metrics (l2, l1, chi2) surface the distance itself
    (lower = better).  The helper is an involution — applying it to a score
    returns the distance — which is what the sharded router relies on to
    merge per-shard results in a convention-free way.
    """
    return -dist if metric in SIMILARITY_METRICS else dist


def build_index(
    items: Array,
    *,
    k: int = 20,
    metric: str = "ip",
    wave: int = 512,
    capacity: Optional[int] = None,
    key: Optional[Array] = None,
    beam: int = 40,
    use_pallas: Optional[bool] = None,
    dispatch: Optional[str] = None,
    precision: str = "fp32",
) -> OnlineIndex:
    """Index a candidate bank with online LGD construction.

    ``dispatch`` follows the four-way enum of ``SearchConfig`` (the default
    ``"auto"`` rides the fused Pallas expansion kernel on TPU and the
    pure-JAX reference elsewhere); ``use_pallas`` is the deprecated
    tri-state spelling.  ``precision`` selects the distance-engine
    representation (``"fp32"|"bf16"|"int8"|"pq"``).  All three are stored in
    ``build_cfg`` so serving (``retrieve``) and catalog churn
    (``add_items``, via ``dynamic.insert``) run the same path as the build.
    """
    cfg = construct.BuildConfig(
        k=k, metric=metric, wave=wave, lgd=True, beam=beam,
        use_pallas=use_pallas, dispatch=dispatch, precision=precision,
    )
    return OnlineIndex.build(items, cfg, capacity=capacity, key=key)


def retrieve(
    index: OnlineIndex,
    interests: Array,  # (K, d) query vectors (MIND interests, or any queries)
    top_k: int,
    *,
    beam: Optional[int] = None,
    key: Optional[Array] = None,
    with_stats: bool = False,
):
    """k-NN retrieval: EHC search per interest + cross-interest dedupe/merge.

    Returns (item_ids (top_k,), scores (top_k,)) — scores follow
    ``score_from_dist``: higher = better for similarity metrics (ip,
    cosine), plain distances (lower = better) otherwise.

    ``with_stats=True`` appends the raw per-interest ``SearchResult`` as a
    third element.  The search computes ``n_comps``/``hash_full``/``n_iters``
    exactly for every query anyway; the default 2-tuple used to be the only
    surface, silently discarding them — serving telemetry (``obs.SearchStats``
    saturation/scanning-rate accounting) folds this object at its own sync
    boundary, so requesting it adds no host sync here.
    """
    # one search dispatch for facade and serving: OnlineIndex.search flushes
    # buffered writes and serves on the build's kernel path / LGD setting
    res = index.search(interests, top_k, beam=beam, key=key)
    ids = res.ids.reshape(-1)
    dist = res.dists.reshape(-1)
    # cross-interest dedupe: keep the best (smallest-distance) copy —
    # sort-based segmented idiom (core.segments), not a pairwise matrix
    order = jnp.argsort(dist)
    ids_s = ids[order]
    dup = segments.mask_row_duplicates(ids_s[None, :])[0]
    dist_s = jnp.where(dup | (ids_s < 0), jnp.inf, dist[order])
    sel = jnp.argsort(dist_s)[:top_k]
    out_ids = ids_s[sel]
    scores = score_from_dist(dist_s[sel], index.metric)
    if with_stats:
        return out_ids, scores, res
    return out_ids, scores


def retrieve_brute(index: OnlineIndex, interests: Array, top_k: int):
    """Exact baseline (the retrieval_cand roofline cell): full GEMM + top-k.

    Honors catalog churn exactly: buffered adds are flushed and removed rows
    are masked out via ``KNNGraph.alive``, so this stays the oracle for the
    graph path on a churned index.
    """
    index.flush()
    ids, dist = brute.brute_force_knn(
        index.items, interests, top_k, index.metric,
        n_valid=index.graph.n_valid, alive=index.graph.alive, use_pallas=False,
    )
    flat_i = ids.reshape(-1)
    flat_d = dist.reshape(-1)
    order = jnp.argsort(flat_d)
    ids_s = flat_i[order]
    dup = segments.mask_row_duplicates(ids_s[None, :])[0]
    d_s = jnp.where(dup | (ids_s < 0), jnp.inf, flat_d[order])
    sel = jnp.argsort(d_s)[:top_k]
    return ids_s[sel], score_from_dist(d_s[sel], index.metric)


def add_items(index: OnlineIndex, new_items: Array, key=None) -> OnlineIndex:
    """Catalog insert: append rows + online insertion waves (§IV-C).

    Functional: returns a new index, the argument is untouched.  Capacity is
    managed by the lifecycle layer — an over-capacity insert recycles free
    slots or grows the index (amortized doubling), it never raises.
    """
    return index.clone().add(new_items, key=key, flush=True)


def remove_items(index: OnlineIndex, ids: Array) -> OnlineIndex:
    """Catalog withdraw: the paper's O(k²/2) removal with λ repair.

    Functional, like ``add_items``; the victims enter the returned index's
    free-slot ledger for later recycling.
    """
    return index.clone().remove(ids)
