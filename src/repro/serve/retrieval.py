"""ANN retrieval serving: the paper's LGD graph as a production index.

This is the paper's own deployment story (§IV-C e-shopping scenario) wired
to the MIND recsys arch (DESIGN.md §5): candidate item embeddings are
indexed once with online LGD construction; at serve time each user's
interest vectors (from MIND's capsule encoder) query the graph with
EHC search under the inner-product metric; results from the K interests are
deduped and re-ranked.

Because construction is online, catalog churn (new items listed, stale items
withdrawn) maps to ``core.dynamic.insert``/``remove`` — no index rebuilds,
which is precisely the capability the paper contributes over offline
builders (NN-Descent / DPG / HNSW).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import brute, construct, dynamic, segments
from repro.core import search as search_lib
from repro.core.graph import KNNGraph

Array = jax.Array


@dataclasses.dataclass
class RetrievalIndex:
    graph: KNNGraph
    items: Array  # (capacity, d) item embeddings (rows >= n_valid are free)
    metric: str
    build_cfg: construct.BuildConfig

    @property
    def n_items(self) -> int:
        return int(self.graph.n_valid)


def build_index(
    items: Array,
    *,
    k: int = 20,
    metric: str = "ip",
    wave: int = 512,
    capacity: Optional[int] = None,
    key: Optional[Array] = None,
    beam: int = 40,
    use_pallas: Optional[bool] = None,
) -> RetrievalIndex:
    """Index a candidate bank with online LGD construction.

    ``use_pallas`` follows the three-way dispatch of ``SearchConfig``: the
    default ``None`` rides the fused Pallas expansion kernel on TPU and the
    pure-JAX reference elsewhere; the choice is stored in ``build_cfg`` so
    serving (``retrieve``) and catalog churn (``add_items``, via
    ``dynamic.insert``) run the same path as the build.
    """
    cfg = construct.BuildConfig(
        k=k, metric=metric, wave=wave, lgd=True, beam=beam, use_pallas=use_pallas
    )
    n = items.shape[0]
    cap = capacity or n
    g, _ = construct.build(items, cfg, key)  # index the REAL rows only
    if cap > n:  # headroom for future add_items (rows stay unallocated)
        from repro.core.graph import grow_graph

        g = grow_graph(g, cap)
        items = jnp.pad(items, ((0, cap - n), (0, 0)))
    return RetrievalIndex(graph=g, items=items, metric=metric, build_cfg=cfg)


def retrieve(
    index: RetrievalIndex,
    interests: Array,  # (K, d) query vectors (MIND interests, or any queries)
    top_k: int,
    *,
    beam: Optional[int] = None,
    key: Optional[Array] = None,
):
    """k-NN retrieval: EHC search per interest + cross-interest dedupe/merge.

    Returns (item_ids (top_k,), scores (top_k,)) — scores are inner products
    (higher = better) when metric='ip'.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    scfg = search_lib.SearchConfig(
        k=top_k,
        beam=max(beam or 2 * top_k, top_k),
        metric=index.metric,
        use_lgd_mask=True,
        use_pallas=index.build_cfg.use_pallas,  # serve on the build's kernel path
    )
    res = search_lib.search(index.graph, index.items, interests, key, scfg)
    ids = res.ids.reshape(-1)
    dist = res.dists.reshape(-1)
    # cross-interest dedupe: keep the best (smallest-distance) copy —
    # sort-based segmented idiom (core.segments), not a pairwise matrix
    order = jnp.argsort(dist)
    ids_s = ids[order]
    dup = segments.mask_row_duplicates(ids_s[None, :])[0]
    dist_s = jnp.where(dup | (ids_s < 0), jnp.inf, dist[order])
    sel = jnp.argsort(dist_s)[:top_k]
    out_ids = ids_s[sel]
    out_dist = dist_s[sel]
    score = -out_dist if index.metric == "ip" else out_dist
    return out_ids, score


def retrieve_brute(index: RetrievalIndex, interests: Array, top_k: int):
    """Exact baseline (the retrieval_cand roofline cell): full GEMM + top-k."""
    ids, dist = brute.brute_force_knn(
        index.items, interests, top_k, index.metric,
        n_valid=index.graph.n_valid, use_pallas=False,
    )
    flat_i = ids.reshape(-1)
    flat_d = dist.reshape(-1)
    order = jnp.argsort(flat_d)
    ids_s = flat_i[order]
    dup = segments.mask_row_duplicates(ids_s[None, :])[0]
    d_s = jnp.where(dup | (ids_s < 0), jnp.inf, flat_d[order])
    sel = jnp.argsort(d_s)[:top_k]
    score = -d_s[sel] if index.metric == "ip" else d_s[sel]
    return ids_s[sel], score


def add_items(index: RetrievalIndex, new_items: Array, key=None) -> RetrievalIndex:
    """Catalog insert: append rows + online insertion waves (§IV-C)."""
    n0 = int(index.graph.n_valid)
    m = new_items.shape[0]
    items = index.items
    assert n0 + m <= items.shape[0], "capacity exceeded — grow the index"
    items = items.at[n0 : n0 + m].set(new_items)
    g, _ = dynamic.insert(index.graph, items, m, index.build_cfg, key)
    return dataclasses.replace(index, graph=g, items=items)


def remove_items(index: RetrievalIndex, ids: Array) -> RetrievalIndex:
    """Catalog withdraw: the paper's O(k²/2) removal with λ repair."""
    g = dynamic.remove(index.graph, index.items, ids, index.metric)
    return dataclasses.replace(index, graph=g)
