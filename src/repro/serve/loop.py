"""Instrumented serving loop: continuous query batching over an OnlineIndex.

Serving so far has been batch-function calls (``serve.retrieval.retrieve``)
— the caller owns batching, latency is whatever ``time.time`` around the
call says, and the per-query search signals vanish.  ``ServingLoop`` is the
production-shaped front end the ROADMAP's item 3 asked for:

  * **arrival queue + pow2-bucketed coalescing** — queries arrive one by one
    or in bursts (``submit``); each ``step`` drains up to ``max_batch`` of
    them and pads the wave to the next power of two (the PR-4 ingest-
    coalescing idiom applied to reads), so the jitted search compiles
    O(log max_batch) shapes instead of one per arrival pattern;
  * **churn interleave** — writes (``add``/``remove``) ride the index's
    micro-batch buffer and are flushed *between* query waves by the loop, so
    reads always observe prior writes (the index's own flush-on-read
    guarantee) and the flush cost lands in its own span, not smeared into
    query latency;
  * **latency truth** — per-query latency is measured enqueue→result with
    the result synced (``block_until_ready``) before the clock stops, so
    p50/p99 include queueing delay and device work, not just dispatch;
  * **recall reservoir** — every ``recall_sample_every``-th served query is
    stashed (query vector + the ids actually served) in a fixed-size
    round-robin reservoir; ``audit_recall`` brute-forces those queries
    against the live index (alive-aware) and reports both the recall of a
    *fresh* search (current serving quality — the gated number) and of the
    *served* ids (what users actually got, which churn can have invalidated);
  * **telemetry** — every wave folds its ``SearchResult`` accounting into a
    ``SearchStats`` (scanning rate, hash saturation, comps histogram) at the
    sync boundary the latency clock already created, and ``report()`` logs
    p50/p99/QPS through the attached ``Tracker``.

The loop is deliberately synchronous and deterministic — a host-side state
machine, not a thread pool: benchmarks and tests drive it step by step, and
the paper's online claim (serve while building/churning) is exercised by
interleaving ``submit``/``add``/``remove``/``pump`` calls, which is exactly
what ``benchmarks.bench_serving`` does under the CI gate.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import brute
from repro.index.lifecycle import OnlineIndex
from repro.obs import NOOP, SearchStats, Tracker

Array = jax.Array

__all__ = ["ServeLoopConfig", "ServingLoop"]


@dataclasses.dataclass(frozen=True)
class ServeLoopConfig:
    """Static serving-loop configuration.

    ``max_batch`` must be a power of two — it is the largest coalescing
    bucket, and every wave is padded up to a pow2 ≤ it, bounding jit
    recompiles to log2(max_batch)+1 shapes.  ``recall_sample_every`` is a
    deterministic stride (no RNG in the sampling path: replaying the same
    arrival sequence audits the same queries)."""

    top_k: int = 10
    beam: Optional[int] = None  # None -> the index's default (2*top_k)
    max_batch: int = 64  # pow2 coalescing cap per query wave
    recall_reservoir: int = 64  # audited-query slots (round-robin overwrite)
    recall_sample_every: int = 7  # stride between sampled queries

    def __post_init__(self):
        assert self.max_batch >= 1 and (
            self.max_batch & (self.max_batch - 1) == 0
        ), "max_batch must be a power of two"
        assert self.recall_sample_every >= 1
        assert self.recall_reservoir >= 1


class ServingLoop:
    """Query/churn front end over one ``OnlineIndex`` (see module doc)."""

    def __init__(
        self,
        index: OnlineIndex,
        cfg: ServeLoopConfig = ServeLoopConfig(),
        tracker: Optional[Tracker] = None,
        seed: int = 0,
    ):
        self.index = index
        self.cfg = cfg
        self.tracker = tracker or NOOP
        # the index reports its lifecycle spans (flush/remove/compact/grow)
        # through the same tracker, so the JSONL is one nested trace
        if tracker is not None and index.tracker is None:
            index.tracker = tracker
        self.stats = SearchStats(n_items=index.n_items)
        self._queue: deque = deque()  # (query row np (d,), t_enqueue)
        self._key = jax.random.PRNGKey(seed)
        self._wave_idx = 0
        self._served = 0
        self._lat: List[float] = []  # per-query enqueue->synced-result secs
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        # recall reservoir: parallel lists, round-robin slot assignment
        self._res_q: List[np.ndarray] = []
        self._res_ids: List[np.ndarray] = []
        self._sample_count = 0

    # -- ingress -------------------------------------------------------------

    def submit(self, queries) -> int:
        """Enqueue one query (1-D) or a burst (2-D); returns queue depth."""
        q = np.asarray(queries, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        now = time.perf_counter()
        for row in q:
            self._queue.append((row, now))
        return len(self._queue)

    def add(self, items, *, key: Optional[Array] = None) -> None:
        """Catalog insert, buffered: the write lands at the next wave
        boundary (the loop flushes before searching), never mid-wave."""
        with self.tracker.span("serve/add"):
            self.index.add(items, key=key, flush=False)

    def remove(self, ids) -> None:
        """Catalog withdraw (flushes buffered adds first, like the index)."""
        with self.tracker.span("serve/remove") as sp:
            self.index.remove(ids)
            sp.sync(self.index.graph.alive)

    # -- the wave ------------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def served(self) -> int:
        return self._served

    def _next_key(self) -> Array:
        self._key, k = jax.random.split(self._key)
        return k

    def step(self) -> Optional[dict]:
        """Serve one coalesced wave; returns a per-wave summary (None if the
        queue was empty).  The wave: flush pending writes, drain up to
        ``max_batch`` queries, pad to the pow2 bucket, search, sync, stamp
        latencies, fold stats, feed the reservoir."""
        if not self._queue:
            return None
        cfg = self.cfg
        t_wave0 = time.perf_counter()
        if self._t_first is None:
            self._t_first = t_wave0

        with self.tracker.span("serve/step") as step_sp:
            if self.index.n_pending:
                # writes land between waves; OnlineIndex.flush carries its
                # own span through the shared tracker
                self.index.flush()

            m = min(len(self._queue), cfg.max_batch)
            rows, t_enq = zip(*(self._queue.popleft() for _ in range(m)))
            P = 1 << (m - 1).bit_length()  # pow2 bucket (compile-bounded)
            batch = np.empty((P, rows[0].shape[0]), np.float32)
            batch[:m] = np.stack(rows)
            batch[m:] = rows[-1]  # pad with a real row: no NaN/dtype hazards
            n_live = self.index.n_items

            with self.tracker.span("serve/search") as sp:
                res = self.index.search(
                    jnp.asarray(batch), cfg.top_k, beam=cfg.beam,
                    key=self._next_key(),
                )
                # materialize the answers: serving hands ids to the caller,
                # so this pull is the wave's OWN host sync (tracker or not) —
                # the latency clock must not stop before the device finishes
                ids = np.asarray(res.ids)[:m]
                sp.synced = True
            t_done = time.perf_counter()
            step_sp.synced = True  # the search sync covers the step's device work
            self._lat.extend(t_done - t for t in t_enq)
            self._served += m
            self._t_last = t_done
            self.stats.update(
                _slice_result(res, m), n_items=n_live
            )
            for i in range(m):
                c = self._sample_count
                self._sample_count += 1
                if c % cfg.recall_sample_every:
                    continue
                slot = (c // cfg.recall_sample_every) % cfg.recall_reservoir
                if slot < len(self._res_q):
                    self._res_q[slot] = batch[i]
                    self._res_ids[slot] = ids[i]
                else:
                    self._res_q.append(batch[i])
                    self._res_ids.append(ids[i])

        self._wave_idx += 1
        wave = {
            "wave": self._wave_idx,
            "batch": m,
            "bucket": P,
            "latency_s": t_done - t_wave0,
            "queue_depth": len(self._queue),
        }
        self.tracker.log_metrics(
            {f"serve/{k}": v for k, v in wave.items() if k != "wave"},
            step=self._wave_idx,
        )
        return wave

    def pump(self) -> int:
        """Drain the queue; returns the number of waves served."""
        waves = 0
        while self._queue:
            self.step()
            waves += 1
        return waves

    # -- audits + reporting --------------------------------------------------

    def audit_recall(self, k: int = 10) -> dict:
        """Brute-force the recall reservoir against the live index.

        ``recall_at_k`` — a FRESH search of each sampled query scored
        against exact (alive-aware) ground truth: current serving quality,
        the number the CI gate floors.  ``recall_at_k_served`` — the ids
        actually served at sample time scored against the same truth:
        under churn it can trail the fresh number (rows served earlier may
        since have been removed), which is a fact about the workload worth
        seeing, not a serving bug."""
        if not self._res_q:
            return {"n_audited": 0}
        with self.tracker.span("serve/audit") as sp:
            q = np.stack(self._res_q)
            self.index.flush()
            true_ids, _ = brute.brute_force_knn(
                self.index.items, jnp.asarray(q), k, self.index.metric,
                n_valid=self.index.graph.n_valid, alive=self.index.graph.alive,
                use_pallas=False,
            )
            fresh = self.index.search(
                jnp.asarray(q), self.cfg.top_k, beam=self.cfg.beam,
                key=self._next_key(),
            )
            sp.sync((true_ids, fresh.ids))
            fresh_rec = float(brute.recall_at_k(fresh.ids, true_ids, k))
            served = jnp.asarray(np.stack(self._res_ids))
            served_rec = float(brute.recall_at_k(served, true_ids, k))
        out = {
            "n_audited": len(self._res_q),
            f"recall_at_{k}": fresh_rec,
            f"recall_at_{k}_served": served_rec,
        }
        self.tracker.log_metrics({f"serve/{kk}": v for kk, v in out.items()})
        return out

    def report(self, audit_k: int = 10) -> dict:
        """The sustained-load record: p50/p99 latency, QPS, scanning rate,
        hash saturation, sampled recall — logged through the tracker and
        returned as a flat dict (what ``bench_serving`` emits to CI)."""
        lat = np.asarray(self._lat, np.float64)
        span_s = (
            (self._t_last - self._t_first)
            if self._t_first is not None and self._t_last is not None
            else 0.0
        )
        rec = {
            "n_served": self._served,
            "n_waves": self._wave_idx,
            "qps": self._served / span_s if span_s > 0 else 0.0,
            "p50_latency_ms": float(np.percentile(lat, 50) * 1e3) if lat.size else 0.0,
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3) if lat.size else 0.0,
            "mean_latency_ms": float(lat.mean() * 1e3) if lat.size else 0.0,
            "comps_per_query": self.stats.comps_per_query,
            "scanning_rate": self.stats.scanning_rate,
            "hash_saturation_ratio": self.stats.hash_saturation_ratio,
            "capped_ratio": self.stats.capped_ratio,
        }
        if self._res_q:
            rec.update(self.audit_recall(k=audit_k))
        self.tracker.log_metrics(
            {f"serve/{k}": v for k, v in rec.items()}
        )
        return rec

    def reset_window(self) -> None:
        """Start a fresh measurement window (latency, stats, reservoir,
        wave clock) without touching the index or the queue — call after
        warm-up so compile time never lands in the sustained-load record."""
        self.stats.reset()
        self._lat = []
        self._served = 0
        self._wave_idx = 0
        self._t_first = None
        self._t_last = None
        self._res_q, self._res_ids = [], []
        self._sample_count = 0


def _slice_result(res, m: int):
    """First m lanes of a padded wave's SearchResult (padding lanes repeat a
    real query; their accounting must not be double-counted)."""
    return res._replace(
        ids=res.ids[:m], dists=res.dists[:m],
        vis_ids=res.vis_ids[:m], vis_dist=res.vis_dist[:m],
        n_comps=res.n_comps[:m], n_iters=res.n_iters[:m],
        converged=res.converged[:m], hash_full=res.hash_full[:m],
        seed_cell=res.seed_cell[:m],
    )
