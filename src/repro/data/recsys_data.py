"""Recsys batch generators (criteo-like CTR + behavior sequences).

The CTR batch layout follows the Criteo convention the assigned archs were
published on: 13 dense features + 39 (deepfm/xdeepfm) categorical fields with
heavily skewed (zipf) id distributions over large per-field vocabularies —
the skew is what makes embedding-lookup locality a real systems problem.

Labels are synthesized from a hidden sparse linear model over the field ids
so CTR training has signal (AUC/logloss actually improves — used by the
example driver and the convergence smoke tests).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

Array = jax.Array


def zipf_ids(key: Array, shape, vocab: int, a: float = 1.2) -> Array:
    """Zipf-ish categorical ids: id ~ rank^-a over [0, vocab)."""
    u = jax.random.uniform(key, shape, minval=1e-6)
    ids = (vocab * u ** (a + 1.0)).astype(jnp.int32)
    return jnp.minimum(ids, vocab - 1)


def ctr_batch(
    key: Array,
    batch: int,
    n_sparse: int,
    vocab: int,
    *,
    n_dense: int = 13,
) -> Dict[str, Array]:
    """One CTR batch: dense (B, 13), sparse ids (B, F), label (B,)."""
    kd, ks, kl = jax.random.split(key, 3)
    dense = jax.random.normal(kd, (batch, n_dense), jnp.float32)
    sparse = zipf_ids(ks, (batch, n_sparse), vocab)
    # hidden model: a few "hot" hash buckets drive the label
    w = jnp.sin(jnp.arange(n_sparse, dtype=jnp.float32) * 1.7)[None, :]
    score = jnp.sum(jnp.where(sparse % 97 < 8, w, -0.05 * w), axis=1)
    score = score + 0.3 * dense[:, 0]
    p = jax.nn.sigmoid(score)
    label = jax.random.bernoulli(kl, p).astype(jnp.float32)
    return {"dense": dense, "sparse": sparse, "label": label}


def behavior_batch(
    key: Array,
    batch: int,
    seq_len: int,
    vocab: int,
) -> Dict[str, Array]:
    """BST/MIND-style batch: user history (B, S), target item, label."""
    kh, kt, kl = jax.random.split(key, 3)
    hist = zipf_ids(kh, (batch, seq_len), vocab)
    target = zipf_ids(kt, (batch,), vocab)
    # positive when the target shares a "genre" (mod-class) with the history
    genre_match = jnp.mean((hist % 17 == (target % 17)[:, None]).astype(jnp.float32), axis=1)
    p = jax.nn.sigmoid(4.0 * genre_match - 1.0)
    label = jax.random.bernoulli(kl, p).astype(jnp.float32)
    return {"hist": hist, "target": target, "label": label}


def retrieval_batch(
    key: Array,
    n_candidates: int,
    embed_dim: int,
    *,
    seq_len: int = 20,
    vocab: int = 1_000_000,
) -> Dict[str, Array]:
    """retrieval_cand shape: one user's history + the candidate item bank."""
    kh, kc = jax.random.split(key)
    hist = zipf_ids(kh, (1, seq_len), vocab)
    cands = jax.random.normal(kc, (n_candidates, embed_dim), jnp.float32)
    return {"hist": hist, "candidates": cands}
