"""Synthetic vector datasets calibrated to the paper's benchmark suite.

The paper evaluates on Rand100K/Rand1M (uniform — intrinsic dim == d),
SIFT1M/10M + YFCC (clustered local descriptors — low intrinsic dim),
GloVe1M (heavy-tailed word vectors — high intrinsic dim under cosine) and
NUSW (BoVW histograms — χ² metric).  Those files are offline-unavailable
here; these generators produce distributions with the matching *difficulty
structure* so every paper table has a stand-in with the same (n, d, metric)
and a comparable intrinsic-dimension regime (DESIGN.md §8.6):

* ``uniform``       — U[0,1)^d, intrinsic dim == d              (Rand*)
* ``clustered``     — Gaussian mixture on a low-dim manifold     (SIFT-like)
* ``heavy_tailed``  — power-law-scaled gaussian directions       (GloVe-like)
* ``histogram``     — sparse positive Dirichlet rows             (NUSW-like, χ²)

All generators are pure functions of a PRNG key (skip-ahead friendly: any
shard or wave can be regenerated independently — straggler/fault story).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def uniform(key: Array, n: int, d: int) -> Array:
    """The paper's Rand100K/Rand1M: U[0,1)^d, intrinsic dim ~= d."""
    return jax.random.uniform(key, (n, d), jnp.float32)


def clustered(
    key: Array,
    n: int,
    d: int,
    *,
    n_clusters: int = 256,
    intrinsic_dim: int = 16,
    noise: float = 0.05,
) -> Array:
    """SIFT/YFCC-like: clusters on a low-dim linear manifold + small noise.

    Intrinsic dimension ~= ``intrinsic_dim`` << d, which is the regime where
    the paper reports its largest speedups (Fig. 8/9 discussion).
    """
    kc, kb, kz, kn = jax.random.split(key, 4)
    basis = jax.random.normal(kb, (intrinsic_dim, d)) / jnp.sqrt(d)
    centers_z = jax.random.normal(kc, (n_clusters, intrinsic_dim))
    assign = jax.random.randint(kz, (n,), 0, n_clusters)
    local = jax.random.normal(kn, (n, intrinsic_dim)) * 0.15
    z = centers_z[assign] + local
    x = z @ basis + noise * jax.random.normal(jax.random.fold_in(kn, 1), (n, d))
    return x.astype(jnp.float32)


def heavy_tailed(key: Array, n: int, d: int, *, alpha: float = 1.1) -> Array:
    """GloVe-like: directions with power-law coordinate scales (high intrinsic
    dim under cosine — the paper's 'most challenging' regime)."""
    kg, ks = jax.random.split(key)
    g = jax.random.normal(kg, (n, d))
    scales = jnp.arange(1, d + 1, dtype=jnp.float32) ** (-alpha / 2.0)
    x = g * scales[None, :]
    norms = jax.random.pareto(ks, 3.0, (n, 1)) + 1.0
    return (x * norms).astype(jnp.float32)


def histogram(key: Array, n: int, d: int, *, sparsity: float = 0.1) -> Array:
    """NUSW-like BoVW histograms: sparse, non-negative, l1-normalized (χ²)."""
    kv, km = jax.random.split(key)
    vals = jax.random.gamma(kv, 0.5, (n, d))
    mask = jax.random.bernoulli(km, sparsity, (n, d))
    x = jnp.where(mask, vals, 0.0)
    x = x / jnp.maximum(jnp.sum(x, axis=1, keepdims=True), 1e-9)
    return x.astype(jnp.float32)


GENERATORS = {
    "uniform": uniform,
    "clustered": clustered,
    "heavy_tailed": heavy_tailed,
    "histogram": histogram,
}


def make(kind: str, key: Array, n: int, d: int, **kw) -> Array:
    return GENERATORS[kind](key, n, d, **kw)
