"""Deterministic, skip-ahead batch loaders.

Every batch is a pure function of (seed, step) — ``fold_in`` based — so any
worker can regenerate any batch without coordination.  This is the fault-
tolerance substrate: a restarted host resumes mid-epoch from the checkpoint's
step counter alone, and a straggler's wave can be re-issued elsewhere
(DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LoaderSpec:
    """What one batch looks like: name -> (shape, dtype, sampler kind)."""

    batch_fn: Callable[[Array], Dict[str, Array]]
    seed: int = 0

    def batch(self, step: int) -> Dict[str, Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return self.batch_fn(key)

    def __iter__(self) -> Iterator[Dict[str, Array]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def lm_batches(batch: int, seq: int, vocab: int, seed: int = 0) -> LoaderSpec:
    """Token batches for LM training: tokens double as labels (shift inside
    the loss).  A mixture of zipf-ish ranks so the loss actually decreases."""

    def fn(key: Array) -> Dict[str, Array]:
        ku, kz = jax.random.split(key)
        # zipf-like: floor(vocab * u^3) concentrates mass on small ids
        u = jax.random.uniform(ku, (batch, seq))
        tokens = jnp.minimum((vocab * u**3).astype(jnp.int32), vocab - 1)
        # add a learnable bigram structure: every other token repeats + 1
        shift = jnp.roll(tokens, 1, axis=1) + 1
        sel = jax.random.bernoulli(kz, 0.5, (batch, seq))
        tokens = jnp.where(sel, jnp.minimum(shift, vocab - 1), tokens)
        return {"tokens": tokens}

    return LoaderSpec(batch_fn=fn, seed=seed)


def vector_waves(
    x: Array, wave: int, *, start: int = 0
) -> Iterator[tuple[int, Array]]:
    """Yield (row_start, wave_block) slices for online graph construction."""
    n = x.shape[0]
    pos = start
    while pos < n:
        w = min(wave, n - pos)
        yield pos, x[pos : pos + w]
        pos += w
