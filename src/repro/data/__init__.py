from repro.data import synthetic, loader, graphs, recsys_data  # noqa: F401
