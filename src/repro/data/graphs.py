"""Graph data substrate: generators, CSR utilities and a real neighbor sampler.

The assigned GNN shapes span three data regimes:
  * ``full_graph_sm`` / ``ogb_products`` — one fixed graph, full-batch message
    passing (cora-size and products-size);
  * ``minibatch_lg`` — reddit-size graph trained with *sampled* mini-batches:
    this file provides the actual GraphSAGE-style fanout sampler (uniform with
    replacement over CSR rows), not a stub;
  * ``molecule`` — batches of small point clouds whose radius/k-NN edges are
    built by the paper's own construction code (``repro.core``) — the one
    place in the zoo where OLG/LGD is the data pipeline (DESIGN.md §5).

Everything is fixed-shape: samplers return (batch, fanout) index arrays with
self-loops standing in for missing neighbors, which keeps the whole pipeline
jit-able and shard_map-able.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class Graph(NamedTuple):
    """One static graph in edge-list + CSR form."""

    senders: Array  # (E,) int32 src node per edge
    receivers: Array  # (E,) int32 dst node per edge
    indptr: Array  # (N+1,) int32 CSR row pointers (receiver-major)
    indices: Array  # (E,) int32 CSR column ids (= senders sorted by receiver)
    features: Array  # (N, d) float32
    labels: Array  # (N,) int32


def csr_from_edges(senders: Array, receivers: Array, n_nodes: int):
    """Build (indptr, indices) with edges grouped by receiver."""
    order = jnp.argsort(receivers, stable=True)
    indices = senders[order].astype(jnp.int32)
    counts = jax.ops.segment_sum(
        jnp.ones_like(receivers, dtype=jnp.int32), receivers, num_segments=n_nodes
    )
    indptr = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)])
    return indptr.astype(jnp.int32), indices


def random_graph(
    key: Array,
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    *,
    n_classes: int = 16,
    power: float = 0.8,
) -> Graph:
    """Power-law-ish random graph (citation-network stand-in).

    Receiver ids are drawn with density ~ rank^-power so a few hub nodes have
    large in-degree — the degree skew that makes real GNN workloads irregular.
    """
    ks, kr, kf, kl = jax.random.split(key, 4)
    u = jax.random.uniform(kr, (n_edges,))
    receivers = jnp.minimum(
        (n_nodes * u ** (1.0 / (1.0 - power))).astype(jnp.int32), n_nodes - 1
    )
    senders = jax.random.randint(ks, (n_edges,), 0, n_nodes, dtype=jnp.int32)
    indptr, indices = csr_from_edges(senders, receivers, n_nodes)
    features = jax.random.normal(kf, (n_nodes, d_feat), jnp.float32)
    labels = jax.random.randint(kl, (n_nodes,), 0, n_classes, dtype=jnp.int32)
    return Graph(senders, receivers, indptr, indices, features, labels)


def sample_neighbors(
    key: Array,
    indptr: Array,
    indices: Array,
    seeds: Array,  # (B,)
    fanout: int,
) -> Array:
    """GraphSAGE uniform-with-replacement fanout sampling over CSR rows.

    Returns (B, fanout) int32 neighbor ids; isolated nodes sample themselves
    (self-loop), keeping shapes static and aggregation well-defined.
    """
    B = seeds.shape[0]
    deg = indptr[seeds + 1] - indptr[seeds]  # (B,)
    u = jax.random.uniform(key, (B, fanout))
    offs = jnp.floor(u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
    slot = indptr[seeds][:, None] + offs
    nbrs = indices[jnp.minimum(slot, indices.shape[0] - 1)]
    return jnp.where(deg[:, None] > 0, nbrs, seeds[:, None])


def khop_sample(
    key: Array,
    indptr: Array,
    indices: Array,
    seeds: Array,  # (B,)
    fanouts: tuple[int, ...],
):
    """Layered sampling: seeds -> (B, f1) -> (B, f1*f2) -> ...

    Returns the per-layer frontier list [(B,), (B, f1), (B, f1, f2), ...] —
    the shape GraphSAGE-style models aggregate bottom-up.
    """
    frontiers = [seeds]
    cur = seeds
    for li, f in enumerate(fanouts):
        k = jax.random.fold_in(key, li)
        flat = cur.reshape(-1)
        nbr = sample_neighbors(k, indptr, indices, flat, f)
        cur = nbr.reshape(cur.shape + (f,))
        frontiers.append(cur)
    return frontiers


def molecules(
    key: Array,
    batch: int,
    n_nodes: int,
    *,
    n_species: int = 8,
    box: float = 6.0,
) -> tuple[Array, Array]:
    """Random molecular point clouds: positions (B, N, 3), species (B, N)."""
    kp, ks = jax.random.split(key)
    pos = jax.random.uniform(kp, (batch, n_nodes, 3), jnp.float32) * box
    species = jax.random.randint(ks, (batch, n_nodes), 0, n_species, jnp.int32)
    return pos, species


def knn_edges_from_positions(
    pos: Array,  # (N, 3) one molecule
    k: int,
) -> tuple[Array, Array]:
    """Exact k-NN edges over atom positions (small N — brute force tile).

    For large point sets the framework swaps this for the paper's online
    LGD construction (see examples/molecule_graphs.py); the interface is
    identical: (senders, receivers) with receivers the k-NN list owner.
    """
    d2 = jnp.sum((pos[:, None, :] - pos[None, :, :]) ** 2, axis=-1)
    n = pos.shape[0]
    d2 = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d2)
    _, nbr = jax.lax.top_k(-d2, k)  # (N, k)
    receivers = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    senders = nbr.reshape(-1).astype(jnp.int32)
    return senders, receivers
