"""Versioned on-disk snapshots of a k-NN index: manifest JSON + npz payload.

The paper's index lives *online* — samples join and leave without a rebuild —
which only pays off if the index also lives *longer than one process*.  A
snapshot captures everything a serving replica needs to resume: the forward
graph (``nbr_ids``/``nbr_dist``/``nbr_lam``), the reverse side
(``rev_ids``/``rev_lam``/``rev_ptr``), the liveness mask, the backing data
region, and the ``BuildConfig`` that built it (so churn after restore runs
the same kernel path and wave shape as the original build).

Format (a directory, so payloads can grow side files without a version bump):

    <path>/manifest.json   human-readable header: format version, shapes,
                           dtypes, build config, provenance
    <path>/payload.npz     the arrays, canonical dtypes (int32/float32/bool)

Restore policy — the part that makes snapshots survive format-version bumps
and dtype drift:

  * every array is cast back to its canonical dtype on load (a payload
    written by a future JAX that changed a default dtype still restores);
  * the ``sq_norms``/``row_scale`` caches are persisted VERBATIM by v3
    writers and restored verbatim (they are graph state maintained by the
    same owners as every other field, and re-deriving them on load is not
    bit-stable: XLA codegen differences between the jitted build owners and
    an eager load-time recompute shift ~4% of entries by one ulp, breaking
    the round-trip bit-exactness contract).  v1/v2 payloads carry neither
    cache and re-derive both through ``graph.attach_sq_norms`` — the single
    definition of the cache contents;
  * the reverse side is validated against the structural contract of
    ``graph.rebuild_reverse`` (ids in range, live owners); a payload that
    predates ``rev_lam`` (or fails validation) is repaired by rebuilding the
    reverse lists from the forward lists — the canonical repair path.

``BuildConfig`` round-trips as a plain dict filtered against the dataclass's
current fields: configs written before a field existed pick up its default,
fields that were deleted are dropped.

Format history:
  * v1 — graph + items + config.
  * v2 — optional coarse entry-point level (``core.hierarchy.CoarseLevel``):
    ``coarse_*`` payload arrays carrying the landmark rows, frozen routing
    points, member rings, and the coarse graph's FORWARD lists only — its
    reverse side and norm cache are re-derived on load through the same
    canonical repair paths as the main graph's.  v1 snapshots (no
    ``coarse_*`` keys) load fine with ``coarse=None``; the lifecycle layer
    re-derives a level when serving wants one.  Bump policy (ROADMAP): add
    arrays/keys without a bump when absence has a sound default; bump when
    the READER must behave differently to restore correctly.
  * v3 — precision API (``BuildConfig.precision``/``dispatch`` in the config
    dict) and an optional ``pq_codebook`` payload array: the (M, K, dsub)
    trained PQ codebook, persisted so a restored ``precision="pq"`` index
    serves the SAME code space it was built with (retraining on a churned
    dataset would silently shift every ADC score).  v3 also persists the
    ``sq_norms``/``row_scale`` cache tables verbatim (see restore policy
    above).  The per-row PQ *codes* and the bf16/int8 tiles are NOT stored —
    they re-derive from ``items`` through the one definition in
    ``kernels.precision``.

    Version-compat matrix (reader = this module):

        payload   reader<=2                reader v3
        v1        loads (coarse=None)      loads; fp32 config defaults;
                                           row_scale/enc re-derived
        v2        loads                    loads; fp32 config defaults;
                                           row_scale/enc re-derived
        v3        REFUSED (newer format)   loads; pq_codebook + caches
                                           restored verbatim, codes/tiles
                                           re-derived

    v1/v2 payloads carry no precision state at all — on a v3 reader they
    restore as fp32 indexes whose ``row_scale`` table is re-derived by
    ``attach_sq_norms``, and a caller switching them to a compressed
    precision triggers a fresh (deterministic) encode.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import construct, graph as graph_lib
from repro.core.graph import KNNGraph

Array = jax.Array

FORMAT_VERSION = 3

MANIFEST_NAME = "manifest.json"
PAYLOAD_NAME = "payload.npz"

# canonical dtype per persisted array — load casts back through this table,
# so dtype drift in a writer (or a future numpy default) cannot leak into the
# restored graph
_CANONICAL = {
    "nbr_ids": np.int32,
    "nbr_dist": np.float32,
    "nbr_lam": np.int32,
    "rev_ids": np.int32,
    "rev_lam": np.int32,
    "rev_ptr": np.int32,
    "alive": np.bool_,
    "items": np.float32,
    # v2: coarse entry-point level (core.hierarchy.CoarseLevel)
    "coarse_landmark_rows": np.int32,
    "coarse_points": np.float32,
    "coarse_members": np.int32,
    "coarse_mem_ptr": np.int32,
    "coarse_nbr_ids": np.int32,
    "coarse_nbr_dist": np.float32,
    "coarse_nbr_lam": np.int32,
    # v3: trained PQ codebook (codes/tiles re-derive from items) + the cache
    # tables, persisted verbatim for bit-exact restore
    "pq_codebook": np.float32,
    "sq_norms": np.float32,
    "row_scale": np.float32,
}


def _config_dict(cfg: construct.BuildConfig) -> dict:
    d = dataclasses.asdict(cfg)
    # None round-trips through JSON; everything else in BuildConfig is a
    # scalar already
    return d


def _config_from_dict(d: dict) -> construct.BuildConfig:
    known = {f.name for f in dataclasses.fields(construct.BuildConfig)}
    return construct.BuildConfig(**{k: v for k, v in d.items() if k in known})


def save(
    path: str,
    g: KNNGraph,
    items: Array,
    cfg: construct.BuildConfig,
    *,
    coarse=None,
    pq_codebook: Optional[Array] = None,
    extra_meta: Optional[dict] = None,
) -> str:
    """Write a versioned snapshot of (graph, data, config) under ``path``.

    ``items`` is the (capacity, d) data region backing the graph rows.  Data
    stored in a non-float32 dtype (e.g. ``data_bf16`` builds) is persisted as
    float32 — lossless for bf16 — with the original dtype recorded in the
    manifest and restored on load.  ``coarse`` (optional
    ``core.hierarchy.CoarseLevel``) persists as ``coarse_*`` arrays —
    forward coarse graph only; reverse/norms re-derive on load.
    ``pq_codebook`` (optional, v3) persists the trained (M, K, dsub) PQ
    codebook so a ``precision="pq"`` index restores into the same code
    space; per-row codes re-derive on demand.  The write is crash-atomic
    (staged then swapped in), and overwriting an existing snapshot is safe.
    """
    arrays = {
        "nbr_ids": np.asarray(g.nbr_ids),
        "nbr_dist": np.asarray(g.nbr_dist),
        "nbr_lam": np.asarray(g.nbr_lam),
        "rev_ids": np.asarray(g.rev_ids),
        "rev_lam": np.asarray(g.rev_lam),
        "rev_ptr": np.asarray(g.rev_ptr),
        "alive": np.asarray(g.alive),
        "items": np.asarray(items.astype(jnp.float32)),
        "sq_norms": np.asarray(g.sq_norms),
        "row_scale": np.asarray(g.row_scale),
    }
    if coarse is not None:
        arrays.update(
            coarse_landmark_rows=np.asarray(coarse.landmark_rows),
            coarse_points=np.asarray(coarse.points.astype(jnp.float32)),
            coarse_members=np.asarray(coarse.members),
            coarse_mem_ptr=np.asarray(coarse.mem_ptr),
            coarse_nbr_ids=np.asarray(coarse.graph.nbr_ids),
            coarse_nbr_dist=np.asarray(coarse.graph.nbr_dist),
            coarse_nbr_lam=np.asarray(coarse.graph.nbr_lam),
        )
    if pq_codebook is not None:
        arrays["pq_codebook"] = np.asarray(pq_codebook)
    arrays = {k: v.astype(_CANONICAL[k]) for k, v in arrays.items()}
    manifest = {
        "format_version": FORMAT_VERSION,
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "jax_version": jax.__version__,
        "n_valid": int(g.n_valid),
        "capacity": int(g.capacity),
        "k": int(g.k),
        "rev_capacity": int(g.rev_capacity),
        "dim": int(items.shape[1]),
        "items_dtype": str(items.dtype),
        "build_config": _config_dict(cfg),
        "arrays": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in arrays.items()
        },
    }
    if extra_meta:
        manifest["extra"] = extra_meta
    # crash-atomic: stage payload + manifest into a sibling temp dir, then
    # swap it in — a process dying mid-save can never leave a torn snapshot
    # (stale manifest over a new payload, or a truncated npz) at ``path``
    stage = path.rstrip(os.sep) + ".tmp"
    if os.path.isdir(stage):
        shutil.rmtree(stage)
    os.makedirs(stage)
    np.savez(os.path.join(stage, PAYLOAD_NAME), **arrays)
    with open(os.path.join(stage, MANIFEST_NAME), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    old = None
    if os.path.isdir(path) and os.listdir(path):
        old = path.rstrip(os.sep) + ".old"
        if os.path.isdir(old):
            shutil.rmtree(old)
        os.replace(path, old)
    elif os.path.isdir(path):
        os.rmdir(path)
    os.replace(stage, path)
    if old is not None:
        shutil.rmtree(old)
    return path


def _reverse_ok(g: KNNGraph) -> bool:
    """Structural contract of the reverse side: ids in [-1, capacity), no
    dead owners, non-negative append counters.  ``rev_ptr`` has NO upper
    bound by design — it counts *total* appends (``mod R`` gives the ring
    write slot), so values above ``rev_capacity`` are the normal state of an
    incrementally-maintained graph, not corruption."""
    ids = g.rev_ids
    in_range = bool(jnp.all((ids >= -1) & (ids < g.capacity)))
    owners_alive = bool(jnp.all((ids < 0) | g.alive[jnp.maximum(ids, 0)]))
    ptr_ok = bool(jnp.all(g.rev_ptr >= 0))
    return in_range and owners_alive and ptr_ok


def load(
    path: str,
    *,
    validate_reverse: bool = True,
    with_coarse: bool = False,
    with_pq_codebook: bool = False,
):
    """Restore (graph, items, config, manifest) from a snapshot directory.

    With ``with_coarse`` the return gains a fifth element: the restored
    ``core.hierarchy.CoarseLevel``, or None when the snapshot predates v2
    (or was saved without one) — callers wanting coarse seeding then
    re-derive via ``hierarchy.derive_coarse``.  With ``with_pq_codebook``
    it gains a further element: the persisted (M, K, dsub) PQ codebook, or
    None when the snapshot predates v3 (or was saved without one) — PQ
    serving then retrains deterministically from the restored items.

    Raises ``ValueError`` for snapshots written by a NEWER format than this
    reader understands; older formats load with repairs (see module doc).
    """
    with open(os.path.join(path, MANIFEST_NAME)) as f:
        manifest = json.load(f)
    version = int(manifest.get("format_version", 0))
    if version > FORMAT_VERSION:
        raise ValueError(
            f"snapshot at {path!r} has format_version {version}; this reader "
            f"understands <= {FORMAT_VERSION}"
        )
    with np.load(os.path.join(path, PAYLOAD_NAME)) as z:
        raw = {k: z[k] for k in z.files}

    def arr(name: str) -> Optional[np.ndarray]:
        v = raw.get(name)
        return None if v is None else np.asarray(v, _CANONICAL[name])

    missing = [k for k in ("nbr_ids", "nbr_dist", "nbr_lam", "items")
               if k not in raw]
    if missing:
        raise ValueError(
            f"snapshot at {path!r} is missing payload arrays {missing}; the "
            "forward graph and data region are not reconstructible"
        )
    # manifest/payload agreement: a torn or mixed-up snapshot (stale manifest
    # over a different payload) must fail cleanly here, not as a cryptic
    # indexing error after restore
    for name, spec in manifest.get("arrays", {}).items():
        if name in raw and list(raw[name].shape) != list(spec["shape"]):
            raise ValueError(
                f"snapshot at {path!r} is corrupt: payload array {name!r} has "
                f"shape {list(raw[name].shape)}, manifest records "
                f"{spec['shape']}"
            )
    nbr_ids = arr("nbr_ids")
    cap, k = nbr_ids.shape
    rev_cap = int(manifest.get("rev_capacity", 2 * k))
    if not 0 <= int(manifest["n_valid"]) <= cap:
        raise ValueError(
            f"snapshot at {path!r} is corrupt: n_valid {manifest['n_valid']} "
            f"outside [0, capacity={cap}]"
        )
    n_valid = jnp.asarray(int(manifest["n_valid"]), jnp.int32)

    alive_np = arr("alive")
    if alive_np is None:  # pre-liveness payloads: every allocated row lives
        alive_np = np.arange(cap) < int(manifest["n_valid"])

    items = jnp.asarray(arr("items"))
    items_dtype = manifest.get("items_dtype", "float32")
    if items_dtype != "float32":
        items = items.astype(jnp.dtype(items_dtype))

    def rev_or(name: str, fill, shape) -> np.ndarray:
        v = arr(name)
        return v if v is not None else np.full(shape, fill, _CANONICAL[name])

    g = KNNGraph(
        nbr_ids=jnp.asarray(nbr_ids),
        nbr_dist=jnp.asarray(arr("nbr_dist")),
        nbr_lam=jnp.asarray(arr("nbr_lam")),
        rev_ids=jnp.asarray(rev_or("rev_ids", -1, (cap, rev_cap))),
        rev_lam=jnp.asarray(rev_or("rev_lam", 0, (cap, rev_cap))),
        rev_ptr=jnp.asarray(rev_or("rev_ptr", 0, (cap,))),
        alive=jnp.asarray(alive_np),
        n_valid=n_valid,
        sq_norms=jnp.zeros((cap,), jnp.float32),
        row_scale=jnp.zeros((cap,), jnp.float32),
    )
    # norm and int8-scale caches: v3 payloads carry them verbatim (re-derive
    # is one-ulp unstable across jit/eager codegen — see module doc); older
    # payloads re-derive through the one definition of the cache contents
    if "sq_norms" in raw and "row_scale" in raw:
        g = g._replace(
            sq_norms=jnp.asarray(arr("sq_norms")),
            row_scale=jnp.asarray(arr("row_scale")),
        )
    else:
        g = graph_lib.attach_sq_norms(g, items.astype(jnp.float32))
    # reverse side: repair payloads that predate rev_lam or fail the
    # structural contract by rebuilding from the forward lists
    rev_missing = "rev_ids" not in raw or "rev_lam" not in raw
    if rev_missing or (validate_reverse and not _reverse_ok(g)):
        g = graph_lib.rebuild_reverse(g)

    cfg = _config_from_dict(manifest.get("build_config", {}))
    pq_cb = None
    if "pq_codebook" in raw:
        pq_cb = jnp.asarray(arr("pq_codebook"))
    if not with_coarse:
        if with_pq_codebook:
            return g, items, cfg, manifest, pq_cb
        return g, items, cfg, manifest

    coarse = None
    if "coarse_landmark_rows" in raw:
        from repro.core import hierarchy

        points = jnp.asarray(arr("coarse_points"))
        c_ids = arr("coarse_nbr_ids")
        L, kc = c_ids.shape
        gc = KNNGraph(
            nbr_ids=jnp.asarray(c_ids),
            nbr_dist=jnp.asarray(arr("coarse_nbr_dist")),
            nbr_lam=jnp.asarray(arr("coarse_nbr_lam")),
            rev_ids=jnp.full((L, 2 * kc), -1, jnp.int32),
            rev_lam=jnp.zeros((L, 2 * kc), jnp.int32),
            rev_ptr=jnp.zeros((L,), jnp.int32),
            alive=jnp.ones((L,), bool),
            n_valid=jnp.asarray(L, jnp.int32),
            sq_norms=jnp.zeros((L,), jnp.float32),
            row_scale=jnp.zeros((L,), jnp.float32),
        )
        # same restore policy as the main graph: forward lists are the
        # payload, reverse side + norm cache re-derive canonically
        gc = graph_lib.attach_sq_norms(gc, points)
        gc = graph_lib.rebuild_reverse(gc)
        coarse = hierarchy.CoarseLevel(
            landmark_rows=jnp.asarray(arr("coarse_landmark_rows")),
            points=points,
            graph=gc,
            members=jnp.asarray(arr("coarse_members")),
            mem_ptr=jnp.asarray(arr("coarse_mem_ptr")),
        )
    if with_pq_codebook:
        return g, items, cfg, manifest, coarse, pq_cb
    return g, items, cfg, manifest, coarse
