"""OnlineIndex: the index's life outside a single build call.

``construct.build`` produces a graph; ``dynamic.insert``/``remove`` keep it
current; but the seed repo left everything around those calls to the caller:
capacity was a hard assert, removed rows leaked their slots forever, every
tiny insert paid a full wave dispatch, and nothing survived the process.
``OnlineIndex`` owns that lifecycle, riding the fused wave pipeline
untouched:

  * **amortized-doubling auto-growth** — an insert that would overflow the
    data region grows graph + items to ``growth_factor * capacity`` (one
    O(cap) copy amortized over O(cap) inserts) instead of asserting;
  * **free-slot ledger** — ``remove`` records its victims; before growing,
    an insert first reclaims those slots via ``compact()`` (when
    ``auto_compact``), so steady-state churn (insert ≈ remove) runs in
    bounded memory forever;
  * **compact()** — re-packs alive rows with ``dynamic.compact`` and returns
    the old→new id map so callers holding row ids (the sharded router,
    result caches) can follow the move;
  * **micro-batched ingest** — ``add(..., flush=False)`` buffers small
    inserts host-side and coalesces them into ONE ``construct.build`` wave
    (via ``dynamic.insert``) once ``ingest_batch`` items accumulate; a
    search flushes first, so reads always observe prior writes;
  * **snapshots** — ``save``/``load`` wrap ``repro.index.snapshot`` so a
    serving replica restores graph + data + build config (and therefore the
    same kernel dispatch) bit-for-bit.

The facade is mutable — it *is* the serving-side state machine — but every
underlying buffer is an immutable jax array, so ``clone()`` is O(fields) and
gives the functional entry points in ``serve.retrieval`` copy-on-write
semantics for free.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import construct, dynamic
from repro.core import graph as graph_lib
from repro.core import search as search_lib
from repro.core.graph import KNNGraph
from repro.index import snapshot as snapshot_lib

Array = jax.Array


@dataclasses.dataclass
class OnlineIndex:
    """A long-lived online k-NN index: graph + data + config + churn state.

    Field-compatible with the old ``serve.retrieval.RetrievalIndex``
    (``graph``, ``items``, ``build_cfg``, ``metric``), plus the lifecycle
    state described in the module docstring.
    """

    graph: KNNGraph
    items: Array  # (capacity, d); rows beyond n_valid are free
    build_cfg: construct.BuildConfig
    free_ids: tuple = ()  # ledger of removed (dead) rows < n_valid
    pending: tuple = ()  # micro-batch ingest buffer: tuples of (m_i, d) arrays
    ingest_batch: int = 64  # coalesce threshold for buffered adds
    auto_compact: bool = True  # reclaim free slots before growing
    growth_factor: float = 2.0  # amortized-doubling factor
    last_compact_map: Optional[np.ndarray] = None  # old->new rows, last compact
    pending_key: Optional[Array] = None  # PRNG key stashed by buffered adds
    _ledger_synced: bool = False  # reconciliation ran (clones inherit True)

    def __post_init__(self):
        # The ledger is a host-side cache of the graph's liveness holes; the
        # alive mask stays the ground truth.  A graph that arrives with dead
        # rows but no ledger (a hand-built graph, or a churned graph saved
        # through ``snapshot.save`` directly rather than ``OnlineIndex.save``)
        # reconciles here, so capacity accounting and auto-compaction never
        # trust stale state.  Runs once per lineage: ``clone()`` carries
        # ``_ledger_synced``, keeping it O(fields) with no device sync.
        if not self._ledger_synced:
            if not self.free_ids:
                n_valid = int(self.graph.n_valid)
                dead = np.flatnonzero(~np.asarray(self.graph.alive[:n_valid]))
                if dead.size:
                    self.free_ids = tuple(int(i) for i in dead)
            self._ledger_synced = True

    # -- views ---------------------------------------------------------------

    @property
    def metric(self) -> str:
        return self.build_cfg.metric

    @property
    def capacity(self) -> int:
        return self.graph.capacity

    @property
    def n_pending(self) -> int:
        return sum(int(p.shape[0]) for p in self.pending)

    @property
    def free_slots(self) -> int:
        return len(self.free_ids)

    @property
    def n_items(self) -> int:
        """Live catalog size: allocated − removed + buffered."""
        return int(self.graph.n_valid) - len(self.free_ids) + self.n_pending

    def clone(self) -> "OnlineIndex":
        """O(fields) copy; jax buffers are immutable and shared."""
        return dataclasses.replace(self)

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        items: Array,
        cfg: Optional[construct.BuildConfig] = None,
        *,
        capacity: Optional[int] = None,
        key: Optional[Array] = None,
        ingest_batch: int = 64,
        auto_compact: bool = True,
        growth_factor: float = 2.0,
        **cfg_kw,
    ) -> "OnlineIndex":
        """Index ``items`` with online LGD/OLG construction.

        ``capacity > n`` pre-allocates headroom; either way later inserts
        auto-grow, so capacity is a hint, not a ceiling.
        """
        if cfg is None:
            cfg = construct.BuildConfig(**cfg_kw)
        elif cfg_kw:
            raise ValueError(
                f"pass either cfg or BuildConfig kwargs, not both (got cfg "
                f"and {sorted(cfg_kw)})"
            )
        n = items.shape[0]
        cap = capacity or n
        g, _ = construct.build(items, cfg, key)
        if cap > n:
            g = graph_lib.grow_graph(g, cap)
            items = jnp.pad(items, ((0, cap - n), (0, 0)))
        return cls(
            graph=g,
            items=items,
            build_cfg=cfg,
            ingest_batch=ingest_batch,
            auto_compact=auto_compact,
            growth_factor=growth_factor,
        )

    # -- churn ---------------------------------------------------------------

    def add(
        self,
        new_items: Array,
        *,
        key: Optional[Array] = None,
        flush: Optional[bool] = None,
    ) -> "OnlineIndex":
        """Insert items (catalog listing).

        ``flush=False`` only buffers; ``flush=True`` forces the insertion
        wave now; the default flushes once ``ingest_batch`` items are
        buffered — the micro-batch path that coalesces trickling single-item
        inserts into one wave.  A ``key`` supplied with a buffered add is
        stashed and used by the eventual coalescing flush, so replicas fed
        the same (items, key) sequence build the same graph regardless of
        when the threshold trips.  Returns self (mutates in place).
        """
        new_items = jnp.asarray(new_items)
        if new_items.ndim == 1:
            new_items = new_items[None, :]
        if new_items.shape[0]:
            self.pending = self.pending + (new_items,)
        if key is not None:
            self.pending_key = key
        do_flush = flush if flush is not None else self.n_pending >= self.ingest_batch
        if do_flush:
            self.flush(key=key)
        return self

    def flush(self, *, key: Optional[Array] = None) -> "OnlineIndex":
        """Coalesce buffered adds into one insertion wave."""
        if not self.pending:
            return self
        if key is None:
            key = self.pending_key
        batch = jnp.concatenate(
            [p.astype(self.items.dtype) for p in self.pending], axis=0
        )
        m = batch.shape[0]
        self._ensure_room(m)
        n0 = int(self.graph.n_valid)
        items = self.items.at[n0 : n0 + m].set(batch)
        g, _ = dynamic.insert(self.graph, items, m, self.build_cfg, key)
        self.graph, self.items = g, items
        # drained only after the wave landed: a failure above (growth OOM,
        # insert error) leaves the buffer intact for retry, not silently lost
        self.pending = ()
        self.pending_key = None
        return self

    def remove(self, ids: Array) -> "OnlineIndex":
        """Remove items (catalog withdrawal); victims enter the free-slot
        ledger for later reclamation.  Flushes pending adds first so the
        ledger and the graph agree on liveness; if that flush auto-compacts,
        the caller's (pre-flush) row ids are remapped through the compaction
        id map, so they always name the rows the caller saw.

        Only ids that are in range and currently alive act (-1 result
        padding and stale ids are no-ops); the removal batch is padded to
        power-of-two buckets so the jitted ``dynamic.remove`` compiles
        O(log cap) shapes, not one per batch size.
        """
        pre_map = self.last_compact_map
        self.flush()
        ids_np = np.unique(np.asarray(ids).reshape(-1).astype(np.int64))
        if self.last_compact_map is not pre_map:
            # the flush compacted: translate the caller's pre-flush rows
            id_map = self.last_compact_map
            ok = (ids_np >= 0) & (ids_np < len(id_map))
            ids_np = id_map[ids_np[ok]]
        alive = np.asarray(self.graph.alive)
        ids_np = ids_np[(ids_np >= 0) & (ids_np < alive.shape[0])]
        newly_dead = ids_np[alive[ids_np]]
        if not newly_dead.size:
            return self
        bucket = 1 << int(newly_dead.size - 1).bit_length()
        padded = np.full(bucket, -1, np.int64)
        padded[: newly_dead.size] = newly_dead
        self.graph = dynamic.remove(
            self.graph, self.items, jnp.asarray(padded, jnp.int32),
            self.metric,
        )
        self.free_ids = self.free_ids + tuple(int(i) for i in newly_dead)
        return self

    def compact(self) -> np.ndarray:
        """Re-pack alive rows to the front, reclaiming the ledger's slots.

        Returns the (capacity,) old→new row map (-1 for removed rows); it is
        also retained as ``last_compact_map`` so batch entry points that
        compact implicitly (``flush`` under ``auto_compact``) leave a trail
        for id-holding callers (the sharded router).
        """
        g, x, id_map = dynamic.compact(self.graph, self.items)
        self.graph, self.items = g, x
        self.free_ids = ()
        self.last_compact_map = np.asarray(id_map)
        return self.last_compact_map

    def _ensure_room(self, m: int) -> None:
        """Make room for m tail inserts: recycle free slots, then grow."""
        tail_room = self.capacity - int(self.graph.n_valid)
        if m <= tail_room:
            return
        # recycle before growing: compaction frees the ledger's slots
        if self.auto_compact and self.free_ids:
            n_alive = int(self.graph.n_valid) - len(self.free_ids)
            if n_alive + m <= self.capacity:
                self.compact()
                return
        needed = int(self.graph.n_valid) + m
        new_cap = max(needed, int(self.capacity * self.growth_factor), 1)
        self.graph = graph_lib.grow_graph(self.graph, new_cap)
        self.items = jnp.pad(
            self.items, ((0, new_cap - self.items.shape[0]), (0, 0))
        )

    # -- search --------------------------------------------------------------

    def search(
        self,
        queries: Array,
        top_k: int,
        *,
        beam: Optional[int] = None,
        key: Optional[Array] = None,
    ) -> search_lib.SearchResult:
        """Per-query EHC search (flushes buffered adds first).

        This is the raw (B, k) search surface; the serving-side merge/dedupe
        and score convention live in ``serve.retrieval.retrieve``.
        """
        self.flush()
        if key is None:
            key = jax.random.PRNGKey(0)
        scfg = search_lib.SearchConfig(
            k=top_k,
            beam=max(beam or 2 * top_k, top_k),
            metric=self.metric,
            use_lgd_mask=self.build_cfg.lgd,
            use_pallas=self.build_cfg.use_pallas,
        )
        return search_lib.search(self.graph, self.items, queries, key, scfg)

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> str:
        """Snapshot graph + data + config (flushes buffered adds first)."""
        self.flush()
        return snapshot_lib.save(
            path,
            self.graph,
            self.items,
            self.build_cfg,
            extra_meta={"free_ids": [int(i) for i in self.free_ids]},
        )

    @classmethod
    def load(cls, path: str, **lifecycle_kw) -> "OnlineIndex":
        """Restore an index a snapshot-for-snapshot replica of the saved one."""
        g, items, cfg, manifest = snapshot_lib.load(path)
        free = tuple(manifest.get("extra", {}).get("free_ids", []))
        return cls(
            graph=g, items=items, build_cfg=cfg, free_ids=free, **lifecycle_kw
        )
