"""OnlineIndex: the index's life outside a single build call.

``construct.build`` produces a graph; ``dynamic.insert``/``remove`` keep it
current; but the seed repo left everything around those calls to the caller:
capacity was a hard assert, removed rows leaked their slots forever, every
tiny insert paid a full wave dispatch, and nothing survived the process.
``OnlineIndex`` owns that lifecycle, riding the fused wave pipeline
untouched:

  * **amortized-doubling auto-growth** — an insert that would overflow the
    data region grows graph + items to ``growth_factor * capacity`` (one
    O(cap) copy amortized over O(cap) inserts) instead of asserting;
  * **free-slot ledger** — ``remove`` records its victims; before growing,
    an insert first reclaims those slots via ``compact()`` (when
    ``auto_compact``), so steady-state churn (insert ≈ remove) runs in
    bounded memory forever;
  * **compact()** — re-packs alive rows with ``dynamic.compact`` and returns
    the old→new id map so callers holding row ids (the sharded router,
    result caches) can follow the move;
  * **micro-batched ingest** — ``add(..., flush=False)`` buffers small
    inserts host-side and coalesces them into ONE ``construct.build`` wave
    (via ``dynamic.insert``) once ``ingest_batch`` items accumulate; a
    search flushes first, so reads always observe prior writes;
  * **snapshots** — ``save``/``load`` wrap ``repro.index.snapshot`` so a
    serving replica restores graph + data + build config (and therefore the
    same kernel dispatch) bit-for-bit.

The facade is mutable — it *is* the serving-side state machine — but every
underlying buffer is an immutable jax array, so ``clone()`` is O(fields) and
gives the functional entry points in ``serve.retrieval`` copy-on-write
semantics for free.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import construct, dynamic
from repro.core import graph as graph_lib
from repro.core import search as search_lib
from repro.core.graph import KNNGraph
from repro.index import snapshot as snapshot_lib
from repro.obs import NOOP

Array = jax.Array


@dataclasses.dataclass
class OnlineIndex:
    """A long-lived online k-NN index: graph + data + config + churn state.

    Field-compatible with the old ``serve.retrieval.RetrievalIndex``
    (``graph``, ``items``, ``build_cfg``, ``metric``), plus the lifecycle
    state described in the module docstring.
    """

    graph: KNNGraph
    items: Array  # (capacity, d); rows beyond n_valid are free
    build_cfg: construct.BuildConfig
    coarse: object = None  # hierarchy.CoarseLevel under seed_mode="coarse"
    free_ids: tuple = ()  # ledger of removed (dead) rows < n_valid
    pending: tuple = ()  # micro-batch ingest buffer: tuples of (m_i, d) arrays
    ingest_batch: int = 64  # coalesce threshold for buffered adds
    auto_compact: bool = True  # reclaim free slots before growing
    growth_factor: float = 2.0  # amortized-doubling factor
    last_compact_map: Optional[np.ndarray] = None  # old->new rows, last compact
    pending_key: Optional[Array] = None  # PRNG key stashed by buffered adds
    pq_codebook: Optional[Array] = None  # trained PQ code space (precision="pq")
    tracker: object = None  # obs.Tracker for lifecycle spans (None -> no-op)
    _enc: object = None  # cached kernels.precision.EncodedData (serving table)
    _ledger_synced: bool = False  # reconciliation ran (clones inherit True)

    def __post_init__(self):
        # The ledger is a host-side cache of the graph's liveness holes; the
        # alive mask stays the ground truth.  A graph that arrives with dead
        # rows but no ledger (a hand-built graph, or a churned graph saved
        # through ``snapshot.save`` directly rather than ``OnlineIndex.save``)
        # reconciles here, so capacity accounting and auto-compaction never
        # trust stale state.  Runs once per lineage: ``clone()`` carries
        # ``_ledger_synced``, keeping it O(fields) with no device sync.
        if not self._ledger_synced:
            if not self.free_ids:
                n_valid = int(self.graph.n_valid)
                dead = np.flatnonzero(~np.asarray(self.graph.alive[:n_valid]))
                if dead.size:
                    self.free_ids = tuple(int(i) for i in dead)
            self._ledger_synced = True

    # -- views ---------------------------------------------------------------

    @property
    def metric(self) -> str:
        return self.build_cfg.metric

    @property
    def capacity(self) -> int:
        return self.graph.capacity

    @property
    def n_pending(self) -> int:
        return sum(int(p.shape[0]) for p in self.pending)

    @property
    def free_slots(self) -> int:
        return len(self.free_ids)

    @property
    def n_items(self) -> int:
        """Live catalog size: allocated − removed + buffered."""
        return int(self.graph.n_valid) - len(self.free_ids) + self.n_pending

    def clone(self) -> "OnlineIndex":
        """O(fields) copy; jax buffers are immutable and shared."""
        return dataclasses.replace(self)

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        items: Array,
        cfg: Optional[construct.BuildConfig] = None,
        *,
        capacity: Optional[int] = None,
        key: Optional[Array] = None,
        ingest_batch: int = 64,
        auto_compact: bool = True,
        growth_factor: float = 2.0,
        **cfg_kw,
    ) -> "OnlineIndex":
        """Index ``items`` with online LGD/OLG construction.

        ``capacity > n`` pre-allocates headroom; either way later inserts
        auto-grow, so capacity is a hint, not a ceiling.
        """
        if cfg is None:
            cfg = construct.BuildConfig(**cfg_kw)
        elif cfg_kw:
            raise ValueError(
                f"pass either cfg or BuildConfig kwargs, not both (got cfg "
                f"and {sorted(cfg_kw)})"
            )
        n = items.shape[0]
        cap = capacity or n
        g, _, coarse = construct.build(items, cfg, key, return_coarse=True)
        if cap > n:
            g = graph_lib.grow_graph(g, cap)
            items = jnp.pad(items, ((0, cap - n), (0, 0)))
        return cls(
            graph=g,
            items=items,
            build_cfg=cfg,
            coarse=coarse,
            ingest_batch=ingest_batch,
            auto_compact=auto_compact,
            growth_factor=growth_factor,
        )

    # -- churn ---------------------------------------------------------------

    def add(
        self,
        new_items: Array,
        *,
        key: Optional[Array] = None,
        flush: Optional[bool] = None,
    ) -> "OnlineIndex":
        """Insert items (catalog listing).

        ``flush=False`` only buffers; ``flush=True`` forces the insertion
        wave now; the default flushes once ``ingest_batch`` items are
        buffered — the micro-batch path that coalesces trickling single-item
        inserts into one wave.  A ``key`` supplied with a buffered add is
        stashed and used by the eventual coalescing flush, so replicas fed
        the same (items, key) sequence build the same graph regardless of
        when the threshold trips.  Returns self (mutates in place).
        """
        new_items = jnp.asarray(new_items)
        if new_items.ndim == 1:
            new_items = new_items[None, :]
        if new_items.shape[0]:
            self.pending = self.pending + (new_items,)
            # the key belongs to THIS batch: an empty add must not stash one
            # (it would outlive this call and redirect a later, unrelated
            # flush — the replica-determinism leak), so the stash rides the
            # same condition as the buffer append and the invariant
            # ``pending == () ⇒ pending_key is None`` holds everywhere
            if key is not None:
                self.pending_key = key
        do_flush = flush if flush is not None else self.n_pending >= self.ingest_batch
        if do_flush:
            self.flush(key=key)
        return self

    def flush(self, *, key: Optional[Array] = None) -> "OnlineIndex":
        """Coalesce buffered adds into one insertion wave.

        Every exit clears ``pending_key``: a stale key surviving an
        empty-buffer flush would silently redirect the next coalescing
        flush's PRNG stream and break replica determinism (replaying the
        same (items, key) sequence with different flush timing must build
        the same graph).
        """
        if not self.pending:
            self.pending_key = None
            return self
        if key is None:
            key = self.pending_key
        trk = self.tracker or NOOP
        with trk.span("index/flush") as sp:
            batch = jnp.concatenate(
                [p.astype(self.items.dtype) for p in self.pending], axis=0
            )
            m = batch.shape[0]
            self._ensure_room(m)
            n0 = int(self.graph.n_valid)
            items = self.items.at[n0 : n0 + m].set(batch)
            out = dynamic.insert(
                self.graph, items, m, self.build_cfg, key, coarse=self.coarse
            )
            if len(out) == 3:
                g, _, self.coarse = out
            else:
                g, _ = out
            self.graph, self.items = g, items
            self._enc = None  # compressed serving table re-derives lazily
            # drained only after the wave landed: a failure above (growth OOM,
            # insert error) leaves the buffer intact for retry, not silently
            # lost
            self.pending = ()
            self.pending_key = None
            sp.sync(self.graph.nbr_ids)
        trk.log_metrics(
            {
                "index/flushed": m,
                "index/n_items": self.n_items,
                "index/ledger_depth": self.free_slots,
                "index/capacity": self.capacity,
            }
        )
        return self

    def remove(self, ids: Array) -> "OnlineIndex":
        """Remove items (catalog withdrawal); victims enter the free-slot
        ledger for later reclamation.  Flushes pending adds first so the
        ledger and the graph agree on liveness; if that flush auto-compacts,
        the caller's (pre-flush) row ids are remapped through the compaction
        id map, so they always name the rows the caller saw.

        Only ids that are in range and currently alive act (-1 result
        padding and stale ids are no-ops); the removal batch is padded to
        power-of-two buckets so the jitted ``dynamic.remove`` compiles
        O(log cap) shapes, not one per batch size.
        """
        pre_map = self.last_compact_map
        self.flush()
        ids_np = np.unique(np.asarray(ids).reshape(-1).astype(np.int64))
        if self.last_compact_map is not pre_map:
            # the flush compacted: translate the caller's pre-flush rows
            id_map = self.last_compact_map
            ok = (ids_np >= 0) & (ids_np < len(id_map))
            ids_np = id_map[ids_np[ok]]
        alive = np.asarray(self.graph.alive)
        ids_np = ids_np[(ids_np >= 0) & (ids_np < alive.shape[0])]
        newly_dead = ids_np[alive[ids_np]]
        if not newly_dead.size:
            return self
        trk = self.tracker or NOOP
        with trk.span("index/remove") as sp:
            bucket = 1 << int(newly_dead.size - 1).bit_length()
            padded = np.full(bucket, -1, np.int64)
            padded[: newly_dead.size] = newly_dead
            self.graph = dynamic.remove(
                self.graph, self.items, jnp.asarray(padded, jnp.int32),
                self.metric,
            )
            if self.coarse is not None:
                # landmark victims are masked like any dead row; their frozen
                # routing vectors keep steering the coarse walk
                from repro.core import hierarchy

                self.coarse = hierarchy.purge_rows(
                    self.coarse, jnp.asarray(newly_dead, jnp.int32)
                )
            self.free_ids = self.free_ids + tuple(int(i) for i in newly_dead)
            self._enc = None  # victims' rows must drop out of the table
            sp.sync(self.graph.alive)
        trk.log_metrics(
            {
                "index/removed": int(newly_dead.size),
                "index/n_items": self.n_items,
                "index/ledger_depth": self.free_slots,
            }
        )
        return self

    def compact(self) -> np.ndarray:
        """Re-pack alive rows to the front, reclaiming the ledger's slots.

        Returns the (capacity,) old→new row map (-1 for removed rows); it is
        also retained as ``last_compact_map`` so batch entry points that
        compact implicitly (``flush`` under ``auto_compact``) leave a trail
        for id-holding callers (the sharded router).
        """
        trk = self.tracker or NOOP
        with trk.span("index/compact") as sp:
            reclaimed = len(self.free_ids)
            g, x, id_map = dynamic.compact(self.graph, self.items)
            self.graph, self.items = g, x
            if self.coarse is not None:
                from repro.core import hierarchy

                self.coarse = hierarchy.remap_rows(self.coarse, id_map)
            self.free_ids = ()
            self.last_compact_map = np.asarray(id_map)  # this IS a host sync
            self._enc = None  # rows moved; compressed serving table re-derives
            sp.synced = True
        trk.log_metrics(
            {
                "index/compact_reclaimed": reclaimed,
                "index/n_items": self.n_items,
                "index/capacity": self.capacity,
            }
        )
        return self.last_compact_map

    def _ensure_room(self, m: int) -> None:
        """Make room for m tail inserts: recycle free slots, then grow."""
        tail_room = self.capacity - int(self.graph.n_valid)
        if m <= tail_room:
            return
        # recycle before growing: compaction frees the ledger's slots
        if self.auto_compact and self.free_ids:
            n_alive = int(self.graph.n_valid) - len(self.free_ids)
            if n_alive + m <= self.capacity:
                self.compact()
                return
        needed = int(self.graph.n_valid) + m
        old_cap = self.capacity
        new_cap = max(needed, int(self.capacity * self.growth_factor), 1)
        self.graph = graph_lib.grow_graph(self.graph, new_cap)
        self.items = jnp.pad(
            self.items, ((0, new_cap - self.items.shape[0]), (0, 0))
        )
        (self.tracker or NOOP).log_metrics(
            {"index/grow_from": old_cap, "index/grow_to": new_cap}
        )

    # -- search --------------------------------------------------------------

    def search_config(
        self, top_k: int, beam: Optional[int] = None
    ) -> search_lib.SearchConfig:
        """The serving SearchConfig: the build-time search parameters
        (``build_cfg.search_config()`` — n_seeds, hash_slots, max_iters,
        seed_mode, …) with only the per-request k/beam overridden.  Serving
        with anything else would silently diverge from the configuration the
        index was built and validated with (the old from-scratch
        ``SearchConfig(...)`` here dropped every non-default build field)."""
        return dataclasses.replace(
            self.build_cfg.search_config(),
            k=top_k,
            beam=max(beam or 2 * top_k, top_k),
        )

    def _ensure_coarse(self):
        """Lazily (re-)derive the coarse level when serving wants coarse
        seeding but none is attached (pre-v2 snapshot, hand-built index, or
        ``seed_mode`` flipped on after the build)."""
        if self.coarse is None and self.build_cfg.seed_mode == "coarse":
            if int(self.graph.n_valid) - len(self.free_ids) > 0:
                from repro.core import hierarchy

                self.coarse = hierarchy.derive_coarse(
                    self.graph, self.items, self.build_cfg,
                    jax.random.PRNGKey(int(self.graph.n_valid)),
                )
        return self.coarse

    def _ensure_enc(self):
        """Lazily (re-)encode the compressed serving table when the build
        precision is not fp32 — the ``_ensure_coarse`` pattern for the
        distance engine's companion data.  Invalidated by every mutation of
        the rows (``flush``/``remove``/``compact``), re-derived once here
        rather than per search; int8 scales come from the graph-resident
        ``row_scale`` cache, and the PQ codebook is trained ONCE and pinned
        (``pq_codebook``) so churn never shifts the code space under a
        serving replica."""
        precision = self.build_cfg.precision
        if precision == "fp32":
            return None
        if self._enc is None:
            from repro.kernels import precision as precision_lib

            self._enc = precision_lib.encode_dataset(
                self.items.astype(jnp.float32),
                precision,
                row_scale=self.graph.row_scale if precision == "int8" else None,
                codebook=self.pq_codebook if precision == "pq" else None,
            )
            if precision == "pq" and self.pq_codebook is None:
                self.pq_codebook = self._enc.codebook
        return self._enc

    def search(
        self,
        queries: Array,
        top_k: int,
        *,
        beam: Optional[int] = None,
        key: Optional[Array] = None,
    ) -> search_lib.SearchResult:
        """Per-query EHC search (flushes buffered adds first).

        This is the raw (B, k) search surface; the serving-side merge/dedupe
        and score convention live in ``serve.retrieval.retrieve``.  Serving
        inherits the builder's precision (``search_config``); the compressed
        companion table is cached on the index and re-derived only after
        catalog churn.
        """
        self.flush()
        if key is None:
            key = jax.random.PRNGKey(0)
        scfg = self.search_config(top_k, beam)
        coarse = None
        if scfg.seed_mode == "coarse":
            coarse = self._ensure_coarse()
            if coarse is None:  # nothing alive to derive from
                scfg = dataclasses.replace(scfg, seed_mode="random")
        return search_lib.search(
            self.graph, self.items, queries, key, scfg, coarse=coarse,
            enc=self._ensure_enc(),
        )

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> str:
        """Snapshot graph + data + config + coarse level + PQ codebook
        (flushes buffered adds first).  The compressed tiles/codes/scales are
        not persisted — they re-derive canonically on load — but a trained PQ
        codebook is, so the replica serves the same code space."""
        self.flush()
        return snapshot_lib.save(
            path,
            self.graph,
            self.items,
            self.build_cfg,
            coarse=self.coarse,
            pq_codebook=self.pq_codebook,
            extra_meta={"free_ids": [int(i) for i in self.free_ids]},
        )

    @classmethod
    def load(cls, path: str, **lifecycle_kw) -> "OnlineIndex":
        """Restore an index a snapshot-for-snapshot replica of the saved one.

        Pre-v2 snapshots carry no coarse payload; under
        ``seed_mode="coarse"`` the level is re-derived here (offline
        maintenance) so the replica serves coarsely from the first query.
        Pre-v3 snapshots carry no PQ codebook; a ``precision="pq"`` config
        then retrains deterministically from the restored items on first
        search."""
        g, items, cfg, manifest, coarse, pq_cb = snapshot_lib.load(
            path, with_coarse=True, with_pq_codebook=True
        )
        free = tuple(manifest.get("extra", {}).get("free_ids", []))
        idx = cls(
            graph=g, items=items, build_cfg=cfg, coarse=coarse, free_ids=free,
            pq_codebook=pq_cb, **lifecycle_kw,
        )
        if coarse is None and cfg.seed_mode == "coarse":
            idx._ensure_coarse()
        return idx
