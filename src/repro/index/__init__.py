"""Index lifecycle subsystem: the graph's life outside one build call.

``snapshot``  — versioned on-disk format (manifest JSON + npz payload);
``lifecycle`` — ``OnlineIndex``: auto-growth, free-slot ledger, compaction,
                micro-batched ingest, save/load;
``router``    — ``ShardedIndex``: one logical index over S shards.
"""

from repro.index import snapshot  # noqa: F401
from repro.index.lifecycle import OnlineIndex  # noqa: F401
from repro.index.router import ShardedIndex  # noqa: F401
