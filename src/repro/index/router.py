"""Sharded serving router: one logical index over S per-shard OnlineIndexes.

The ROADMAP's north star — heavy traffic over a catalog too big for one
device — needs the standard ANN serving shape: partition the catalog across
shards, fan each query out, and merge per-shard top-k into a global answer.
Each shard is a full ``OnlineIndex`` (its own graph, data region, free-slot
ledger and snapshot), so every lifecycle capability composes with sharding
for free.

Routing policies (recorded in ROADMAP "Architecture decisions in force"):

  * **queries** fan out to every shard and merge by distance — the per-shard
    searches are independent EHC walks over disjoint catalogs, so the merged
    global top-k over brute per-shard results is *exactly* the unsharded
    top-k (the property the router tests pin);
  * **inserts** route to the least-full shard (by live item count), keeping
    shards balanced without a hash ring;
  * **removals** route by id ownership: the router owns the global id space
    and keeps a per-shard local-row → global-id table, remapped whenever a
    shard compacts (shards surface their ``last_compact_map``).

Global ids are stable for the life of the router — shard-internal row moves
(compaction, growth) never leak to callers.
"""

from __future__ import annotations

import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import construct
from repro.index.lifecycle import OnlineIndex

Array = jax.Array

_MANIFEST = "router.json"


class ShardedIndex:
    """S ``OnlineIndex`` shards serving one logical catalog."""

    def __init__(
        self,
        shards: list,
        gids: list,
        next_gid: int,
        tracker=None,
    ):
        self.shards: list[OnlineIndex] = shards
        # per shard: (shard capacity,) int64, local row -> global id (-1 free)
        self.gids: list[np.ndarray] = [np.asarray(g, np.int64) for g in gids]
        self.next_gid = int(next_gid)
        # one tracker for the router AND its shards: shard lifecycle spans
        # (flush/remove/compact) nest under the router's fan-out spans
        self.tracker = tracker
        if tracker is not None:
            for sh in self.shards:
                if sh.tracker is None:
                    sh.tracker = tracker

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        items: Array,
        n_shards: int,
        cfg: Optional[construct.BuildConfig] = None,
        *,
        key: Optional[Array] = None,
        **build_kw,
    ) -> "ShardedIndex":
        """Partition ``items`` into contiguous blocks and build each shard.

        Global ids are the original row indices of ``items`` — a catalog
        indexed sharded or unsharded answers queries in the same id space.
        """
        n = items.shape[0]
        if not 1 <= n_shards <= n:
            raise ValueError(f"need 1 <= n_shards <= n, got {n_shards} for n={n}")
        if key is None:
            key = jax.random.PRNGKey(0)
        # the ONE partition rule, shared with construct.build_parallel — a
        # catalog split here and one split there agree row for row
        bounds = construct.partition_bounds(n, n_shards)
        shards, gids = [], []
        for s in range(n_shards):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            shard = OnlineIndex.build(
                items[lo:hi], cfg, key=jax.random.fold_in(key, s), **build_kw
            )
            table = np.full(shard.capacity, -1, np.int64)
            table[: hi - lo] = np.arange(lo, hi)
            shards.append(shard)
            gids.append(table)
        return cls(shards, gids, next_gid=n)

    # -- views ---------------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_items(self) -> int:
        return sum(s.n_items for s in self.shards)

    @property
    def metric(self) -> str:
        return self.shards[0].metric

    # -- shard-table maintenance ---------------------------------------------

    def _sync_table(self, s: int) -> None:
        """Absorb shard-internal row moves: compaction remap + growth pad."""
        shard = self.shards[s]
        table = self.gids[s]
        if shard.last_compact_map is not None:
            id_map = shard.last_compact_map  # old row -> new row
            new_table = np.full(max(len(id_map), shard.capacity), -1, np.int64)
            moved = id_map >= 0
            new_table[id_map[moved]] = table[: len(id_map)][moved]
            table = new_table
            shard.last_compact_map = None
        if len(table) < shard.capacity:  # shard grew
            table = np.concatenate(
                [table, np.full(shard.capacity - len(table), -1, np.int64)]
            )
        self.gids[s] = table

    # -- churn ---------------------------------------------------------------

    def add(self, new_items: Array, *, key: Optional[Array] = None) -> np.ndarray:
        """Insert a batch; routed to the least-full shard.  Returns the
        assigned global ids."""
        new_items = jnp.asarray(new_items)
        if new_items.ndim == 1:
            new_items = new_items[None, :]
        m = int(new_items.shape[0])
        if m == 0:
            return np.empty((0,), np.int64)
        s = int(np.argmin([sh.n_items for sh in self.shards]))
        shard = self.shards[s]
        shard.add(new_items, key=key, flush=True)
        self._sync_table(s)
        n1 = int(shard.graph.n_valid)
        new_gids = np.arange(self.next_gid, self.next_gid + m, dtype=np.int64)
        self.gids[s][n1 - m : n1] = new_gids
        self.next_gid += m
        return new_gids

    def remove(self, global_ids) -> int:
        """Withdraw global ids; routed by ownership.  Returns #removed."""
        want = np.unique(np.asarray(global_ids, np.int64))
        want = want[want >= 0]  # -1 is the tables' free-slot sentinel
        removed = 0
        for s, shard in enumerate(self.shards):
            self._sync_table(s)  # local rows must be current before lookup
            table = self.gids[s]
            local = np.nonzero(np.isin(table, want))[0]
            if not local.size:
                continue
            shard.remove(jnp.asarray(local, jnp.int32))
            table[local] = -1
            removed += local.size
        return removed

    def compact(self) -> None:
        """Compact every shard, following the row moves in the id tables."""
        for s, shard in enumerate(self.shards):
            if shard.free_slots:
                shard.compact()
                self._sync_table(s)

    # -- shard collapse ------------------------------------------------------

    def merge_shards(
        self,
        *,
        refine_rounds: int = 1,
        key: Optional[Array] = None,
    ) -> "ShardedIndex":
        """Collapse the router into ONE shard: a single ``OnlineIndex`` over
        the union catalog.

        The per-shard graphs are folded with ``merge.merge_subgraphs`` (the
        divide-and-conquer construction path in reverse: what was sharded for
        build throughput is re-joined for serving locality) and the residual
        recall gap is closed with ``nndescent.refine``.  The global id space
        is preserved verbatim — every id the router ever handed out keeps
        resolving, and the id tables keep following shard-internal row moves
        — so callers notice nothing but the fan-out disappearing.  The merged
        ``OnlineIndex`` is ``self.shards[0]`` afterwards; lifecycle knobs and
        the build config come from the old shard 0.

        Returns ``self`` (mutated in place, like the churn entry points).
        """
        from repro.core import merge as merge_lib
        from repro.core import nndescent
        from repro.core import graph as graph_lib

        if key is None:
            key = jax.random.PRNGKey(0)
        # settle every shard: land buffered adds, re-pack liveness holes so
        # every sub-graph is dense and fully allocated, then absorb the row
        # moves into the id tables
        for s, shard in enumerate(self.shards):
            shard.flush()
            if shard.free_slots:
                shard.compact()
            self._sync_table(s)
        if self.n_shards == 1:
            return self

        graphs, parts, tables, coarses = [], [], [], []
        for s, shard in enumerate(self.shards):
            nv = int(shard.graph.n_valid)
            if nv == 0:
                continue
            graphs.append(graph_lib.trim_graph(shard.graph, nv))
            parts.append(shard.items[:nv])
            tables.append(self.gids[s][:nv])
            # shard coarse levels live in shard-local rows — exactly the id
            # space the level-0 merge cross-searches run in (post-compact,
            # rows are dense in [0, nv))
            coarses.append(shard.coarse)
        base = self.shards[0]
        if not graphs:  # an all-empty router collapses to empty shard 0
            self.shards = [base]
            self.gids = [self.gids[0]]
            return self

        x = jnp.concatenate(parts)
        scfg = base.build_cfg.search_config()
        g, _, coarse = merge_lib.merge_subgraphs(
            graphs, x, scfg, key, coarses=coarses
        )
        g, _ = nndescent.refine(
            g, x, base.metric, rounds=refine_rounds,
            dispatch=base.build_cfg.dispatch,
        )
        # the merge fold's root coarse level is already in the union id
        # space (shard levels fold with the same offset arithmetic as the
        # graphs), so the merged index serves coarse-seeded searches
        # immediately; shards without levels leave it None and
        # OnlineIndex._ensure_coarse re-derives lazily as before
        merged = OnlineIndex(
            graph=g,
            items=x,
            build_cfg=base.build_cfg,
            ingest_batch=base.ingest_batch,
            auto_compact=base.auto_compact,
            growth_factor=base.growth_factor,
            coarse=coarse,
        )
        self.shards = [merged]
        self.gids = [np.concatenate(tables)]
        return self

    # -- serving -------------------------------------------------------------

    def retrieve(
        self,
        interests: Array,
        top_k: int,
        *,
        beam: Optional[int] = None,
        key: Optional[Array] = None,
        brute: bool = False,
        with_stats: bool = False,
    ):
        """Fan out to every shard, merge per-shard top-k globally.

        Returns (global ids (top_k,), scores (top_k,)) in the serving score
        convention (``serve.retrieval.score_from_dist``).  ``brute=True``
        serves each shard exactly — the merged result is then exactly the
        unsharded brute answer (the router's correctness oracle).

        With a tracker attached, each shard's leg of the fan-out gets its own
        ``router/shard`` span (the per-shard ``np.asarray`` merge pull is the
        existing sync, so the span measures the shard's device work, not
        dispatch) — the straggler profile of the fan-out in one trace.
        ``with_stats=True`` appends a merged ``obs.SearchStats`` over all
        shards' graph searches (``None`` under ``brute=True``).
        """
        from repro.obs import NOOP, SearchStats
        from repro.serve import retrieval  # late: serve imports repro.index

        if key is None:
            key = jax.random.PRNGKey(0)
        trk = self.tracker or NOOP
        stats = None if brute else SearchStats()
        all_gids, all_dist = [], []
        for s, shard in enumerate(self.shards):
            with trk.span(f"router/shard{s}") as sp:
                if brute:
                    ids, scores = retrieval.retrieve_brute(
                        shard, interests, top_k
                    )
                else:
                    ids, scores, res = retrieval.retrieve(
                        shard, interests, top_k, beam=beam,
                        key=jax.random.fold_in(key, s), with_stats=True,
                    )
                    stats.update(res, n_items=shard.n_items)
                ids = np.asarray(ids)
                # scores -> distances for a convention-free merge;
                # score_from_dist is an involution (negation for similarity
                # metrics, identity otherwise)
                dist = np.asarray(
                    retrieval.score_from_dist(scores, self.metric)
                )
                sp.synced = True  # the np.asarray pulls are the sync
            # drop -1 padding AND inf-distance filler: a shard with fewer
            # than top_k live items pads with dedupe-masked duplicates whose
            # distance is inf — letting them through would surface duplicate
            # global ids in a scarce merged result
            ok = (ids >= 0) & np.isfinite(dist)
            all_gids.append(self.gids[s][ids[ok]])
            all_dist.append(dist[ok])
        gids = np.concatenate(all_gids)
        dist = np.concatenate(all_dist)
        order = np.argsort(dist, kind="stable")[:top_k]
        out_ids = np.full(top_k, -1, np.int64)
        out_dist = np.full(top_k, np.inf, np.float32)
        out_ids[: order.size] = gids[order]
        out_dist[: order.size] = dist[order]
        scores = retrieval.score_from_dist(out_dist, self.metric)
        if with_stats:
            return out_ids, scores, stats
        return out_ids, scores

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> str:
        """Snapshot the router: per-shard snapshots + the id tables."""
        os.makedirs(path, exist_ok=True)
        for s, shard in enumerate(self.shards):
            shard.save(os.path.join(path, f"shard_{s:03d}"))
        np.savez(
            os.path.join(path, "router_tables.npz"),
            **{f"gids_{s}": t for s, t in enumerate(self.gids)},
        )
        with open(os.path.join(path, _MANIFEST), "w") as f:
            json.dump(
                {"n_shards": self.n_shards, "next_gid": self.next_gid}, f
            )
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: str) -> "ShardedIndex":
        with open(os.path.join(path, _MANIFEST)) as f:
            man = json.load(f)
        with np.load(os.path.join(path, "router_tables.npz")) as z:
            gids = [z[f"gids_{s}"] for s in range(man["n_shards"])]
        shards = [
            OnlineIndex.load(os.path.join(path, f"shard_{s:03d}"))
            for s in range(man["n_shards"])
        ]
        return cls(shards, gids, next_gid=man["next_gid"])
