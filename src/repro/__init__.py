"""repro — online approximate k-NN graph construction (the paper, end to end).

The one-stop facade.  Everything a typical user needs lives here; the full
surface stays importable from the subpackages:

  * ``repro.core``    — algorithms: EHC search, OLG/LGD construction,
                        NN-Descent, dynamic insert/remove, merge, hierarchy
  * ``repro.kernels`` — the blocked Pallas distance engine + precision codecs
  * ``repro.index``   — lifecycle (OnlineIndex), sharded serving
                        (ShardedIndex), versioned snapshots
  * ``repro.serve``   — retrieval-facing entry points + the instrumented
                        ``ServingLoop``
  * ``repro.obs``     — telemetry: ``Tracker`` (noop/in-memory/JSONL spans +
                        metrics) and the ``SearchStats`` aggregator
  * ``repro.data`` / ``repro.models`` / ``repro.train`` — substrate

Quick start::

    import repro

    g, stats = repro.build(x, repro.BuildConfig(k=20, precision="int8"))
    idx = repro.OnlineIndex.build(x, repro.BuildConfig(k=20))
    res = idx.search(queries, top_k=10)
"""

from repro.core.construct import BuildConfig, build, build_parallel
from repro.core.search import SearchConfig, SearchResult, search
from repro.index.lifecycle import OnlineIndex
from repro.index.router import ShardedIndex
from repro.obs import (
    InMemoryTracker,
    JsonlTracker,
    NoopTracker,
    SearchStats,
    Tracker,
)

__version__ = "0.9.0"  # tracks the PR sequence; PR 9 = telemetry + serving

__all__ = [
    "BuildConfig",
    "SearchConfig",
    "SearchResult",
    "OnlineIndex",
    "ShardedIndex",
    "Tracker",
    "NoopTracker",
    "InMemoryTracker",
    "JsonlTracker",
    "SearchStats",
    "build",
    "build_parallel",
    "search",
    "__version__",
]
