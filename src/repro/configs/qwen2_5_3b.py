"""qwen2.5-3b [hf:Qwen/Qwen2.5 family]: 36L d2048 16H GQA(kv=2) d_ff 11008,
vocab 151936, QKV bias, full attention, tied embeddings."""

from repro.configs.lm_shapes import LM_SHAPES, FULL_ATTENTION_SKIP
from repro.models.transformer import TransformerConfig

ARCH = "qwen2.5-3b"
FAMILY = "lm"
SHAPES = LM_SHAPES
SKIP = {"long_500k": FULL_ATTENTION_SKIP}


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH,
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        d_ff=11008,
        vocab=151936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1e6,
        param_dtype="bfloat16",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        qkv_bias=True,
        tie_embeddings=True,
        remat=False,
        q_chunk=32,
        kv_chunk=32,
    )
