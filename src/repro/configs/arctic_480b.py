"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: 35L d7168 56H GQA(kv=8)
dense-FFN d_ff 4864 residual + MoE 128 experts top-2 (expert d_ff 4864).

Dense-MoE hybrid: every layer runs a (small) dense residual FFN in parallel
with the 128-expert MoE — the published Arctic topology.  Adafactor is
selected by the cell builder (optimizer state for 480B params would not fit
with Adam even sharded)."""

from repro.configs.lm_shapes import LM_SHAPES, FULL_ATTENTION_SKIP
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH = "arctic-480b"
FAMILY = "lm"
SHAPES = LM_SHAPES
SKIP = {"long_500k": FULL_ATTENTION_SKIP}


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH,
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab=32000,
        moe=MoEConfig(n_experts=128, top_k=2),
        moe_d_ff=4864,
        dense_residual=True,
        dense_d_ff=4864,
        tie_embeddings=False,
        rope_theta=1e6,
        param_dtype="bfloat16",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2),
        moe_d_ff=96,
        dense_residual=True,
        dense_d_ff=96,
        tie_embeddings=False,
        remat=False,
        q_chunk=32,
        kv_chunk=32,
    )
