"""mixtral-8x7b [arXiv:2401.04088; hf]: 32L d4096 32H GQA(kv=8) d_ff 14336,
vocab 32000, MoE 8 experts top-2, sliding-window attention (4096)."""

from repro.configs.lm_shapes import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH = "mixtral-8x7b"
FAMILY = "lm"
SHAPES = LM_SHAPES
# SWA bounds the decode window — long_500k runs (reads a 4096 window/layer).
SKIP = {}


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=32000,
        window=4096,
        moe=MoEConfig(n_experts=8, top_k=2),
        moe_d_ff=14336,
        tie_embeddings=False,
        rope_theta=1e6,
        param_dtype="bfloat16",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        window=32,
        moe=MoEConfig(n_experts=4, top_k=2),
        moe_d_ff=128,
        tie_embeddings=False,
        remat=False,
        q_chunk=32,
        kv_chunk=32,
    )
