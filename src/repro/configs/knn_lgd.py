"""The paper's own technique as a first-class arch: LGD (Alg. 3).

Production setting: 16.7M vectors (d=128, l2 — SIFT-like scale x16) sharded
over the mesh; per-shard online construction (zero-collective build) and
scatter-gather search (DESIGN.md §4).  The dry-run lowers one build wave and
one 4096-query search wave under shard_map on the production mesh."""

from repro.core.construct import BuildConfig

ARCH = "knn-lgd"
FAMILY = "knn"

SHAPES = {
    "build_wave": {"kind": "knn_build", "n_total": 16_777_216, "d": 128, "wave": 4096},
    "search_4k": {"kind": "knn_search", "n_total": 16_777_216, "d": 128, "batch": 4096},
}
SKIP = {}


def full_config() -> BuildConfig:
    return BuildConfig(k=20, metric="l2", wave=4096, lgd=True, beam=40, n_seeds=8)


def smoke_config() -> BuildConfig:
    # k close to the smoke set's dim (d=12, the paper's guidance) and enough
    # search budget for EHC to converge under the LGD expansion filter —
    # k=5/beam=12 leaves the occlusion-pruned graph too sparse to navigate.
    return BuildConfig(
        k=8, metric="l2", wave=64, lgd=True, beam=16, n_seeds=4,
        n_seed_init=32, hash_slots=512, max_iters=24,
    )
