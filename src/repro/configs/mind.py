"""mind [arXiv:1904.08030]: embed_dim 64, 4 interest capsules, 3 dynamic
routing iterations, label-aware attention.  Item vocab 10^7.

The retrieval_cand shape is the paper's own use case: the LGD graph over the
candidate bank serves the interests-to-items k-NN query
(serve/retrieval.py; DESIGN.md §5)."""

from repro.configs.recsys_shapes import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

ARCH = "mind"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES
SKIP = {}


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name="mind",
        embed_dim=64,
        seq_len=20,
        n_interests=4,
        capsule_iters=3,
        mlp=(256,),
        vocab_per_field=10_000_000,
    )


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="mind", embed_dim=16, seq_len=8, n_interests=4, capsule_iters=3,
        mlp=(32,), vocab_per_field=512,
    )
