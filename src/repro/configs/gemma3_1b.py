"""gemma3-1b [hf:google/gemma-3-1b-pt]: 26L d1152 4H GQA(kv=1) head_dim 256,
d_ff 6912, vocab 262144, 5:1 local:global attention (local window 512),
128k context, tied embeddings."""

from repro.configs.lm_shapes import LM_SHAPES
from repro.models.transformer import TransformerConfig

ARCH = "gemma3-1b"
FAMILY = "lm"
SHAPES = LM_SHAPES
# 5:1 local:global — decode reads a bounded window on 5/6 of layers, so the
# long_500k cell runs (the single global layer per period is O(S) decode).
SKIP = {}


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH,
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        d_head=256,
        d_ff=6912,
        vocab=262144,
        local_global=(5, 1),
        local_window=512,
        tie_embeddings=True,
        rope_theta=1e6,
        logit_softcap=30.0,
        param_dtype="bfloat16",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH + "-smoke",
        n_layers=3,
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        d_head=32,
        d_ff=128,
        vocab=256,
        local_global=(2, 1),
        local_window=16,
        tie_embeddings=True,
        logit_softcap=30.0,
        remat=False,
        q_chunk=32,
        kv_chunk=32,
    )
