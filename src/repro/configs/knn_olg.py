"""OLG (Alg. 2): the paper's construction without lazy diversification —
the ablation baseline of LGD (same flow, no λ bookkeeping)."""

from repro.core.construct import BuildConfig

ARCH = "knn-olg"
FAMILY = "knn"

SHAPES = {
    "build_wave": {"kind": "knn_build", "n_total": 16_777_216, "d": 128, "wave": 4096},
    "search_4k": {"kind": "knn_search", "n_total": 16_777_216, "d": 128, "batch": 4096},
}
SKIP = {}


def full_config() -> BuildConfig:
    return BuildConfig(k=20, metric="l2", wave=4096, lgd=False, beam=40, n_seeds=8)


def smoke_config() -> BuildConfig:
    return BuildConfig(
        k=5, metric="l2", wave=64, lgd=False, beam=12, n_seeds=4,
        n_seed_init=32, hash_slots=256, max_iters=12,
    )
