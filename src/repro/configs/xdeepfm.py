"""xdeepfm [arXiv:1803.05170]: 39 sparse fields, embed_dim 10,
CIN 200-200-200, MLP 400-400."""

from repro.configs.recsys_shapes import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

ARCH = "xdeepfm"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES
SKIP = {}


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name="xdeepfm",
        n_sparse=39,
        embed_dim=10,
        mlp=(400, 400),
        cin_layers=(200, 200, 200),
        vocab_per_field=1_000_000,
    )


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="xdeepfm",
        n_sparse=6,
        embed_dim=8,
        mlp=(32,),
        cin_layers=(16, 16),
        vocab_per_field=128,
    )
