"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b]: 24L d2048 32H (kv=32 = MHA)
d_ff 5632, vocab 100352, full attention."""

from repro.configs.lm_shapes import LM_SHAPES, FULL_ATTENTION_SKIP
from repro.models.transformer import TransformerConfig

ARCH = "stablelm-1.6b"
FAMILY = "lm"
SHAPES = LM_SHAPES
SKIP = {"long_500k": FULL_ATTENTION_SKIP}


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH,
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab=100352,
        tie_embeddings=False,
        rope_theta=1e4,
        param_dtype="bfloat16",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        tie_embeddings=False,
        remat=False,
        q_chunk=32,
        kv_chunk=32,
    )
