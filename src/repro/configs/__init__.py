"""Architecture registry: ``--arch <id>`` resolves here.

Each assigned architecture has its own module exporting:
  * ``ARCH``         — the public id (e.g. "mixtral-8x7b");
  * ``FAMILY``       — "lm" | "gnn" | "recsys" | "knn";
  * ``full_config()``  — the exact published configuration (dry-run only);
  * ``smoke_config()`` — reduced same-family config for CPU tests;
  * ``SHAPES``       — shape-name -> params for this arch's input-shape set;
  * ``SKIP``         — shape-name -> reason, for documented inapplicability.

``repro.configs.cells`` turns (arch, shape, mesh) into a lowerable CellPlan.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

_ARCH_MODULES = {
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "mace": "repro.configs.mace_cfg",
    "deepfm": "repro.configs.deepfm",
    "bst": "repro.configs.bst",
    "xdeepfm": "repro.configs.xdeepfm",
    "mind": "repro.configs.mind",
    # the paper's own technique as a first-class arch
    "knn-lgd": "repro.configs.knn_lgd",
    "knn-olg": "repro.configs.knn_olg",
}

ASSIGNED = [a for a in _ARCH_MODULES if not a.startswith("knn-")]


def get(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch])


def names(include_knn: bool = True) -> List[str]:
    return list(_ARCH_MODULES) if include_knn else list(ASSIGNED)


def all_cells(include_knn: bool = False) -> List[tuple]:
    """Every (arch, shape) pair, with skips annotated: [(arch, shape, skip_reason|None)]."""
    out = []
    for arch in names(include_knn):
        mod = get(arch)
        for shape in mod.SHAPES:
            out.append((arch, shape, mod.SKIP.get(shape)))
    return out
