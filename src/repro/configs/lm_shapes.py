"""The shared LM-family input-shape set (seq_len x global_batch)."""

LM_SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

FULL_ATTENTION_SKIP = (
    "long_500k needs sub-quadratic attention; this arch is pure full "
    "attention (unbounded KV window) — skipped per assignment rule, "
    "see DESIGN.md §5"
)
