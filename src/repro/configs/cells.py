"""CellPlan: (arch x shape x mesh) -> a lowerable, sharded step function.

This is what the multi-pod dry-run compiles for every assigned cell.  A plan
carries the step callable, positional ShapeDtypeStruct args (no allocation)
and a matching NamedSharding tree, plus MODEL_FLOPS for the roofline's
useful-compute ratio.

Sharding policy (baseline — §Perf iterates on it):
  * LM train: params per Megatron TP rules (models.transformer.param_pspecs),
    batch over (pod, data); MoE experts over 'model' when E >= 16.
  * LM decode: KV cache sequence-sharded over 'model' (flash-decoding style
    split-K; the softmax reduction becomes an all-reduce), batch over data
    axes; long_500k (batch=1) shards sequence over EVERY axis.
  * GNN: node/edge arrays sharded over all axes (edge-parallel message
    passing); shapes are padded to multiples of 512 with explicit masks.
  * RecSys: embedding tables row-sharded over 'model' (DLRM), batch over
    data axes; retrieval candidates sharded over all axes.
  * k-NN (the paper): graph+data row-sharded over all axes via shard_map
    (zero-collective build, all-gather-merge search) — core.distributed.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.models import mace as mace_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.train import optimizer as opt_lib
from repro.train import train_loop

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    args: tuple  # positional ShapeDtypeStruct pytrees
    in_shardings: tuple  # matching NamedSharding pytrees
    model_flops: Optional[float]  # 6·N·D (train) / 2·N·D (fwd) where defined
    notes: str = ""
    donate_argnums: tuple = ()
    # while-loop-dominated programs (EHC search): cost_analysis counts loop
    # bodies once; multiply flops/bytes by this factor (== expected trips)
    loop_factor: float = 1.0


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def flat_axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


def _ns(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _specs_of(tree):
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_plan(arch: str, shape: str, mesh: Mesh, mod, opts=None) -> CellPlan:
    opts = opts or {}
    cfg: tfm.TransformerConfig = mod.full_config()
    info = mod.SHAPES[shape]
    kind, S, B = info["kind"], info["seq"], info["batch"]
    da = data_axes(mesh)
    fa = flat_axes(mesh)
    # dry-run lowering: unrolled layers + statically-tiled attention so
    # cost_analysis counts every layer/tile (scan bodies count once) and
    # fully-masked tiles are skipped (the production flash schedule).
    chunk = max(512, S // 4)
    n_data = int(np.prod([mesh.shape[a] for a in da]))
    cfg = dataclasses.replace(
        cfg, unrolled=True, q_chunk=chunk, kv_chunk=chunk,
        moe_groups=n_data,  # shard-local MoE dispatch (EXPERIMENTS §Perf it.1)
    )

    params_shapes = jax.eval_shape(
        lambda k: tfm.init_params(k, cfg), SDS((2,), jnp.uint32)
    )
    # FSDP: weight matrices sharded over BOTH axes (d over data, out over
    # model) — params+optimizer state for the big archs exceed HBM under
    # model-only sharding (arctic: 960 GB bf16 params alone)
    pspecs = tfm.param_pspecs(cfg, fsdp=True)
    params_sh = _ns(mesh, pspecs)

    n_active = cfg.active_param_count()

    if kind == "train":
        ocfg = opt_lib.OptConfig(
            name="adafactor" if cfg.param_count() > 1e11 else "adamw"
        )
        opt_shapes = jax.eval_shape(
            functools.partial(opt_lib.init_opt_state, cfg=ocfg), params_shapes
        )
        opt_specs = opt_lib.opt_state_pspecs(pspecs, params_shapes, ocfg)
        opt_sh = _ns(mesh, opt_specs)
        step = train_loop.make_train_step(
            lambda p, b: tfm.loss_fn(p, b["tokens"], cfg), ocfg
        )
        batch = {"tokens": SDS((B, S), jnp.int32)}
        batch_sh = {"tokens": NamedSharding(mesh, P(da, None))}
        return CellPlan(
            arch, shape, kind, step,
            (params_shapes, opt_shapes, batch),
            (params_sh, opt_sh, batch_sh),
            model_flops=6.0 * n_active * B * S,
            notes=f"opt={ocfg.name}",
        )

    if kind == "prefill":
        step = functools.partial(tfm.prefill, cfg=cfg)
        step = lambda params, tokens: tfm.prefill(params, tokens, cfg)  # noqa: E731
        tokens = SDS((B, S), jnp.int32)
        tok_sh = NamedSharding(mesh, P(da, None))
        return CellPlan(
            arch, shape, kind, step,
            (params_shapes, tokens),
            (params_sh, tok_sh),
            model_flops=2.0 * n_active * B * S,
        )

    # decode
    split_cache = bool(opts.get("split_cache")) and (
        cfg.window is not None or cfg.local_global is not None)
    if B == 1:
        seq_axes = fa  # long_500k: every axis on the sequence (split-K decode)
        kv_spec = P(None, None, seq_axes, None, None)
        len_spec = P(None)
        tok_spec = P(None)
    else:
        kv_spec = P(None, da, "model", None, None)
        len_spec = P(da)
        tok_spec = P(da)
    if split_cache:
        cache_shapes = jax.eval_shape(
            lambda: tfm.init_split_cache(cfg, B, S, dtype=jnp.bfloat16))
        # ring caches are small (window-sized): batch-shard only; global
        # layers keep the sequence sharding
        ring_spec = P(None, da if B > 1 else None, None, None, None)
        cache_sh = {"k_loc": NamedSharding(mesh, ring_spec),
                    "v_loc": NamedSharding(mesh, ring_spec),
                    "len": NamedSharding(mesh, len_spec)}
        if "k_glob" in cache_shapes:
            cache_sh["k_glob"] = NamedSharding(mesh, kv_spec)
            cache_sh["v_glob"] = NamedSharding(mesh, kv_spec)
        step = lambda params, cache, tokens: tfm.decode_step_split(  # noqa: E731
            params, cache, tokens, cfg)
        notes = "windowed ring KV caches (exact SWA; §Perf it.4)"
    else:
        cache_shapes = jax.eval_shape(
            lambda: tfm.init_cache(cfg, B, S, dtype=jnp.bfloat16))
        cache_sh = {
            "k": NamedSharding(mesh, kv_spec),
            "v": NamedSharding(mesh, kv_spec),
            "len": NamedSharding(mesh, len_spec),
        }
        step = lambda params, cache, tokens: tfm.decode_step(params, cache, tokens, cfg)  # noqa: E731
        notes = "KV cache sequence-sharded (split-K decode)"
    tokens = SDS((B,), jnp.int32)
    return CellPlan(
        arch, shape, kind, step,
        (params_shapes, cache_shapes, tokens),
        (params_sh, cache_sh, NamedSharding(mesh, tok_spec)),
        model_flops=2.0 * n_active * B,
        notes=notes,
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_plan(arch: str, shape: str, mesh: Mesh, mod) -> CellPlan:
    info = mod.SHAPES[shape]
    cfg: mace_lib.MACEConfig = mod.full_config(shape)
    fa = flat_axes(mesh)
    da = data_axes(mesh)
    ndev = int(np.prod([mesh.shape[a] for a in fa]))

    params_shapes = jax.eval_shape(
        lambda k: mace_lib.init_params(k, cfg), SDS((2,), jnp.uint32)
    )
    params_sh = _ns(mesh, mace_lib.param_pspecs(cfg))
    ocfg = opt_lib.OptConfig(name="adamw")
    opt_shapes = jax.eval_shape(
        functools.partial(opt_lib.init_opt_state, cfg=ocfg), params_shapes
    )
    opt_sh = _ns(mesh, opt_lib.opt_state_pspecs(mace_lib.param_pspecs(cfg), params_shapes, ocfg))

    if shape == "molecule":
        Bm, N, E = info["batch"], info["n_nodes"], info["n_edges"]
        step = train_loop.make_train_step(
            lambda p, b: mace_lib.energy_loss(p, b, cfg), ocfg
        )
        batch = {
            "positions": SDS((Bm, N, 3), jnp.float32),
            "species": SDS((Bm, N), jnp.int32),
            "senders": SDS((Bm, E), jnp.int32),
            "receivers": SDS((Bm, E), jnp.int32),
            "energy": SDS((Bm,), jnp.float32),
        }
        bsh = {k: NamedSharding(mesh, P(da, *([None] * (len(v.shape) - 1))))
               for k, v in batch.items()}
        mflops = 2.0 * Bm * E * cfg.d_hidden * (9 + 3 + 1) * 3  # messages fwd~
        return CellPlan(
            arch, shape, "train", step,
            (params_shapes, opt_shapes, batch),
            (params_sh, opt_sh, bsh),
            model_flops=3.0 * mflops,
            notes="vmapped energy MSE; k-NN edges from repro.core (DESIGN §5)",
        )

    # full-batch / sampled node classification: padded to shard boundaries
    if shape == "minibatch_lg":
        seeds = info["batch_nodes"]
        f1, f2 = info["fanout"]
        N = seeds * (1 + f1 + f1 * f2)  # sampled frontier (dups kept, padded slots)
        E = seeds * f1 + seeds * f1 * f2
        notes = f"sampled subgraph: {seeds} seeds x fanout {f1}-{f2} (data.graphs sampler)"
    else:
        N, E = info["n_nodes"], info["n_edges"]
        notes = "full-batch"
    Np, Ep = _pad_to(N, 512), _pad_to(E, 512)
    if (Np, Ep) != (N, E):
        notes += f"; padded nodes {N}->{Np}, edges {E}->{Ep} (masked)"

    step = train_loop.make_train_step(
        lambda p, b: mace_lib.node_class_loss(p, b, cfg), ocfg
    )
    batch = {
        "positions": SDS((Np, 3), jnp.float32),
        "species": SDS((Np,), jnp.int32),
        "node_feat": SDS((Np, info["d_feat"]), jnp.float32),
        "labels": SDS((Np,), jnp.int32),
        "train_mask": SDS((Np,), jnp.bool_),
        "node_mask": SDS((Np,), jnp.bool_),
        "senders": SDS((Ep,), jnp.int32),
        "receivers": SDS((Ep,), jnp.int32),
        "edge_mask": SDS((Ep,), jnp.bool_),
    }
    bsh = {k: NamedSharding(mesh, P(fa, *([None] * (len(v.shape) - 1))))
           for k, v in batch.items()}
    # messages: per edge ~ (1+3+9)·C mults for A-basis x3 ranks; fwd+bwd ~3x
    mflops = 3.0 * 2.0 * Ep * cfg.d_hidden * 13 * cfg.n_layers
    return CellPlan(
        arch, shape, "train", step,
        (params_shapes, opt_shapes, batch),
        (params_sh, opt_sh, bsh),
        model_flops=mflops,
        notes=notes,
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_plan(arch: str, shape: str, mesh: Mesh, mod) -> CellPlan:
    info = mod.SHAPES[shape]
    cfg: recsys_lib.RecsysConfig = mod.full_config()
    da = data_axes(mesh)
    fa = flat_axes(mesh)
    kind = info["kind"]

    params_shapes = jax.eval_shape(
        lambda k: recsys_lib.init_params(k, cfg), SDS((2,), jnp.uint32)
    )
    params_sh = _ns(mesh, recsys_lib.param_pspecs(cfg))

    def batch_specs(B):
        if cfg.name in ("deepfm", "xdeepfm"):
            return (
                {
                    "dense": SDS((B, cfg.n_dense), jnp.float32),
                    "sparse": SDS((B, cfg.n_sparse), jnp.int32),
                    "label": SDS((B,), jnp.float32),
                },
                {
                    "dense": NamedSharding(mesh, P(da, None)),
                    "sparse": NamedSharding(mesh, P(da, None)),
                    "label": NamedSharding(mesh, P(da)),
                },
            )
        return (
            {
                "hist": SDS((B, cfg.seq_len), jnp.int32),
                "target": SDS((B,), jnp.int32),
                "label": SDS((B,), jnp.float32),
            },
            {
                "hist": NamedSharding(mesh, P(da, None)),
                "target": NamedSharding(mesh, P(da)),
                "label": NamedSharding(mesh, P(da)),
            },
        )

    # useful compute ~ 2 * dense-tower params per example (embedding gather is
    # memory, not FLOPs); train ~ 3x fwd
    tower_params = sum(
        int(np.prod(v.shape))
        for k, v in jax.tree_util.tree_leaves_with_path(params_shapes)
        if "table" not in jax.tree_util.keystr(k)
    )

    if kind == "train":
        B = info["batch"]
        ocfg = opt_lib.OptConfig(name="adamw")
        opt_shapes = jax.eval_shape(
            functools.partial(opt_lib.init_opt_state, cfg=ocfg), params_shapes
        )
        opt_sh = _ns(mesh, opt_lib.opt_state_pspecs(
            recsys_lib.param_pspecs(cfg), params_shapes, ocfg))
        step = train_loop.make_train_step(
            lambda p, b: recsys_lib.loss_fn(p, b, cfg), ocfg
        )
        batch, bsh = batch_specs(B)
        return CellPlan(
            arch, shape, kind, step,
            (params_shapes, opt_shapes, batch),
            (params_sh, opt_sh, bsh),
            model_flops=3.0 * 2.0 * tower_params * B,
            notes="table row-sharded over 'model' (DLRM)",
        )

    if kind == "serve":
        B = info["batch"]
        step = lambda params, batch: recsys_lib.serve_scores(params, batch, cfg)  # noqa: E731
        batch, bsh = batch_specs(B)
        return CellPlan(
            arch, shape, kind, step,
            (params_shapes, batch),
            (params_sh, bsh),
            model_flops=2.0 * tower_params * B,
        )

    # retrieval_cand: 1 query x N candidates, padded to shard multiple
    N = _pad_to(info["n_candidates"], 512)
    notes = f"candidates padded {info['n_candidates']}->{N}"
    if cfg.name in ("deepfm", "xdeepfm"):
        batch = {
            "dense": SDS((1, cfg.n_dense), jnp.float32),
            "sparse": SDS((1, cfg.n_sparse), jnp.int32),
            "cand": SDS((N,), jnp.int32),
        }
        bsh = {
            "dense": NamedSharding(mesh, P(None, None)),
            "sparse": NamedSharding(mesh, P(None, None)),
            "cand": NamedSharding(mesh, P(fa)),
        }
        step = lambda params, batch: recsys_lib.ctr_retrieval_scores(params, batch, cfg)  # noqa: E731
        mflops = 2.0 * tower_params * N
    elif cfg.name == "bst":
        batch = {
            "hist": SDS((1, cfg.seq_len), jnp.int32),
            "cand": SDS((N,), jnp.int32),
        }
        bsh = {
            "hist": NamedSharding(mesh, P(None, None)),
            "cand": NamedSharding(mesh, P(fa)),
        }
        step = lambda params, batch: recsys_lib.bst_retrieval_scores(params, batch, cfg)  # noqa: E731
        mflops = 2.0 * tower_params * N
    else:  # mind: interests once, then a (N, D) x (D, K) GEMM
        batch = {
            "hist": SDS((1, cfg.seq_len), jnp.int32),
            "candidates": SDS((N, cfg.embed_dim), jnp.float32),
        }
        bsh = {
            "hist": NamedSharding(mesh, P(None, None)),
            "candidates": NamedSharding(mesh, P(fa, None)),
        }
        step = lambda params, batch: recsys_lib.retrieval_scores(
            params, batch["hist"], batch["candidates"], cfg)  # noqa: E731
        mflops = 2.0 * N * cfg.embed_dim * cfg.n_interests
        notes += "; two-tower dot (ANN alternative: serve/retrieval.py)"
    return CellPlan(
        arch, shape, kind, step,
        (params_shapes, batch),
        (params_sh, bsh),
        model_flops=mflops,
        notes=notes,
    )


# ---------------------------------------------------------------------------
# k-NN (the paper) cells
# ---------------------------------------------------------------------------


def _knn_plan(arch: str, shape: str, mesh: Mesh, mod) -> CellPlan:
    from repro.core import distributed as dist
    from repro.core.graph import KNNGraph

    cfg = mod.full_config()
    info = mod.SHAPES[shape]
    fa = flat_axes(mesh)
    ndev = int(np.prod([mesh.shape[a] for a in fa]))
    n_total, d = info["n_total"], info["d"]
    assert n_total % ndev == 0
    R = cfg.rev_cap or 2 * cfg.k
    g_shapes = KNNGraph(
        nbr_ids=SDS((n_total, cfg.k), jnp.int32),
        nbr_dist=SDS((n_total, cfg.k), jnp.float32),
        nbr_lam=SDS((n_total, cfg.k), jnp.int32),
        rev_ids=SDS((n_total, R), jnp.int32),
        rev_lam=SDS((n_total, R), jnp.int32),
        rev_ptr=SDS((n_total,), jnp.int32),
        alive=SDS((n_total,), jnp.bool_),
        n_valid=SDS((), jnp.int32),
        sq_norms=SDS((n_total,), jnp.float32),
        row_scale=SDS((n_total,), jnp.float32),
    )
    g_sh = _ns(mesh, dist.graph_pspec(fa))
    x_dtype = jnp.bfloat16 if getattr(cfg, "data_bf16", False) else jnp.float32
    x_shapes = SDS((n_total, d), x_dtype)
    x_sh = NamedSharding(mesh, P(fa, None))
    key_s = SDS((2,), jnp.uint32)
    key_sh = NamedSharding(mesh, P(None))

    if info["kind"] == "knn_build":
        step = dist.make_distributed_build_step(mesh, cfg)
        args = (g_shapes, x_shapes, SDS((), jnp.int32), SDS((), jnp.int32), key_s)
        shs = (g_sh, x_sh, NamedSharding(mesh, P()), NamedSharding(mesh, P()), key_sh)
        W = cfg.wave
        # useful work: one wave of W queries x (expansions x candidate dists)
        mflops = 2.0 * W * cfg.max_iters * (cfg.k + R) * d * ndev
        notes = f"per-shard online insertion, wave={W}/shard, zero-collective"
        lf = float(cfg.max_iters)
    else:
        scfg = cfg.search_config()
        step = dist.make_distributed_search(mesh, scfg)
        B = info["batch"]
        args = (g_shapes, x_shapes, SDS((B, d), jnp.float32), key_s)
        shs = (g_sh, x_sh, NamedSharding(mesh, P(None, None)), key_sh)
        mflops = 2.0 * B * scfg.max_iters * (scfg.k + R) * d * ndev
        notes = "scatter-gather EHC + tournament top-k merge"
        lf = float(cfg.max_iters)
    return CellPlan(
        arch, shape, info["kind"], step, args, shs, mflops, notes,
        loop_factor=lf,
    )


# ---------------------------------------------------------------------------


def plan(arch: str, shape: str, mesh: Mesh, opts=None) -> CellPlan:
    from repro.models import sharding as sharding_lib

    sharding_lib.set_mesh(mesh)  # activate constrain() for this mesh
    mod = configs.get(arch)
    if shape not in mod.SHAPES:
        raise KeyError(f"{arch} has no shape {shape!r}")
    fam = mod.FAMILY
    if fam == "lm":
        return _lm_plan(arch, shape, mesh, mod, opts)
    if fam == "gnn":
        return _gnn_plan(arch, shape, mesh, mod)
    if fam == "recsys":
        return _recsys_plan(arch, shape, mesh, mod)
    if fam == "knn":
        return _knn_plan(arch, shape, mesh, mod)
    raise ValueError(fam)


def lower(cell: CellPlan):
    """jit + lower (no execution, no allocation)."""
    fn = jax.jit(
        cell.step_fn,
        in_shardings=cell.in_shardings,
        donate_argnums=cell.donate_argnums,
    )
    return fn.lower(*cell.args)
