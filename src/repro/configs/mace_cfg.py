"""mace [arXiv:2206.07697]: n_layers=2 d_hidden=128 l_max=2 correlation=3
n_rbf=8, E(3) equivariance (Cartesian-tensor carrier — models/mace.py).

Four data regimes (the assigned GNN shape set): cora-size full batch,
reddit-size sampled mini-batches (real fanout 15-10 sampler), products-size
full batch, and batched small molecules (whose k-NN edges come from the
paper's own construction code — DESIGN.md §5)."""

from repro.models.mace import MACEConfig

ARCH = "mace"
FAMILY = "gnn"

SHAPES = {
    "full_graph_sm": {
        "kind": "train",
        "n_nodes": 2708,
        "n_edges": 10556,
        "d_feat": 1433,
        "n_classes": 7,
    },
    "minibatch_lg": {
        "kind": "train",
        "n_nodes": 232_965,
        "n_edges": 114_615_892,
        "batch_nodes": 1024,
        "fanout": (15, 10),
        "d_feat": 602,
        "n_classes": 41,
    },
    "ogb_products": {
        "kind": "train",
        "n_nodes": 2_449_029,
        "n_edges": 61_859_140,
        "d_feat": 100,
        "n_classes": 47,
    },
    "molecule": {
        "kind": "train",
        "n_nodes": 30,
        "n_edges": 64,
        "batch": 128,
    },
}
SKIP = {}


def full_config(shape: str = "molecule") -> MACEConfig:
    base = dict(n_layers=2, d_hidden=128, l_max=2, correlation=3, n_rbf=8)
    if shape == "molecule":
        return MACEConfig(name=ARCH, n_species=8, **base)
    s = SHAPES[shape]
    return MACEConfig(
        name=ARCH,
        n_species=1,
        d_node_feat=s["d_feat"],
        n_classes=s["n_classes"],
        **base,
    )


def smoke_config(shape: str = "molecule") -> MACEConfig:
    base = dict(n_layers=2, d_hidden=16, l_max=2, correlation=3, n_rbf=4, readout_hidden=8)
    if shape == "molecule":
        return MACEConfig(name=ARCH + "-smoke", n_species=4, **base)
    return MACEConfig(
        name=ARCH + "-smoke", n_species=1, d_node_feat=24, n_classes=5, **base
    )
