"""bst [arXiv:1905.06874] — Behavior Sequence Transformer (Alibaba):
embed_dim 32, 20-item history, 1 transformer block, 8 heads,
MLP 1024-512-256.  Item vocab 10^7 (taobao-scale)."""

from repro.configs.recsys_shapes import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

ARCH = "bst"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES
SKIP = {}


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name="bst",
        embed_dim=32,
        seq_len=20,
        n_blocks=1,
        n_heads=8,
        mlp=(1024, 512, 256),
        vocab_per_field=10_000_000,
    )


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="bst", embed_dim=16, seq_len=8, n_blocks=1, n_heads=4,
        mlp=(64, 32), vocab_per_field=512,
    )
