"""deepfm [arXiv:1703.04247]: 39 sparse fields, embed_dim 10,
MLP 400-400-400, FM interaction.  Tables: 39 x 10^6 rows (criteo-scale)."""

from repro.configs.recsys_shapes import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

ARCH = "deepfm"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES
SKIP = {}


def full_config() -> RecsysConfig:
    return RecsysConfig(
        name="deepfm",
        n_sparse=39,
        embed_dim=10,
        mlp=(400, 400, 400),
        vocab_per_field=1_000_000,
    )


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="deepfm", n_sparse=6, embed_dim=8, mlp=(32, 32), vocab_per_field=128
    )
