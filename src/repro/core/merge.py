"""Batched ``insertG``: merge a flat stream of candidate edges into k-NN lists.

This is the vectorized form of the paper's ``insertG(a, b, m(a,b), 𝒢)``.  On
a CPU each call surgically splices one node into one sorted linked list; on a
TPU we instead collect *all* candidate edges produced by a wave (OLG/LGD
construction), a local-join round (NN-Descent) or a refinement pass into flat
``(row, id, dist)`` triples and commit them in one shot:

  qualify -> dedupe -> segment-rank -> scatter to per-row buffers -> row merge

The merge is exact with respect to the final top-k content: any candidate
that sequential insertion would have kept is kept (rank-<k filtering per row
is lossless because at most k candidates can enter a k-list).  What differs
from sequential semantics is only *when* displaced entries disappear — the
same batching trade NN-Descent makes (DESIGN.md §8.1).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import segments

Array = jax.Array


class MergeResult(NamedTuple):
    nbr_ids: Array  # (cap, k) int32  merged lists
    nbr_dist: Array  # (cap, k) float32
    nbr_lam: Array  # (cap, k) int32 — carried for old entries, 0 for new
    is_new: Array  # (cap, k) bool — slot filled by this merge
    old_slot: Array  # (cap, k) int32 — original slot index if carried, -1 if new
    cand_ids: Array  # (cap, k) int32 — per-row qualified candidates (post rank-filter)
    cand_dist: Array  # (cap, k) float32
    n_inserted: Array  # () int32 — number of slots that changed


def merge_candidates(
    nbr_ids: Array,
    nbr_dist: Array,
    nbr_lam: Array,
    v: Array,
    q: Array,
    d: Array,
) -> MergeResult:
    """Commit candidate edges (v -> q with distance d) into the k-NN lists.

    Args:
      nbr_ids/nbr_dist/nbr_lam: (cap, k) graph rows (sorted ascending).
      v: (T,) int32 target rows; -1 (or any negative) = padding.
      q: (T,) int32 candidate neighbor ids.
      d: (T,) float32 distances m(v, q).

    Returns: MergeResult with merged rows and provenance masks.
    """
    cap, k = nbr_ids.shape
    v = v.astype(jnp.int32)
    q = q.astype(jnp.int32)
    d = d.astype(jnp.float32)

    # --- qualify -----------------------------------------------------------
    valid = (v >= 0) & (v < cap) & (q >= 0) & (q != v) & jnp.isfinite(d)
    vs = jnp.where(valid, v, cap)
    kth = jnp.where(valid, nbr_dist[jnp.minimum(vs, cap - 1), k - 1], -jnp.inf)
    valid &= d < kth
    # drop candidates already present in the row
    row_ids = nbr_ids[jnp.minimum(vs, cap - 1)]  # (T, k)
    present = jnp.any(row_ids == q[:, None], axis=1)
    valid &= ~present

    # --- dedupe exact (v, q) duplicates (NN-Descent emits them) ------------
    v1 = jnp.where(valid, v, cap)
    q1 = jnp.where(valid, q, cap)
    order1 = jnp.lexsort((q1, v1))
    sv1, sq1 = v1[order1], q1[order1]
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), (sv1[1:] == sv1[:-1]) & (sq1[1:] == sq1[:-1])]
    )
    dup_unsorted = jnp.zeros_like(dup).at[order1].set(dup)
    valid &= ~dup_unsorted

    # --- segment rank by (v, d), keep top-k per row -------------------------
    vv = jnp.where(valid, v, cap)
    order2 = jnp.lexsort((d, vv))
    sv = vv[order2]
    sq = q[order2]
    sd = d[order2]
    (cand_ids, cand_dist), _ = segments.grouped_top_r(
        sv, [sq, sd], [-1, jnp.inf], cap, k
    )

    # --- row-wise merge: top-k of (old ‖ candidates) ------------------------
    all_ids = jnp.concatenate([nbr_ids, cand_ids], axis=1)  # (cap, 2k)
    all_dist = jnp.concatenate([nbr_dist, cand_dist], axis=1)
    all_lam = jnp.concatenate([nbr_lam, jnp.zeros_like(nbr_lam)], axis=1)
    origin = jnp.broadcast_to(jnp.arange(2 * k, dtype=jnp.int32), (cap, 2 * k))
    # stable sort keeps old entries ahead of equal-distance candidates
    order = jnp.argsort(jnp.where(all_ids >= 0, all_dist, jnp.inf), axis=1, stable=True)
    take = lambda a: jnp.take_along_axis(a, order[:, :k], axis=1)
    m_ids = take(all_ids)
    m_dist = take(all_dist)
    m_lam = take(all_lam)
    m_origin = take(origin)
    is_new = (m_origin >= k) & (m_ids >= 0)
    old_slot = jnp.where(m_origin < k, m_origin, -1)
    m_lam = jnp.where(is_new, 0, m_lam)
    n_inserted = jnp.sum(is_new).astype(jnp.int32)
    return MergeResult(
        nbr_ids=m_ids,
        nbr_dist=m_dist,
        nbr_lam=m_lam,
        is_new=is_new,
        old_slot=old_slot,
        cand_ids=cand_ids,
        cand_dist=cand_dist,
        n_inserted=n_inserted,
    )


def append_reverse(
    rev_ids: Array,
    rev_lam: Array,
    rev_ptr: Array,
    owner: Array,
    member: Array,
    lam: Array | None = None,
) -> tuple[Array, Array, Array]:
    """Batched FIFO ring-buffer append: owner joins rev list of member.

    Args:
      rev_ids: (cap, R) ring buffers.
      rev_lam: (cap, R) forward-twin λ snapshots aligned with rev_ids.
      rev_ptr: (cap,) total-appends counters.
      owner: (T,) int32 rows that now list ``member`` in their k-NN list.
      member: (T,) int32; negative = padding.
      lam: optional (T,) int32 λ of ``member`` inside G[owner] at append
        time (the rev_lam payload); defaults to 0 (fresh edges join with
        λ = 0 per Alg. 3).

    Returns updated (rev_ids, rev_lam, rev_ptr).
    """
    cap, R = rev_ids.shape
    if lam is None:
        lam = jnp.zeros_like(owner)
    valid = (member >= 0) & (member < cap) & (owner >= 0)
    m = jnp.where(valid, member, cap)
    order = jnp.argsort(m)
    sm = m[order]
    so = jnp.where(valid, owner, -1)[order]
    sl = jnp.where(valid, lam.astype(jnp.int32), 0)[order]
    rank = segments.segment_rank(sm)
    # If more than R appends hit one member in a single wave, keep the last R
    # (FIFO overwrite — matches ring semantics of sequential appends).
    counts = segments.segment_counts(sm, cap)
    cnt_e = jnp.where(sm < cap, counts[jnp.minimum(sm, cap - 1)], 0)
    # keep only the last R appends per member so ring slots are unique within
    # one batch (deterministic FIFO overwrite)
    ok = (sm < cap) & (rank >= cnt_e - R)
    base = rev_ptr[jnp.minimum(sm, cap - 1)]
    slot = (base + rank) % R
    row = jnp.where(ok, sm, cap)
    col = jnp.where(ok, slot, 0)
    ext = jnp.concatenate([rev_ids, jnp.full((1, R), -1, jnp.int32)], axis=0)
    ext = ext.at[row, col].set(jnp.where(ok, so, -1))
    ext_l = jnp.concatenate([rev_lam, jnp.zeros((1, R), jnp.int32)], axis=0)
    ext_l = ext_l.at[row, col].set(jnp.where(ok, sl, 0))
    rev_ids = ext[:cap]
    rev_lam = ext_l[:cap]
    rev_ptr = rev_ptr + counts
    return rev_ids, rev_lam, rev_ptr
