"""Batched ``insertG``: merge a flat stream of candidate edges into k-NN lists.

This is the vectorized form of the paper's ``insertG(a, b, m(a,b), 𝒢)``.  On
a CPU each call surgically splices one node into one sorted linked list; on a
TPU we instead collect *all* candidate edges produced by a wave (OLG/LGD
construction), a local-join round (NN-Descent) or a refinement pass into flat
``(row, id, dist)`` triples and commit them in one shot:

  qualify -> dedupe -> segment-rank -> scatter to per-row buffers -> row merge

The merge is exact with respect to the final top-k content: any candidate
that sequential insertion would have kept is kept (rank-<k filtering per row
is lossless because at most k candidates can enter a k-list).  What differs
from sequential semantics is only *when* displaced entries disappear — the
same batching trade NN-Descent makes (DESIGN.md §8.1).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import segments

Array = jax.Array

# Second-hop expansion width for merge proposals: only the nearest HOP_TOP
# cross-search hits donate their neighbor lists.  Proposal volume (and the
# two full lexsorts inside ``merge_candidates``) scales linearly with this;
# the recall contribution concentrates in the first few hits' neighborhoods,
# so a small cap keeps the k² candidate quality at a fraction of the cost.
HOP_TOP = 20


class MergeResult(NamedTuple):
    nbr_ids: Array  # (cap, k) int32  merged lists
    nbr_dist: Array  # (cap, k) float32
    nbr_lam: Array  # (cap, k) int32 — carried for old entries, 0 for new
    is_new: Array  # (cap, k) bool — slot filled by this merge
    old_slot: Array  # (cap, k) int32 — original slot index if carried, -1 if new
    cand_ids: Array  # (cap, k) int32 — per-row qualified candidates (post rank-filter)
    cand_dist: Array  # (cap, k) float32
    n_inserted: Array  # () int32 — number of slots that changed


def merge_candidates(
    nbr_ids: Array,
    nbr_dist: Array,
    nbr_lam: Array,
    v: Array,
    q: Array,
    d: Array,
) -> MergeResult:
    """Commit candidate edges (v -> q with distance d) into the k-NN lists.

    Args:
      nbr_ids/nbr_dist/nbr_lam: (cap, k) graph rows (sorted ascending).
      v: (T,) int32 target rows; -1 (or any negative) = padding.
      q: (T,) int32 candidate neighbor ids.
      d: (T,) float32 distances m(v, q).

    Returns: MergeResult with merged rows and provenance masks.
    """
    cap, k = nbr_ids.shape
    v = v.astype(jnp.int32)
    q = q.astype(jnp.int32)
    d = d.astype(jnp.float32)

    # --- qualify -----------------------------------------------------------
    valid = (v >= 0) & (v < cap) & (q >= 0) & (q != v) & jnp.isfinite(d)
    vs = jnp.where(valid, v, cap)
    kth = jnp.where(valid, nbr_dist[jnp.minimum(vs, cap - 1), k - 1], -jnp.inf)
    valid &= d < kth
    # drop candidates already present in the row
    row_ids = nbr_ids[jnp.minimum(vs, cap - 1)]  # (T, k)
    present = jnp.any(row_ids == q[:, None], axis=1)
    valid &= ~present

    # --- dedupe exact (v, q) duplicates (NN-Descent emits them) ------------
    v1 = jnp.where(valid, v, cap)
    q1 = jnp.where(valid, q, cap)
    order1 = jnp.lexsort((q1, v1))
    sv1, sq1 = v1[order1], q1[order1]
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), (sv1[1:] == sv1[:-1]) & (sq1[1:] == sq1[:-1])]
    )
    dup_unsorted = jnp.zeros_like(dup).at[order1].set(dup)
    valid &= ~dup_unsorted

    # --- segment rank by (v, d), keep top-k per row -------------------------
    vv = jnp.where(valid, v, cap)
    order2 = jnp.lexsort((d, vv))
    sv = vv[order2]
    sq = q[order2]
    sd = d[order2]
    (cand_ids, cand_dist), _ = segments.grouped_top_r(
        sv, [sq, sd], [-1, jnp.inf], cap, k
    )

    # --- row-wise merge: top-k of (old ‖ candidates) ------------------------
    all_ids = jnp.concatenate([nbr_ids, cand_ids], axis=1)  # (cap, 2k)
    all_dist = jnp.concatenate([nbr_dist, cand_dist], axis=1)
    all_lam = jnp.concatenate([nbr_lam, jnp.zeros_like(nbr_lam)], axis=1)
    origin = jnp.broadcast_to(jnp.arange(2 * k, dtype=jnp.int32), (cap, 2 * k))
    # stable sort keeps old entries ahead of equal-distance candidates
    order = jnp.argsort(jnp.where(all_ids >= 0, all_dist, jnp.inf), axis=1, stable=True)
    take = lambda a: jnp.take_along_axis(a, order[:, :k], axis=1)
    m_ids = take(all_ids)
    m_dist = take(all_dist)
    m_lam = take(all_lam)
    m_origin = take(origin)
    is_new = (m_origin >= k) & (m_ids >= 0)
    old_slot = jnp.where(m_origin < k, m_origin, -1)
    m_lam = jnp.where(is_new, 0, m_lam)
    n_inserted = jnp.sum(is_new).astype(jnp.int32)
    return MergeResult(
        nbr_ids=m_ids,
        nbr_dist=m_dist,
        nbr_lam=m_lam,
        is_new=is_new,
        old_slot=old_slot,
        cand_ids=cand_ids,
        cand_dist=cand_dist,
        n_inserted=n_inserted,
    )


def append_reverse(
    rev_ids: Array,
    rev_lam: Array,
    rev_ptr: Array,
    owner: Array,
    member: Array,
    lam: Array | None = None,
) -> tuple[Array, Array, Array]:
    """Batched FIFO ring-buffer append: owner joins rev list of member.

    Args:
      rev_ids: (cap, R) ring buffers.
      rev_lam: (cap, R) forward-twin λ snapshots aligned with rev_ids.
      rev_ptr: (cap,) total-appends counters.
      owner: (T,) int32 rows that now list ``member`` in their k-NN list.
      member: (T,) int32; negative = padding.
      lam: optional (T,) int32 λ of ``member`` inside G[owner] at append
        time (the rev_lam payload); defaults to 0 (fresh edges join with
        λ = 0 per Alg. 3).

    Returns updated (rev_ids, rev_lam, rev_ptr).
    """
    cap, R = rev_ids.shape
    if lam is None:
        lam = jnp.zeros_like(owner)
    valid = (member >= 0) & (member < cap) & (owner >= 0)
    m = jnp.where(valid, member, cap)
    order = jnp.argsort(m)
    sm = m[order]
    so = jnp.where(valid, owner, -1)[order]
    sl = jnp.where(valid, lam.astype(jnp.int32), 0)[order]
    rank = segments.segment_rank(sm)
    # If more than R appends hit one member in a single wave, keep the last R
    # (FIFO overwrite — matches ring semantics of sequential appends).
    counts = segments.segment_counts(sm, cap)
    cnt_e = jnp.where(sm < cap, counts[jnp.minimum(sm, cap - 1)], 0)
    # keep only the last R appends per member so ring slots are unique within
    # one batch (deterministic FIFO overwrite)
    ok = (sm < cap) & (rank >= cnt_e - R)
    base = rev_ptr[jnp.minimum(sm, cap - 1)]
    slot = (base + rank) % R
    row = jnp.where(ok, sm, cap)
    col = jnp.where(ok, slot, 0)
    ext = jnp.concatenate([rev_ids, jnp.full((1, R), -1, jnp.int32)], axis=0)
    ext = ext.at[row, col].set(jnp.where(ok, so, -1))
    ext_l = jnp.concatenate([rev_lam, jnp.zeros((1, R), jnp.int32)], axis=0)
    ext_l = ext_l.at[row, col].set(jnp.where(ok, sl, 0))
    rev_ids = ext[:cap]
    rev_lam = ext_l[:cap]
    rev_ptr = rev_ptr + counts
    return rev_ids, rev_lam, rev_ptr


# ---------------------------------------------------------------------------
# Symmetric sub-graph merge (divide-and-conquer construction)
# ---------------------------------------------------------------------------


def stack_subgraphs(g_a, g_b, n_a: int):
    """Concatenate two fully-allocated sub-graphs into one id space.

    ``g_a`` covers global rows [0, n_a) and ``g_b`` LOCAL rows [0, n_b) that
    become global rows [n_a, n_a + n_b).  Forward lists are remapped by
    offset; the reverse side is left empty (callers rebuild it canonically
    via ``graph.rebuild_reverse`` after cross edges land).  The norm cache is
    *gathered* (concatenated), never recomputed — the cache owners already
    paid for it.
    """
    n_b = g_b.capacity
    if int(g_a.n_valid) != g_a.capacity or int(g_b.n_valid) != n_b:
        raise ValueError(
            "stack_subgraphs needs fully-allocated sub-graphs "
            f"(n_valid == capacity); got {int(g_a.n_valid)}/{g_a.capacity} "
            f"and {int(g_b.n_valid)}/{n_b} — compact first"
        )
    return _stack_core(g_a, g_b)


def _stack_core(g_a, g_b):
    """Traceable body of ``stack_subgraphs`` (shapes carry the capacities,
    so the concatenation works identically under jit/shard_map — the host
    wrapper keeps the fully-allocated precondition check)."""
    from repro.core.graph import KNNGraph  # graph does not import merge

    n_a = g_a.nbr_ids.shape[0]
    n_b = g_b.nbr_ids.shape[0]
    b_ids = jnp.where(g_b.nbr_ids >= 0, g_b.nbr_ids + n_a, -1)
    R = max(g_a.rev_capacity, g_b.rev_capacity)
    cap = n_a + n_b
    return KNNGraph(
        nbr_ids=jnp.concatenate([g_a.nbr_ids, b_ids]),
        nbr_dist=jnp.concatenate([g_a.nbr_dist, g_b.nbr_dist]),
        nbr_lam=jnp.concatenate([g_a.nbr_lam, g_b.nbr_lam]),
        rev_ids=jnp.full((cap, R), -1, jnp.int32),
        rev_lam=jnp.zeros((cap, R), jnp.int32),
        rev_ptr=jnp.zeros((cap,), jnp.int32),
        alive=jnp.concatenate([g_a.alive, g_b.alive]),
        n_valid=jnp.asarray(cap, jnp.int32),
        sq_norms=jnp.concatenate([g_a.sq_norms, g_b.sq_norms]),
        row_scale=jnp.concatenate([g_a.row_scale, g_b.row_scale]),
    )


def _chunked_cross_search(g, xg, queries, key, scfg, chunk: int, coarse=None):
    """Search ``queries`` against sub-graph ``g`` in fixed-size chunks.

    Chunking bounds the (B, hash_slots) visited tables AND pins the jitted
    search (``core.search.search`` is already jit-compiled over static cfg)
    to one batch shape per merge — the last chunk is padded, not
    specialized.  Returns (ids (B, k) LOCAL, dists (B, k), n_comps int).
    Comps accumulate as a host int: per-chunk counts fit int32 comfortably
    (chunk * C * max_iters), but a whole production-scale merge does not —
    the same 2^31 wrap Counter64 exists to prevent in the wave pipeline.
    """
    from repro.core import search as search_lib  # search never imports merge

    import dataclasses

    if coarse is None and scfg.seed_mode == "coarse":
        # no level for this sub-graph's id space — fall back to random seeds
        scfg = dataclasses.replace(scfg, seed_mode="random")
    B = queries.shape[0]
    nchunks = -(-B // chunk)
    qp = jnp.pad(queries, ((0, nchunks * chunk - B), (0, 0)))
    ids, dists, comps = [], [], 0
    for i in range(nchunks):
        res = search_lib.search(
            g, xg, qp[i * chunk : (i + 1) * chunk],
            jax.random.fold_in(key, i), scfg, coarse=coarse,
        )
        ids.append(res.ids)
        dists.append(res.dists)
        comps += int(jnp.sum(res.n_comps))
    return jnp.concatenate(ids)[:B], jnp.concatenate(dists)[:B], comps


def merge_commit_core(
    g_a, g_b, xa, xb, ab_ids, ab_d, ba_ids, ba_d, metric, dispatch,
    hop_top=HOP_TOP,
):
    """Traceable merge commit: stack + proposals + candidate commit + reverse.

    The single implementation behind the host path (jitted as
    ``_merge_commit``) and the mesh fold (inlined into ``distributed
    .merge_pairs_mesh``'s shard_map body).  Cross-search hits come in as
    ``ab_ids``/``ab_d`` ((n_a, k), b-LOCAL ids: a's points vs g_b) and
    ``ba_ids``/``ba_d`` ((n_b, k), a's ids — already the global [0, n_a)
    space).  On top of the hits, each direction proposes the hits' own
    neighbor lists through ``ops.merge_proposals`` (second-hop candidates,
    distances via the one blocked engine), every pair goes in both
    directions, and ``merge_candidates`` re-selects the joint top-k.

    Returns (merged KNNGraph, hop-proposal comps () int32 — the cross-search
    comps are the caller's, hop distances are charged here).
    """
    from repro.core import graph as graph_lib
    from repro.kernels import ops

    n_a, n_b = xa.shape[0], xb.shape[0]
    stacked = _stack_core(g_a, g_b)

    # second-hop proposals: the hits' own neighbor lists, blocked engine
    ab_hop, ab_hop_d, c_ab = ops.merge_proposals(
        xa, xb, ab_ids, g_b.nbr_ids, g_b.alive, metric,
        dispatch=dispatch, sq_norms=g_b.sq_norms, hop_top=hop_top,
    )
    ba_hop, ba_hop_d, c_ba = ops.merge_proposals(
        xb, xa, ba_ids, g_a.nbr_ids, g_a.alive, metric,
        dispatch=dispatch, sq_norms=g_a.sq_norms, hop_top=hop_top,
    )

    # per-query pre-selection: of the h·k_t hop lanes only the best 2k can
    # matter (at most k enter the query's own list; the surplus k keeps the
    # reverse direction rich).  This caps the global candidate sort inside
    # ``merge_candidates`` — its two full lexsorts are the commit's dominant
    # cost — at O(n·k) instead of O(n·h·k_t).
    k = g_a.nbr_ids.shape[1]
    if ab_hop.shape[1] > 2 * k:
        ab_hop_d, ab_hop = ops.topk_smallest(ab_hop_d, ab_hop, 2 * k)
        ba_hop_d, ba_hop = ops.topk_smallest(ba_hop_d, ba_hop, 2 * k)

    # a dead row must not receive or donate edges (search already masks dead
    # *targets*; this masks dead *queries*)
    def rows_for(side_lo, live, like):
        r = jnp.arange(like.shape[0], dtype=jnp.int32) + side_lo
        r = jnp.broadcast_to(r[:, None], like.shape)
        return jnp.where(live[:, None], r, -1)

    to_global_b = lambda ids: jnp.where(ids >= 0, ids + n_a, -1)
    # (query rows, candidate ids GLOBAL, distances) per proposal family
    families = (
        (rows_for(0, g_a.alive, ab_ids), to_global_b(ab_ids), ab_d),
        (rows_for(n_a, g_b.alive, ba_ids), ba_ids, ba_d),
        (rows_for(0, g_a.alive, ab_hop), to_global_b(ab_hop), ab_hop_d),
        (rows_for(n_a, g_b.alive, ba_hop), ba_hop, ba_hop_d),
    )
    # both directions for every pair: (row -> cand, d) and (cand -> row, d)
    v = jnp.concatenate(
        [r.reshape(-1) for r, _, _ in families]
        + [c.reshape(-1) for _, c, _ in families]
    )
    q = jnp.concatenate(
        [c.reshape(-1) for _, c, _ in families]
        + [r.reshape(-1) for r, _, _ in families]
    )
    d = jnp.concatenate([dd.reshape(-1) for _, _, dd in families] * 2)
    # a pair with either end masked is dropped entirely (q < 0 or v < 0)
    v = jnp.where((q >= 0) & (v >= 0), v, -1)

    mres = merge_candidates(
        stacked.nbr_ids, stacked.nbr_dist, stacked.nbr_lam, v, q, d
    )
    merged = stacked._replace(
        nbr_ids=mres.nbr_ids,
        nbr_dist=mres.nbr_dist,
        nbr_lam=mres.nbr_lam,
    )
    return graph_lib.rebuild_reverse(merged), c_ab + c_ba


_merge_commit = jax.jit(
    merge_commit_core, static_argnames=("metric", "dispatch", "hop_top")
)


def symmetric_merge(
    g_a,
    g_b,
    x: Array,
    scfg,
    key: Optional[Array] = None,
    *,
    search_chunk: int = 512,
    coarse_a=None,
    coarse_b=None,
):
    """Merge two independently built sub-graphs into one graph (1908.00814).

    ``g_a`` covers rows [0, n_a) of ``x`` (ids already global for the fold),
    ``g_b`` covers x[n_a:] in LOCAL ids.  The merge is symmetric: each side's
    points search the *other* side's graph (cross-graph candidate generation
    out of each side's lists, distances through the blocked engine the search
    already rides, norm caches gathered from the sub-graphs — never
    recomputed), every cross pair is proposed in both directions, and
    ``merge_candidates`` re-selects the joint top-k per row over
    (own list ‖ cross candidates).  Reverse lists and their ``rev_lam``
    snapshots are rebuilt canonically from the merged forward lists via the
    segmented-scan core (``graph.rebuild_reverse``).

    Dead rows neither search nor receive edges: a removed sample must not
    re-enter anyone's list through a merge.

    Args:
      g_a, g_b: fully-allocated sub-graphs (compact churned shards first).
      x: (n_a + n_b, d) combined data, sub-graph order.
      scfg: ``search.SearchConfig`` for the cross searches (k = graph degree).
      key: PRNG key for search entry points.
      search_chunk: cross-search batch size (bounds memory + compile count).
      coarse_a, coarse_b: optional ``core.hierarchy.CoarseLevel`` per side,
        in that side's LOCAL id space — the cross searches then seed
        coarsely (``scfg.seed_mode == "coarse"``); a side without a level
        falls back to random seeding.

    Returns:
      (merged KNNGraph, n_comps) — comps spent on cross candidate distances
      plus the second-hop proposal distances (``ops.merge_proposals``).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    n_a = g_a.capacity
    n_b = g_b.capacity
    if x.shape[0] != n_a + n_b:
        raise ValueError(f"x has {x.shape[0]} rows, graphs cover {n_a + n_b}")
    if int(g_a.n_valid) != n_a or int(g_b.n_valid) != n_b:
        # host-cheap; checked BEFORE the expensive cross searches (the same
        # precondition aborts stack_subgraphs, but only after the searches)
        raise ValueError(
            "symmetric_merge needs fully-allocated sub-graphs "
            f"(n_valid == capacity); got {int(g_a.n_valid)}/{n_a} and "
            f"{int(g_b.n_valid)}/{n_b} — compact/trim first"
        )
    xa, xb = x[:n_a], x[n_a:]
    ka, kb = jax.random.split(key)

    # cross-graph candidates: each side's points walk the other side's graph
    ab_ids, ab_d, comps_a = _chunked_cross_search(
        g_b, xb, xa, ka, scfg, search_chunk, coarse=coarse_b
    )
    ba_ids, ba_d, comps_b = _chunked_cross_search(
        g_a, xa, xb, kb, scfg, search_chunk, coarse=coarse_a
    )

    # one jitted commit: stack + second-hop proposals + candidate merge +
    # reverse rebuild stay on-device (no per-pair eager dispatch)
    merged, hop_comps = _merge_commit(
        g_a, g_b, xa, xb, ab_ids, ab_d, ba_ids, ba_d,
        metric=scfg.metric, dispatch=scfg.dispatch,
    )
    return merged, comps_a + comps_b + int(hop_comps)


def _pairs_mesh_ready(pairs, mesh) -> bool:
    """A fold level can go mesh-resident iff every pair has identical leaf
    shapes (shard_map stacks them) and there are enough devices."""
    if mesh is None or len(pairs) > int(mesh.devices.size):
        return False

    def shape_sig(node):
        g = node[0]
        return (g.capacity, g.k, g.rev_capacity)

    a0 = shape_sig(pairs[0][0])
    b0 = shape_sig(pairs[0][1])
    return all(
        shape_sig(a) == a0 and shape_sig(b) == b0 for a, b in pairs
    )


def merge_subgraphs(
    graphs,
    x: Array,
    scfg,
    key: Optional[Array] = None,
    *,
    search_chunk: int = 512,
    coarses=None,
    mesh=None,
):
    """Fold S adjacent sub-graphs into one via a balanced pairwise merge tree.

    ``graphs[s]`` covers (in LOCAL ids) the s-th contiguous block of ``x``,
    block sizes given by each graph's capacity.  Adjacent pairs merge with
    ``symmetric_merge`` level by level — O(log S) cross-searches per point
    instead of the O(S) a left-to-right fold costs (shard 0's points would
    re-search every later shard) — and the merges within a level run on
    host threads, or mesh-resident under ``shard_map`` when ``mesh`` is
    given (``distributed.merge_pairs_mesh``, one pair per device; a level
    whose pair shapes disagree or outnumber the devices falls back to host
    threads).

    ``coarses`` (optional, aligned with ``graphs``, entries may be None)
    supplies each leaf's ``core.hierarchy.CoarseLevel`` for the level-0
    cross searches.  Each merged intermediate then gets a FOLDED level
    (``hierarchy.fold_coarse`` — the two sides' landmark graphs merged by
    this same ``symmetric_merge``, members remapped by the block offset), so
    every deeper fold level seeds coarsely too, and the root level rides
    out to the caller instead of being re-derived from scratch.

    Returns (merged KNNGraph over all of x, total cross-search + fold
    comps, root CoarseLevel or None).
    """
    import concurrent.futures

    from repro.core import hierarchy  # late: hierarchy imports merge

    if not graphs:
        raise ValueError("merge_subgraphs needs at least one sub-graph")
    if coarses is not None and len(coarses) != len(graphs):
        raise ValueError(
            f"coarses has {len(coarses)} entries for {len(graphs)} sub-graphs"
        )
    if sum(g.capacity for g in graphs) != x.shape[0]:
        raise ValueError(
            f"sub-graphs cover {sum(g.capacity for g in graphs)} rows, "
            f"x has {x.shape[0]}"
        )
    if key is None:
        key = jax.random.PRNGKey(0)
    # (graph, lo, hi, coarse): graph covers x[lo:hi] in slice-local ids.
    # Merging adjacent pairs keeps every node contiguous, so the final
    # graph's ids are exactly the row indices of x.
    nodes = []
    off = 0
    for s, g in enumerate(graphs):
        nodes.append((g, off, off + g.capacity, coarses[s] if coarses else None))
        off += g.capacity
    total_comps = 0
    level = 0
    while len(nodes) > 1:
        pairs = [
            (nodes[i], nodes[i + 1]) for i in range(0, len(nodes) - 1, 2)
        ]
        carry = [nodes[-1]] if len(nodes) % 2 else []
        pair_keys = [
            jax.random.fold_in(key, (level << 16) | i)
            for i in range(len(pairs))
        ]

        if _pairs_mesh_ready(pairs, mesh):
            from repro.core import distributed  # late: imports construct

            pair_coarses = [(a[3], b[3]) for a, b in pairs]
            if any(ca is None or cb is None for ca, cb in pair_coarses):
                pair_coarses = None
            merged_graphs, c = distributed.merge_pairs_mesh(
                [(a[0], b[0]) for a, b in pairs],
                [x[a[1] : b[2]] for a, b in pairs],
                scfg,
                pair_keys,
                coarses=pair_coarses,
            )
            merged = [
                (g, None) for g in merged_graphs
            ]
            total_comps += c
        else:

            def _merge_pair(item):
                i, ((ga, lo, mid, ca), (gb, mid2, hi, cb)) = item
                assert mid == mid2
                return symmetric_merge(
                    ga, gb, x[lo:hi], scfg, pair_keys[i],
                    search_chunk=search_chunk,
                    coarse_a=ca, coarse_b=cb,
                )

            with concurrent.futures.ThreadPoolExecutor(
                max_workers=len(pairs)
            ) as ex:
                merged = list(ex.map(_merge_pair, enumerate(pairs)))
            total_comps += sum(c for _, c in merged)

        # fold the coarse levels host-side (landmark graphs are tiny): the
        # merged intermediate seeds the NEXT level's cross searches coarsely
        out = []
        for i, ((ga, lo, mid, ca), (gb, _, hi, cb)) in enumerate(pairs):
            lvl, cc = hierarchy.fold_coarse(
                ca, cb, mid - lo, scfg, jax.random.fold_in(pair_keys[i], 7)
            )
            total_comps += cc
            out.append((merged[i][0], lo, hi, lvl))
        nodes = out + carry
        level += 1
    return nodes[0][0], total_comps, nodes[0][3]
