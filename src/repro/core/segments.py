"""Segmented-scan / group-by primitives — the shared core of every batched
commit in this codebase.

Four subsystems used to carry private copies of the same sort-based group-by
idiom: ``graph.rebuild_reverse`` (edges grouped by member), ``merge`` (wave
candidates grouped by target row), ``nndescent._reverse_sample`` (reverse
lists grouped by neighbor) and ``models.moe`` (token routing grouped by
expert).  All of them reduce to: sort a key column, find segment boundaries,
rank elements within their segment, and scatter the first R per segment into
a dense (num_segments, R) buffer.

This module is that idiom, written once against stable JAX primitives
(``jax.lax.associative_scan`` — the old copies used ``jnp.maximum.accumulate``
which no longer exists).  Conventions:

* key columns are **sorted ascending**; callers sort first (``jnp.argsort`` /
  ``jnp.lexsort``) because they usually need the permutation anyway;
* invalid/padding entries use a **sentinel key >= num_segments** so they sort
  to the tail and scatter with ``mode="drop"``.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def segment_starts(sorted_keys: Array) -> Array:
    """(T,) sorted keys -> (T,) bool, True where a new segment begins."""
    return jnp.concatenate(
        [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]]
    )


def running_max(values: Array) -> Array:
    """Inclusive prefix maximum along axis 0 (associative scan)."""
    return jax.lax.associative_scan(jnp.maximum, values)


def running_min(values: Array) -> Array:
    """Inclusive prefix minimum along axis 0 (associative scan)."""
    return jax.lax.associative_scan(jnp.minimum, values)


def _segmented_combine(op):
    """Combiner for (start_flag, value) pairs: reset the scan at segment
    starts.  Classic segmented-scan construction (Blelloch)."""

    def combine(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb, vb, op(va, vb))

    return combine


def segment_max(values: Array, starts: Array) -> Array:
    """Inclusive running max within each segment (reset at ``starts``)."""
    _, out = jax.lax.associative_scan(
        _segmented_combine(jnp.maximum), (starts, values)
    )
    return out


def segment_min(values: Array, starts: Array) -> Array:
    """Inclusive running min within each segment (reset at ``starts``)."""
    _, out = jax.lax.associative_scan(
        _segmented_combine(jnp.minimum), (starts, values)
    )
    return out


def segment_rank(sorted_keys: Array) -> Array:
    """Rank (0-based) of each element within its run of equal keys.

    ``sorted_keys`` must be sorted ascending; padding sentinels form their own
    tail segment and rank normally (callers mask them out).
    """
    idx = jnp.arange(sorted_keys.shape[0])
    starts = segment_starts(sorted_keys)
    seg_start = running_max(jnp.where(starts, idx, 0))
    return (idx - seg_start).astype(jnp.int32)


def segment_counts(sorted_keys: Array, num_segments: int) -> Array:
    """(num_segments,) occurrence count per key; keys >= num_segments dropped."""
    valid = sorted_keys < num_segments
    return jax.ops.segment_sum(
        valid.astype(jnp.int32),
        jnp.where(valid, sorted_keys, num_segments),
        num_segments=num_segments + 1,
    )[:num_segments].astype(jnp.int32)


def mask_row_duplicates(ids: Array) -> Array:
    """(B, C) int ids -> (B, C) bool, True at every later copy of an id >= 0.

    The batched row-local form of the sort-based dedupe idiom: stable-sort
    each row, mark adjacent equal runs past their first element, and scatter
    the marks back through the permutation.  Replaces the O(C²) pairwise
    ``triu`` masks the search layer used to build — same keep-the-earliest
    semantics (stable sort preserves original order within equal runs),
    O(C log C) work and O(B·C) memory.  Negative ids (padding) are never
    marked.
    """
    B, C = ids.shape
    order = jnp.argsort(ids, axis=1, stable=True)
    s = jnp.take_along_axis(ids, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((B, 1), bool), (s[:, 1:] == s[:, :-1]) & (s[:, 1:] >= 0)],
        axis=1,
    )
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
    return jnp.zeros((B, C), bool).at[rows, order].set(dup_sorted)


def grouped_top_r(
    sorted_keys: Array,
    payloads: Sequence[Array],
    fills: Sequence,
    num_segments: int,
    r: int,
    *,
    keep: Array | None = None,
) -> tuple[list[Array], Array]:
    """Scatter the first ``r`` elements of each segment into dense buffers.

    Args:
      sorted_keys: (T,) int32 segment ids, sorted ascending; >= num_segments
        is padding.
      payloads: sequence of (T,) arrays to scatter, aligned with the keys.
      fills: fill value per payload (buffer background / padding value).
      num_segments: number of output rows.
      r: row width — elements ranked >= r within their segment are dropped.
      keep: optional (T,) bool of extra per-element drops (applied on top of
        the rank filter).

    Returns:
      (buffers, counts): one (num_segments, r) buffer per payload, and the
      (num_segments,) total occurrence count per segment (NOT capped at r —
      ring-buffer callers need the uncapped count).
    """
    rank = segment_rank(sorted_keys)
    ok = (sorted_keys < num_segments) & (rank < r)
    if keep is not None:
        ok &= keep
    row = jnp.where(ok, sorted_keys, num_segments)
    col = jnp.where(ok, rank, 0)
    buffers = []
    for payload, fill in zip(payloads, fills):
        buf = jnp.full((num_segments + 1, r), fill, payload.dtype)
        buf = buf.at[row, col].set(jnp.where(ok, payload, fill), mode="drop")
        buffers.append(buf[:num_segments])
    counts = segment_counts(sorted_keys, num_segments)
    return buffers, counts
