"""Distance metrics for k-NN graph construction and search.

The paper's central "generic" claim is that OLG/LGD make no assumption about
the metric beyond it being computable pairwise.  Everything in ``repro.core``
is therefore written against this registry; adding a metric here makes it
available to brute force, EHC search, OLG/LGD construction, NN-Descent and the
benchmarks alike.

Conventions
-----------
* Smaller distance == closer (the paper's convention, footnote 1).
* ``l2`` is the *squared* euclidean distance.  Squaring is monotone, so every
  ordering-based quantity (k-NN lists, recalls, occlusion comparisons between
  distances) is unchanged while the MXU-friendly ``|q|^2 + |x|^2 - 2 q.x``
  expansion stays a single matmul.  Benchmarks that report raw distances
  sqrt() at the edge.
* ``chi2`` assumes non-negative inputs (BoVW histograms, the paper's NUSW
  setting).
"""

from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array

# metric name -> (pairwise_fn, needs_matmul)
_REGISTRY: Dict[str, Callable[[Array, Array], Array]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def names():
    return sorted(_REGISTRY)


def pairwise(metric: str, q: Array, x: Array) -> Array:
    """All-pairs distances.

    Args:
      metric: registry key ("l2", "l1", "cosine", "chi2", "ip").
      q: (m, d) queries.
      x: (n, d) points.

    Returns:
      (m, n) distances, float32.
    """
    if metric not in _REGISTRY:
        raise KeyError(f"unknown metric {metric!r}; have {names()}")
    return _REGISTRY[metric](q, x)


def one_to_many(metric: str, q: Array, x: Array) -> Array:
    """(d,) query vs (n, d) points -> (n,) distances."""
    return pairwise(metric, q[None, :], x)[0]


@register("l2")
def _l2(q: Array, x: Array) -> Array:
    # Squared euclidean via the matmul expansion: hits the MXU on TPU and is
    # the form the Pallas kernel implements.  max(., 0) guards the tiny
    # negative residue of the expansion in low precision.
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # (m, 1)
    xn = jnp.sum(x * x, axis=-1, keepdims=True).T  # (1, n)
    d = qn + xn - 2.0 * (q @ x.T)
    return jnp.maximum(d, 0.0)


@register("ip")
def _ip(q: Array, x: Array) -> Array:
    # Negative inner product (so that smaller == closer holds).
    return -(q.astype(jnp.float32) @ x.astype(jnp.float32).T)


@register("cosine")
def _cosine(q: Array, x: Array) -> Array:
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    return 1.0 - qn @ xn.T


@register("l1")
def _l1(q: Array, x: Array) -> Array:
    # VPU-bound: no matmul form exists.  Blocked over the feature axis to keep
    # the (m, n, d_block) broadcast bounded; XLA fuses the abs/sum.
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    m, d = q.shape
    n = x.shape[0]
    block = 128 if d > 128 else d
    nblk = -(-d // block)
    pad = nblk * block - d
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        x = jnp.pad(x, ((0, 0), (0, pad)))
    qb = q.reshape(m, nblk, block)
    xb = x.reshape(n, nblk, block)

    def body(c, i):
        c = c + jnp.sum(
            jnp.abs(qb[:, i, None, :] - xb[None, :, i, :]), axis=-1
        )
        return c, None

    out, _ = jax.lax.scan(body, jnp.zeros((m, n), jnp.float32), jnp.arange(nblk))
    return out


@register("chi2")
def _chi2(q: Array, x: Array) -> Array:
    # chi^2 distance for histograms: sum (q - x)^2 / (q + x), with the usual
    # 0/0 -> 0 convention.
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    m, d = q.shape
    n = x.shape[0]
    block = 128 if d > 128 else d
    nblk = -(-d // block)
    pad = nblk * block - d
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        x = jnp.pad(x, ((0, 0), (0, pad)))
    qb = q.reshape(m, nblk, block)
    xb = x.reshape(n, nblk, block)

    def body(c, i):
        qq = qb[:, i, None, :]
        xx = xb[None, :, i, :]
        num = (qq - xx) ** 2
        den = qq + xx
        c = c + jnp.sum(jnp.where(den > 1e-12, num / jnp.maximum(den, 1e-12), 0.0), axis=-1)
        return c, None

    out, _ = jax.lax.scan(body, jnp.zeros((m, n), jnp.float32), jnp.arange(nblk))
    return out


@functools.lru_cache(maxsize=None)
def is_matmul_metric(metric: str) -> bool:
    """True when the metric reduces to a GEMM (MXU-eligible on TPU)."""
    return metric in ("l2", "ip", "cosine")
