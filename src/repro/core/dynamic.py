"""Dynamic updates — §IV-C: samples join and leave the graph online.

Insertion *is* the construction step (Alg. 2/3): ``insert`` simply runs more
waves against the existing graph, so an open set (the paper's Flickr / object
-tracking / e-shopping scenarios) is supported by the same code path as the
initial build — no separate machinery, no reconstruction.

Removal follows the paper exactly:
  * drop the row (k-NN list released, ``alive`` cleared);
  * purge the sample from the reverse side (its reverse list tells us which
    rows reference it; we additionally sweep all lists since ring-buffer
    reverse lists are bounded — DESIGN.md §8.2);
  * LGD λ repair: per the paper, only samples ranked *after* the removed one
    in each affected list need their λ updated (undo of Rule 3) — ~k²/2
    distance computations per removal on average, recomputed on the spot.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import construct as construct_lib
from repro.core import metrics as metrics_lib
from repro.core import search as search_lib
from repro.core.graph import KNNGraph

Array = jax.Array


def insert(
    g: KNNGraph,
    x: Array,
    n_new: int,
    cfg: construct_lib.BuildConfig,
    key: Optional[Array] = None,
) -> tuple[KNNGraph, construct_lib.BuildStats]:
    """Insert rows [n_valid, n_valid + n_new) of x into the graph online.

    ``x`` is the full (capacity, d) data array with the new samples already
    written at their rows (the framework's data region grows append-only,
    which is also what the sharded serving path assumes).  The insertion
    waves run the same fused expansion step as the initial build —
    ``cfg.use_pallas`` selects the kernel/reference path exactly as in
    ``construct.build``.
    """
    start = int(g.n_valid)
    if key is None:
        key = jax.random.PRNGKey(start)
    sub = x[: start + n_new]
    return construct_lib.build(sub, cfg, key, initial=(g, start))


def remove(
    g: KNNGraph,
    x: Array,
    ids: Array,
    metric: str = "l2",
    *,
    repair_lambda: bool = True,
) -> KNNGraph:
    """Remove samples from the graph (batched).

    Args:
      g: graph.
      x: (cap, d) data (needed for the λ repair distance recomputations).
      ids: (m,) int32 sample ids to remove.

    Returns the updated graph.  Rows that lose neighbors keep holes (padding
    moves to the tail); search tolerates short lists, and the next refinement
    or insertion wave naturally refills them.
    """
    cap, k = g.nbr_ids.shape
    removed = jnp.zeros((cap,), bool).at[jnp.clip(ids, 0, cap - 1)].set(True)

    hit = jnp.where(g.nbr_ids >= 0, removed[jnp.maximum(g.nbr_ids, 0)], False)

    nbr_lam = g.nbr_lam
    if repair_lambda:
        # Undo Rule 3: for each removed member m at slot s of row r, samples
        # at slots > s lose one λ count if m(x_j, x_m) < m(x_m, x_r).
        # Distances are recomputed directly (k^2/2 per affected row, as the
        # paper prescribes) — vectorized over all rows at once.
        safe_ids = jnp.maximum(g.nbr_ids, 0)
        vecs = x[safe_ids]  # (cap, k, d)
        rows = x[: cap]  # (cap, d)

        def row_repair(row_vec, member_vecs, member_hit, member_valid, row_dist):
            # pair distances between members (k, k)
            dm = metrics_lib.pairwise(metric, member_vecs, member_vecs)
            s = jnp.arange(k)
            later = s[None, :] > s[:, None]  # (s_removed, s_later)
            # threshold: m(x_m, row) — the removed member's distance to row
            thresh = row_dist[:, None]
            undo = (
                member_hit[:, None]
                & member_valid[None, :]
                & ~member_hit[None, :]
                & later
                & (dm < thresh)
            )
            return jnp.sum(undo, axis=0).astype(jnp.int32)  # per later slot

        dec = jax.vmap(row_repair)(
            rows, vecs, hit, g.nbr_ids >= 0, g.nbr_dist
        )
        nbr_lam = jnp.maximum(nbr_lam - dec, 0)

    # purge removed entries and re-pack rows (stable sort keeps order)
    dist = jnp.where(hit, jnp.inf, g.nbr_dist)
    idsx = jnp.where(hit, -1, g.nbr_ids)
    lam = jnp.where(hit, 0, nbr_lam)
    order = jnp.argsort(jnp.where(idsx >= 0, dist, jnp.inf), axis=1, stable=True)
    take = lambda a: jnp.take_along_axis(a, order, axis=1)
    nbr_ids = take(idsx)
    nbr_dist = jnp.where(nbr_ids >= 0, take(dist), jnp.inf)
    nbr_lam2 = jnp.where(nbr_ids >= 0, take(lam), 0)

    # clear the removed rows themselves
    rid = jnp.clip(ids, 0, cap - 1)
    nbr_ids = nbr_ids.at[rid].set(-1)
    nbr_dist = nbr_dist.at[rid].set(jnp.inf)
    nbr_lam2 = nbr_lam2.at[rid].set(0)

    # purge from reverse lists (ring buffers keep their ptr; holes are -1);
    # the rev_lam snapshots travel with their edges
    rev_hit = jnp.where(g.rev_ids >= 0, removed[jnp.maximum(g.rev_ids, 0)], False)
    rev_ids = jnp.where(rev_hit, -1, g.rev_ids)
    rev_ids = rev_ids.at[rid].set(-1)
    rev_lam = jnp.where(rev_hit, 0, g.rev_lam)
    rev_lam = rev_lam.at[rid].set(0)
    rev_ptr = g.rev_ptr.at[rid].set(0)

    alive = g.alive.at[rid].set(False)
    return KNNGraph(
        nbr_ids=nbr_ids,
        nbr_dist=nbr_dist,
        nbr_lam=nbr_lam2,
        rev_ids=rev_ids,
        rev_lam=rev_lam,
        rev_ptr=rev_ptr,
        alive=alive,
        n_valid=g.n_valid,
        # norm-cache invariant: removed rows drop back to 0
        sq_norms=jnp.where(removed, 0.0, g.sq_norms),
    )
