"""Dynamic updates — §IV-C: samples join and leave the graph online.

Insertion *is* the construction step (Alg. 2/3): ``insert`` simply runs more
waves against the existing graph, so an open set (the paper's Flickr / object
-tracking / e-shopping scenarios) is supported by the same code path as the
initial build — no separate machinery, no reconstruction.

Removal follows the paper exactly:
  * drop the row (k-NN list released, ``alive`` cleared);
  * purge the sample from the reverse side (its reverse list tells us which
    rows reference it; we additionally sweep all lists since ring-buffer
    reverse lists are bounded — DESIGN.md §8.2);
  * LGD λ repair: per the paper, only samples ranked *after* the removed one
    in each affected list need their λ updated (undo of Rule 3) — ~k²/2
    distance computations per removal on average, recomputed on the spot.

``compact`` is the complement removal needs to stay long-lived: removed rows
keep their capacity slot (``alive`` masking is the paper's O(1)-ish delete),
so sustained churn leaks capacity until the alive rows are re-packed to the
front.  The index lifecycle layer (``repro.index.lifecycle``) drives it from
its free-slot ledger.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import construct as construct_lib
from repro.core import graph as graph_lib
from repro.core import metrics as metrics_lib
from repro.core import search as search_lib
from repro.core.graph import KNNGraph

Array = jax.Array


def insert(
    g: KNNGraph,
    x: Array,
    n_new: int,
    cfg: construct_lib.BuildConfig,
    key: Optional[Array] = None,
    coarse=None,
):
    """Insert rows [n_valid, n_valid + n_new) of x into the graph online.

    ``x`` is the full (capacity, d) data array with the new samples already
    written at their rows (the framework's data region grows append-only,
    which is also what the sharded serving path assumes).  The insertion
    waves run the same fused expansion step as the initial build —
    ``cfg.use_pallas`` selects the kernel/reference path exactly as in
    ``construct.build``.

    Returns ``(graph, stats)``; with a ``coarse`` level passed in (or
    ``cfg.seed_mode == "coarse"``, which derives one if missing) the return
    is ``(graph, stats, coarse)`` — the level maintained through the waves
    (new rows assigned to their winning cells).
    """
    start = int(g.n_valid)
    if key is None:
        key = jax.random.PRNGKey(start)
    sub = x[: start + n_new]
    with_coarse = coarse is not None or cfg.seed_mode == "coarse"
    return construct_lib.build(
        sub, cfg, key, initial=(g, start), coarse=coarse,
        return_coarse=with_coarse,
    )


@functools.partial(jax.jit, static_argnames=("metric", "repair_lambda"))
def remove(
    g: KNNGraph,
    x: Array,
    ids: Array,
    metric: str = "l2",
    *,
    repair_lambda: bool = True,
) -> KNNGraph:
    """Remove samples from the graph (batched).

    Args:
      g: graph.
      x: (cap, d) data (needed for the λ repair distance recomputations).
      ids: (m,) int32 sample ids to remove.  Out-of-range ids (including the
        -1 padding sentinel) are ignored, so callers may pad a batch to a
        fixed shape — the jit specializes on (m,), and padding to size
        buckets bounds the compile cache.

    Returns the updated graph.  Rows that lose neighbors keep holes (padding
    moves to the tail); search tolerates short lists, and the next refinement
    or insertion wave naturally refills them.
    """
    cap, k = g.nbr_ids.shape
    ids = jnp.where((ids >= 0) & (ids < cap), ids, cap)  # cap = drop sentinel
    removed = jnp.zeros((cap,), bool).at[ids].set(True, mode="drop")

    hit = jnp.where(g.nbr_ids >= 0, removed[jnp.maximum(g.nbr_ids, 0)], False)

    nbr_lam = g.nbr_lam
    if repair_lambda:
        # Undo Rule 3: for each removed member m at slot s of row r, samples
        # at slots > s lose one λ count if m(x_j, x_m) < m(x_m, x_r).
        # Distances are recomputed directly (k^2/2 per affected row, as the
        # paper prescribes) — vectorized over all rows at once.
        safe_ids = jnp.maximum(g.nbr_ids, 0)
        vecs = x[safe_ids]  # (cap, k, d)
        rows = x[: cap]  # (cap, d)

        def row_repair(row_vec, member_vecs, member_hit, member_valid, row_dist):
            # pair distances between members (k, k)
            dm = metrics_lib.pairwise(metric, member_vecs, member_vecs)
            s = jnp.arange(k)
            later = s[None, :] > s[:, None]  # (s_removed, s_later)
            # threshold: m(x_m, row) — the removed member's distance to row
            thresh = row_dist[:, None]
            undo = (
                member_hit[:, None]
                & member_valid[None, :]
                & ~member_hit[None, :]
                & later
                & (dm < thresh)
            )
            return jnp.sum(undo, axis=0).astype(jnp.int32)  # per later slot

        dec = jax.vmap(row_repair)(
            rows, vecs, hit, g.nbr_ids >= 0, g.nbr_dist
        )
        nbr_lam = jnp.maximum(nbr_lam - dec, 0)

    # purge removed entries and re-pack rows (stable sort keeps order)
    dist = jnp.where(hit, jnp.inf, g.nbr_dist)
    idsx = jnp.where(hit, -1, g.nbr_ids)
    lam = jnp.where(hit, 0, nbr_lam)
    order = jnp.argsort(jnp.where(idsx >= 0, dist, jnp.inf), axis=1, stable=True)
    take = lambda a: jnp.take_along_axis(a, order, axis=1)
    nbr_ids = take(idsx)
    nbr_dist = jnp.where(nbr_ids >= 0, take(dist), jnp.inf)
    nbr_lam2 = jnp.where(nbr_ids >= 0, take(lam), 0)

    # clear the removed rows themselves (padding ids carry the drop sentinel)
    nbr_ids = nbr_ids.at[ids].set(-1, mode="drop")
    nbr_dist = nbr_dist.at[ids].set(jnp.inf, mode="drop")
    nbr_lam2 = nbr_lam2.at[ids].set(0, mode="drop")

    # purge from reverse lists (ring buffers keep their ptr; holes are -1);
    # the rev_lam snapshots travel with their edges
    rev_hit = jnp.where(g.rev_ids >= 0, removed[jnp.maximum(g.rev_ids, 0)], False)
    rev_ids = jnp.where(rev_hit, -1, g.rev_ids)
    rev_ids = rev_ids.at[ids].set(-1, mode="drop")
    rev_lam = jnp.where(rev_hit, 0, g.rev_lam)
    rev_lam = rev_lam.at[ids].set(0, mode="drop")
    rev_ptr = g.rev_ptr.at[ids].set(0, mode="drop")

    alive = g.alive.at[ids].set(False, mode="drop")
    return KNNGraph(
        nbr_ids=nbr_ids,
        nbr_dist=nbr_dist,
        nbr_lam=nbr_lam2,
        rev_ids=rev_ids,
        rev_lam=rev_lam,
        rev_ptr=rev_ptr,
        alive=alive,
        n_valid=g.n_valid,
        # norm-/scale-cache invariant: removed rows drop back to 0
        sq_norms=jnp.where(removed, 0.0, g.sq_norms),
        row_scale=jnp.where(removed, 0.0, g.row_scale),
    )


@jax.jit
def compact(g: KNNGraph, x: Array) -> tuple[KNNGraph, Array, Array]:
    """Re-pack alive rows to the front, reclaiming removed rows' capacity.

    ``remove`` leaves dead rows in place (the paper's O(1)-ish delete); under
    sustained churn those holes leak capacity forever.  Compaction restores a
    dense index: alive rows keep their relative order and move to rows
    [0, n_alive); everything behind ``n_valid`` returns to the unallocated
    state, ready for later insertion waves to recycle.

    The whole surgery is two vectorized passes:
      * ``id_map`` (old row -> new row, -1 for dead) comes from a prefix sum
        over the alive mask; its inverse ``old_of_new`` is one scatter — the
        same sort/scatter shape as ``core.segments`` group-bys;
      * every per-row array is gathered through ``old_of_new`` and every
        stored id (``nbr_ids``) remapped through ``id_map``; the reverse side
        is rebuilt exactly with ``graph.rebuild_reverse`` (which snapshots
        ``rev_lam`` from the remapped forward lists — the rebuild path is the
        canonical repair, so the rev/λ invariants hold by construction).

    The norm cache moves with its rows (gathered, not recomputed), so the
    invariant — exact for alive allocated rows, 0 elsewhere — is preserved
    bit-for-bit.  Capacity (array shapes) is unchanged, which keeps this a
    single jitted call with no host sync.

    Args:
      g: graph (typically after one or more ``remove`` calls).
      x: (cap, d) backing data.

    Returns:
      (graph, x2, id_map): the compacted graph, the re-packed data region,
      and the (cap,) old-row -> new-row map (-1 for removed rows) callers
      use to remap any ids they hold (serving routers, result caches).
    """
    cap, k = g.nbr_ids.shape
    row = jnp.arange(cap, dtype=jnp.int32)
    alive = g.alive & (row < g.n_valid)
    n_alive = jnp.sum(alive.astype(jnp.int32))
    id_map = jnp.where(alive, jnp.cumsum(alive.astype(jnp.int32)) - 1, -1)
    # inverse permutation: one scatter, dead/unallocated rows stay -1
    old_of_new = (
        jnp.full((cap + 1,), -1, jnp.int32)
        .at[jnp.where(alive, id_map, cap)]
        .set(row, mode="drop")[:cap]
    )
    filled = old_of_new >= 0
    src = jnp.maximum(old_of_new, 0)

    def pack(a: Array, fill) -> Array:
        out = a[src]
        mask = filled if a.ndim == 1 else filled[:, None]
        return jnp.where(mask, out, fill)

    # forward lists: gather rows, remap member ids (dead members were already
    # purged by remove(); any survivor maps cleanly, holes stay -1)
    nbr_ids = pack(g.nbr_ids, -1)
    nbr_ids = jnp.where(nbr_ids >= 0, id_map[jnp.maximum(nbr_ids, 0)], -1)
    g2 = KNNGraph(
        nbr_ids=nbr_ids,
        nbr_dist=jnp.where(nbr_ids >= 0, pack(g.nbr_dist, jnp.inf), jnp.inf),
        nbr_lam=jnp.where(nbr_ids >= 0, pack(g.nbr_lam, 0), 0),
        rev_ids=jnp.full_like(g.rev_ids, -1),
        rev_lam=jnp.zeros_like(g.rev_lam),
        rev_ptr=jnp.zeros_like(g.rev_ptr),
        alive=filled,
        n_valid=n_alive,
        sq_norms=pack(g.sq_norms, 0.0),
        row_scale=pack(g.row_scale, 0.0),
    )
    g2 = graph_lib.rebuild_reverse(g2)
    x2 = pack(x, 0)
    return g2, x2, id_map
