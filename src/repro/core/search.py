"""Batched Enhanced Hill-Climbing (EHC) — Alg. 1, TPU-native.

The paper's Alg. 1 is a best-first walk: repeatedly take the closest
not-yet-expanded vertex r from a sorted list Q, compare the query against
G[r] ∪ Ḡ[r], and stop when no unexpanded vertex can improve the result.

TPU adaptation (DESIGN.md §2):
  * a whole wave of B queries climbs simultaneously (leading batch axis, not
    vmap, so the gathers/distance kernels see batched shapes);
  * Q becomes a fixed-width beam (ids, dists, expanded-flags) maintained by
    top-k merges;
  * the O(n) Flag array becomes a per-query open-addressing hash table that
    doubles as the paper's D array of Alg. 3 (id -> computed distance), which
    is exactly what the LGD commit needs later;
  * ``while updated`` becomes a lax.while_loop over a convergence mask: a
    lane is done when its best unexpanded beam entry cannot enter its current
    top-k (the paper's "no closer sample identified"), with a hard
    ``max_iters`` cap as straggler mitigation — one pathological query cannot
    stall the wave (converged lanes are masked, SIMT style).

LGD-aware expansion (Alg. 3 lines 15/19): neighbors whose occlusion factor λ
exceeds the mean λ of the expanded row are skipped; for reverse edges the λ
of the forward twin (r's slot inside G[j]) is looked up.  ``hard_diversify``
gives the FANNG/DPG-style λ>0 ablation the paper argues against.

The per-iteration hot path (hash probe → candidate-row gather + distance →
hash record → beam top-k merge) is one fused call, ``kernels.ops
.expand_step``: a single Pallas kernel on TPU, the XLA-fused pure-JAX
reference elsewhere — see ``SearchConfig.use_pallas`` for the dispatch.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import segments
from repro.core.graph import KNNGraph
from repro.kernels import expand as expand_lib
from repro.kernels import ops
from repro.kernels import precision as precision_lib

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Static EHC search configuration.

    ``dispatch`` selects the execution path of the fused expansion step
    (``kernels.ops.expand_step`` — one call per EHC iteration covering hash
    probe, candidate-row gather + distance, hash record, and beam top-k
    merge) and of the seed-distance gather.  One enum, resolved only in
    ``kernels.ops``:

      * ``"auto"`` (default): the compiled fused Pallas kernel on TPU, the
        pure-JAX reference elsewhere (XLA fuses it into the jitted search
        loop; the fast CPU path);
      * ``"pallas"``: always the kernel — compiled on TPU, interpret mode
        off-TPU (slow, but bit-identical to compiled semantics);
      * ``"interpret"``: the kernel in interpret mode everywhere (what the
        parity/correctness tests sweep);
      * ``"reference"``: always the pure-JAX reference path.

    ``use_pallas`` is the DEPRECATED tri-state ancestor of ``dispatch``
    (None/True/False = auto/pallas/reference).  Setting it still works —
    it is mapped onto ``dispatch`` with a ``DeprecationWarning`` — so old
    callers and old snapshots keep loading.

    ``precision`` selects the candidate representation the distance engine
    fetches (``kernels.precision``): ``"fp32"`` (exact, the default —
    bit-identical to the pre-precision engine), ``"bf16"``/``"int8"``
    (compressed tiles, fp32 accumulation, tolerance-suite accuracy), or
    ``"pq"`` (ADC first-pass rank + exact fp32 re-rank of the top
    ``rerank_factor * k`` fresh candidates per expansion; only exact
    distances enter the visited hash or beam).  The compressed companion
    table rides as the ``enc`` operand of ``search`` and is derived from
    the dataset (and the graph-resident ``row_scale`` table) when absent.

    ``seed_mode`` selects the Alg. 1 line-5 entry points: ``"random"`` is the
    paper's p uniform draws over [0, n); ``"coarse"`` first runs a short EHC
    pass on a coarse landmark graph (``core.hierarchy.CoarseLevel``, passed
    as the ``coarse`` operand of ``search``/``init_state``) and seeds the
    full-graph beam from the winning landmarks' rows plus their assigned
    member cells — the EFANNA-style hierarchical initialization that drops
    the scanning rate from O(n) territory to polylog.
    """

    k: int = 10  # result size; also the improvement-termination horizon
    beam: int = 64  # beam width e >= k
    n_seeds: int = 8  # p random entry points
    # H, power of two.  None auto-sizes from beam/max_iters (see
    # __post_init__); explicit values are respected — the hash_full flag in
    # SearchResult reports per-lane saturation either way.
    hash_slots: Optional[int] = None
    hash_probes: int = 8  # linear-probe depth
    max_iters: int = 64  # straggler cap on expansions
    metric: str = "l2"
    use_reverse: bool = True  # False = plain HC (Fig. 5 ablation: no Ḡ[r])
    use_lgd_mask: bool = False  # λ <= mean-λ expansion filter (Alg. 3)
    lgd_rev_lambda: bool = True  # look up λ of the forward twin for rev edges
    hard_diversify: bool = False  # ablation: skip any λ > 0 (DPG/FANNG style)
    use_pallas: Optional[bool] = None  # DEPRECATED -> dispatch
    dispatch: Optional[str] = None  # None -> "auto" (post-init)
    precision: str = "fp32"  # "fp32" | "bf16" | "int8" | "pq"
    rerank_factor: int = 4  # pq: exact re-rank width = rerank_factor * k
    seed_mode: str = "random"  # "random" | "coarse"
    coarse_top: int = 4  # T winning landmarks whose cells seed the beam
    coarse_beam: int = 16  # beam width of the coarse EHC pass
    coarse_iters: int = 16  # max_iters of the coarse EHC pass

    def __post_init__(self):
        assert self.beam >= self.k, "beam must be >= k"
        assert self.seed_mode in ("random", "coarse"), self.seed_mode
        if self.use_pallas is not None:
            warnings.warn(
                "SearchConfig.use_pallas is deprecated; use dispatch="
                "'auto'|'pallas'|'interpret'|'reference' instead",
                DeprecationWarning,
                stacklevel=2,
            )
            if self.dispatch is None:
                object.__setattr__(
                    self, "dispatch",
                    "pallas" if self.use_pallas else "reference",
                )
            # normalize so dataclasses.replace round trips don't re-warn and
            # configs differing only in the legacy spelling compare equal
            object.__setattr__(self, "use_pallas", None)
        if self.dispatch is None:
            object.__setattr__(self, "dispatch", "auto")
        assert self.dispatch in ops.DISPATCHES, self.dispatch
        precision_lib.validate_precision(self.precision)
        assert self.rerank_factor >= 1, "rerank_factor must be >= 1"
        if self.hash_slots is None:
            object.__setattr__(
                self, "hash_slots", auto_hash_slots(self.beam, self.max_iters)
            )
        assert self.hash_slots & (self.hash_slots - 1) == 0, "hash_slots must be 2^h"


def auto_hash_slots(beam: int, max_iters: int) -> int:
    """Default H for a (beam, max_iters) search shape: the next power of two
    above ``beam * max_iters / 2`` (a per-row candidate width is beam-scale
    and masking/convergence roughly halve the recorded entries), clamped to
    [1024, 65536].  A heuristic, not a guarantee — ``SearchResult.hash_full``
    is the ground truth for saturation."""
    est = (beam * max_iters) // 2
    H = 1024
    while H < est and H < (1 << 16):
        H <<= 1
    return H


class SearchResult(NamedTuple):
    ids: Array  # (B, k) int32 top-k ids, ascending distance
    dists: Array  # (B, k) float32
    vis_ids: Array  # (B, H) int32 — every vertex compared (the D array keys)
    vis_dist: Array  # (B, H) float32 — m(q, vertex) (the D array values)
    n_comps: Array  # (B,) int32 — distance computations (scanning rate)
    n_iters: Array  # (B,) int32 — expansions until convergence
    converged: Array  # (B,) bool — False = stopped by max_iters cap
    hash_full: Array  # (B,) bool — True = some computed distance was NOT
    #   recorded in the D array (insert failed: table saturated or slot
    #   collision); n_comps may then overcount unique evaluations
    seed_cell: Array  # (B,) int32 — winning coarse landmark (seed_mode=
    #   "coarse"; -1 under random seeding).  Lets callers assign freshly
    #   inserted rows to their cell without a separate brute pass.


# The hash/beam primitives live next to the fused kernel that consumes them
# (kernels.expand); these aliases keep the established core-layer surface.
_probe_slots = expand_lib.probe_slots
hash_lookup = expand_lib.hash_lookup
_hash_probe_state = expand_lib.hash_probe_state
_dedupe_beam = expand_lib.dedupe_beam


def _row_mean_lambda(lam_row: Array, ids_row: Array) -> Array:
    """Mean λ over valid entries of a k-NN list: λ̄(r)."""
    valid = ids_row >= 0
    cnt = jnp.maximum(jnp.sum(valid, axis=-1), 1)
    return jnp.sum(jnp.where(valid, lam_row, 0), axis=-1) / cnt


class _LoopState(NamedTuple):
    beam_ids: Array
    beam_dist: Array
    beam_exp: Array
    vis_ids: Array
    vis_dist: Array
    n_comps: Array
    n_iters: Array
    done: Array
    it: Array
    hash_full: Array
    seed_cell: Array


def _candidates_from_expansion(
    g: KNNGraph, r_id: Array, has_r: Array, cfg: SearchConfig
) -> Array:
    """Expand r: G[r] ∪ Ḡ[r] with LGD masking. Returns (B, k+R) ids, -1 masked."""
    B = r_id.shape[0]
    safe_r = jnp.maximum(r_id, 0)
    fwd_ids = g.nbr_ids[safe_r]  # (B, kg)
    rev_ids = g.rev_ids[safe_r]  # (B, R)
    if not cfg.use_reverse:  # plain hill-climbing (Hajebi'11): G[r] only
        rev_ids = jnp.full_like(rev_ids, -1)
    if cfg.use_lgd_mask or cfg.hard_diversify:
        fwd_lam = g.nbr_lam[safe_r]  # (B, kg)
        mean_lam = _row_mean_lambda(fwd_lam, fwd_ids)[:, None]
        if cfg.hard_diversify:
            fwd_keep = fwd_lam <= 0
        else:
            fwd_keep = fwd_lam.astype(jnp.float32) <= mean_lam  # Alg.3 line 15 (≤)
        fwd_ids = jnp.where(fwd_keep, fwd_ids, -1)
        if cfg.lgd_rev_lambda:
            # λ of the forward twin, from the graph-resident rev_lam table —
            # a flat (B, R) gather.  The table snapshots λ at append/rebuild
            # time (the old live (B, R, kg) twin-row gather per iteration is
            # gone); staleness only perturbs this expansion *filter*, exactly
            # like stale rev_ids entries, never distances or results.
            rev_lam = g.rev_lam[safe_r].astype(jnp.float32)  # (B, R)
            if cfg.hard_diversify:
                rev_keep = rev_lam <= 0
            else:
                rev_keep = rev_lam < mean_lam  # Alg.3 line 19 (<)
            rev_ids = jnp.where(rev_keep, rev_ids, -1)
    cands = jnp.concatenate([fwd_ids, rev_ids], axis=1)  # (B, C0)
    cands = jnp.where(has_r[:, None], cands, -1)
    # mask ids beyond allocation / dead rows
    in_range = (cands >= 0) & (cands < g.n_valid)
    alive = jnp.where(in_range, g.alive[jnp.maximum(cands, 0)], False)
    cands = jnp.where(in_range & alive, cands, -1)
    # in-step dedupe (G[r] and Ḡ[r] overlap, per the paper's Fig. 1 remark) —
    # sort-based segmented idiom, not the old O(C²) pairwise matrix
    cands = jnp.where(segments.mask_row_duplicates(cands), -1, cands)
    return cands


def _prepare_expansion(
    g: KNNGraph, st: _LoopState, cfg: SearchConfig
) -> tuple[Array, Array]:
    """Select r (closest unexpanded beam entry per lane), mark it expanded,
    and emit its masked candidate ids.  Returns (cands (B, C), beam_exp)."""
    B = st.beam_ids.shape[0]
    sel_dist = jnp.where(st.beam_exp, jnp.inf, st.beam_dist)
    r_slot = jnp.argmin(sel_dist, axis=1)
    r_best = jnp.take_along_axis(sel_dist, r_slot[:, None], axis=1)[:, 0]
    has_r = jnp.isfinite(r_best) & ~st.done
    r_id = jnp.where(
        has_r, jnp.take_along_axis(st.beam_ids, r_slot[:, None], axis=1)[:, 0], -1
    )
    beam_exp = st.beam_exp.at[jnp.arange(B), r_slot].set(
        st.beam_exp[jnp.arange(B), r_slot] | has_r
    )
    cands = _candidates_from_expansion(g, r_id, has_r, cfg)
    return cands, beam_exp


def _expand(
    g: KNNGraph, x: Array, q: Array, cands: Array, beam_exp: Array,
    st: _LoopState, cfg: SearchConfig, enc=None,
):
    """The fused expansion: probe the visited hash, compute surviving
    distances (blocked MXU engine fed by the graph-resident norm cache),
    record them, merge into the beam.  One ``ops.expand_step`` call —
    engine per ``cfg.dispatch``, candidate representation per
    ``cfg.precision`` (``enc`` is the compressed companion table)."""
    return ops.expand_step(
        q, x, cands, st.beam_ids, st.beam_dist, beam_exp,
        st.vis_ids, st.vis_dist,
        metric=cfg.metric, hash_probes=cfg.hash_probes,
        sq_norms=g.sq_norms, dispatch=cfg.dispatch,
        enc=enc, precision=cfg.precision,
        rerank_keep=cfg.rerank_factor * cfg.k,
    )


def _hash_fill(vis_ids: Array) -> Array:
    """Occupied D-array slots per lane."""
    return jnp.sum(vis_ids >= 0, axis=1).astype(jnp.int32)


def _make_step(g: KNNGraph, x: Array, q: Array, cfg: SearchConfig, enc=None):
    def step(st: _LoopState) -> _LoopState:
        cands, beam_exp = _prepare_expansion(g, st, cfg)
        fill_before = _hash_fill(st.vis_ids)
        beam_ids, beam_dist, beam_exp, vis_ids, vis_dist, comps = _expand(
            g, x, q, cands, beam_exp, st, cfg, enc
        )
        n_comps = st.n_comps + comps
        # every computed distance must land in the D array; a fill delta below
        # the comparison count means an insert was dropped (probe depth
        # exhausted on a saturated table, or a same-slot scatter collision)
        hash_full = st.hash_full | (_hash_fill(vis_ids) - fill_before < comps)
        # -- convergence: best unexpanded cannot improve current top-k --------
        best_unexp = jnp.min(jnp.where(beam_exp, jnp.inf, beam_dist), axis=1)
        kth = beam_dist[:, cfg.k - 1]
        newly_done = ~(best_unexp < kth)
        n_iters = st.n_iters + (~st.done).astype(jnp.int32)
        return _LoopState(
            beam_ids,
            beam_dist,
            beam_exp,
            vis_ids,
            vis_dist,
            n_comps,
            n_iters,
            st.done | newly_done,
            st.it + 1,
            hash_full,
            st.seed_cell,
        )

    return step


def coarse_config(cfg: SearchConfig) -> SearchConfig:
    """The config of the short coarse-graph EHC pass implied by a
    ``seed_mode="coarse"`` config: top-``coarse_top`` over a small beam and
    few iterations, random seeding (so the recursion terminates), LGD
    filtering off (the landmark graph is tiny and routing-only), and exact
    fp32 distances (the landmark table is tiny — compressing it buys nothing
    and would demand a second enc table for the coarse points)."""
    return dataclasses.replace(
        cfg,
        k=cfg.coarse_top,
        beam=max(cfg.coarse_beam, cfg.coarse_top),
        hash_slots=None,  # re-auto-size for the coarse shape
        max_iters=cfg.coarse_iters,
        use_lgd_mask=False,
        hard_diversify=False,
        seed_mode="random",
        precision="fp32",
    )


def init_state(
    g: KNNGraph,
    x: Array,
    q: Array,
    key: Array,
    cfg: SearchConfig,
    coarse=None,
    enc=None,
) -> _LoopState:
    """Pre-loop search state: entry points scored, hashed, and merged into
    an otherwise-empty beam (Alg. 1 line 5).  Public so benchmarks and the
    expansion parity suite can drive single EHC iterations directly.

    ``seed_mode="random"`` draws p uniform seeds.  ``seed_mode="coarse"``
    additionally runs a short EHC pass over ``coarse`` (a
    ``core.hierarchy.CoarseLevel``) and seeds from the winning landmarks'
    full-graph rows plus their assigned member cells; the coarse pass's
    comparisons are pre-charged into ``n_comps`` so the scanning rate stays
    honest, and its top-1 winner is carried out as ``seed_cell``."""
    B = q.shape[0]
    e, H = cfg.beam, cfg.hash_slots

    # -- entry points (Alg. 1 line 5) ----------------------------------------
    if cfg.seed_mode == "coarse":
        if coarse is None:
            raise ValueError(
                "seed_mode='coarse' needs a coarse level (core.hierarchy."
                "CoarseLevel) passed as the `coarse` operand"
            )
        key_c, key_r = jax.random.split(key)
        cres = search(coarse.graph, coarse.points, q, key_c, coarse_config(cfg))
        win = cres.ids  # (B, T) landmark indices, -1 padded
        safe_win = jnp.maximum(win, 0)
        lm_rows = jnp.where(win >= 0, coarse.landmark_rows[safe_win], -1)
        members = jnp.where(
            win[:, :, None] >= 0, coarse.members[safe_win], -1
        ).reshape(B, -1)
        rand = jax.random.randint(
            key_r, (B, cfg.n_seeds), 0, jnp.maximum(g.n_valid, 1),
            dtype=jnp.int32,
        )
        seeds = jnp.concatenate([lm_rows, members, rand], axis=1)
        seed_cell = win[:, 0]
        pre_comps = cres.n_comps
        pre_full = cres.hash_full
    else:
        seeds = jax.random.randint(
            key, (B, cfg.n_seeds), 0, jnp.maximum(g.n_valid, 1),
            dtype=jnp.int32,
        )
        seed_cell = jnp.full((B,), -1, jnp.int32)
        pre_comps = jnp.zeros((B,), jnp.int32)
        pre_full = jnp.zeros((B,), bool)
    # dedupe seeds within a lane (sort-based segmented idiom)
    seeds = jnp.where(segments.mask_row_duplicates(seeds), -1, seeds)
    in_range = (seeds >= 0) & (seeds < g.n_valid)
    seeds = jnp.where(in_range & g.alive[jnp.maximum(seeds, 0)], seeds, -1)
    # Seed distances enter the beam and the visited hash, so they follow the
    # engine precision for bf16/int8 (those ARE the engine's distances) but
    # stay exact under pq — ADC scores never land in the hash by policy, and
    # p seeds are too few for the prerank to pay for itself.
    seed_precision = cfg.precision if cfg.precision in ("bf16", "int8") else "fp32"
    seed_dist = ops.gather_distance(
        q, x, seeds, cfg.metric, sq_norms=g.sq_norms, dispatch=cfg.dispatch,
        enc=enc if seed_precision != "fp32" else None, precision=seed_precision,
    )

    beam_ids = jnp.full((B, e), -1, jnp.int32)
    beam_dist = jnp.full((B, e), jnp.inf, jnp.float32)
    beam_exp = jnp.ones((B, e), bool)
    vis_ids = jnp.full((B, H), -1, jnp.int32)
    vis_dist = jnp.full((B, H), jnp.inf, jnp.float32)

    # install seeds via one merge + hash insert
    _, ins_ok, ins_slot = _hash_probe_state(vis_ids, seeds, cfg.hash_probes)
    do_ins = (seeds >= 0) & ins_ok
    B_idx = jnp.broadcast_to(jnp.arange(B)[:, None], seeds.shape)
    slot = jnp.where(do_ins, ins_slot, H)
    vis_ids = vis_ids.at[B_idx, slot].set(jnp.where(do_ins, seeds, -1), mode="drop")
    vis_dist = vis_dist.at[B_idx, slot].set(
        jnp.where(do_ins, seed_dist, jnp.inf), mode="drop"
    )
    cat_ids = jnp.concatenate([beam_ids, seeds], axis=1)
    cat_dist = jnp.concatenate([beam_dist, seed_dist], axis=1)
    cat_exp = jnp.concatenate([beam_exp, seeds < 0], axis=1)
    neg, sel = jax.lax.top_k(-cat_dist, e)
    beam_ids = jnp.take_along_axis(cat_ids, sel, axis=1)
    beam_dist = -neg
    beam_exp = jnp.take_along_axis(cat_exp, sel, axis=1)

    seed_comps = jnp.sum(seeds >= 0, axis=1).astype(jnp.int32)
    return _LoopState(
        beam_ids=beam_ids,
        beam_dist=beam_dist,
        beam_exp=beam_exp,
        vis_ids=vis_ids,
        vis_dist=vis_dist,
        n_comps=pre_comps + seed_comps,
        n_iters=jnp.zeros((B,), jnp.int32),
        done=jnp.zeros((B,), bool),
        it=jnp.zeros((), jnp.int32),
        hash_full=pre_full | (_hash_fill(vis_ids) < seed_comps),
        seed_cell=seed_cell,
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def search(
    g: KNNGraph,
    x: Array,
    q: Array,
    key: Array,
    cfg: SearchConfig,
    coarse=None,
    enc=None,
) -> SearchResult:
    """Batched EHC search of queries q against graph g over dataset x.

    Args:
      g: the (possibly under-construction) graph.
      x: (n, d) dataset backing the graph rows.
      q: (B, d) queries.
      key: PRNG key for the entry points.
      cfg: static search configuration.
      coarse: ``core.hierarchy.CoarseLevel`` operand, required when
        ``cfg.seed_mode == "coarse"`` (ignored otherwise).
      enc: ``kernels.precision.EncodedData`` companion table matching
        ``cfg.precision`` (ignored for fp32).  Derived from ``x`` at trace
        time when absent — fine for one-off calls, but persistent callers
        (``index.lifecycle.OnlineIndex``) pass a cached table so encoding
        isn't redone per search; int8 reuses the graph-resident
        ``g.row_scale`` cache either way.

    Returns: SearchResult (top-k per lane + the comparison log).
    """
    if cfg.precision != "fp32" and enc is None:
        reuse_scale = (
            cfg.precision == "int8" and g.row_scale.shape[0] == x.shape[0]
        )
        enc = precision_lib.encode_dataset(
            x, cfg.precision,
            row_scale=g.row_scale if reuse_scale else None,
        )
    st = init_state(g, x, q, key, cfg, coarse=coarse, enc=enc)
    step = _make_step(g, x, q, cfg, enc)
    st = jax.lax.while_loop(
        lambda s: (~jnp.all(s.done)) & (s.it < cfg.max_iters), step, st
    )
    return SearchResult(
        ids=st.beam_ids[:, : cfg.k],
        dists=st.beam_dist[:, : cfg.k],
        vis_ids=st.vis_ids,
        vis_dist=st.vis_dist,
        n_comps=st.n_comps,
        n_iters=st.n_iters,
        converged=st.done,
        hash_full=st.hash_full,
        seed_cell=st.seed_cell,
    )
