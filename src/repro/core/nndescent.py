"""NN-Descent (Dong et al., WWW'11) — the paper's primary baseline.

The paper's Tables II/III and Figs. 6/7 compare OLG/LGD against NN-Descent at
matched scanning rates, so a faithful, measurable NN-Descent is part of the
required substrate.  This is the standard batched formulation with the two
optimizations of the original: *incremental search* (new/old flags — only
pairs touching a new entry are joined) and *reverse sampling* (bounded
reverse-neighbor participation).

Also exported: ``local_join_refine`` — the §IV-D refinement pass, which is
exactly one NN-Descent join round applied to an already-built (OLG/LGD)
graph with every entry treated as "new".
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import merge, segments
from repro.core import graph as graph_lib
from repro.core.graph import KNNGraph, rebuild_reverse
from repro.kernels import ops

Array = jax.Array


def _dispatch_of(dispatch, use_pallas):
    """Fold the deprecated tri-state into the dispatch enum (ops semantics)."""
    if dispatch is not None or use_pallas is None:
        return dispatch
    return "pallas" if use_pallas else "reference"


@dataclasses.dataclass(frozen=True)
class NNDescentConfig:
    k: int = 20
    metric: str = "l2"
    max_iters: int = 12
    delta: float = 0.001  # stop when updates < delta * n * k
    rev_sample: Optional[int] = None  # reverse neighbors joined per node (default k)
    node_chunk: int = 2048  # nodes per local-join tile (bounds the (B,C,C) buffer)
    use_pallas: Optional[bool] = None  # DEPRECATED -> dispatch
    dispatch: Optional[str] = None  # kernels.ops dispatch enum


class NNDescentState(NamedTuple):
    ids: Array  # (n, k)
    dist: Array  # (n, k)
    is_new: Array  # (n, k) — entry not yet joined


def _random_init(x: Array, k: int, metric: str, key: Array, dispatch) -> NNDescentState:
    n = x.shape[0]
    # k distinct-ish random neighbors per node (collisions masked)
    ids = jax.random.randint(key, (n, k + 4), 0, n, dtype=jnp.int32)
    row = jnp.arange(n, dtype=jnp.int32)[:, None]
    ids = jnp.where(ids == row, -1, ids)
    dup = jnp.triu((ids[:, None, :] == ids[:, :, None]) & (ids[:, None, :] >= 0), k=1)
    ids = jnp.where(jnp.any(dup, axis=1), -1, ids)
    d = ops.gather_distance(x, x, ids, metric, dispatch=dispatch)
    d, ids = ops.topk_smallest(d, ids, k)
    ids = jnp.where(jnp.isfinite(d), ids, -1)
    return NNDescentState(ids=ids, dist=jnp.where(ids >= 0, d, jnp.inf), is_new=ids >= 0)


def _reverse_sample(ids: Array, is_new: Array, r: int):
    """Bounded reverse lists with propagated new/old flags: (n, r) each."""
    n, k = ids.shape
    owners = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
    flat_m = jnp.where(ids >= 0, ids, n).reshape(-1)
    flat_o = owners.reshape(-1)
    flat_f = is_new.reshape(-1)
    order = jnp.argsort(flat_m, stable=True)
    sm, so, sf = flat_m[order], flat_o[order], flat_f[order]
    (rev_ids, rev_new), _ = segments.grouped_top_r(
        sm, [so, sf], [-1, False], n, r
    )
    return rev_ids, rev_new


def _local_join_chunk(x, cand_ids, cand_new, metric, dispatch):
    """Join all (new x any) pairs inside each node's candidate list.

    Args:
      cand_ids: (B, C) candidate ids per node (-1 pad).
      cand_new: (B, C) new flags.
    Returns flat proposal triples (v, q, d) of length B*C*C (padded with -1)
    and the number of distance computations.
    """
    B, C = cand_ids.shape
    safe = jnp.maximum(cand_ids, 0)
    vec = x[safe]  # (B, C, dfeat)
    # pairwise distances inside the candidate set (one (C,C) tile per node)
    from repro.core import metrics as metrics_lib

    def tile(v):
        return metrics_lib.pairwise(metric, v, v)

    dmat = jax.vmap(tile)(vec)  # (B, C, C)
    valid = (cand_ids[:, :, None] >= 0) & (cand_ids[:, None, :] >= 0)
    iu = jnp.triu(jnp.ones((C, C), bool), k=1)[None]
    joinable = valid & iu & (cand_new[:, :, None] | cand_new[:, None, :])
    # also drop degenerate a == b pairs (duplicate ids across fwd/rev lists)
    joinable &= cand_ids[:, :, None] != cand_ids[:, None, :]
    n_comps = jnp.sum(joinable)
    a = jnp.broadcast_to(cand_ids[:, :, None], dmat.shape)
    b = jnp.broadcast_to(cand_ids[:, None, :], dmat.shape)
    d = jnp.where(joinable, dmat, jnp.inf)
    a = jnp.where(joinable, a, -1)
    b = jnp.where(joinable, b, -1)
    # proposals both directions
    v = jnp.concatenate([a.reshape(-1), b.reshape(-1)])
    q = jnp.concatenate([b.reshape(-1), a.reshape(-1)])
    dd = jnp.concatenate([d.reshape(-1), d.reshape(-1)])
    return v, q, dd, n_comps


@functools.partial(jax.jit, static_argnames=("metric", "chunk_size"))
def _lambda_round(x: Array, ids: Array, dist: Array, metric: str, chunk_size: int):
    """Canonical λ for already-sorted neighbor lists, chunked over rows.

    λ(j_i ∈ G[v]) = #{l < i : m(j_l, j_i) < m(v, j_i)} — the same occlusion
    rule the sequential commit path maintains incrementally (Rules 1-3 in
    ``construct.commit_wave``), evaluated from scratch on the final lists.
    m(v, j_i) is read off ``dist``; the member-pair distances are computed
    here and charged.  Returns ((n, k) λ, per-chunk comp counts).
    """
    n, k = ids.shape
    nchunks = -(-n // chunk_size)
    npad = nchunks * chunk_size
    pids = jnp.pad(ids, ((0, npad - n), (0, 0)), constant_values=-1)
    pdist = jnp.pad(dist, ((0, npad - n), (0, 0)), constant_values=jnp.inf)
    from repro.core import metrics as metrics_lib

    # mask[l, i] = l < i: occlusion only by closer-ranked members
    earlier = jnp.triu(jnp.ones((k, k), bool), k=1)[None]

    def body(_, i):
        ci = jax.lax.dynamic_slice_in_dim(pids, i * chunk_size, chunk_size, 0)
        cd = jax.lax.dynamic_slice_in_dim(pdist, i * chunk_size, chunk_size, 0)
        vec = x[jnp.maximum(ci, 0)]  # (B, k, dfeat)
        dmat = jax.vmap(lambda v: metrics_lib.pairwise(metric, v, v))(vec)
        valid = (ci[:, :, None] >= 0) & (ci[:, None, :] >= 0) & earlier
        occ = valid & (dmat < cd[:, None, :])
        lam = jnp.sum(occ, axis=1).astype(jnp.int32)
        return None, (jnp.where(ci >= 0, lam, 0), jnp.sum(valid, dtype=jnp.int32))

    _, (lam_chunks, comp_chunks) = jax.lax.scan(body, None, jnp.arange(nchunks))
    return lam_chunks.reshape(npad, k)[:n], comp_chunks


def recompute_lambda(
    ids: Array, dist: Array, x: Array, metric: str, *, node_chunk: int = 2048
) -> tuple[Array, int]:
    """Host wrapper for ``_lambda_round``: (λ table, exact python-int comps)."""
    lam, comp_chunks = _lambda_round(x, ids, dist, metric, node_chunk)
    return lam, sum(int(c) for c in comp_chunks)


@functools.partial(jax.jit, static_argnames=("metric", "dispatch", "chunk_size"))
def _join_round(
    x: Array,
    ids: Array,
    dist: Array,
    is_new: Array,
    rev_ids: Array,
    rev_new: Array,
    metric: str,
    dispatch,
    chunk_size: int,
):
    n, k = ids.shape
    r = rev_ids.shape[1]
    C = k + r
    cand_ids = jnp.concatenate([ids, rev_ids], axis=1)
    cand_new = jnp.concatenate([is_new, rev_new], axis=1)
    nchunks = -(-n // chunk_size)
    npad = nchunks * chunk_size
    cand_ids = jnp.pad(cand_ids, ((0, npad - n), (0, 0)), constant_values=-1)
    cand_new = jnp.pad(cand_new, ((0, npad - n), (0, 0)))

    lam0 = jnp.zeros_like(ids)

    def body(carry, i):
        cur_ids, cur_dist, cur_new, tot, ins = carry
        ci = jax.lax.dynamic_slice_in_dim(cand_ids, i * chunk_size, chunk_size, 0)
        cn = jax.lax.dynamic_slice_in_dim(cand_new, i * chunk_size, chunk_size, 0)
        v, q, d, nc = _local_join_chunk(x, ci, cn, metric, dispatch)
        res = merge.merge_candidates(cur_ids, cur_dist, lam0, v, q, d)
        # carried entries keep their flag, fresh inserts are new, and the
        # just-joined chunk's (fwd) entries become old — Dong's incremental
        # search, chunk-at-a-time.
        carried = jnp.where(
            res.old_slot >= 0,
            jnp.take_along_axis(cur_new, jnp.maximum(res.old_slot, 0), axis=1),
            False,
        )
        rows = jnp.arange(n)
        in_chunk = (rows >= i * chunk_size) & (rows < (i + 1) * chunk_size)
        nxt_new = res.is_new | (carried & ~in_chunk[:, None])
        return (res.nbr_ids, res.nbr_dist, nxt_new, tot + nc, ins + res.n_inserted), None

    (ids, dist, is_new_out, total, inserted), _ = jax.lax.scan(
        body,
        (ids, dist, is_new, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)),
        jnp.arange(nchunks),
    )
    return ids, dist, is_new_out, total, inserted


def build(
    x: Array,
    cfg: NNDescentConfig,
    key: Optional[Array] = None,
) -> tuple[KNNGraph, dict]:
    """Run NN-Descent to convergence. Returns (KNNGraph, stats dict)."""
    n = x.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    k = cfg.k
    st = _random_init(x, k, cfg.metric, key, _dispatch_of(cfg.dispatch, cfg.use_pallas))
    total_comps = float(n)  # init distances ~ n*k but pairs may repeat; count k*n
    total_comps = float(n * k)
    r = cfg.rev_sample or k
    updates_hist = []
    for it in range(cfg.max_iters):
        rev_ids, rev_new = _reverse_sample(st.ids, st.is_new, r)
        ids, dist, is_new, comps, upd = _join_round(
            x,
            st.ids,
            st.dist,
            st.is_new,
            rev_ids,
            rev_new,
            cfg.metric,
            _dispatch_of(cfg.dispatch, cfg.use_pallas),
            cfg.node_chunk,
        )
        st = NNDescentState(ids=ids, dist=dist, is_new=is_new)
        total_comps += float(comps)
        updates_hist.append(int(upd))
        if int(upd) < cfg.delta * n * k:
            break
    g = KNNGraph(
        nbr_ids=st.ids,
        nbr_dist=st.dist,
        nbr_lam=jnp.zeros_like(st.ids),
        rev_ids=jnp.full((n, 2 * k), -1, jnp.int32),
        rev_lam=jnp.zeros((n, 2 * k), jnp.int32),
        rev_ptr=jnp.zeros((n,), jnp.int32),
        alive=jnp.ones((n,), bool),
        n_valid=jnp.asarray(n, jnp.int32),
        sq_norms=graph_lib.squared_norms(x),
        row_scale=graph_lib.row_scales(x),
    )
    g = rebuild_reverse(g)
    stats = {
        "n_comps": total_comps,
        "scanning_rate": total_comps / (n * (n - 1) / 2.0),
        "iters": len(updates_hist),
        "updates": updates_hist,
    }
    return g, stats


def local_join_refine(
    g: KNNGraph,
    x: Array,
    metric: str = "l2",
    *,
    rounds: int = 1,
    node_chunk: int = 2048,
    use_pallas: Optional[bool] = None,
    dispatch: Optional[str] = None,
) -> tuple[KNNGraph, int]:
    """§IV-D refinement: NN-Descent join round(s) over an existing graph.

    Recovers missed true-neighbor pairs after online construction.  The
    refined lists get canonical λ recomputed (``recompute_lambda``) before
    the reverse rebuild, so ``rev_lam`` snapshots real occlusion factors and
    LGD search on a refined graph behaves like it does on a sequential
    build.  Returns (refined graph, exact python-int distance comps —
    join rounds plus the λ recompute).
    """
    ids, dist = g.nbr_ids, g.nbr_dist
    is_new = ids >= 0
    comps = 0
    k = g.k
    for _ in range(rounds):
        rev_ids, rev_new = _reverse_sample(ids, is_new, k)
        ids, dist, is_new, c, _ = _join_round(
            x, ids, dist, is_new, rev_ids, rev_new, metric,
            _dispatch_of(dispatch, use_pallas), node_chunk,
        )
        comps += int(c)
    lam, lam_comps = recompute_lambda(
        ids, dist, x, metric, node_chunk=node_chunk
    )
    comps += lam_comps
    g = g._replace(nbr_ids=ids, nbr_dist=dist, nbr_lam=lam)
    return rebuild_reverse(g), comps


def refine(
    g: KNNGraph,
    x: Array,
    metric: str = "l2",
    *,
    rounds: int = 1,
    node_chunk: int = 2048,
    use_pallas: Optional[bool] = None,
    dispatch: Optional[str] = None,
) -> tuple[KNNGraph, int]:
    """Bounded refinement sweep: the EFANNA-style recall-recovery pass.

    The canonical post-merge step of the divide-and-conquer construction
    path (``construct.build_parallel``): a fixed number of NN-Descent join
    rounds over the merged graph closes the residual recall gap the
    sub-graph merge leaves.  ``rounds=0`` is a no-op (returns ``g`` with 0
    comps), so callers can thread a config knob straight through.  Comps
    are exact python ints per the Counter64 policy.
    """
    if rounds <= 0:
        return g, 0
    return local_join_refine(
        g, x, metric, rounds=rounds, node_chunk=node_chunk,
        use_pallas=use_pallas, dispatch=dispatch,
    )
