"""Exact device-side event counters.

``BuildStats`` used to accumulate comparison/edge counts in float32, which is
exact only up to 2^24 — a production build (n=10^8, k=40) performs ~10^12
comparisons, so every wave past the first few thousand silently stopped
counting (flagged in the ROADMAP PR-1 notes).  JAX disables int64 by default
(x64 mode is a global flag we don't own), so the fix is a carried int32/uint32
pair: a ``Counter64`` is an exact 64-bit unsigned counter that lives on device
as two 32-bit words and folds new counts in with an explicit carry.

It is a NamedTuple, hence a pytree: it jits, donates, and carries through
``lax``-loops like any other ``BuildStats`` leaf.  Reading it (``int()`` /
``float()``) is the host sync, same discipline as before.
"""

from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp

Array = jax.Array

_WORD = 1 << 32


class Counter64(NamedTuple):
    """Exact 64-bit counter as (hi int32, lo uint32) device scalars.

    ``add`` folds in a non-negative per-step count (anything below 2^32 —
    wave-level counts are bounded by W * C * max_iters << 2^31); the uint32
    low word wraps naturally and the carry bumps the high word.
    """

    hi: Array  # () int32 — high 32 bits
    lo: Array  # () uint32 — low 32 bits

    @classmethod
    def zero(cls) -> "Counter64":
        return cls(jnp.zeros((), jnp.int32), jnp.zeros((), jnp.uint32))

    @classmethod
    def of(cls, value: Union[int, float]) -> "Counter64":
        """Host-side constructor; floats are truncated (counts are integers)."""
        v = int(value)
        if v < 0:
            raise ValueError(f"Counter64 holds non-negative counts, got {v}")
        return cls(
            jnp.asarray(v // _WORD, jnp.int32),
            jnp.asarray(v % _WORD, jnp.uint32),
        )

    def add(self, amount: Array) -> "Counter64":
        """Fold in a traced scalar count (int dtype, 0 <= amount < 2^32)."""
        amt = jnp.asarray(amount).astype(jnp.uint32)
        lo = self.lo + amt  # wraps mod 2^32
        hi = self.hi + (lo < amt).astype(jnp.int32)  # wrapped iff lo < amt
        return Counter64(hi, lo)

    def to_float(self) -> Array:
        """Traced float32 view — for monitoring reductions (e.g. the psum in
        ``core.distributed``) where float rounding is acceptable."""
        return self.hi.astype(jnp.float32) * jnp.float32(_WORD) + self.lo.astype(
            jnp.float32
        )

    # host reads (each is the one device sync, as with any stats leaf)
    def __int__(self) -> int:
        return (int(self.hi) << 32) + int(self.lo)

    def __float__(self) -> float:
        return float(int(self))
