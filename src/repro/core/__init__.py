"""repro.core — the paper's contribution: online k-NN graph construction.

Public API:
  * ``metrics``       — generic distance registry (l2/l1/cosine/chi2/ip)
  * ``brute``         — tiled exact k-NN (ground truth, seed graph, baseline)
  * ``graph``         — KNNGraph state (G ∪ Ḡ as dense arrays) + invariants
  * ``search``        — batched Enhanced Hill-Climbing (Alg. 1)
  * ``construct``     — OLG (Alg. 2) / LGD (Alg. 3) wave-based online build
  * ``nndescent``     — NN-Descent baseline + §IV-D refinement
  * ``dynamic``       — online insert / remove (§IV-C)
  * ``hierarchy``     — coarse landmark level for hierarchical entry points
  * ``distributed``   — shard_map sharded build & scatter-gather search
  * ``segments``      — segmented-scan / group-by primitives (shared core)
  * ``counters``      — exact 64-bit device-side counters (BuildStats)
"""

from repro.core import (
    brute,
    construct,
    counters,
    dynamic,
    graph,
    hierarchy,
    merge,
    metrics,
    nndescent,
    search,
    segments,
)

from repro.core.construct import BuildConfig, build
from repro.core.counters import Counter64
from repro.core.graph import KNNGraph, empty_graph
from repro.core.search import SearchConfig
from repro.core.brute import brute_force_knn, recall_at_k

__all__ = [
    "brute",
    "construct",
    "counters",
    "Counter64",
    "dynamic",
    "graph",
    "hierarchy",
    "merge",
    "metrics",
    "nndescent",
    "search",
    "segments",
    "BuildConfig",
    "build",
    "KNNGraph",
    "empty_graph",
    "SearchConfig",
    "brute_force_knn",
    "recall_at_k",
]
