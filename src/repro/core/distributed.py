"""Sharded-graph parallelism: the production (1000+ node) k-NN deployment.

DESIGN.md §4: the dataset is partitioned row-wise across every device of the
mesh; each device owns an independent LGD graph over its shard.

  * **build** — embarrassingly parallel: one ``shard_map`` wave step runs
    search+commit per shard with ZERO collective traffic (the paper's online
    property is what makes this possible: a shard never needs another
    shard's rows to insert its own).  Node failure loses one shard only;
    the shard is rebuilt from its data slice while serving continues on the
    rest (test_distributed.py exercises the degraded-recall path).
  * **search** — scatter-gather: the query wave is replicated (one broadcast),
    every shard runs local EHC, and the per-shard top-k lists (k ids+dists
    per query — tiny) meet in an all-gather + tournament top-k merge.
    Recall >= single-graph recall; cost is the classic p-way fanout trade.

Ids are translated local -> global (shard_index * shard_rows + local) at the
merge boundary, so callers see one logical id space.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import construct as construct_lib
from repro.core import search as search_lib
from repro.core.graph import KNNGraph
from repro.kernels import compat, ops

Array = jax.Array


def _flat_axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


def _shard_index(ax: tuple, mesh: Mesh) -> Array:
    """Linearized shard index over ``ax`` (row-major, shapes from the mesh —
    static, so no dependence on the newer ``jax.lax.axis_size``)."""
    idx = jnp.int32(0)
    stride = 1
    for a in reversed(ax):
        idx = idx + jax.lax.axis_index(a) * stride
        stride = stride * mesh.shape[a]
    return idx


def graph_pspec(axes) -> KNNGraph:
    """PartitionSpecs for a row-sharded KNNGraph (n_valid replicated —
    distributed builds keep shards in lockstep)."""
    return KNNGraph(
        nbr_ids=P(axes, None),
        nbr_dist=P(axes, None),
        nbr_lam=P(axes, None),
        rev_ids=P(axes, None),
        rev_lam=P(axes, None),
        rev_ptr=P(axes),
        alive=P(axes),
        n_valid=P(),
        sq_norms=P(axes),
        row_scale=P(axes),
    )


def wave_step(
    g: KNNGraph,
    x: Array,
    pos: Array,  # () int32 — wave rows are [pos, pos + cfg.wave)
    n_real: Array,  # () int32
    key: Array,
    cfg: construct_lib.BuildConfig,
) -> tuple[KNNGraph, Array]:
    """One fused search+commit insertion wave (the unit the dry-run lowers).

    Thin shard-local adapter over ``construct.wave_core`` — the single
    implementation of wave semantics; returns (updated graph, distance
    computations spent, edges inserted).
    """
    g2, stats = construct_lib.wave_core(
        g, x, pos, key, construct_lib.zero_stats(), cfg, n_real=n_real
    )
    # monitoring-only float views: the cross-shard psum tolerates rounding,
    # and the per-wave counts (< W * C * max_iters) are far below 2^24 anyway
    return g2, stats.n_comps.to_float(), stats.n_inserted_edges.to_float()


def make_distributed_build_step(
    mesh: Mesh, cfg: construct_lib.BuildConfig, axes: Optional[Sequence[str]] = None
):
    """shard_map'd wave step: every shard inserts its own next W rows.

    Returns step(g, x, pos, n_real, key) -> (g, total_comps, total_edges);
    all graph/data leaves row-sharded over ``axes`` (default: every mesh
    axis).  No collectives except the final stats psums (monitoring only).
    """
    ax = tuple(axes) if axes is not None else _flat_axes(mesh)
    gspec = graph_pspec(ax)

    def local(g, x, pos, n_real, key):
        # per-shard PRNG: fold in the linearized shard index
        idx = _shard_index(ax, mesh)
        g2, comps, edges = wave_step(
            g, x, pos, n_real, jax.random.fold_in(key, idx), cfg
        )
        return g2, jax.lax.psum(comps, ax), jax.lax.psum(edges, ax)

    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(gspec, P(ax, None), P(), P(), P(None)),
        out_specs=(gspec, P(), P()),
    )


def make_distributed_search(
    mesh: Mesh,
    scfg: search_lib.SearchConfig,
    axes: Optional[Sequence[str]] = None,
):
    """shard_map'd scatter-gather search.

    Returns search(g, x, q, key) -> (ids (B,k) GLOBAL ids, dists (B,k)),
    with q replicated, graph/data row-sharded, and one all-gather of the
    per-shard (k ids, k dists) — the only collective on the serving path.
    """
    ax = tuple(axes) if axes is not None else _flat_axes(mesh)
    gspec = graph_pspec(ax)

    def local(g, x, q, key):
        idx = _shard_index(ax, mesh)
        n_local = x.shape[0]
        res = search_lib.search(g, x, q, jax.random.fold_in(key, idx), scfg)
        gids = jnp.where(res.ids >= 0, res.ids + idx * n_local, -1)
        # tournament merge: gather every shard's top-k and re-select
        all_ids = jax.lax.all_gather(gids, ax, axis=0, tiled=False)  # (P, B, k)
        all_d = jax.lax.all_gather(res.dists, ax, axis=0, tiled=False)
        nsh = all_ids.shape[0]
        B = q.shape[0]
        cat_i = jnp.moveaxis(all_ids, 0, 1).reshape(B, nsh * scfg.k)
        cat_d = jnp.moveaxis(all_d, 0, 1).reshape(B, nsh * scfg.k)
        d, i = ops.topk_smallest(cat_d, cat_i, scfg.k)
        return i, d

    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(gspec, P(ax, None), P(None, None), P(None)),
        out_specs=(P(None, None), P(None, None)),
    )


def init_sharded_state(
    mesh: Mesh,
    n_total: int,
    d: int,
    cfg: construct_lib.BuildConfig,
    *,
    axes: Optional[Sequence[str]] = None,
    seed: int = 0,
):
    """Device-sharded (graph, data) with per-shard exact seed graphs.

    Every shard gets its own |I|-row exact seed graph (Alg. 2 line 4-5 run
    per shard) so distributed construction starts from the same invariant
    the paper's sequential algorithm does.
    """
    ax = tuple(axes) if axes is not None else _flat_axes(mesh)
    n_dev = 1
    for a in ax:
        n_dev *= mesh.shape[a]
    assert n_total % n_dev == 0, (n_total, n_dev)
    n_local = n_total // n_dev

    gspec = graph_pspec(ax)

    def init_local(key):
        x = jax.random.uniform(key, (n_local, d), jnp.float32)
        from repro.core import brute

        n_seed = min(cfg.n_seed_init, n_local)
        g = brute.exact_seed_graph(
            x, n_seed, cfg.k, cfg.metric, rev_capacity=cfg.rev_cap, use_pallas=False
        )
        return g, x

    def shard_init():
        idx = _shard_index(ax, mesh)
        return init_local(jax.random.fold_in(jax.random.PRNGKey(seed), idx))

    fn = compat.shard_map(
        shard_init, mesh=mesh, in_specs=(), out_specs=(gspec, P(ax, None)),
    )
    return jax.jit(fn)()


def build_subgraphs(
    mesh: Mesh,
    x: Array,
    cfg: construct_lib.BuildConfig,
    key: Optional[Array] = None,
    axes: Optional[Sequence[str]] = None,
):
    """Per-device sub-graph builds over REAL data — ``construct
    .build_parallel``'s multi-device backend.

    ``x`` is split row-wise into one contiguous block per device; each device
    seeds an exact |I|-graph over its block and runs the shard-local fused
    wave step (the same ``wave_core`` the sequential build jits) with zero
    collective traffic.  Returns the per-shard graphs in LOCAL id spaces —
    exactly what ``merge.symmetric_merge`` folds — their coarse levels, and
    aggregate counters:

      (graphs: list[KNNGraph], coarses: list[CoarseLevel | None],
       n_comps: int, n_waves: int, n_edges: int)

    Under ``cfg.seed_mode == "coarse"`` each shard gets a derived coarse
    level (shard-LOCAL ids — ``hierarchy.derive_coarse``, maintenance work
    like the router's lazy re-derive, so uncharged) so the merge fold's
    cross searches seed coarsely instead of falling back to cold EHC; other
    seed modes return ``None`` per shard.
    """
    from repro.core import brute  # late: brute sits above distributed

    ax = tuple(axes) if axes is not None else _flat_axes(mesh)
    n_dev = 1
    for a in ax:
        n_dev *= mesh.shape[a]
    n = x.shape[0]
    if n % n_dev:
        raise ValueError(
            f"build_subgraphs needs n % n_devices == 0, got n={n} over "
            f"{n_dev} devices"
        )
    n_local = n // n_dev
    if key is None:
        key = jax.random.PRNGKey(0)
    n_seed = min(cfg.n_seed_init, n_local)
    gspec = graph_pspec(ax)

    def seed_local(xs):
        return brute.exact_seed_graph(
            xs, n_seed, cfg.k, cfg.metric, rev_capacity=cfg.rev_cap,
            dispatch=cfg.dispatch,
        )

    seed_fn = compat.shard_map(
        seed_local, mesh=mesh, in_specs=(P(ax, None),), out_specs=gspec
    )
    g = jax.jit(seed_fn)(x)
    step = jax.jit(make_distributed_build_step(mesh, cfg, ax))

    # stats stay device-side until the loop ends — no per-wave host sync
    comps_parts, edge_parts = [], []
    n_waves = 0
    pos = n_seed
    while pos < n_local:
        nr = min(cfg.wave, n_local - pos)
        key, sk = jax.random.split(key)
        g, comps, edges = step(
            g, x, jnp.asarray(pos, jnp.int32), jnp.asarray(nr, jnp.int32), sk
        )
        comps_parts.append(comps)  # psums across shards, monitoring-grade
        edge_parts.append(edges)
        pos += nr
        n_waves += 1
    total_comps = float(n_dev * (n_seed * (n_seed - 1) // 2)) + sum(
        float(c) for c in comps_parts
    )
    total_edges = sum(float(e) for e in edge_parts)
    graphs = []
    gh = jax.device_get(g)
    for s in range(n_dev):
        lo, hi = s * n_local, (s + 1) * n_local
        graphs.append(
            KNNGraph(
                nbr_ids=jnp.asarray(gh.nbr_ids[lo:hi]),
                nbr_dist=jnp.asarray(gh.nbr_dist[lo:hi]),
                nbr_lam=jnp.asarray(gh.nbr_lam[lo:hi]),
                rev_ids=jnp.asarray(gh.rev_ids[lo:hi]),
                rev_lam=jnp.asarray(gh.rev_lam[lo:hi]),
                rev_ptr=jnp.asarray(gh.rev_ptr[lo:hi]),
                alive=jnp.asarray(gh.alive[lo:hi]),
                n_valid=jnp.asarray(n_local, jnp.int32),
                sq_norms=jnp.asarray(gh.sq_norms[lo:hi]),
                row_scale=jnp.asarray(gh.row_scale[lo:hi]),
            )
        )
    coarses: list = [None] * n_dev
    if cfg.seed_mode == "coarse":
        from repro.core import hierarchy  # late: hierarchy imports construct

        for s, gs in enumerate(graphs):
            lo = s * n_local
            coarses[s] = hierarchy.derive_coarse(
                gs, x[lo : lo + n_local], cfg,
                jax.random.fold_in(key, 500_000 + s),
            )
    return graphs, coarses, int(total_comps), n_waves * n_dev, int(total_edges)


def merge_pairs_mesh(
    pairs,
    xs,
    scfg,
    keys,
    coarses=None,
):
    """Merge P equal-shape sub-graph pairs under ``shard_map``, one pair per
    device — the mesh-resident fold level of ``merge.merge_subgraphs``.

    Each pair's leaves are stacked along a new leading axis and sharded over
    a P-device sub-mesh; the per-device body runs the full-batch cross
    searches (coarse-seeded when every pair carries levels) and the SAME
    traceable commit as the host path (``merge.merge_commit_core`` — one
    implementation of merge semantics), so proposal assembly, candidate
    commit and reverse rebuild all stay device-resident.

    Args:
      pairs: list of (g_a, g_b) fully-allocated sub-graphs, identical leaf
        shapes across pairs (the caller checks; shapes must stack).
      xs: list of (n_a + n_b, d) data slices, one per pair.
      scfg: ``search.SearchConfig`` for the cross searches.
      keys: list of per-pair PRNG keys.
      coarses: optional list of (coarse_a, coarse_b) CoarseLevels, all
        present (mixed None entries must be filtered by the caller); cross
        searches then seed coarsely, else randomly.

    Returns (list of merged KNNGraph, total cross + hop comps as an exact
    host int).
    """
    import dataclasses

    from repro.core import merge as merge_lib

    P_n = len(pairs)
    mesh = compat.make_mesh((P_n,), ("pairs",))
    stack = lambda trees: jax.tree.map(lambda *ls: jnp.stack(ls), *trees)
    ga_s = stack([a for a, _ in pairs])
    gb_s = stack([b for _, b in pairs])
    x_s = jnp.stack(xs)
    k_s = jnp.stack(keys)
    n_a = pairs[0][0].capacity
    use_coarse = coarses is not None and scfg.seed_mode == "coarse"
    scfg_eff = (
        scfg if use_coarse else dataclasses.replace(scfg, seed_mode="random")
    )
    args = (ga_s, gb_s, x_s, k_s)
    if use_coarse:
        args += (stack([ca for ca, _ in coarses]),
                 stack([cb for _, cb in coarses]))

    def local(ga, gb, xp, kk, *cs):
        take0 = lambda t: jax.tree.map(lambda a: a[0], t)
        g_a, g_b = take0(ga), take0(gb)
        ca = take0(cs[0]) if cs else None
        cb = take0(cs[1]) if cs else None
        xp0, kk0 = xp[0], kk[0]
        xa, xb = xp0[:n_a], xp0[n_a:]
        k_ab, k_ba = jax.random.split(kk0)
        res_ab = search_lib.search(g_b, xb, xa, k_ab, scfg_eff, coarse=cb)
        res_ba = search_lib.search(g_a, xa, xb, k_ba, scfg_eff, coarse=ca)
        merged, hop_c = merge_lib.merge_commit_core(
            g_a, g_b, xa, xb, res_ab.ids, res_ab.dists,
            res_ba.ids, res_ba.dists, scfg.metric, scfg.dispatch,
        )
        comps = (
            jnp.sum(res_ab.n_comps, dtype=jnp.int32)
            + jnp.sum(res_ba.n_comps, dtype=jnp.int32)
            + hop_c
        )
        expand = lambda t: jax.tree.map(lambda a: a[None], t)
        return expand(merged), comps[None]

    spec = P("pairs")
    fn = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=tuple(spec for _ in args),
        out_specs=(spec, spec),
    )
    out_g, out_c = jax.jit(fn)(*args)
    out_g = jax.device_get(out_g)
    graphs = [
        jax.tree.map(lambda a, i=i: jnp.asarray(a[i]), out_g)
        for i in range(P_n)
    ]
    return graphs, sum(int(c) for c in jax.device_get(out_c))
