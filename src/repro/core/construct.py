"""Online k-NN graph construction — OLG (Alg. 2) and LGD (Alg. 3), TPU-native.

The paper inserts samples one at a time: search the graph under construction
with the new sample as query, join its top-k result as a new row, and update
the k-NN lists of every vertex the search compared against.  On TPU we insert
*waves* of W samples (DESIGN.md §2, deviation §8.1):

  1. the whole wave searches the frozen graph G_t in parallel (core.search);
  2. an intra-wave distance tile lets near-simultaneous arrivals find each
     other (what sequential insertion gives for free);
  3. one batched commit produces G_{t+1}:
       * new rows  = top-k over (search result ‖ intra-wave candidates),
       * edge updates to existing rows = the (vertex, query, distance) triples
         logged in the search's visited tables, merged with core.merge,
       * reverse lists appended (ring buffers),
       * LGD occlusion factors λ updated under Rules 1-3 using ONLY distances
         the search already computed — the visited table *is* the paper's D
         array (default ∞), the intra-wave tile covers wave-wave pairs.

W=1 degenerates to the paper's sequential algorithm exactly; W=256..4096 is
the production setting.  ``lgd=False`` gives OLG (Alg. 2): same flow, no λ
bookkeeping and no expansion filtering.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import brute, merge
from repro.core import search as search_lib
from repro.core.counters import Counter64
from repro.core.graph import KNNGraph, row_scales, squared_norms
from repro.core.search import SearchConfig
from repro.kernels import compat, ops
from repro.kernels import precision as precision_lib

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    k: int = 20  # graph degree (size of NN lists)
    metric: str = "l2"
    n_seed_init: int = 256  # |I|, fixed to 256 across the paper
    wave: int = 256  # W — queries inserted per batched round
    lgd: bool = True  # Alg. 3 (True) vs Alg. 2 / OLG (False)
    intra_wave: bool = True  # wave members see each other (W x W tile)
    rev_cap: Optional[int] = None  # reverse-list ring capacity (default 2k)
    ins_cap_per_q: Optional[int] = None  # rows one query may update (default 3k)
    # search parameters (Alg. 1/3 inner loop)
    beam: int = 40
    n_seeds: int = 8  # p
    hash_slots: Optional[int] = None  # None = auto-size from beam/max_iters
    max_iters: int = 60
    use_pallas: Optional[bool] = None  # DEPRECATED -> dispatch
    dispatch: Optional[str] = None  # None -> "auto"; see SearchConfig
    # distance-engine precision of the insertion searches; the serving-side
    # SearchConfig inherits it (index.lifecycle builds its search config here)
    precision: str = "fp32"  # "fp32" | "bf16" | "int8" | "pq"
    rerank_factor: int = 4  # pq: exact re-rank width = rerank_factor * k
    data_bf16: bool = False  # store the dataset bf16 (distances accum f32)
    # hierarchical entry-point seeding (core.hierarchy)
    seed_mode: str = "random"  # "random" | "coarse"
    coarse_landmarks: Optional[int] = None  # L; None = ~4·√n (hierarchy)
    coarse_members: int = 8  # M — member-cell ring capacity per landmark
    coarse_top: int = 4  # T winning landmarks seeding each fine search

    def __post_init__(self):
        if self.use_pallas is not None:
            warnings.warn(
                "BuildConfig.use_pallas is deprecated; use dispatch="
                "'auto'|'pallas'|'interpret'|'reference' instead",
                DeprecationWarning,
                stacklevel=2,
            )
            if self.dispatch is None:
                object.__setattr__(
                    self, "dispatch",
                    "pallas" if self.use_pallas else "reference",
                )
            object.__setattr__(self, "use_pallas", None)
        if self.dispatch is None:
            object.__setattr__(self, "dispatch", "auto")
        assert self.dispatch in ops.DISPATCHES, self.dispatch
        precision_lib.validate_precision(self.precision)

    def search_config(self) -> SearchConfig:
        return SearchConfig(
            k=self.k,
            beam=max(self.beam, self.k),
            n_seeds=self.n_seeds,
            hash_slots=self.hash_slots,
            max_iters=self.max_iters,
            metric=self.metric,
            use_lgd_mask=self.lgd,
            dispatch=self.dispatch,
            precision=self.precision,
            rerank_factor=self.rerank_factor,
            seed_mode=self.seed_mode,
            coarse_top=self.coarse_top,
        )


class BuildStats(NamedTuple):
    """Device-side build counters — the carry of the fused wave loop.

    All leaves live on device; the build loop folds each wave's contribution
    in *inside* the jitted step, so reading a field (``float()`` / ``int()``)
    is the only host sync and happens once, after the loop.
    ``n_comps``/``n_inserted_edges`` are exact 64-bit ``Counter64`` pairs
    (two int32 words with explicit carry) — float32 accumulation was only
    exact to 2^24, far below production comparison counts.
    """

    n_comps: Counter64  # total distance computations (Eq. 2 numerator)
    n_waves: Array  # () int32
    n_inserted_edges: Counter64


def zero_stats(n_comps: float = 0.0) -> BuildStats:
    """Fresh stats carry (optionally pre-charged with seed-graph comps)."""
    return BuildStats(
        n_comps=Counter64.of(n_comps),
        n_waves=jnp.zeros((), jnp.int32),
        n_inserted_edges=Counter64.zero(),
    )


def scanning_rate(stats: BuildStats, n: int) -> float:
    """Eq. 2: c = C / (n (n-1) / 2)."""
    return float(stats.n_comps) / (n * (n - 1) / 2.0)


# ---------------------------------------------------------------------------
# Wave commit
# ---------------------------------------------------------------------------


def _lookup_D(
    vis_ids: Array,  # (W, H) per-wave-lane tables
    vis_dist: Array,
    lane: Array,  # (T,) which lane's table to consult
    ids: Array,  # (T, k) ids to look up
    probes: int,
) -> Array:
    """D(q_lane, ids): distance if the search computed it, else ∞ (Rule 1/3)."""
    H = vis_ids.shape[1]
    slots = search_lib._probe_slots(ids, H, probes)  # (T, k, P)
    got_ids = vis_ids[lane[:, None, None], slots]
    got_d = vis_dist[lane[:, None, None], slots]
    hit = got_ids == ids[..., None]
    return jnp.min(jnp.where(hit, got_d, jnp.inf), axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg",))
def commit_wave(
    g: KNNGraph,
    x: Array,
    q_start: Array,  # () int32 — wave rows are [q_start, q_start + W)
    n_real: Array,  # () int32 — how many of the W are real (tail padding)
    res: search_lib.SearchResult,
    cfg: BuildConfig,
) -> tuple[KNNGraph, Array]:
    """Apply one wave's results to the graph. Returns (graph, edges_inserted)."""
    W = res.ids.shape[0]
    cap, k = g.nbr_ids.shape
    lanes = jnp.arange(W, dtype=jnp.int32)
    q_ids = q_start + lanes
    q_mask = lanes < n_real
    xq = x[jnp.minimum(q_ids, cap - 1)]
    # wave-row ‖x‖² and int8 scales: computed ONCE here, the norms reused by
    # the intra-wave tile, and both written into their graph-resident caches
    # at commit (step 4) — the caches' incremental maintenance point for
    # insertions (sq_norms and row_scale share owners everywhere)
    xq_sq = squared_norms(xq)
    xq_sc = row_scales(xq)

    # ---- 1. new-row lists: search results ‖ intra-wave candidates ----------
    new_ids, new_dist = res.ids, res.dists
    if cfg.intra_wave and W > 1:
        tile = ops.pairwise_distance(
            xq, xq, cfg.metric, dispatch=cfg.dispatch,
            x_sq_norms=xq_sq if cfg.metric == "l2" else None,
        )
        off = ~(q_mask[None, :] & q_mask[:, None]) | jnp.eye(W, dtype=bool)
        tile = jnp.where(off, jnp.inf, tile)
        wave_ids = jnp.broadcast_to(q_ids[None, :], (W, W))
        cat_d = jnp.concatenate([new_dist, tile], axis=1)
        cat_i = jnp.concatenate([new_ids, wave_ids], axis=1)
        new_dist, new_ids = ops.topk_smallest(cat_d, cat_i, k)
    new_ids = jnp.where(jnp.isfinite(new_dist), new_ids, -1)
    new_dist = jnp.where(new_ids >= 0, new_dist, jnp.inf)

    # ---- 2. candidate edges into existing rows ------------------------------
    ins_cap = cfg.ins_cap_per_q or 3 * k
    v_all = res.vis_ids  # (W, H)
    d_all = res.vis_dist
    kth = g.nbr_dist[jnp.maximum(v_all, 0), k - 1]
    qual = (v_all >= 0) & q_mask[:, None] & (d_all < kth)
    # keep each query's best ins_cap target rows
    keyed = jnp.where(qual, d_all, jnp.inf)
    order = jnp.argsort(keyed, axis=1)[:, :ins_cap]
    v_kept = jnp.take_along_axis(jnp.where(qual, v_all, -1), order, axis=1)
    d_kept = jnp.take_along_axis(keyed, order, axis=1)
    v_flat = v_kept.reshape(-1)
    d_flat = d_kept.reshape(-1)
    q_flat = jnp.broadcast_to(q_ids[:, None], (W, ins_cap)).reshape(-1)
    lane_flat = jnp.broadcast_to(lanes[:, None], (W, ins_cap)).reshape(-1)

    mres = merge.merge_candidates(
        g.nbr_ids, g.nbr_dist, g.nbr_lam, v_flat, q_flat, d_flat
    )
    m_ids, m_dist, m_lam = mres.nbr_ids, mres.nbr_dist, mres.nbr_lam

    # ---- 3. LGD occlusion-factor rules (Alg. 3 / updateG) -------------------
    if cfg.lgd:
        T = v_flat.shape[0]
        probes = 8
        safe_v = jnp.minimum(jnp.maximum(v_flat, 0), cap - 1)
        row_ids = m_ids[safe_v]  # (T, k) merged list of the target row
        at_q = row_ids == q_flat[:, None]
        inserted = jnp.any(at_q, axis=1) & (v_flat >= 0)
        j_star = jnp.argmax(at_q, axis=1)  # slot of q in the merged row
        # D(q, member_j): wave-wave pairs from the intra tile, others from the
        # visited hash (∞ when the search never compared them — Rule 1).
        is_wave = (row_ids >= q_start) & (row_ids < q_start + W)
        D_hash = _lookup_D(res.vis_ids, res.vis_dist, lane_flat, row_ids, probes)
        if cfg.intra_wave and W > 1:
            w_idx = jnp.clip(row_ids - q_start, 0, W - 1)
            D_wave = tile[lane_flat[:, None], w_idx]
            D = jnp.where(is_wave, D_wave, D_hash)
        else:
            D = jnp.where(is_wave, jnp.inf, D_hash)
        occludes = (D < d_flat[:, None]) & (row_ids >= 0) & inserted[:, None]
        slots_k = jnp.arange(k, dtype=jnp.int32)[None, :]
        before = slots_k < j_star[:, None]
        after = slots_k > j_star[:, None]
        # Rule 2: λ(q) = #{j ranked before q : D(q, x_j) < m(q, v)}
        lam_q = jnp.sum(occludes & before, axis=1).astype(jnp.int32)
        m_lam = m_lam.at[
            jnp.where(inserted, safe_v, cap), jnp.where(inserted, j_star, 0)
        ].add(jnp.where(inserted, lam_q, 0), mode="drop")
        # Rule 3: λ(x_j) += 1 for j ranked after q with D(q, x_j) < m(q, v)
        add3 = (occludes & after).astype(jnp.int32)  # (T, k)
        m_lam = m_lam.at[jnp.where(inserted, safe_v, cap)[:, None], slots_k].add(
            jnp.where(inserted[:, None], add3, 0), mode="drop"
        )
    else:
        inserted = jnp.any(m_ids[jnp.minimum(jnp.maximum(v_flat, 0), cap - 1)] == q_flat[:, None], axis=1) & (
            v_flat >= 0
        )

    # ---- 4. write back: existing-row merges + new rows ----------------------
    # padding lanes scatter to the drop sentinel: clamping them to cap-1
    # would collide with the real last row when capacity == n and the final
    # wave is partial (duplicate-index scatters resolve in undefined order)
    drop_q = jnp.where(q_mask, jnp.minimum(q_ids, cap - 1), cap)
    nbr_ids = m_ids.at[drop_q].set(new_ids, mode="drop")
    nbr_dist = m_dist.at[drop_q].set(new_dist, mode="drop")
    # λ init 0 on join (Alg. 3)
    nbr_lam = m_lam.at[drop_q].set(jnp.zeros_like(new_ids), mode="drop")
    # norm- and scale-cache maintenance (shared owners, side by side)
    sq_norms = g.sq_norms.at[drop_q].set(xq_sq, mode="drop")
    row_scale = g.row_scale.at[drop_q].set(xq_sc, mode="drop")

    # ---- 5. reverse-list appends --------------------------------------------
    # (a) new rows list their members; (b) inserted queries join target rows.
    # rev_lam snapshots the forward twin's λ at append time: 0 for (a) — new
    # rows join with λ = 0 (Alg. 3) — and the Rule-2 λ(q) for (b).
    own_a = jnp.broadcast_to(q_ids[:, None], (W, k)).reshape(-1)
    mem_a = jnp.where(q_mask[:, None], new_ids, -1).reshape(-1)
    own_b = jnp.where(inserted, v_flat, -1)
    mem_b = jnp.where(inserted, q_flat, -1)
    owners = jnp.concatenate([own_a, own_b])
    members = jnp.concatenate([mem_a, mem_b])
    lam_b = jnp.where(inserted, lam_q, 0) if cfg.lgd else jnp.zeros_like(own_b)
    lams = jnp.concatenate([jnp.zeros_like(own_a), lam_b])
    rev_ids, rev_lam, rev_ptr = merge.append_reverse(
        g.rev_ids, g.rev_lam, g.rev_ptr, owners, members, lams
    )

    alive = g.alive.at[drop_q].set(True, mode="drop")
    n_valid = jnp.minimum(g.n_valid + n_real, cap).astype(jnp.int32)
    g2 = KNNGraph(
        nbr_ids=nbr_ids,
        nbr_dist=nbr_dist,
        nbr_lam=nbr_lam,
        rev_ids=rev_ids,
        rev_lam=rev_lam,
        rev_ptr=rev_ptr,
        alive=alive,
        n_valid=n_valid,
        sq_norms=sq_norms,
        row_scale=row_scale,
    )
    return g2, mres.n_inserted


# ---------------------------------------------------------------------------
# Fused wave step + driver
# ---------------------------------------------------------------------------


def wave_core(
    g: KNNGraph,
    x: Array,
    pos: Array,  # () int32 — wave rows are [pos, pos + W)
    key: Array,
    stats: BuildStats,
    cfg: BuildConfig,
    *,
    n_real: Optional[Array] = None,
    coarse=None,
    enc=None,
):
    """Traceable fused search+commit: one wave of W insertions, no host sync.

    This is the single implementation behind the jitted ``wave_step`` (local
    builds) and the shard-local step of ``core.distributed`` — both paths run
    the identical wave semantics.  ``n_real`` defaults to the in-range tail
    ``min(W, n - pos)``; distributed callers pass their shard-local count.

    ``coarse`` (a ``core.hierarchy.CoarseLevel``) makes the wave's insertion
    searches seed coarsely AND assigns each committed row to its winning
    landmark cell for free (``SearchResult.seed_cell``).  With a coarse
    level the return is the 3-tuple ``(graph, stats, coarse)``; without one
    it stays ``(graph, stats)`` — ``cfg.seed_mode="coarse"`` falls back to
    random seeding for this wave (the distributed shard step runs that way).

    ``enc`` is the compressed companion table of ``x`` when
    ``cfg.precision != "fp32"`` — ``build`` encodes the full dataset once
    up front and threads it through every wave (rows not yet inserted are
    never candidates, so the eager whole-dataset encode is exact); passing
    None makes the search re-derive it per wave, which is correct but
    wasteful.
    """
    W = cfg.wave
    n = x.shape[0]
    pos = pos.astype(jnp.int32)
    if n_real is None:
        n_real = jnp.minimum(W, n - pos).astype(jnp.int32)
    q_ids = jnp.minimum(pos + jnp.arange(W, dtype=jnp.int32), n - 1)
    q = x[q_ids]
    scfg = cfg.search_config()
    if coarse is None and scfg.seed_mode == "coarse":
        scfg = dataclasses.replace(scfg, seed_mode="random")
    res = search_lib.search(g, x, q, key, scfg, coarse=coarse, enc=enc)
    res = res._replace(
        n_comps=jnp.where(jnp.arange(W) < n_real, res.n_comps, 0)
    )
    g2, edges = commit_wave(g, x, pos, n_real, res, cfg)
    comps = jnp.sum(res.n_comps)  # int32; bounded by W * C * max_iters << 2^31
    if cfg.intra_wave and W > 1:
        nr = n_real.astype(jnp.int32)
        comps = comps + nr * (nr - 1) // 2
    stats2 = BuildStats(
        n_comps=stats.n_comps.add(comps),
        n_waves=stats.n_waves + 1,
        n_inserted_edges=stats.n_inserted_edges.add(edges),
    )
    if coarse is None:
        return g2, stats2
    from repro.core import hierarchy  # late: hierarchy imports construct

    lanes = jnp.arange(W, dtype=jnp.int32)
    rows = jnp.where(lanes < n_real, pos + lanes, -1)
    coarse2 = hierarchy.note_inserted(coarse, rows, res.seed_cell)
    return g2, stats2, coarse2


# The production wave step: one compiled call per wave with the graph and the
# stats carry donated (TPU/GPU update the ~O(cap*k) graph buffers in place;
# CPU skips donation — see compat.donating_jit).
wave_step = compat.donating_jit(
    wave_core, static_argnames=("cfg",), donate_argnums=(0, 4)
)


def build(
    x: Array,
    cfg: BuildConfig,
    key: Optional[Array] = None,
    *,
    wave_callback: Optional[Callable[[int, KNNGraph], None]] = None,
    callback_stride: int = 1,
    initial: Optional[tuple[KNNGraph, int]] = None,
    coarse=None,
    return_coarse: bool = False,
    tracker=None,
):
    """Build the k-NN graph over x with OLG (cfg.lgd=False) or LGD (True).

    The loop is host-round-trip free: each iteration is one fused jitted
    ``wave_step`` (search + commit + stats fold) and the Python side only
    advances an integer cursor.  The only host syncs are the optional
    ``wave_callback`` (every ``callback_stride`` waves) and whatever the
    caller reads from the returned device-side ``BuildStats``.

    ``tracker`` (an ``obs.Tracker``) makes the stride boundary a telemetry
    point as well: each ``callback_stride``-wave block runs under a
    ``build/stride`` span synced on the committed graph, and the cumulative
    build counters (comps, edges, partial scanning rate) are logged there —
    the ONLY host syncs telemetry introduces, and only at boundaries that
    are already sync points when a callback is in use.  ``tracker=None``
    (the default) keeps the loop bitwise and sync-wise identical to before.

    Args:
      x: (n, d) dataset.
      cfg: build configuration.
      key: PRNG key (entry-point sampling).
      wave_callback: called as f(wave_index, graph) every ``callback_stride``
        committed waves — checkpoint / progress hook (fault tolerance:
        construction resumes from any wave boundary, see train.checkpoint).
        Touching the graph inside the callback synchronizes the device.
        On TPU/GPU the graph's buffers are donated to the NEXT wave step:
        read/serialize it inside the callback, but copy it
        (``jax.device_get`` / ``jnp.copy``) before retaining it.
      callback_stride: waves between callback invocations (>= 1).
      initial: optional (graph, next_row) to resume from a checkpoint.
      coarse: optional ``core.hierarchy.CoarseLevel``.  With
        ``cfg.seed_mode == "coarse"`` and no level given, a fresh one is
        bootstrapped before the wave loop: over the full x (comps charged to
        the scanning rate) for a from-scratch build, or derived from the
        resumed graph (maintenance, uncharged) when ``initial`` is set.
      return_coarse: also return the (maintained) coarse level.

    Returns: (graph, stats) — stats leaves are device scalars — plus the
    coarse level when ``return_coarse``.
    """
    n = x.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    if callback_stride < 1:
        raise ValueError(f"callback_stride must be >= 1, got {callback_stride}")
    # one whole-dataset encode feeds every wave's insertion searches — rows
    # not yet inserted are masked out of candidate sets, so this is exact
    enc = (
        precision_lib.encode_dataset(x, cfg.precision)
        if cfg.precision != "fp32"
        else None
    )

    from repro.core import hierarchy  # late: hierarchy imports construct

    if initial is not None:
        g, start = initial
        if compat.donation_enabled():
            # wave_step donates its graph argument; copy so the caller's
            # graph (e.g. dynamic.insert's input index) survives the build
            g = jax.tree.map(jnp.copy, g)
        if coarse is None and cfg.seed_mode == "coarse" and int(start) > 0:
            key, ck = jax.random.split(key)
            coarse = hierarchy.derive_coarse(g, x, cfg, ck)
        pre_charge = 0
    else:
        n_seed = min(cfg.n_seed_init, n)
        g = brute.exact_seed_graph(
            x, n_seed, cfg.k, cfg.metric, rev_capacity=cfg.rev_cap,
            dispatch=cfg.dispatch,
        )
        start = n_seed
        # seed-graph comparisons count toward the scanning rate
        pre_charge = n_seed * (n_seed - 1) // 2
        if coarse is None and cfg.seed_mode == "coarse":
            key, ck = jax.random.split(key)
            coarse, coarse_comps = hierarchy.build_coarse(
                x, cfg, ck, assign_rows=jnp.arange(n_seed, dtype=jnp.int32)
            )
            pre_charge += coarse_comps
    stats = zero_stats(pre_charge)
    W = cfg.wave

    from repro.obs import NOOP  # late: keep core importable without obs init

    trk = tracker if tracker is not None else NOOP
    pos = int(start)
    n_waves = 0
    while pos < n:
        # one stride block = one span; under NoopTracker span() and sync()
        # are free passthroughs, so the untracked loop shape is unchanged
        with trk.span("build/stride") as sp:
            stride_end = n_waves + callback_stride
            while pos < n and n_waves < stride_end:
                key, sk = jax.random.split(key)
                if coarse is None:
                    g, stats = wave_step(
                        g, x, jnp.asarray(pos, jnp.int32), sk, stats, cfg,
                        enc=enc,
                    )
                else:
                    g, stats, coarse = wave_step(
                        g, x, jnp.asarray(pos, jnp.int32), sk, stats, cfg,
                        coarse=coarse, enc=enc,
                    )
                pos += min(W, n - pos)
                n_waves += 1
            sp.sync(g.nbr_ids)
        if wave_callback is not None and n_waves % callback_stride == 0:
            wave_callback(n_waves, g)
        if tracker is not None:
            # int()/float() on Counter64 is the host sync — stride-boundary
            # only, per the sync-boundary-only capture policy
            comps = int(stats.n_comps)
            trk.log_metrics(
                {
                    "build/rows_inserted": pos,
                    "build/n_comps": comps,
                    "build/n_inserted_edges": int(stats.n_inserted_edges),
                    "build/scanning_rate_partial": (
                        comps / (n * (n - 1) / 2.0) if n > 1 else 0.0
                    ),
                },
                step=n_waves,
            )

    if return_coarse:
        return g, stats, coarse
    return g, stats


# ---------------------------------------------------------------------------
# Divide-and-conquer construction: parallel sub-builds + symmetric merge
# ---------------------------------------------------------------------------


def partition_bounds(n: int, shards: int):
    """Contiguous partition boundaries (shards + 1 ints, balanced ±1 row).

    Matches the sharded router's split, so a catalog partitioned here and one
    partitioned by ``ShardedIndex.build`` agree row for row.
    """
    import numpy as np

    if not 1 <= shards <= n:
        raise ValueError(f"need 1 <= shards <= n, got {shards} for n={n}")
    return np.linspace(0, n, shards + 1).astype(int)


def build_parallel(
    x: Array,
    cfg: BuildConfig,
    key: Optional[Array] = None,
    *,
    shards: int = 2,
    refine_rounds: int = 1,
    search_chunk: int = 512,
    mesh=None,
    return_coarse: bool = False,
    sub_cfg: Optional[BuildConfig] = None,
    merge_scfg=None,
):
    """Divide-and-conquer build: S concurrent sub-builds + symmetric merges.

    The sequential online build caps construction throughput at one wave
    pipeline.  This path partitions ``x`` into ``shards`` contiguous blocks,
    builds an independent sub-graph per block through the SAME fused
    ``wave_core`` pipeline (host threads on CPU — each shard's compiled wave
    steps overlap; a ``mesh`` routes the sub-builds through
    ``core.distributed``'s shard_map step on multi-device), then folds the
    sub-graphs together with a balanced ``merge.merge_subgraphs`` tree of
    ``symmetric_merge`` calls and closes the residual recall gap with a
    bounded NN-Descent sweep (``nndescent.refine``).

    The merged graph lives in the same id space as a sequential build over
    ``x`` (global ids = row indices), and the result supports every online
    operation — ``dynamic.insert``/``remove`` ride on it unchanged.

    Args:
      x: (n, d) dataset.
      cfg: build configuration (shared by every sub-build and the merge
        searches).
      key: PRNG key; sub-build s folds in s, merges fold in their step.
      shards: number of partitions (1 degenerates to ``build``).
      refine_rounds: NN-Descent join rounds after the final merge (0 = none).
      search_chunk: cross-search batch size inside ``symmetric_merge``.
      mesh: optional device mesh — sub-builds run via
        ``distributed.build_subgraphs`` (requires n % n_devices == 0 and
        ``shards`` equal to the mesh's device count), and the merge-tree
        levels run mesh-resident under shard_map where pair shapes allow.
      return_coarse: append the merged graph's ``CoarseLevel`` to the
        return — the same contract as ``build``: the merge fold's root
        level when the tree produced one, a fresh ``derive_coarse``
        otherwise (always a level under ``seed_mode="coarse"``, else None).
      sub_cfg: optional distinct build configuration for the per-shard
        sub-builds.  The merge's cross-searches + second-hop proposals
        repair boundary and interior alike, so sub-builds can afford a
        lighter effort (smaller ``beam``/``hash_slots``) than a standalone
        build at the same quality target — the wallclock lever behind the
        ``parallel_gate`` CI record.  Defaults to ``cfg``.
      merge_scfg: optional ``SearchConfig`` for the merge-tree cross
        searches.  Merge hits only seed the candidate commit (the hop
        proposals widen them k_t-fold), so a shallow search — low
        ``max_iters``, ``beam == k`` — loses little recall; coarse-seeded
        entry points (``seed_mode="coarse"``) keep the shallow walks on
        target.  Defaults to ``cfg.search_config()``.

    Returns: (graph, stats) — stats aggregate sub-builds, merge candidate
    distances, and refinement comps (host-side fold, exact) — plus the
    coarse level when ``return_coarse``.
    """
    n = x.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    if shards == 1 and mesh is None:
        return build(x, cfg, key, return_coarse=return_coarse)
    bounds = partition_bounds(n, shards)
    sub = sub_cfg if sub_cfg is not None else cfg

    if mesh is not None:
        from repro.core import distributed  # late: distributed imports construct

        n_dev = int(mesh.devices.size)
        if shards != n_dev:  # validate BEFORE the expensive sub-builds
            raise ValueError(
                f"mesh has {n_dev} devices, build_parallel got "
                f"shards={shards} — on a mesh, one sub-graph per device"
            )
        graphs, coarses, sub_comps, sub_waves, sub_edges = (
            distributed.build_subgraphs(mesh, x, sub, key)
        )
    else:
        import concurrent.futures

        def _one(s: int):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            return build(
                x[lo:hi], sub, jax.random.fold_in(key, s), return_coarse=True
            )

        with concurrent.futures.ThreadPoolExecutor(max_workers=shards) as ex:
            results = list(ex.map(_one, range(shards)))
        graphs = [g for g, _, _ in results]
        # leaf coarse levels (shard-LOCAL ids) seed the level-0 merge
        # cross-searches; None everywhere under random seeding
        coarses = [c for _, _, c in results]
        sub_comps = sum(int(st.n_comps) for _, st, _ in results)
        sub_waves = sum(int(st.n_waves) for _, st, _ in results)
        sub_edges = sum(int(st.n_inserted_edges) for _, st, _ in results)

    from repro.core import nndescent  # late: nndescent is a leaf consumer

    scfg = merge_scfg if merge_scfg is not None else cfg.search_config()
    g, merge_comps, coarse = merge.merge_subgraphs(
        graphs, x, scfg, jax.random.fold_in(key, 1_000_000),
        search_chunk=search_chunk, coarses=coarses, mesh=mesh,
    )

    g, refine_comps = nndescent.refine(
        g, x, cfg.metric, rounds=refine_rounds, dispatch=cfg.dispatch
    )

    stats = BuildStats(
        n_comps=Counter64.of(sub_comps + merge_comps + refine_comps),
        n_waves=jnp.asarray(sub_waves, jnp.int32),
        n_inserted_edges=Counter64.of(sub_edges),
    )
    if not return_coarse:
        return g, stats
    if coarse is None and cfg.seed_mode == "coarse":
        # no folded level survived the tree (e.g. a seed-mode mismatch on
        # one shard) — re-derive on the merged graph, maintenance-style
        from repro.core import hierarchy  # late: hierarchy imports construct

        coarse = hierarchy.derive_coarse(
            g, x, cfg, jax.random.fold_in(key, 2_000_000)
        )
    return g, stats, coarse
