"""Two-level entry-point hierarchy: coarse landmark graph + assignment table.

ROADMAP Open item 1: the paper's Alg. 1 seeds every search with p uniform
draws over [0, n), so the walk re-descends the whole graph from random
altitude each time — our bench measured recall@10 0.977 at a **scanning rate
of 0.405** (n=2000/d=20).  EFANNA (arXiv 1609.07228) and the kNN-graph
search of arXiv 1701.08475 show the fix: route the query through a coarse
structure over a *sample* of the data first, then start the fine walk from
the sample's neighborhood.

This module builds that coarse structure out of the machinery we already
have — the one-expansion-body / one-distance-engine policies hold:

  * L ≈ 4·√n landmark rows are sampled; their vectors are snapshotted as the
    routing ``points`` (frozen: removals only mask seeds, they never
    invalidate routing);
  * a k-NN graph over the landmarks is built by ``construct.build`` itself
    (seed_mode forced back to "random" — the recursion bottoms out here);
  * a landmark→member ring table assigns full-graph rows to their winning
    landmark cell.  During online construction the assignment is FREE: each
    inserted row's own coarse search already knows its top-1 landmark
    (``SearchResult.seed_cell``), so ``construct.wave_core`` just appends it
    — the same batched FIFO ring idiom as the reverse lists
    (``merge.append_reverse``).

``search.init_state(seed_mode="coarse")`` consumes the level: a short EHC
pass over ``graph``/``points`` picks the top-T landmarks, and the fine beam
seeds from their ``landmark_rows`` plus their ``members`` cells.

Lifecycle: the level is a pytree and rides through jit; removals mask rows
(``purge_rows``), compaction remaps them (``remap_rows``), and a level can
always be re-derived offline from a live graph (``derive_coarse``) — which
is also how pre-v2 snapshots (no coarse payload) come back up.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import construct, merge
from repro.core.graph import KNNGraph
from repro.kernels import ops

Array = jax.Array


class CoarseLevel(NamedTuple):
    """The coarse entry-point level (a pytree — threads through jit)."""

    landmark_rows: Array  # (L,) int32 full-graph row per landmark; -1 = dead
    points: Array  # (L, d) float32 frozen routing vectors
    graph: KNNGraph  # k-NN graph over the landmarks (local ids [0, L))
    members: Array  # (L, M) int32 ring table: full-graph rows per cell; -1 empty
    mem_ptr: Array  # (L,) int32 total-appends counters (ring cursors)

    @property
    def n_landmarks(self) -> int:
        return self.landmark_rows.shape[0]


def default_landmarks(n: int) -> int:
    """L ≈ 4·√n, clamped to [32, 4096]: coarse search cost grows with L while
    cell size (and thus fine-seed locality) shrinks as n/L — √n balances the
    two, the paper-standard choice for two-level schemes."""
    return max(32, min(4096, int(4 * math.sqrt(max(n, 1)))))


def coarse_build_config(cfg):
    """The BuildConfig for the landmark graph: identical machinery, but seed
    coarsely never (the recursion bottoms out at random seeding)."""
    return dataclasses.replace(cfg, seed_mode="random", coarse_landmarks=None)


def nearest_landmark(
    points: Array,
    xs: Array,
    metric: str,
    *,
    use_pallas: Optional[bool] = None,
    dispatch: Optional[str] = None,
    chunk: int = 4096,
) -> Array:
    """Brute top-1 landmark per row of xs, chunked: (T,) int32 cell ids."""
    outs = []
    for lo in range(0, xs.shape[0], chunk):
        d = ops.pairwise_distance(
            xs[lo : lo + chunk], points, metric,
            use_pallas=use_pallas, dispatch=dispatch,
        )
        outs.append(jnp.argmin(d, axis=1).astype(jnp.int32))
    if not outs:
        return jnp.zeros((0,), jnp.int32)
    return jnp.concatenate(outs)


def note_inserted(coarse: CoarseLevel, rows: Array, cells: Array) -> CoarseLevel:
    """Append freshly inserted full-graph ``rows`` to their winning ``cells``
    (ring FIFO, same batched idiom as the reverse lists).  Traceable — this
    is the wave-commit maintenance point.  Negative rows/cells are padding."""
    members, _, mem_ptr = merge.append_reverse(
        coarse.members,
        jnp.zeros_like(coarse.members),
        coarse.mem_ptr,
        owner=rows,
        member=cells,
    )
    return coarse._replace(members=members, mem_ptr=mem_ptr)


def purge_rows(coarse: CoarseLevel, removed: Array) -> CoarseLevel:
    """Mask removed full-graph rows out of the level (post ``dynamic.remove``).

    A removed landmark keeps its routing vector — the coarse walk still
    travels through it — but its dead ``landmark_rows`` entry (and any dead
    member) stops seeding the fine beam, exactly like any dead row."""
    removed = removed.astype(jnp.int32)

    def mask(a: Array) -> Array:
        hit = jnp.any(a[..., None] == removed[None, :], axis=-1) & (a >= 0)
        return jnp.where(hit, -1, a)

    return coarse._replace(
        landmark_rows=mask(coarse.landmark_rows), members=mask(coarse.members)
    )


def remap_rows(coarse: CoarseLevel, id_map: Array) -> CoarseLevel:
    """Rewrite full-graph row references through a compaction ``id_map``
    ((cap,) old→new, -1 = dead) — the ``dynamic.compact`` follow-up."""
    cap = id_map.shape[0]

    def m(a: Array) -> Array:
        safe = jnp.clip(a, 0, cap - 1)
        return jnp.where((a >= 0) & (a < cap), id_map[safe], -1)

    return coarse._replace(
        landmark_rows=m(coarse.landmark_rows), members=m(coarse.members)
    )


def _assemble(
    x: Array,
    landmark_rows: Array,
    cfg,
    key: Array,
    assign_rows: Optional[Array],
) -> tuple[CoarseLevel, int]:
    """Build the landmark graph + member table for given landmark rows.
    Returns (level, comparisons charged)."""
    points = x[landmark_rows]
    gc, stats = construct.build(points, coarse_build_config(cfg), key)
    comps = int(stats.n_comps)
    L = int(landmark_rows.shape[0])
    M = cfg.coarse_members
    members = jnp.full((L, M), -1, jnp.int32)
    mem_ptr = jnp.zeros((L,), jnp.int32)
    level = CoarseLevel(
        landmark_rows=landmark_rows.astype(jnp.int32),
        points=points,
        graph=gc,
        members=members,
        mem_ptr=mem_ptr,
    )
    if assign_rows is not None and assign_rows.shape[0]:
        cells = nearest_landmark(
            points, x[assign_rows], cfg.metric, dispatch=cfg.dispatch
        )
        comps += int(assign_rows.shape[0]) * L
        level = note_inserted(level, assign_rows.astype(jnp.int32), cells)
    return level, comps


def build_coarse(
    x: Array,
    cfg,
    key: Array,
    *,
    assign_rows: Optional[Array] = None,
) -> tuple[CoarseLevel, int]:
    """Sample landmarks over the FULL dataset and build the coarse level.

    Used at the top of an online build: landmarks may reference rows not yet
    inserted — their vectors route fine from wave 1, and their
    ``landmark_rows`` seeds simply stay masked (dead) until those rows
    commit.  ``assign_rows`` (typically the exact-seed-graph prefix) get a
    brute cell assignment; every later row is assigned for free by its own
    insertion search (``SearchResult.seed_cell``).

    Returns (level, comps) with comps = landmark-graph build + brute
    assignment comparisons, so the caller can charge them to the scanning
    rate (Eq. 2 honesty).
    """
    n = x.shape[0]
    L = min(cfg.coarse_landmarks or default_landmarks(n), n)
    key_s, key_b = jax.random.split(key)
    landmark_rows = jax.random.choice(
        key_s, n, shape=(L,), replace=False
    ).astype(jnp.int32)
    return _assemble(x, landmark_rows, cfg, key_b, assign_rows)


def fold_coarse(
    ca: Optional[CoarseLevel],
    cb: Optional[CoarseLevel],
    n_a: int,
    scfg,
    key: Array,
) -> tuple[Optional[CoarseLevel], int]:
    """Fold two sides' coarse levels into one for a merged intermediate.

    ``ca`` routes the left block (rows [0, n_a) of the merged graph, already
    its own id space) and ``cb`` the right block in LOCAL ids — the same
    offset arithmetic ``merge.stack_subgraphs`` applies to the graphs
    remaps ``cb``'s full-graph references (+n_a on live entries).  The two
    landmark graphs — small, fully allocated by construction — merge via
    ``merge.symmetric_merge`` over the concatenated frozen routing points
    (landmark-local ids, random-seeded cross searches: levels don't carry
    levels), and the member rings concatenate per landmark.

    Either side missing means no fold: the merged intermediate seeds
    randomly, exactly like a leaf without a level.  Returns
    (folded level or None, comps charged by the landmark-graph merge).
    """
    if ca is None or cb is None:
        return None, 0
    points = jnp.concatenate([ca.points, cb.points])
    gc, comps = merge.symmetric_merge(
        ca.graph, cb.graph, points, scfg, key
    )
    off = lambda a: jnp.where(a >= 0, a + n_a, -1)
    level = CoarseLevel(
        landmark_rows=jnp.concatenate(
            [ca.landmark_rows, off(cb.landmark_rows)]
        ),
        points=points,
        graph=gc,
        members=jnp.concatenate([ca.members, off(cb.members)], axis=0),
        mem_ptr=jnp.concatenate([ca.mem_ptr, cb.mem_ptr]),
    )
    return level, comps


def derive_coarse(g: KNNGraph, x: Array, cfg, key: Array) -> CoarseLevel:
    """Re-derive a coarse level offline from a live graph — the recovery path
    for pre-v2 snapshots, ``ShardedIndex.merge_shards`` outputs, and any
    index built before ``seed_mode="coarse"`` was switched on.  Landmarks are
    sampled from ALIVE rows only and every alive row gets a brute cell
    assignment.  Maintenance work, not search work: not charged to any
    scanning rate."""
    import numpy as np

    nv = int(g.n_valid)
    alive = np.asarray(jax.device_get(g.alive[:nv])) if nv else np.zeros(0, bool)
    rows = np.nonzero(alive)[0].astype(np.int32)
    if rows.size == 0:
        raise ValueError("derive_coarse needs a graph with at least one alive row")
    L = min(cfg.coarse_landmarks or default_landmarks(rows.size), rows.size)
    key_s, key_b = jax.random.split(key)
    perm = jax.random.permutation(key_s, rows.size)[:L]
    landmark_rows = jnp.asarray(rows)[perm].astype(jnp.int32)
    level, _ = _assemble(x, landmark_rows, cfg, key_b, jnp.asarray(rows))
    return level
