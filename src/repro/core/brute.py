"""Tiled exact k-NN (brute force).

Three roles, all from the paper:
  * ground truth for recall@k (Eq. 1) in tests/benchmarks,
  * the exact seed graph over the initial |I| = 256 samples (Alg. 2 line 4-5),
  * the exhaustive-search baseline that defines "speed-up" (Table IV).

The x side is walked in tiles with a running top-k so the (m, n) distance
matrix never materializes; each tile is one Pallas ``pairwise_distance`` call
on TPU (MXU GEMM for l2/cos/ip).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import graph as graph_lib
from repro.core import merge as merge_lib
from repro.kernels import ops

Array = jax.Array


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "tile", "use_pallas", "dispatch")
)
def brute_force_knn(
    x: Array,
    q: Array,
    k: int,
    metric: str = "l2",
    *,
    exclude_ids: Optional[Array] = None,
    n_valid: Optional[Array] = None,
    alive: Optional[Array] = None,
    tile: int = 8192,
    use_pallas: Optional[bool] = None,
    dispatch: Optional[str] = None,
    sq_norms: Optional[Array] = None,
):
    """Exact top-k nearest neighbors of q among rows of x.

    Args:
      x: (n, d) dataset.
      q: (m, d) queries.
      k: neighbors to return.
      exclude_ids: optional (m,) id per query to exclude (self-match when the
        queries are dataset rows).
      n_valid: optional scalar — only rows [0, n_valid) participate.
      alive: optional (n,) bool — rows with ``alive=False`` are excluded
        (``KNNGraph.alive``: the exact baseline over a churned index must
        skip removed rows just like graph search does).
      sq_norms: optional (n,) cached ``‖x‖²`` (the graph-resident norm
        cache); each x tile's norms ride along to the distance engine
        instead of being re-reduced per tile.

    Returns:
      ids (m, k) int32, dists (m, k) float32 sorted ascending.
    """
    n, d = x.shape
    m = q.shape[0]
    tile = min(tile, n)
    ntiles = -(-n // tile)
    npad = ntiles * tile
    xp = jnp.pad(x, ((0, npad - n), (0, 0)))
    snp = None if sq_norms is None else jnp.pad(
        sq_norms.astype(jnp.float32), (0, npad - n)
    )
    alp = None if alive is None else jnp.pad(alive[:n], (0, npad - n))
    if n_valid is None:
        n_valid = jnp.asarray(n, jnp.int32)

    best_d = jnp.full((m, k), jnp.inf, jnp.float32)
    best_i = jnp.full((m, k), -1, jnp.int32)

    def body(t, carry):
        best_d, best_i = carry
        xt = jax.lax.dynamic_slice_in_dim(xp, t * tile, tile, 0)
        xn_t = None if snp is None else jax.lax.dynamic_slice_in_dim(
            snp, t * tile, tile, 0
        )
        dt = ops.pairwise_distance(
            q, xt, metric, use_pallas=use_pallas, dispatch=dispatch,
            x_sq_norms=xn_t,
        )
        ids = t * tile + jnp.arange(tile, dtype=jnp.int32)[None, :]
        mask = (ids < n_valid)
        if alp is not None:
            mask &= jax.lax.dynamic_slice_in_dim(alp, t * tile, tile, 0)[None, :]
        if exclude_ids is not None:
            mask &= ids != exclude_ids[:, None]
        dt = jnp.where(mask, dt, jnp.inf)
        cat_d = jnp.concatenate([best_d, dt], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, dt.shape)], axis=1)
        return ops.topk_smallest(cat_d, cat_i, k)

    best_d, best_i = jax.lax.fori_loop(0, ntiles, body, (best_d, best_i))
    return best_i, best_d


def exact_seed_graph(
    x: Array,
    n_seed: int,
    k: int,
    metric: str = "l2",
    *,
    capacity: Optional[int] = None,
    rev_capacity: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    dispatch: Optional[str] = None,
) -> graph_lib.KNNGraph:
    """Alg. 2 lines 4-6: exact k-NN graph over the first n_seed rows of x.

    The paper fixes |I| = 256.  Rows beyond n_seed stay unallocated; the
    reverse lists are derived exactly from the forward lists.
    """
    if capacity is None:
        capacity = x.shape[0]
    g = graph_lib.empty_graph(capacity, k, rev_capacity)
    seeds = x[:n_seed]
    seed_sq = graph_lib.squared_norms(seeds)  # seeds the graph norm cache
    seed_sc = graph_lib.row_scales(seeds)  # ... and the int8 scale cache
    ids, dists = brute_force_knn(
        seeds,
        seeds,
        min(k, n_seed - 1),
        metric,
        exclude_ids=jnp.arange(n_seed, dtype=jnp.int32),
        use_pallas=use_pallas,
        dispatch=dispatch,
        sq_norms=seed_sq,
    )
    kk = ids.shape[1]
    nbr_ids = g.nbr_ids.at[:n_seed, :kk].set(ids)
    nbr_dist = g.nbr_dist.at[:n_seed, :kk].set(dists)
    g = g._replace(
        nbr_ids=nbr_ids,
        nbr_dist=nbr_dist,
        alive=g.alive.at[:n_seed].set(True),
        n_valid=jnp.asarray(n_seed, jnp.int32),
        sq_norms=g.sq_norms.at[:n_seed].set(seed_sq),
        row_scale=g.row_scale.at[:n_seed].set(seed_sc),
    )
    return graph_lib.rebuild_reverse(g)


def recall_at_k(pred_ids: Array, true_ids: Array, k: int) -> Array:
    """Eq. 1: |pred ∩ true| / (n k) over top-k lists."""
    hits = jnp.sum(
        (pred_ids[:, :k, None] == true_ids[:, None, :k]) & (pred_ids[:, :k, None] >= 0)
    )
    return hits / (pred_ids.shape[0] * k)
