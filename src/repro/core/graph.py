"""k-NN graph state: the TPU-native replacement of the paper's orthogonal list.

The paper keeps G (k-NN lists) and Ḡ (reverse lists) as one pointer-linked
"orthogonal list" (Fig. 2).  Linked lists do not exist on a TPU; the state
here is a pytree of dense, fixed-capacity arrays that supports the same four
operations the paper needs — expand(G[r]), expand(Ḡ[r]), insertG, removal —
as vectorized gathers/scatters:

* ``nbr_ids/nbr_dist``: (cap, k) k-NN lists, rows sorted ascending by
  distance, padded with (-1, +inf).  This *is* G.
* ``nbr_lam``: (cap, k) the LGD occlusion factor λ attached to each directed
  edge (Alg. 3).
* ``rev_ids/rev_ptr``: (cap, R) reverse lists as FIFO ring buffers. Ḡ[i] in
  the paper is unbounded; a production system cannot allocate unbounded
  per-row storage, so we bound it at R (default 2k) and overwrite oldest
  entries first (deviation §8.2 of DESIGN.md).  Stale entries (edges whose
  forward counterpart was displaced) are *kept*: they act as extra shortcut
  candidates during search, never as correctness hazards.
* ``alive``: removal support (§IV-C) — dead rows are masked out of search
  rather than compacted, matching the paper's O(1)-ish delete.
* ``sq_norms``: (cap,) graph-resident cache of ``‖x_i‖²`` backing the blocked
  MXU distance engine (``‖q‖² + ‖x‖² − 2 q·x``).  Invariant: valid for every
  allocated row, 0 for unallocated/removed rows.  Owners: ``brute
  .exact_seed_graph`` (seed rows), ``construct.commit_wave`` (wave rows),
  ``dynamic.remove`` (zeroes victims); hand-built graphs attach it with
  ``attach_sq_norms``.  No search/construction path recomputes norms per
  iteration.
* ``row_scale``: (cap,) per-row symmetric int8 quantization scales
  (``max|x_i|/127``) backing the compressed distance engine
  (``precision="int8"``).  Same invariant and the same owners as
  ``sq_norms`` — the two tables are maintained side by side everywhere, and
  ``attach_sq_norms`` fills both.  Rows with scale 0 (unallocated, removed,
  or the all-zero vector) dequantize through a scale of 1 in the engine, so
  a stale zero can never produce NaNs.
* ``rev_lam``: (cap, R) snapshot of the forward twin's λ for each reverse
  edge — Ḡ[i] entry j means i ∈ G[j], and ``rev_lam[i, slot]`` is λ of i
  inside G[j] at append/rebuild time.  Search's LGD reverse-edge filter
  (Alg. 3 line 19) reads this flat table instead of gathering the (R, k)
  twin rows per expansion.  Like ``rev_ids`` it may go stale (λ updates on
  the forward side do not propagate); stale values only perturb the
  expansion *filter*, never distances or results ordering.

Everything is int32/float32; the graph for n=10^8, k=40, R=80 is ~50 GB —
sharded over a pod it is ~200 MB/device, which is why this layout scales
where pointer structures cannot.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import segments

Array = jax.Array


class KNNGraph(NamedTuple):
    nbr_ids: Array  # (cap, k) int32
    nbr_dist: Array  # (cap, k) float32, sorted ascending per row
    nbr_lam: Array  # (cap, k) int32  (LGD occlusion factor)
    rev_ids: Array  # (cap, R) int32 ring buffer
    rev_lam: Array  # (cap, R) int32 — forward-twin λ snapshot per rev edge
    rev_ptr: Array  # (cap,) int32 — total appends (mod R = write slot)
    alive: Array  # (cap,) bool
    n_valid: Array  # () int32 — rows [0, n_valid) are allocated
    sq_norms: Array  # (cap,) float32 — ‖x_i‖² cache (0 where unallocated)
    row_scale: Array  # (cap,) float32 — int8 quant scale cache (0 where unallocated)

    @property
    def capacity(self) -> int:
        return self.nbr_ids.shape[0]

    @property
    def k(self) -> int:
        return self.nbr_ids.shape[1]

    @property
    def rev_capacity(self) -> int:
        return self.rev_ids.shape[1]


def empty_graph(capacity: int, k: int, rev_capacity: int | None = None) -> KNNGraph:
    if rev_capacity is None:
        rev_capacity = 2 * k
    return KNNGraph(
        nbr_ids=jnp.full((capacity, k), -1, jnp.int32),
        nbr_dist=jnp.full((capacity, k), jnp.inf, jnp.float32),
        nbr_lam=jnp.zeros((capacity, k), jnp.int32),
        rev_ids=jnp.full((capacity, rev_capacity), -1, jnp.int32),
        rev_lam=jnp.zeros((capacity, rev_capacity), jnp.int32),
        rev_ptr=jnp.zeros((capacity,), jnp.int32),
        alive=jnp.zeros((capacity,), bool),
        n_valid=jnp.zeros((), jnp.int32),
        sq_norms=jnp.zeros((capacity,), jnp.float32),
        row_scale=jnp.zeros((capacity,), jnp.float32),
    )


def squared_norms(x: Array) -> Array:
    """(n, d) data -> (n,) float32 ‖x_i‖² (the norm-cache values).

    The one place the cache contents are defined; every owner of
    ``KNNGraph.sq_norms`` computes its entries through here.
    """
    xf = x.astype(jnp.float32)
    return jnp.sum(xf * xf, axis=-1)


def row_scales(x: Array) -> Array:
    """(n, d) data -> (n,) float32 symmetric int8 scales (``max|x_i|/127``).

    The one place the scale-table contents are defined; every owner of
    ``KNNGraph.row_scale`` computes its entries through here (mirroring
    ``squared_norms`` for the norm cache).

    Written as a multiply by the precomputed f32 reciprocal, NOT ``/ 127.0``:
    XLA's algebraic simplifier rewrites the constant divide into exactly this
    multiply inside jit, so the explicit form yields the same bits from
    eager owners (``attach_sq_norms`` on a hand-built graph) and jitted
    owners (wave commit) — a plain divide diverges by one ulp on ~4% of
    rows depending on compilation context.
    """
    xf = x.astype(jnp.float32)
    return jnp.max(jnp.abs(xf), axis=-1) * jnp.float32(1.0 / 127.0)


def attach_sq_norms(g: KNNGraph, x: Array) -> KNNGraph:
    """Populate the norm and scale caches of a hand-built graph from its
    backing data.

    Rows at or beyond ``n_valid`` — and dead rows — keep 0 per the cache
    invariant.
    """
    cap = g.capacity
    sq = squared_norms(x[:cap])
    sc = row_scales(x[:cap])
    if sq.shape[0] < cap:
        sq = jnp.pad(sq, (0, cap - sq.shape[0]))
        sc = jnp.pad(sc, (0, cap - sc.shape[0]))
    row = jnp.arange(cap, dtype=jnp.int32)
    allocated = (row < g.n_valid) & g.alive
    return g._replace(
        sq_norms=jnp.where(allocated, sq, 0.0),
        row_scale=jnp.where(allocated, sc, 0.0),
    )


def grow_graph(g: KNNGraph, new_capacity: int) -> KNNGraph:
    """Extend capacity with unallocated rows (append-only data region)."""
    cap = g.capacity
    if new_capacity <= cap:
        return g
    extra = new_capacity - cap
    return KNNGraph(
        nbr_ids=jnp.concatenate([g.nbr_ids, jnp.full((extra, g.k), -1, jnp.int32)]),
        nbr_dist=jnp.concatenate([g.nbr_dist, jnp.full((extra, g.k), jnp.inf, jnp.float32)]),
        nbr_lam=jnp.concatenate([g.nbr_lam, jnp.zeros((extra, g.k), jnp.int32)]),
        rev_ids=jnp.concatenate([g.rev_ids, jnp.full((extra, g.rev_capacity), -1, jnp.int32)]),
        rev_lam=jnp.concatenate([g.rev_lam, jnp.zeros((extra, g.rev_capacity), jnp.int32)]),
        rev_ptr=jnp.concatenate([g.rev_ptr, jnp.zeros((extra,), jnp.int32)]),
        alive=jnp.concatenate([g.alive, jnp.zeros((extra,), bool)]),
        n_valid=g.n_valid,
        sq_norms=jnp.concatenate([g.sq_norms, jnp.zeros((extra,), jnp.float32)]),
        row_scale=jnp.concatenate([g.row_scale, jnp.zeros((extra,), jnp.float32)]),
    )


def trim_graph(g: KNNGraph, new_capacity: int) -> KNNGraph:
    """Drop unallocated tail rows (the inverse of ``grow_graph``).

    Only rows at or beyond ``n_valid`` may be trimmed — stored ids are all
    < n_valid, so no list can dangle.  Used by the sub-graph merge path,
    which requires fully-allocated operands (capacity == n_valid).
    """
    cap = g.capacity
    if new_capacity >= cap:
        return g
    if new_capacity < int(g.n_valid):
        raise ValueError(
            f"cannot trim below n_valid: {new_capacity} < {int(g.n_valid)}"
        )
    return KNNGraph(
        nbr_ids=g.nbr_ids[:new_capacity],
        nbr_dist=g.nbr_dist[:new_capacity],
        nbr_lam=g.nbr_lam[:new_capacity],
        rev_ids=g.rev_ids[:new_capacity],
        rev_lam=g.rev_lam[:new_capacity],
        rev_ptr=g.rev_ptr[:new_capacity],
        alive=g.alive[:new_capacity],
        n_valid=g.n_valid,
        sq_norms=g.sq_norms[:new_capacity],
        row_scale=g.row_scale[:new_capacity],
    )


def rebuild_reverse(g: KNNGraph) -> KNNGraph:
    """Recompute rev lists from forward lists (checkpoint-restore / repair).

    Edges are grouped by member id; each member keeps its most recent R
    owners.  The forward twin's λ rides along as a second payload, so the
    ``rev_lam`` snapshot is exact at rebuild time.  Pure function of the
    forward graph — used to verify the incremental ring-buffer maintenance
    in tests.
    """
    cap, k = g.nbr_ids.shape
    R = g.rev_capacity
    owners = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32)[:, None], (cap, k))
    members = g.nbr_ids
    valid = members >= 0
    flat_owner = jnp.where(valid, owners, cap).reshape(-1)
    flat_member = jnp.where(valid, members, cap).reshape(-1)
    flat_lam = jnp.where(valid, g.nbr_lam, 0).reshape(-1)
    order = jnp.argsort(flat_member, stable=True)
    sm = flat_member[order]
    so = flat_owner[order]
    sl = flat_lam[order]
    # group owners by member, keep each member's first R (most recent) owners
    (rev_ids, rev_lam), counts = segments.grouped_top_r(
        sm, [so, sl], [-1, 0], cap, R
    )
    return g._replace(
        rev_ids=rev_ids,
        rev_lam=rev_lam,
        rev_ptr=jnp.minimum(counts, R).astype(jnp.int32),
    )


def graph_invariants_ok(g: KNNGraph) -> dict:
    """Structural invariants (used by property tests).

    Returns a dict of boolean arrays — all must be all-True:
      * rows sorted ascending (padding +inf at the tail)
      * no self loops
      * no duplicate ids within a row
      * ids within [0, n_valid) or -1
      * liveness: no alive row references a dead (``~alive``) neighbor —
        forward or reverse.  ``dynamic.remove`` purges victims from every
        list, so any dead reference after a removal is a leak.
    """
    ids, dist = g.nbr_ids, g.nbr_dist
    cap, k = ids.shape
    row = jnp.arange(cap, dtype=jnp.int32)[:, None]
    sorted_ok = jnp.all(dist[:, 1:] >= dist[:, :-1], axis=1)
    no_self = jnp.all(ids != row, axis=1)
    eq = (ids[:, :, None] == ids[:, None, :]) & (ids[:, :, None] >= 0)
    dup = jnp.sum(eq, axis=(1, 2)) > jnp.sum(ids >= 0, axis=1)
    in_range = jnp.all((ids == -1) | ((ids >= 0) & (ids < g.n_valid)), axis=1)
    live_nbrs = jnp.all((ids < 0) | g.alive[jnp.maximum(ids, 0)], axis=1)
    live_rev = jnp.all(
        (g.rev_ids < 0) | g.alive[jnp.maximum(g.rev_ids, 0)], axis=1
    )
    active = jnp.arange(cap) < g.n_valid
    live_row = active & g.alive
    return {
        "sorted": jnp.where(active, sorted_ok, True),
        "no_self_loops": jnp.where(active, no_self, True),
        "no_duplicates": jnp.where(active, ~dup, True),
        "ids_in_range": jnp.where(active, in_range, True),
        "live_neighbors": jnp.where(live_row, live_nbrs, True),
        "live_reverse": jnp.where(live_row, live_rev, True),
    }
