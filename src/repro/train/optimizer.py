"""Optimizers in pure JAX: AdamW and (factored) Adafactor.

AdamW is the default.  Adafactor is selected for the >=100B-param configs
(arctic-480b) where Adam's 8 bytes/param of second-moment state would not fit
HBM even fully sharded — the factored second moment reduces optimizer state
to O(rows + cols) per matrix (DESIGN.md §6 memory budget).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # "adamw" | "adafactor" | "sgd"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # adafactor
    min_dim_factored: int = 128  # only factor matrices at least this big
    decay_offset: int = 0


def _global_norm(tree: PyTree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _adamw_init(params: PyTree) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum)
# ---------------------------------------------------------------------------


def _factorable(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128


def _adafactor_init(params: PyTree) -> Dict[str, Any]:
    def vr(p):
        if _factorable(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)  # row stats
        return jnp.zeros((1,), jnp.float32)

    def vc(p):
        if _factorable(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)  # col stats
        return jnp.zeros(p.shape, jnp.float32)  # unfactored full second moment

    return {
        "vr": jax.tree.map(vr, params),
        "vc": jax.tree.map(vc, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _adafactor_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-0.8)  # Adafactor's decay schedule

    def upd(p, g, vr, vc):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + 1e-30
        if _factorable(p):
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            vhat = r[..., None] * vc[..., None, :]
        else:
            vc = beta2 * vc + (1 - beta2) * g2
            vhat = vc
            vr = vr
        u = g32 / jnp.sqrt(vhat + 1e-30)
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        delta = cfg.lr * u + cfg.lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype), vr, vc

    out = jax.tree.map(upd, params, grads, state["vr"], state["vc"])
    istup = lambda x: isinstance(x, tuple)
    return (
        jax.tree.map(lambda o: o[0], out, is_leaf=istup),
        {
            "vr": jax.tree.map(lambda o: o[1], out, is_leaf=istup),
            "vc": jax.tree.map(lambda o: o[2], out, is_leaf=istup),
            "step": step,
        },
    )


# ---------------------------------------------------------------------------
# SGD (tests / toy examples)
# ---------------------------------------------------------------------------


def _sgd_init(params):
    return {"step": jnp.zeros((), jnp.int32)}


def _sgd_update(params, grads, state, cfg: OptConfig):
    new = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - cfg.lr * g.astype(jnp.float32)).astype(p.dtype),
        params,
        grads,
    )
    return new, {"step": state["step"] + 1}


_OPTS = {
    "adamw": (_adamw_init, _adamw_update),
    "adafactor": (_adafactor_init, _adafactor_update),
    "sgd": (_sgd_init, _sgd_update),
}


def init_opt_state(params: PyTree, cfg: OptConfig) -> PyTree:
    return _OPTS[cfg.name][0](params)


def apply_updates(params: PyTree, grads: PyTree, state: PyTree, cfg: OptConfig):
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = _global_norm(grads)
    params, state = _OPTS[cfg.name][1](params, grads, state, cfg)
    return params, state, gnorm


def opt_state_pspecs(param_specs: PyTree, params_shape: PyTree, cfg: OptConfig) -> PyTree:
    """Optimizer-state PartitionSpecs mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P

    if cfg.name == "adamw":
        return {
            "m": param_specs,
            "v": param_specs,
            "step": P(),
        }
    if cfg.name == "adafactor":
        def vr_spec(spec, p):
            parts = list(spec) if spec is not None else [None] * p.ndim
            parts = parts + [None] * (p.ndim - len(parts))
            if _factorable(p):
                return P(*parts[:-1])
            return P(None)

        def vc_spec(spec, p):
            parts = list(spec) if spec is not None else [None] * p.ndim
            parts = parts + [None] * (p.ndim - len(parts))
            if _factorable(p):
                return P(*(parts[:-2] + parts[-1:]))
            return P(*parts)

        from jax.sharding import PartitionSpec
        return {
            "vr": jax.tree.map(
                vr_spec, param_specs, params_shape,
                is_leaf=lambda x: isinstance(x, PartitionSpec),
            ),
            "vc": jax.tree.map(
                vc_spec, param_specs, params_shape,
                is_leaf=lambda x: isinstance(x, PartitionSpec),
            ),
            "step": P(),
        }
    return {"step": P()}
