"""int8 error-feedback gradient compression for the cross-pod reduction.

Cross-DCN (pod-to-pod) links are ~an order of magnitude thinner than
intra-pod ICI, so only the 'pod'-axis all-reduce is worth compressing.  The
scheme is the standard 1-bit-Adam-family error-feedback quantizer:

  q = round(clip((g + e) / s, int8))     s = max|g + e| / 127  (per-tensor)
  e' = (g + e) - s * q                   (residual carried to the next step)

The all-reduce then moves int8 payloads + one f32 scale per tensor (a ~4x
byte reduction vs f32, ~2x vs bf16).  Used inside the shard_map DP path
(``train_loop.make_sharded_train_step``); numerically validated on CPU in
``tests/test_train.py``.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (int8 payload, f32 scale, new error residual)."""
    x = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - scale * q.astype(jnp.float32)
    return q, scale, new_err


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def allreduce_compressed(grads: PyTree, err: PyTree, axis_name: str):
    """Error-feedback compressed psum over ``axis_name``.

    Each participant contributes an int8-quantized (grad + residual); the sum
    of dequantized payloads is exact in f32.  Returns (mean grads, new err).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, s, e2 = compress(g, e)
        # payload sum: int8 tensors summed in int32 to avoid overflow,
        # scales exchanged alongside (sum of per-peer dequantized values)
        total = jax.lax.psum(q.astype(jnp.float32) * s, axis_name)
        return (total / n).astype(g.dtype), e2

    out = jax.tree.map(one, grads, err)
    istup = lambda x: isinstance(x, tuple)
    return (
        jax.tree.map(lambda o: o[0], out, is_leaf=istup),
        jax.tree.map(lambda o: o[1], out, is_leaf=istup),
    )
