from repro.train import checkpoint, compress, optimizer, train_loop  # noqa: F401
