"""train_step factories: the function every dry-run cell lowers.

``make_train_step(loss_fn, opt_cfg)`` builds the canonical fused step:

    grads = grad(loss); clip; optimizer update      (one jit'd function)

with optional microbatch gradient accumulation (``accum_steps``) — the accum
loop is a scan whose per-microbatch backward overlaps the previous
microbatch's gradient reduction under XLA's latency-hiding scheduler
(DESIGN.md §6 overlap).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_lib

PyTree = Any


def make_train_step(
    loss_fn: Callable[[PyTree, Any], tuple[jax.Array, Dict[str, jax.Array]]],
    opt_cfg: opt_lib.OptConfig,
    *,
    accum_steps: int = 1,
):
    """loss_fn(params, batch) -> (loss, metrics). Returns train_step fn."""

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            # split the batch leading axis into microbatches and accumulate
            def micro(carry, mb):
                acc, loss_acc = carry
                (l, _), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
                batch,
            )
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = {}
        params, opt_state, gnorm = opt_lib.apply_updates(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def make_sharded_train_step(
    loss_fn: Callable,
    opt_cfg: opt_lib.OptConfig,
    mesh,
    *,
    data_axes: tuple = ("data",),
    pod_axis: Optional[str] = "pod",
    compress_pod: bool = True,
):
    """Explicit shard_map DP train step with cross-pod gradient compression.

    The pjit path (make_train_step under in_shardings) lets XLA place one
    big all-reduce over all data axes; this variant makes the hierarchy
    explicit so the *pod* hop — DCN, ~10x thinner than ICI — can run the
    int8 error-feedback compressor (train/compress.py):

        grads --psum(data axes, ICI, full precision)-->
              --compressed psum(pod axis, DCN, int8+scale)--> update

    Params/optimizer are replicated across the mesh (pure DP); the batch is
    sharded over (pod, data).  Returns step(params, opt_state, err, batch)
    -> (params, opt_state, err, metrics).  ``err`` is the error-feedback
    residual: per-POD state (identical within a pod since grads are pmean'd
    over the data axes first), so its leaves carry a leading (n_pods,) dim
    sharded over the pod axis — init via ``init_pod_error_state``.
    """
    from jax.sharding import PartitionSpec as P

    from repro.train import compress as compress_lib

    have_pod = pod_axis is not None and pod_axis in mesh.axis_names
    batch_spec = P(tuple(a for a in (pod_axis, *data_axes) if a in mesh.axis_names))
    err_spec = P(pod_axis) if have_pod else P(None)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def local_step(params, opt_state, err, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        # intra-pod reduction: full precision over the ICI axes
        grads = jax.tree.map(
            lambda g: jax.lax.pmean(g, tuple(a for a in data_axes)), grads)
        loss = jax.lax.pmean(loss, tuple(a for a in data_axes))
        if have_pod:
            if compress_pod:
                e_local = jax.tree.map(lambda e: e[0], err)
                grads, e_local = compress_lib.allreduce_compressed(
                    grads, e_local, pod_axis)
                err = jax.tree.map(lambda e: e[None], e_local)
            else:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, pod_axis), grads)
            loss = jax.lax.pmean(loss, pod_axis)
        params, opt_state, gnorm = opt_lib.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return params, opt_state, err, metrics

    rep = P()  # params/opt replicated
    from repro.kernels import compat

    return compat.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(rep, rep, err_spec, batch_spec),
        out_specs=(rep, rep, err_spec, rep),
    )


def init_pod_error_state(params, mesh, pod_axis: str = "pod"):
    """(n_pods, *shape) zero residuals for make_sharded_train_step."""
    import jax.numpy as jnp

    n_pods = mesh.shape[pod_axis] if pod_axis in mesh.axis_names else 1
    return jax.tree.map(
        lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)


def make_eval_step(loss_fn: Callable):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return metrics

    return eval_step
