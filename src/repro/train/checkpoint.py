"""Checkpoint / restore with elastic resharding.

Design (DESIGN.md §6):
  * each host writes the *addressable* shards of every array under its own
    directory (`shard-<host>/<leaf>.npy` pieces keyed by global index range);
    a JSON manifest records step, mesh shape/axes, leaf treedef, per-leaf
    global shape/dtype and PartitionSpec;
  * restore validates the manifest, reassembles by GLOBAL INDEX, and places
    the result under the *current* mesh's shardings — a checkpoint written on
    (16,16) restores onto (8,16), (2,16,16) or a single CPU device (elastic
    scaling / shrink-to-survive after node loss);
  * graph construction checkpoints at wave boundaries: the KNNGraph pytree is
    5 dense arrays + a scalar, so the same code path covers both training
    state and the paper's index state (pointer-based ANN indexes cannot do
    this — a paper-level advantage the framework exploits).

On this single-process CPU runtime every array is fully addressable, so the
implementation reads/writes whole leaves; the global-index reassembly path is
the same one a multi-host deployment uses (process_index keys the shard dir).
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

MANIFEST = "manifest.json"


def _leaf_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(path: str, tree: PyTree, *, step: int = 0, meta: Optional[dict] = None) -> None:
    """Write a checkpoint. Arrays are gathered to host (fully replicated read
    of each leaf's global value) and written once per leaf."""
    os.makedirs(path, exist_ok=True)
    names, leaves, _ = _leaf_paths(tree)
    host = jax.process_index()
    shard_dir = os.path.join(path, f"shard-{host}")
    os.makedirs(shard_dir, exist_ok=True)
    records = []
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = name.replace("/", "__") + ".npy"
        np.save(os.path.join(shard_dir, fn), arr)
        records.append(
            {"name": name, "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    manifest = {
        "step": int(step),
        "process_count": jax.process_count(),
        "leaves": records,
        "meta": meta or {},
    }
    with open(os.path.join(path, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)


def load_manifest(path: str) -> dict:
    with open(os.path.join(path, MANIFEST)) as f:
        return json.load(f)


def restore(
    path: str,
    like: PyTree,
    *,
    shardings: Optional[PyTree] = None,
    strict_shapes: bool = True,
) -> tuple[PyTree, int]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs), placing leaves under ``shardings`` if given.

    Resharding is implicit: the stored global value is placed under whatever
    sharding the *current* mesh prescribes (jax.device_put partitions it) —
    the checkpoint carries no device-topology dependence at all.
    """
    manifest = load_manifest(path)
    names, leaves, treedef = _leaf_paths(like)
    by_name = {r["name"]: r for r in manifest["leaves"]}
    shard_dir = os.path.join(path, "shard-0")
    sh_leaves = None
    if shardings is not None:
        sh_names, sh_leaves, _ = _leaf_paths(shardings)
        assert sh_names == names, "shardings tree must match target tree"
    out = []
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        rec = by_name[name]
        arr = np.load(os.path.join(shard_dir, rec["file"]))
        want_shape = tuple(leaf.shape)
        if strict_shapes and tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {name!r}: checkpoint shape {arr.shape} != target {want_shape}"
            )
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


# ---------------------------------------------------------------------------
# Wave-boundary construction checkpoints (fault-tolerant graph builds)
# ---------------------------------------------------------------------------


def save_graph(path: str, graph, next_row: int, build_cfg_dict: dict) -> None:
    save(
        path,
        graph._asdict(),
        step=next_row,
        meta={"kind": "knn_graph", "build_cfg": build_cfg_dict},
    )


def restore_graph(path: str, like_graph):
    tree, next_row = restore(path, like_graph._asdict())
    return type(like_graph)(**tree), next_row
