"""Tiled pairwise-distance Pallas TPU kernel.

This is the compute hot-spot of everything in the paper: brute-force k-NN
(ground truth + the seed graph), NN-Descent local joins, refinement passes and
the intra-wave tiles of the online construction all reduce to "distances
between a block of queries and a block of points".

TPU mapping
-----------
For MXU-eligible metrics (l2 / ip / cosine) the kernel accumulates the
``q @ x^T`` GEMM over feature tiles on the MXU and folds the norm terms in on
the last reduction step (``|q|^2 + |x|^2 - 2 q.x`` expansion).  For VPU
metrics (l1 / chi2) the kernel walks the x-block row-tiles with a fori_loop of
broadcasted absolute-difference reductions — no matmul form exists.

Grid: ``(m_tiles, n_tiles, d_tiles)`` with the reduction axis innermost
("arbitrary" semantics) so each output tile sees its partial sums
consecutively; partials live in VMEM scratch, the HBM output is written once.

Block shapes are multiples of (8, 128) so fp32 tiles are register-aligned and
the MXU sees 128x128-aligned operands.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

Array = jax.Array

MXU_METRICS = ("l2", "ip", "dot")
VPU_METRICS = ("l1", "chi2")


def _dist_kernel_mxu(q_ref, x_ref, o_ref, acc_ref, qsq_ref, xsq_ref, *, metric: str, nd: int):
    """One (bm, bn) output tile; reduction step k over feature tiles."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        qsq_ref[...] = jnp.zeros_like(qsq_ref)
        xsq_ref[...] = jnp.zeros_like(xsq_ref)

    q = q_ref[...].astype(jnp.float32)  # (bm, bd)
    x = x_ref[...].astype(jnp.float32)  # (bn, bd)
    acc_ref[...] += jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    if metric == "l2":
        qsq_ref[...] += jnp.sum(q * q, axis=1, keepdims=True)
        xsq_ref[...] += jnp.sum(x * x, axis=1, keepdims=True).T

    @pl.when(k == nd - 1)
    def _done():
        if metric == "l2":
            o_ref[...] = jnp.maximum(qsq_ref[...] + xsq_ref[...] - 2.0 * acc_ref[...], 0.0)
        elif metric == "ip":
            o_ref[...] = -acc_ref[...]
        else:  # "dot": raw dot product (cosine handled by the wrapper)
            o_ref[...] = acc_ref[...]


def _dist_kernel_mxu_cached(q_ref, x_ref, xn_ref, o_ref, acc_ref, qsq_ref, *, nd: int):
    """l2 tile with the graph-resident ``‖x‖²`` cache: the x-side norm
    accumulation is skipped entirely — the cached (1, bn) row supplies the
    norm term on the last reduction step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        qsq_ref[...] = jnp.zeros_like(qsq_ref)

    q = q_ref[...].astype(jnp.float32)  # (bm, bd)
    x = x_ref[...].astype(jnp.float32)  # (bn, bd)
    acc_ref[...] += jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    qsq_ref[...] += jnp.sum(q * q, axis=1, keepdims=True)

    @pl.when(k == nd - 1)
    def _done():
        o_ref[...] = jnp.maximum(
            qsq_ref[...] + xn_ref[...] - 2.0 * acc_ref[...], 0.0
        )


def _dist_kernel_vpu(q_ref, x_ref, o_ref, acc_ref, *, metric: str, nd: int, rows_per_step: int):
    """VPU path: accumulate sum-reductions of |q - x| / chi2 over d tiles.

    The (bm, bn, bd) broadcast is walked in row-strips of the x block so the
    VMEM-resident intermediate stays at (bm, rows_per_step, bd).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)  # (bm, bd)
    x = x_ref[...].astype(jnp.float32)  # (bn, bd)
    bn = x.shape[0]
    nsteps = bn // rows_per_step

    def body(i, acc):
        xs = jax.lax.dynamic_slice_in_dim(x, i * rows_per_step, rows_per_step, 0)
        diff = q[:, None, :] - xs[None, :, :]  # (bm, rps, bd)
        if metric == "l1":
            part = jnp.sum(jnp.abs(diff), axis=-1)
        else:  # chi2
            den = q[:, None, :] + xs[None, :, :]
            part = jnp.sum(
                jnp.where(den > 1e-12, diff * diff / jnp.maximum(den, 1e-12), 0.0),
                axis=-1,
            )
        return jax.lax.dynamic_update_slice_in_dim(
            acc, jax.lax.dynamic_slice_in_dim(acc, i * rows_per_step, rows_per_step, 1) + part,
            i * rows_per_step, 1,
        )

    acc_ref[...] = jax.lax.fori_loop(0, nsteps, body, acc_ref[...])

    @pl.when(k == nd - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def _pad_to(a: Array, m0: int, m1: int) -> Array:
    p0 = -a.shape[0] % m0
    p1 = -a.shape[1] % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(
    jax.jit,
    static_argnames=("metric", "bm", "bn", "bd", "interpret"),
)
def pairwise_distance(
    q: Array,
    x: Array,
    *,
    metric: str = "l2",
    x_sq_norms: Optional[Array] = None,
    bm: int = 128,
    bn: int = 128,
    bd: int = 128,
    interpret: Optional[bool] = None,
) -> Array:
    """Pallas tiled pairwise distances: (m, d) x (n, d) -> (m, n) float32.

    ``x_sq_norms`` is the cached ``‖x‖²`` of the x side (the graph-resident
    norm cache); for l2 the kernel then skips the x-norm accumulation
    entirely.  ``interpret=None`` resolves to compiled on TPU and interpret
    mode elsewhere — the kernel-vs-reference *choice* belongs to
    ``kernels.ops`` dispatch, not here.
    """
    if interpret is None:
        interpret = compat.default_interpret()
    kernel_metric = metric
    if metric == "cosine":
        # Normalize outside the kernel; cosine == 1 - dot on unit vectors.
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
        kernel_metric = "dot"

    m, d = q.shape
    n = x.shape[0]

    def _round8(v):
        return -(-v // 8) * 8

    bm = _round8(min(bm, m))
    bn = _round8(min(bn, n))
    bd = min(bd, d) if d >= 128 else d
    qp = _pad_to(q, bm, bd)
    xp = _pad_to(x, bn, bd)
    mp, dp = qp.shape
    np_ = xp.shape[0]
    grid = (mp // bm, np_ // bn, dp // bd)

    cached_xn = x_sq_norms is not None and kernel_metric == "l2"
    in_specs = [
        pl.BlockSpec((bm, bd), lambda i, j, k: (i, k)),
        pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
    ]
    operands = [qp, xp]
    if cached_xn:
        kern = functools.partial(_dist_kernel_mxu_cached, nd=grid[2])
        scratch = [
            compat.VMEM((bm, bn), jnp.float32),
            compat.VMEM((bm, 1), jnp.float32),
        ]
        xnp = x_sq_norms.astype(jnp.float32)
        if np_ != n:
            xnp = jnp.pad(xnp, (0, np_ - n))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(xnp[None, :])
    elif kernel_metric in MXU_METRICS:
        kern = functools.partial(_dist_kernel_mxu, metric=kernel_metric, nd=grid[2])
        scratch = [
            compat.VMEM((bm, bn), jnp.float32),
            compat.VMEM((bm, 1), jnp.float32),
            compat.VMEM((1, bn), jnp.float32),
        ]
    elif kernel_metric in VPU_METRICS:
        rows = min(8, bn)
        kern = functools.partial(
            _dist_kernel_vpu, metric=kernel_metric, nd=grid[2], rows_per_step=rows
        )
        scratch = [compat.VMEM((bm, bn), jnp.float32)]
    else:
        raise KeyError(f"metric {metric!r} has no Pallas path")

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    out = out[:m, :n]
    if metric == "cosine":
        out = 1.0 - out
    return out
