"""Pallas TPU kernels for the distance hot-spots.

Each kernel ships three layers:
  * ``<name>.py`` — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling,
  * ``ops.py``    — jit'd dispatching wrappers (TPU: compiled kernel,
                    CPU: jnp reference; ``use_pallas=True`` forces the
                    interpreted kernel for validation),
  * ``ref.py``    — pure-jnp oracles the tests sweep against.

Kernels:
  * ``distance``    — tiled pairwise distances (MXU GEMM for l2/ip/cosine,
                      VPU strips for l1/chi2).
  * ``gather_dist`` — fused gather+distance with scalar-prefetched candidate
                      ids and double-buffered HBM→VMEM row DMAs.
  * ``expand``      — the fused EHC expansion step (Alg. 1/3 inner loop):
                      candidate-row DMAs + visited-hash probe/record + beam
                      top-k merge in one kernel, with the bit-identical
                      pure-jnp ``expand_reference`` beside it
                      (``ops.expand_step`` is the three-way dispatcher).
"""

from repro.kernels import expand, ops, ref

__all__ = ["expand", "ops", "ref"]
