"""Blocked gather + distance Pallas TPU kernel — the MXU distance engine.

The inner loop of EHC hill-climbing is: take the candidate ids produced by
expanding a beam vertex, fetch those rows of the dataset, and compute their
distance to the query.  The first-generation kernel here streamed one (1, d)
candidate row per DMA and reduced it on the VPU — ~0.05 flops/byte, idle
MXUs.  This version is *blocked*: candidate ids (riding in scalar-prefetch
SMEM) drive double-buffered HBM->VMEM row gathers into a (C_blk, d) tile,
and each landed tile is reduced against the VMEM-resident query in ONE shot:

  * l2 / ip / cos ride the norms decomposition ``‖q‖² + ‖x‖² − 2·q·x`` — the
    ``q·x`` term is a single (1, d) x (C_blk, d)ᵀ MXU pass per block and the
    ``‖x‖²`` term comes from the graph-resident norm cache
    (``KNNGraph.sq_norms``), so nothing recomputes norms per iteration;
  * l1 / chi2 keep the VPU broadcast reduction (no matmul form exists) over
    the same (C_blk, d) tile — the block analogue of ``kernels.distance``'s
    row-strip walk.

``blocked_gather_phase`` is the whole phase — DMA discipline, block
reduction, and padding-lane masking — and is shared *verbatim* with the
fused expansion kernel (``kernels.expand``), which is what keeps the two
bit-identical per comparison (pinned by the expansion parity suite).

Layout
------
* grid = (B,): one grid step per query; Pallas pipelines steps.
* ``idx`` (B, C_pad) int32: scalar-prefetch operand (SMEM) driving the DMAs;
  the same ids ride again as a VMEM operand for vector-phase masking.
* ``x`` (n, d): stays in HBM/ANY; rows are moved with
  ``pltpu.make_async_copy`` into a 2-slot (C_blk, d) VMEM scratch (block
  j+1 is in flight while block j is reduced).
* ``xn`` (B, C_pad) float32: gathered squared norms of the candidate rows.
* out block (1, C_pad) float32; the wrapper slices back to C.

Candidate lists are padded to a multiple of the block width with -1; negative
ids are padding and their lanes are forced to +inf (the convention the search
layer uses for masked candidates).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

Array = jax.Array

# Widest candidate block one tile reduction covers: matches the MXU's 128
# systolic rows; shorter candidate lists use one exact-width block.
_MAX_BLOCK_C = 128


def block_c(n_cand: int) -> int:
    """Candidate-block width used for a C-wide candidate list."""
    return min(_MAX_BLOCK_C, max(n_cand, 1))


def padded_c(n_cand: int) -> int:
    """C padded up to a whole number of blocks."""
    cb = block_c(n_cand)
    return -(-n_cand // cb) * cb


def block_distance(
    q: Array, tile: Array, xn: Array, metric: str,
    xscale: Optional[Array] = None,
) -> Array:
    """Distances between one query and one block of candidate rows.

    The single in-kernel distance formula, shared by this kernel and the
    fused expansion kernel — keeping it in one place is what makes the two
    bit-identical, which the expansion parity suite pins.

    Args:
      q: (1, d) query.
      tile: (C_blk, d) candidate rows — fp32, or a reduced-precision tile
        (bf16/int8) cast to fp32 on read; accumulation is always fp32.
      xn: (1, C_blk) cached ``‖x‖²`` per row (consumed by l2 and cos;
        ignored by ip/dot/l1/chi2).
      xscale: optional (1, C_blk) per-row int8 dequant scales
        (``KNNGraph.row_scale`` gathered; 1 at padding).  Applied to the
        *dot* term for the matmul metrics — the norm term stays exact from
        the cache — and to the tile for l1/chi2.  None (fp32/bf16) leaves
        the formula untouched, so the fp32 jaxpr is unchanged.

    Returns (1, C_blk) float32 distances.  ``"dot"`` is the raw inner
    product; ``"cos"`` expects a pre-normalized query and *raw* data rows —
    the cached norm supplies the denominator.
    """
    q = q.astype(jnp.float32)
    tile = tile.astype(jnp.float32)
    if xscale is not None and metric in ("l1", "chi2"):
        tile = tile * xscale.reshape(-1, 1)
    if metric in ("l2", "ip", "dot", "cos"):
        dots = jax.lax.dot_general(
            q, tile, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (1, C_blk) — one MXU pass covers the whole block
        if xscale is not None:
            dots = dots * xscale
        if metric == "l2":
            qn = jnp.sum(q * q, axis=1, keepdims=True)
            return jnp.maximum(qn + xn - 2.0 * dots, 0.0)
        if metric == "ip":
            return -dots
        if metric == "dot":
            return dots
        return 1.0 - dots / jnp.maximum(jnp.sqrt(xn), 1e-12)  # cos
    if metric == "l1":
        return jnp.sum(jnp.abs(tile - q), axis=1, keepdims=True).T
    if metric == "chi2":
        num = (tile - q) ** 2
        den = tile + q
        return jnp.sum(
            jnp.where(den > 1e-12, num / jnp.maximum(den, 1e-12), 0.0),
            axis=1,
            keepdims=True,
        ).T
    raise KeyError(metric)


def gathered_sq_norms(x: Array, idx: Array, sq_norms: Optional[Array]) -> Array:
    """(B, C) candidate ids -> (B, C) float32 ``‖x_idx‖²``; 0 at padding.

    ``sq_norms`` is the graph-resident cache (``KNNGraph.sq_norms``).  When a
    caller has none (direct kernel use, tests) the norms are derived from
    ``x`` once per call — never per candidate row, and never inside the
    search iteration — through ``graph.squared_norms``, the cache contents'
    single definition.
    """
    if sq_norms is None:
        from repro.core.graph import squared_norms  # lazy: kernels load first

        sq_norms = squared_norms(x)
    safe = jnp.clip(idx, 0, x.shape[0] - 1)
    return jnp.where(idx >= 0, sq_norms[safe].astype(jnp.float32), 0.0)


def gathered_row_scales(idx: Array, row_scale: Array) -> Array:
    """(B, C) candidate ids -> (B, C) float32 dequant scales; 1 at padding.

    ``row_scale`` is the graph-resident int8 scale table
    (``KNNGraph.row_scale``).  Zero entries (unallocated/removed rows, the
    all-zero vector) map to 1 — mirroring ``precision.quantize_int8``'s
    guard — so the engine never divides or multiplies by 0 into NaN lanes.
    """
    safe = jnp.clip(idx, 0, row_scale.shape[0] - 1)
    s = row_scale[safe].astype(jnp.float32)
    return jnp.where((idx >= 0) & (s > 0), s, 1.0)


def blocked_gather_phase(
    b,  # scalar: which query lane (grid position)
    idx_ref,  # (B, C_pad) int32 SMEM (scalar prefetch) — drives the DMAs
    ids_ref,  # (1, C_pad) int32 VMEM — same ids, vector-phase masking
    q,  # (1, d) float32 (already read from its ref)
    xn_ref,  # (1, C_pad) float32 VMEM — gathered ‖x‖² per candidate
    x_ref,  # (n, d) ANY (HBM)
    out_ref,  # (1, C_pad) float32 VMEM — distances out (+inf at padding)
    tile_buf,  # (2, C_blk, d) VMEM scratch (block double buffer)
    sems,  # (2, C_blk) DMA semaphores
    *,
    n_blocks: int,
    c_blk: int,
    metric: str,
    xs_ref=None,  # (1, C_pad) f32 VMEM — int8 dequant scales (None: fp32/bf16)
):
    """The blocked candidate-distance phase, shared verbatim by the
    gather-distance kernel and the fused expansion kernel's phase 1 — one
    body, two execution sites, zero drift.

    Block j+1's row DMAs are in flight while block j reduces on the
    MXU/VPU.  Padding lanes (id < 0) fetch row 0 and are masked to +inf.

    Reduced precision rides the same discipline: ``x_ref``/``tile_buf`` may
    be bf16 or int8 (cast-on-DMA — the tile lands in its storage dtype and
    is cast to fp32 at the reduction), and ``xs_ref`` carries the gathered
    int8 dequant scales.  With ``xs_ref=None`` and fp32 operands the body
    traces to exactly the pre-precision jaxpr.
    """

    def row_copy(blk, r, slot):
        rid = jnp.maximum(idx_ref[b, blk * c_blk + r], 0)
        return compat.make_async_copy(
            x_ref.at[pl.ds(rid, 1)], tile_buf.at[slot, pl.ds(r, 1)],
            sems.at[slot, r],
        )

    def start_block(blk, slot):
        def start_row(r, _):
            row_copy(blk, r, slot).start()
            return ()

        jax.lax.fori_loop(0, c_blk, start_row, (), unroll=False)

    def wait_block(blk, slot):
        def wait_row(r, _):
            row_copy(blk, r, slot).wait()
            return ()

        jax.lax.fori_loop(0, c_blk, wait_row, (), unroll=False)

    start_block(0, 0)

    def body(blk, _):
        slot = jax.lax.rem(blk, 2)

        @pl.when(blk + 1 < n_blocks)
        def _prefetch_next():
            start_block(blk + 1, jax.lax.rem(blk + 1, 2))

        wait_block(blk, slot)
        off = blk * c_blk
        tile = tile_buf[slot].astype(jnp.float32)  # (C_blk, d)
        ids_blk = ids_ref[0:1, pl.ds(off, c_blk)]  # (1, C_blk)
        xn_blk = xn_ref[0:1, pl.ds(off, c_blk)]
        xs_blk = None if xs_ref is None else xs_ref[0:1, pl.ds(off, c_blk)]
        dist = block_distance(q, tile, xn_blk, metric, xscale=xs_blk)
        out_ref[0:1, pl.ds(off, c_blk)] = jnp.where(ids_blk >= 0, dist, jnp.inf)
        return ()

    jax.lax.fori_loop(0, n_blocks, body, (), unroll=False)


def _gather_dist_kernel(
    idx_ref,  # (B, C_pad) int32, SMEM (scalar prefetch)
    ids_ref,  # (1, C_pad) int32 VMEM
    q_ref,  # (1, d) VMEM
    xn_ref,  # (1, C_pad) VMEM
    *rest,  # [xs_ref (1, C_pad) — int8 only], x_ref ANY, o_ref, tile_buf, sems
    n_blocks: int,
    c_blk: int,
    metric: str,
    quantized: bool = False,
):
    if quantized:
        xs_ref, x_ref, o_ref, tile_buf, sems = rest
    else:
        x_ref, o_ref, tile_buf, sems = rest
        xs_ref = None
    b = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)  # (1, d)
    blocked_gather_phase(
        b, idx_ref, ids_ref, q, xn_ref, x_ref, o_ref, tile_buf, sems,
        n_blocks=n_blocks, c_blk=c_blk, metric=metric, xs_ref=xs_ref,
    )


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def gather_distance(
    q: Array,
    x: Array,
    idx: Array,
    *,
    metric: str = "l2",
    sq_norms: Optional[Array] = None,
    row_scale: Optional[Array] = None,
    interpret: Optional[bool] = None,
) -> Array:
    """(b, d) queries, (n, d) data, (b, c) int32 ids -> (b, c) f32 distances.

    ``sq_norms`` is the graph-resident ``‖x‖²`` cache; omit it and the norms
    are derived once per call.  ``interpret=None`` resolves to compiled on
    TPU and interpret mode elsewhere — the execution-path *choice* (kernel vs
    pure-JAX reference) belongs to ``kernels.ops`` dispatch, not here.

    Reduced precision: pass ``x`` as the *encoded* table (bf16 or int8 —
    ``precision.EncodedData.data``) and, for int8, ``row_scale`` as the
    graph-resident scale table.  The candidate blocks then move as 2- or
    1-byte rows (cast-on-DMA) and dequantize at the block reduction; fp32
    callers pass raw ``x`` and the kernel is unchanged.  PQ never reaches
    this kernel — the ADC first-pass rank lives in ``kernels.ops``.
    """
    if interpret is None:
        interpret = compat.default_interpret()
    kernel_metric = metric
    if metric == "cosine":
        # Normalize the query once; the cached ‖x‖² supplies the data-side
        # denominator in-kernel (no O(n·d) dataset normalization per call).
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        kernel_metric = "cos"

    b, d = q.shape
    c = idx.shape[1]
    cb = block_c(c)
    cp = padded_c(c)
    idx = idx.astype(jnp.int32)
    if cp != c:
        idx = jnp.pad(idx, ((0, 0), (0, cp - c)), constant_values=-1)
    if x.dtype == jnp.int8 and sq_norms is None:
        raise ValueError("int8 tables need the exact sq_norms cache")
    xn = gathered_sq_norms(x, idx, sq_norms)  # (b, cp)
    quantized = x.dtype == jnp.int8
    operands = [idx, idx, q, xn]
    row = lambda w: pl.BlockSpec((1, w), lambda i, idx_ref: (i, 0))
    in_specs = [
        row(cp),  # ids (vector phase masking)
        row(d),  # q
        row(cp),  # xn
    ]
    if quantized:
        if row_scale is None:
            raise ValueError("int8 tables need the row_scale table")
        xs = gathered_row_scales(idx, row_scale)  # (b, cp)
        operands.append(xs)
        in_specs.append(row(cp))
    operands.append(x)
    in_specs.append(pl.BlockSpec(memory_space=compat.ANY))  # x

    kern = functools.partial(
        _gather_dist_kernel, n_blocks=cp // cb, c_blk=cb,
        metric=kernel_metric, quantized=quantized,
    )
    grid_spec = compat.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=in_specs,
        out_specs=row(cp),
        scratch_shapes=[
            compat.VMEM((2, cb, d), x.dtype),  # tile lands in storage dtype
            compat.SemaphoreType.DMA((2, cb)),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, cp), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:, :c]
