"""Fused gather + distance Pallas TPU kernel (scalar-prefetch).

The inner loop of EHC hill-climbing is: take the candidate ids produced by
expanding a beam vertex, fetch those rows of the dataset, and compute their
distance to the query.  Done naively (``x[idx]`` then a distance) XLA
materializes the (B, C, d) gather in HBM.  This kernel fuses the two: the
candidate ids ride in scalar-prefetch SMEM and drive double-buffered HBM->VMEM
DMAs of the candidate rows, which are reduced against the VMEM-resident query
row as soon as they land — the gather never exists as an HBM intermediate.

Layout
------
* grid = (B,): one grid step per query; Pallas pipelines steps.
* ``idx`` (B, C) int32: scalar-prefetch operand (SMEM).
* ``x`` (n, d): stays in HBM/ANY; rows are moved manually with
  ``pltpu.make_async_copy`` into a 2-slot VMEM scratch (double buffering:
  slot (c+1) mod 2 is in flight while slot c mod 2 is reduced).
* ``q`` block (1, d): standard VMEM operand per grid step.
* out block (1, C) float32.

Negative ids are padding: their lanes are forced to +inf (the convention the
search layer uses for masked candidates).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

Array = jax.Array


def row_distance(q, row, metric: str):
    """Distance between one query and one candidate row, both (1, d) f32.

    The single in-kernel distance formula shared by this kernel and the fused
    expansion kernel (``kernels.expand``) — keeping it in one place is what
    makes the two bit-identical, which the expansion parity suite pins.
    ``"dot"`` is the raw inner product (cosine pre-normalizes and finishes
    outside); ``"cos"`` is the fused-kernel variant that applies the
    ``1 - <q, x>`` step in place.
    """
    if metric == "l2":
        diff = q - row
        return jnp.sum(diff * diff)
    if metric in ("ip", "dot"):
        dist = jnp.sum(q * row)
        return -dist if metric == "ip" else dist
    if metric == "cos":
        return 1.0 - jnp.sum(q * row)
    if metric == "l1":
        return jnp.sum(jnp.abs(q - row))
    if metric == "chi2":
        num = (q - row) ** 2
        den = q + row
        return jnp.sum(jnp.where(den > 1e-12, num / jnp.maximum(den, 1e-12), 0.0))
    raise KeyError(metric)


def _gather_dist_kernel(
    idx_ref,  # (B, C) int32, SMEM (scalar prefetch)
    q_ref,  # (1, d) VMEM
    x_ref,  # (n, d) ANY (HBM)
    o_ref,  # (1, C) VMEM
    row_buf,  # (2, 1, d) VMEM scratch
    sems,  # (2,) DMA semaphores
    *,
    n_cand: int,
    metric: str,
):
    b = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)  # (1, d)

    def start_fetch(c, slot):
        rid = jnp.maximum(idx_ref[b, c], 0)
        cp = compat.make_async_copy(
            x_ref.at[pl.ds(rid, 1)], row_buf.at[slot], sems.at[slot]
        )
        cp.start()

    def wait_fetch(c, slot):
        rid = jnp.maximum(idx_ref[b, c], 0)
        cp = compat.make_async_copy(
            x_ref.at[pl.ds(rid, 1)], row_buf.at[slot], sems.at[slot]
        )
        cp.wait()

    # Warm up the pipeline with candidate 0.
    start_fetch(0, 0)

    def body(c, _):
        slot = jax.lax.rem(c, 2)

        @pl.when(c + 1 < n_cand)
        def _prefetch_next():
            start_fetch(c + 1, jax.lax.rem(c + 1, 2))

        wait_fetch(c, slot)
        row = row_buf[slot].astype(jnp.float32)  # (1, d)
        dist = row_distance(q, row, metric)
        valid = idx_ref[b, c] >= 0
        o_ref[0, c] = jnp.where(valid, dist, jnp.inf)
        return ()

    jax.lax.fori_loop(0, n_cand, body, (), unroll=False)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def gather_distance(
    q: Array,
    x: Array,
    idx: Array,
    *,
    metric: str = "l2",
    interpret: bool = True,
) -> Array:
    """(b, d) queries, (n, d) data, (b, c) int32 ids -> (b, c) f32 distances."""
    if metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
        out = gather_distance(q, x, idx, metric="dot", interpret=interpret)
        return jnp.where(idx >= 0, 1.0 - out, jnp.inf)

    b, d = q.shape
    c = idx.shape[1]
    kern = functools.partial(_gather_dist_kernel, n_cand=c, metric=metric)
    grid_spec = compat.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
            pl.BlockSpec(memory_space=compat.ANY),
        ],
        out_specs=pl.BlockSpec((1, c), lambda i, idx_ref: (i, 0)),
        scratch_shapes=[
            compat.VMEM((2, 1, d), jnp.float32),
            compat.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), q, x)
    return out  # "dot" callers (the cosine path) apply masking themselves
