"""Compressed candidate-table codecs for the blocked distance engine.

The expansion hot loop is memory-bound (roofline arithmetic intensity
~0.04), so bytes-per-candidate is the lever: this module defines the three
reduced-precision representations the engine can fetch candidates from, and
the single ``precision`` vocabulary the whole API speaks:

* ``"fp32"``  — the uncompressed baseline; no encoding, the engine reads the
  raw dataset and its path stays bit-identical to the pre-precision engine.
* ``"bf16"``  — candidate rows stored bfloat16 (2 bytes/dim), cast to fp32 at
  tile load; accumulation is always fp32.
* ``"int8"``  — symmetric per-row quantization (1 byte/dim):
  ``x8 = round(x / s)`` with ``s = max|x| / 127`` per row.  The scale table
  is graph-resident (``KNNGraph.row_scale``, maintained next to
  ``sq_norms``) and the engine applies it to the *dot product*, not the
  tile: exact cached ``‖x‖²`` supplies the norm term of the decomposition,
  so only the ``q·x`` term carries quantization error.
* ``"pq"``    — product-quantization codes (``M`` bytes/row) for a cheap
  first-pass rank by asymmetric distance (ADC); survivors are re-ranked with
  exact fp32 distances inside the expansion step (``kernels.ops.expand_step``).

``EncodedData`` is a pytree of arrays so it can ride through jitted callers;
which fields are populated is a static function of the precision string, so
pytree structure is stable per compiled call.

ADC additivity: ``l2`` (squared), ``ip``/``dot``, ``l1`` and ``chi2`` all
decompose as sums of per-subspace terms, so one (B, M, K) lookup table per
query batch covers them.  ``cosine`` is not additive; it is served from the
additive *dot* table plus the exact cached norms.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

PRECISIONS = ("fp32", "bf16", "int8", "pq")

# PQ defaults: dsub dims per subspace (M = d / dsub), K centroids per
# subspace (uint8 codes).  d not divisible by _PQ_DSUB falls back to the
# largest divisor of d that is <= _PQ_DSUB (worst case 1).
_PQ_DSUB = 8
_PQ_K = 256
_PQ_TRAIN_SAMPLE = 2048
_PQ_TRAIN_ITERS = 8


def validate_precision(precision: str) -> str:
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of {PRECISIONS}"
        )
    return precision


class EncodedData(NamedTuple):
    """Compressed companion of a dataset, consumed by the distance engine.

    Populated fields by precision (always the same structure for a given
    precision string, so jitted callers see a stable pytree):

    * bf16: ``data`` (n, d) bfloat16.
    * int8: ``data`` (n, d) int8 + ``scale`` (n,) float32.
    * pq:   ``codes`` (n, M) uint8 + ``codebook`` (M, K, dsub) float32.
    """

    data: Optional[Array] = None
    scale: Optional[Array] = None
    codes: Optional[Array] = None
    codebook: Optional[Array] = None


def pq_subspaces(d: int) -> int:
    """Number of PQ subspaces for dimension d (largest dsub <= _PQ_DSUB)."""
    for dsub in range(min(_PQ_DSUB, d), 0, -1):
        if d % dsub == 0:
            return d // dsub
    return d


def quantize_int8(x: Array, scale: Array) -> Array:
    """(n, d) rows, (n,) per-row scales -> (n, d) int8 codes.

    Zero scales (all-zero rows, unallocated slots) quantize through 1 so the
    result is defined everywhere; the engine's dequant mirrors the guard.
    """
    safe = jnp.where(scale > 0, scale, 1.0)[:, None]
    q = jnp.round(x.astype(jnp.float32) / safe)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def train_pq_codebook(x: Array, d: Optional[int] = None) -> Array:
    """Train per-subspace centroids with a few Lloyd iterations.

    Deterministic (head sample, strided init) so snapshot round trips and
    repeated calls agree.  Returns (M, K, dsub) float32.
    """
    if d is None:
        d = x.shape[1]
    M = pq_subspaces(d)
    dsub = d // M
    n = x.shape[0]
    ns = min(n, _PQ_TRAIN_SAMPLE)
    sub = x[:ns].astype(jnp.float32).reshape(ns, M, dsub)
    sub = jnp.moveaxis(sub, 1, 0)  # (M, ns, dsub)
    init_idx = (jnp.arange(_PQ_K) * ns) // _PQ_K
    cb = sub[:, jnp.clip(init_idx, 0, ns - 1), :]  # (M, K, dsub)

    def assign(cb):
        # (M, ns, K) squared distances via the matmul expansion.
        xn = jnp.sum(sub * sub, axis=-1, keepdims=True)  # (M, ns, 1)
        cn = jnp.sum(cb * cb, axis=-1)[:, None, :]  # (M, 1, K)
        dots = jnp.einsum("msd,mkd->msk", sub, cb)
        return jnp.argmin(xn + cn - 2.0 * dots, axis=-1)  # (M, ns)

    def step(cb, _):
        a = assign(cb)
        onehot = jax.nn.one_hot(a, _PQ_K, dtype=jnp.float32)  # (M, ns, K)
        counts = jnp.sum(onehot, axis=1)  # (M, K)
        sums = jnp.einsum("msk,msd->mkd", onehot, sub)
        new = sums / jnp.maximum(counts, 1.0)[:, :, None]
        # empty clusters keep their old centroid
        cb = jnp.where((counts > 0)[:, :, None], new, cb)
        return cb, None

    cb, _ = jax.lax.scan(step, cb, None, length=_PQ_TRAIN_ITERS)
    return cb


def pq_encode(x: Array, codebook: Array) -> Array:
    """(n, d) rows -> (n, M) uint8 nearest-centroid codes."""
    M, K, dsub = codebook.shape
    n = x.shape[0]
    sub = x.astype(jnp.float32).reshape(n, M, dsub)
    cn = jnp.sum(codebook * codebook, axis=-1)  # (M, K)
    dots = jnp.einsum("nmd,mkd->nmk", sub, codebook)
    # ‖x_m‖² is constant per (n, m) — argmin over K ignores it.
    codes = jnp.argmin(cn[None, :, :] - 2.0 * dots, axis=-1)
    return codes.astype(jnp.uint8)


def encode_dataset(
    x: Array,
    precision: str,
    *,
    row_scale: Optional[Array] = None,
    codebook: Optional[Array] = None,
) -> Optional[EncodedData]:
    """Build the engine-side compressed table for ``x``.

    ``row_scale``: reuse the graph-resident scale table when the caller has
    one (int8); derived from ``x`` otherwise.  ``codebook``: reuse a trained
    PQ codebook (snapshot restore); trained deterministically otherwise.
    Returns None for fp32 — the engine reads the raw dataset directly.
    """
    validate_precision(precision)
    if precision == "fp32":
        return None
    if precision == "bf16":
        return EncodedData(data=x.astype(jnp.bfloat16))
    if precision == "int8":
        if row_scale is None:
            from repro.core.graph import row_scales  # lazy: kernels load first

            row_scale = row_scales(x)
        return EncodedData(
            data=quantize_int8(x, row_scale),
            scale=row_scale.astype(jnp.float32),
        )
    # pq
    if codebook is None:
        codebook = train_pq_codebook(x)
    return EncodedData(codes=pq_encode(x, codebook), codebook=codebook)


@functools.partial(jax.jit, static_argnames=("metric",))
def adc_tables(q: Array, codebook: Array, metric: str) -> Array:
    """(B, d) queries -> (B, M, K) per-subspace ADC lookup tables.

    Additive metrics get their own per-subspace term; ``cosine`` gets the
    *dot* table (the caller divides by the exact cached norms).
    """
    B, d = q.shape
    M, K, dsub = codebook.shape
    qs = q.astype(jnp.float32).reshape(B, M, dsub)
    if metric in ("l2",):
        qn = jnp.sum(qs * qs, axis=-1, keepdims=True)  # (B, M, 1)
        cn = jnp.sum(codebook * codebook, axis=-1)[None]  # (1, M, K)
        dots = jnp.einsum("bmd,mkd->bmk", qs, codebook)
        return jnp.maximum(qn + cn - 2.0 * dots, 0.0)
    if metric in ("ip", "dot", "cosine", "cos"):
        dots = jnp.einsum("bmd,mkd->bmk", qs, codebook)
        return -dots if metric == "ip" else dots
    if metric == "l1":
        return jnp.sum(
            jnp.abs(qs[:, :, None, :] - codebook[None]), axis=-1
        )
    if metric == "chi2":
        num = (codebook[None] - qs[:, :, None, :]) ** 2
        den = codebook[None] + qs[:, :, None, :]
        return jnp.sum(
            jnp.where(den > 1e-12, num / jnp.maximum(den, 1e-12), 0.0), axis=-1
        )
    raise KeyError(metric)


def adc_gather(
    lut: Array, codes: Array, idx: Array, metric: str,
    sq_norms: Optional[Array] = None,
) -> Array:
    """ADC distances for gathered candidates.

    Args:
      lut: (B, M, K) from ``adc_tables``.
      codes: (n, M) uint8 code table.
      idx: (B, C) candidate ids (< 0 = padding -> +inf).
      sq_norms: exact ``‖x‖²`` cache — required for cosine (denominator).

    Returns (B, C) float32 approximate distances.
    """
    B, M, K = lut.shape
    C = idx.shape[1]
    safe = jnp.clip(idx, 0, codes.shape[0] - 1)
    cand_codes = codes[safe].astype(jnp.int32)  # (B, C, M)
    flat_idx = (jnp.arange(M, dtype=jnp.int32)[None, None, :] * K + cand_codes)
    terms = jnp.take_along_axis(
        lut.reshape(B, M * K), flat_idx.reshape(B, C * M), axis=1
    ).reshape(B, C, M)
    d = jnp.sum(terms, axis=-1)
    if metric in ("cosine", "cos"):
        if sq_norms is None:
            raise ValueError("cosine ADC requires the sq_norms cache")
        xn = sq_norms[safe].astype(jnp.float32)
        d = 1.0 - d / jnp.maximum(jnp.sqrt(xn), 1e-12)
    elif metric == "ip":
        pass  # lut already negated
    return jnp.where(idx >= 0, d.astype(jnp.float32), jnp.inf)


def bytes_per_dim(precision: str) -> float:
    """Candidate-fetch bytes per dimension for the roofline report.

    PQ reads one code byte per subspace (dsub dims), i.e. 1/dsub bytes per
    dim for the first-pass rank; the report scales by the actual d.
    """
    return {"fp32": 4.0, "bf16": 2.0, "int8": 1.0, "pq": 1.0 / _PQ_DSUB}[
        validate_precision(precision)
    ]
