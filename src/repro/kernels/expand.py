"""Fused EHC expansion step — the hot loop of Alg. 1/3 as ONE Pallas kernel.

One EHC iteration per query lane is: take the candidate ids produced by
expanding the best unexpanded beam vertex r (``G[r] ∪ Ḡ[r]`` after LGD/alive
masking), drop the ones the per-query open-addressing hash table (the paper's
D array) has already seen, compute distances to the survivors, record them
into the hash, and merge them into the beam top-k.  Unfused, that is ~6
separate XLA ops per iteration with every intermediate round-tripping HBM;
here the whole chain runs per query inside one kernel:

  * candidate data rows are moved HBM->VMEM in double-buffered *blocks* of
    (C_blk, d), each reduced against the query in one MXU/VPU pass via the
    norms decomposition (``kernels.gather_dist.blocked_gather_phase`` — the
    phase is shared verbatim with the gather-distance kernel, so the two are
    bit-identical per comparison); the ``‖x‖²`` term comes from the
    graph-resident norm cache, never recomputed per iteration;
  * the (1, H) visited-hash rows and the (1, e) beam rows live in VMEM for
    the whole step — probe, insert, and top-k merge never touch HBM;
  * one (1, 1) scalar output returns the lane's comparison count (the
    scanning-rate numerator, Eq. 2).

This module also hosts the *pure-jnp expansion primitives* (probe-slot
computation, hash probe/lookup, beam dedupe) and ``expand_reference`` — the
unfused op chain.  Both implementations consume the same helpers; the parity
suite (``tests/test_expand_parity.py``) pins them bit-identical in interpret
mode.  Dispatch between them is ``kernels.ops.expand_step``:

  * TPU (``use_pallas`` unset or True): compiled fused kernel;
  * ``use_pallas=True`` off-TPU: the same kernel, interpret mode (the
    correctness net the tests sweep);
  * ``use_pallas=False`` / unset off-TPU: ``expand_reference`` (XLA fuses the
    whole step into the jitted search loop — the fast CPU path).

Candidate *generation* (graph-row gathers + λ/alive masking,
``core.search._candidates_from_expansion``) stays outside the kernel: it is a
handful of dense row gathers XLA already handles well, and keeping it shared
between both paths means the kernel boundary is exactly the memory-bound
probe/distance/merge chain the ROADMAP's scanning-rate numbers depend on.

Compiled-mode note: the vector phase leans on in-VMEM gather/scatter and a
row-wise ``lax.top_k`` — Mosaic support for these lowers with recent JAX; the
interpret fallback (selected automatically off-TPU) is the portability net.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels import gather_dist as _gather_dist

# NOTE: kernels.ref is imported lazily inside expand_reference — ref pulls in
# core.metrics, and core.search imports this module at class-body time, so a
# module-level import would close an import cycle through repro.core.

Array = jax.Array

# numpy scalars, not jnp: probe_slots runs inside the fused kernel's trace,
# where module-level jax Arrays would be captured constants (rejected by
# pallas_call); numpy scalars fold into the jaxpr as literals.
_KNUTH = np.uint32(2654435761)
_SHIFT = np.uint32(16)


# ---------------------------------------------------------------------------
# Pure-jnp expansion primitives (shared by the kernel and the reference)
# ---------------------------------------------------------------------------


def probe_slots(ids: Array, hash_slots: int, probes: int) -> Array:
    """(...,) ids -> (..., P) linear-probe slot sequence (Knuth hash)."""
    h = (ids.astype(jnp.uint32) * _KNUTH) >> _SHIFT
    h = h.astype(jnp.int32) & (hash_slots - 1)
    return (h[..., None] + jnp.arange(probes, dtype=jnp.int32)) & (hash_slots - 1)


def hash_lookup(
    vis_ids: Array, vis_dist: Array, ids: Array, probes: int
) -> tuple[Array, Array]:
    """Batch lookup ids (B, C) in per-lane tables (B, H).

    Returns (found (B, C) bool, dist (B, C) f32 — +inf where not found).
    The paper's D[i] with default ∞ (Alg. 3 line 3) is exactly this.
    """
    B, H = vis_ids.shape
    C = ids.shape[1]
    slots = probe_slots(ids, H, probes)  # (B, C, P)
    flat = slots.reshape(B, C * probes)
    got_ids = jnp.take_along_axis(vis_ids, flat, axis=1).reshape(B, C, probes)
    got_dist = jnp.take_along_axis(vis_dist, flat, axis=1).reshape(B, C, probes)
    hit = got_ids == ids[..., None]
    found = jnp.any(hit, axis=-1)
    dist = jnp.min(jnp.where(hit, got_dist, jnp.inf), axis=-1)
    return found, dist


def hash_probe_state(vis_ids: Array, ids: Array, probes: int):
    """Classify ids against tables: (present, insert_ok, insert_slot)."""
    B, H = vis_ids.shape
    C = ids.shape[1]
    slots = probe_slots(ids, H, probes)
    flat = slots.reshape(B, C * probes)
    got = jnp.take_along_axis(vis_ids, flat, axis=1).reshape(B, C, probes)
    is_hit = got == ids[..., None]
    is_empty = got == -1
    pidx = jnp.arange(probes, dtype=jnp.int32)
    first_hit = jnp.min(jnp.where(is_hit, pidx, probes), axis=-1)
    first_empty = jnp.min(jnp.where(is_empty, pidx, probes), axis=-1)
    present = first_hit < first_empty
    insert_ok = (~present) & (first_empty < probes)
    insert_slot = jnp.take_along_axis(
        slots, jnp.minimum(first_empty, probes - 1)[..., None], axis=-1
    )[..., 0]
    return present, insert_ok, insert_slot


def dedupe_beam(ids: Array, dist: Array, exp: Array):
    """Mask later copies of duplicate beam ids (rows sorted by distance).

    Duplicates are rare — they only arise when a hash insert failed (probe
    exhaustion) and the same vertex was re-compared later — but they must not
    survive into results/new graph rows.
    """
    dup = jnp.triu((ids[:, None, :] == ids[:, :, None]) & (ids[:, None, :] >= 0), k=1)
    dup = jnp.any(dup, axis=1)
    return (
        jnp.where(dup, -1, ids),
        jnp.where(dup, jnp.inf, dist),
        exp | dup,
    )


def _probe_mask_record_merge(
    cands: Array,  # (B, C) candidate ids, -1 masked
    dists_all: Array,  # (B, C) m(q, cand) for every id >= 0 (rest: anything)
    beam_ids: Array,  # (B, e)
    beam_dist: Array,  # (B, e)
    beam_exp: Array,  # (B, e) bool (r already marked expanded)
    vis_ids: Array,  # (B, H)
    vis_dist: Array,  # (B, H)
    probes: int,
):
    """The op chain downstream of the distance gather, shared verbatim by the
    kernel's vector phase (B=1 blocks) and ``expand_reference`` — one body,
    two execution sites, zero drift."""
    B, e = beam_ids.shape
    H = vis_ids.shape[1]
    present, insert_ok, insert_slot = hash_probe_state(vis_ids, cands, probes)
    fresh = (cands >= 0) & ~present  # compare these (probe-full: compare anyway)
    cand_ids = jnp.where(fresh, cands, -1)
    dists = jnp.where(fresh, dists_all, jnp.inf)
    comps = jnp.sum(fresh, axis=1).astype(jnp.int32)
    # -- record into the hash (the D array) ----------------------------------
    do_ins = fresh & insert_ok
    B_idx = jnp.broadcast_to(jnp.arange(B)[:, None], cand_ids.shape)
    slot = jnp.where(do_ins, insert_slot, H)  # OOB -> dropped
    vis_ids = vis_ids.at[B_idx, slot].set(
        jnp.where(do_ins, cand_ids, -1), mode="drop"
    )
    vis_dist = vis_dist.at[B_idx, slot].set(
        jnp.where(do_ins, dists, jnp.inf), mode="drop"
    )
    # -- beam merge ----------------------------------------------------------
    cat_ids = jnp.concatenate([beam_ids, cand_ids], axis=1)
    cat_dist = jnp.concatenate([beam_dist, dists], axis=1)
    cat_exp = jnp.concatenate(
        [beam_exp, jnp.zeros_like(cand_ids, bool) | (cand_ids < 0)], axis=1
    )
    neg, sel = jax.lax.top_k(-cat_dist, e)
    beam_ids = jnp.take_along_axis(cat_ids, sel, axis=1)
    beam_dist = -neg
    beam_exp = jnp.take_along_axis(cat_exp, sel, axis=1)
    beam_ids, beam_dist, beam_exp = dedupe_beam(beam_ids, beam_dist, beam_exp)
    return beam_ids, beam_dist, beam_exp, vis_ids, vis_dist, comps


# ---------------------------------------------------------------------------
# Unfused reference (the pre-fusion op chain)
# ---------------------------------------------------------------------------


def expand_reference(
    q: Array,  # (B, d) queries
    x: Array,  # (n, d) dataset
    cands: Array,  # (B, C) masked candidate ids (-1 = skip)
    beam_ids: Array,  # (B, e)
    beam_dist: Array,  # (B, e) f32
    beam_exp: Array,  # (B, e) bool
    vis_ids: Array,  # (B, H)
    vis_dist: Array,  # (B, H) f32
    *,
    metric: str = "l2",
    probes: int = 8,
    sq_norms: Optional[Array] = None,
    enc=None,
    precision: str = "fp32",
    pallas_distances: bool = False,
    interpret: Optional[bool] = None,
):
    """Unfused EHC expansion: probe -> gather-distance -> record -> merge.

    With ``pallas_distances=False`` (default) this is the pure-JAX execution
    path — XLA fuses it into the surrounding jitted search loop; its distance
    gather is the same blocked/decomposed formula as the kernels
    (``kernels.ref.gather_distance``).  With ``pallas_distances=True`` the
    distance gather runs the ``kernels.gather_dist`` Pallas kernel instead,
    giving the exact per-block numerics of the fused kernel — that variant
    is what the parity suite diffs ``fused_expand`` against bit-for-bit.
    ``sq_norms`` is the graph-resident ``‖x‖²`` cache (derived once per call
    when absent).  ``enc``/``precision`` select the compressed candidate
    representation (``kernels.precision``) the distance gather fetches from;
    fp32 leaves both paths untouched.
    """
    if precision == "pq":
        # PQ is a *rank*, not a distance: only exact distances may enter the
        # visited hash / beam.  The ADC prerank + fp32 re-rank composition
        # lives one layer up, in kernels.ops.expand_step.
        raise ValueError("expansion kernels take fp32/bf16/int8; pq is an ops-level prerank")
    present, _, _ = hash_probe_state(vis_ids, cands, probes)
    fresh = (cands >= 0) & ~present
    cand_ids = jnp.where(fresh, cands, -1)
    if pallas_distances:
        x_eng = x if enc is None or precision == "fp32" else enc.data
        row_scale = enc.scale if enc is not None and precision == "int8" else None
        dists = _gather_dist.gather_distance(
            q, x_eng, cand_ids, metric=metric, sq_norms=sq_norms,
            row_scale=row_scale, interpret=interpret,
        )
    else:
        from repro.kernels import ref as _ref  # lazy: see module note

        dists = _ref.gather_distance(
            q, x, cand_ids, metric, sq_norms=sq_norms,
            enc=enc, precision=precision,
        )
    return _probe_mask_record_merge(
        cands, dists, beam_ids, beam_dist, beam_exp, vis_ids, vis_dist, probes
    )


# ---------------------------------------------------------------------------
# Fused Pallas kernel
# ---------------------------------------------------------------------------


def _fused_expand_kernel(
    idx_ref,  # (B, C_pad) int32, SMEM (scalar prefetch) — drives the DMAs
    cand_ref,  # (1, C_pad) int32 VMEM — same ids, vector phase operand
    q_ref,  # (1, d) VMEM
    xn_ref,  # (1, C_pad) f32 VMEM — gathered ‖x‖² (the norm cache)
    bi_ref,  # (1, e) int32 beam ids
    bd_ref,  # (1, e) f32 beam dists
    be_ref,  # (1, e) int32 beam expanded flags (bool cast at the boundary)
    vi_ref,  # (1, H) int32 visited-hash ids
    vd_ref,  # (1, H) f32 visited-hash dists
    *rest,  # [xs_ref (1, C_pad) — int8 only], x_ref ANY, outs, scratch
    n_cand: int,
    n_blocks: int,
    c_blk: int,
    metric: str,
    probes: int,
    quantized: bool = False,
):
    if quantized:
        (xs_ref, x_ref, obi_ref, obd_ref, obe_ref, ovi_ref, ovd_ref, oc_ref,
         dist_buf, tile_buf, sems) = rest
    else:
        (x_ref, obi_ref, obd_ref, obe_ref, ovi_ref, ovd_ref, oc_ref,
         dist_buf, tile_buf, sems) = rest
        xs_ref = None
    b = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)  # (1, d)

    # -- phase 1: blocked candidate gather + one-shot tile reductions --------
    # The exact body of kernels.gather_dist (blocked_gather_phase): block
    # j+1's row DMAs are in flight while block j reduces against q on the
    # MXU (l2/ip/cos norms decomposition, ‖x‖² from the cache) or VPU
    # (l1/chi2 broadcast).  Distances land for every id >= 0 and are masked
    # against the hash in phase 2 — trading a few discarded reductions for a
    # DMA loop with no data-dependent control flow.  Counted comps (phase 2)
    # only charge fresh candidates, matching the unfused path.
    _gather_dist.blocked_gather_phase(
        b, idx_ref, cand_ref, q, xn_ref, x_ref, dist_buf, tile_buf, sems,
        n_blocks=n_blocks, c_blk=c_blk, metric=metric, xs_ref=xs_ref,
    )

    # -- phase 2: probe / record / merge, all VMEM-resident ------------------
    beam_ids, beam_dist, beam_exp, vis_ids, vis_dist, comps = (
        _probe_mask_record_merge(
            cand_ref[0:1, 0:n_cand],
            dist_buf[0:1, 0:n_cand],
            bi_ref[...],
            bd_ref[...],
            be_ref[...] > 0,
            vi_ref[...],
            vd_ref[...],
            probes,
        )
    )
    obi_ref[...] = beam_ids
    obd_ref[...] = beam_dist
    obe_ref[...] = beam_exp.astype(jnp.int32)
    ovi_ref[...] = vis_ids
    ovd_ref[...] = vis_dist
    oc_ref[0, 0] = comps[0]


@functools.partial(
    jax.jit, static_argnames=("metric", "probes", "interpret", "precision")
)
def fused_expand(
    q: Array,
    x: Array,
    cands: Array,
    beam_ids: Array,
    beam_dist: Array,
    beam_exp: Array,
    vis_ids: Array,
    vis_dist: Array,
    *,
    metric: str = "l2",
    probes: int = 8,
    sq_norms: Optional[Array] = None,
    enc=None,
    precision: str = "fp32",
    interpret: Optional[bool] = None,
):
    """One fused EHC expansion step for a batch of queries.

    Same signature and return contract as ``expand_reference``:
    (beam_ids, beam_dist, beam_exp, vis_ids, vis_dist, comps (B,) int32).
    ``sq_norms`` is the graph-resident ``‖x‖²`` cache backing the blocked
    distance engine (derived once per call when absent).  With
    ``precision="bf16"``/``"int8"`` (and ``enc`` the matching
    ``precision.EncodedData``) phase 1 DMAs the compressed table instead —
    2-/1-byte candidate rows, cast at the block reduction; int8 also rides a
    gathered scale operand.  fp32 keeps the exact pre-precision operands.
    """
    if precision == "pq":
        raise ValueError("expansion kernels take fp32/bf16/int8; pq is an ops-level prerank")
    if interpret is None:
        interpret = compat.default_interpret()
    kernel_metric = metric
    if metric == "cosine":
        # Normalize the query once (exactly as kernels.gather_dist does); the
        # cached ‖x‖² supplies the data-side denominator in-kernel.
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        kernel_metric = "cos"

    B, d = q.shape
    C = cands.shape[1]
    e = beam_ids.shape[1]
    H = vis_ids.shape[1]
    cb = _gather_dist.block_c(C)
    cp = _gather_dist.padded_c(C)
    cands_p = cands.astype(jnp.int32)
    if cp != C:
        cands_p = jnp.pad(cands_p, ((0, 0), (0, cp - C)), constant_values=-1)
    xn = _gather_dist.gathered_sq_norms(x, cands_p, sq_norms)  # (B, cp)

    x_eng = x if (enc is None or precision == "fp32") else enc.data
    quantized = enc is not None and precision == "int8"

    kern = functools.partial(
        _fused_expand_kernel, n_cand=C, n_blocks=cp // cb, c_blk=cb,
        metric=kernel_metric, probes=probes, quantized=quantized,
    )
    row = lambda w: pl.BlockSpec((1, w), lambda i, idx_ref: (i, 0))
    in_specs = [
        row(cp),  # cands (vector phase; first C entries are the originals)
        row(d),  # q
        row(cp),  # xn
        row(e),  # beam_ids
        row(e),  # beam_dist
        row(e),  # beam_exp
        row(H),  # vis_ids
        row(H),  # vis_dist
    ]
    operands = [
        cands_p,
        cands_p,
        q,
        xn,
        beam_ids,
        beam_dist,
        beam_exp.astype(jnp.int32),
        vis_ids,
        vis_dist,
    ]
    if quantized:
        in_specs.append(row(cp))  # xs (gathered int8 dequant scales)
        operands.append(_gather_dist.gathered_row_scales(cands_p, enc.scale))
    in_specs.append(pl.BlockSpec(memory_space=compat.ANY))  # x
    operands.append(x_eng)
    grid_spec = compat.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=in_specs,
        out_specs=[row(e), row(e), row(e), row(H), row(H), row(1)],
        scratch_shapes=[
            compat.VMEM((1, cp), jnp.float32),
            compat.VMEM((2, cb, d), x_eng.dtype),  # tile in storage dtype
            compat.SemaphoreType.DMA((2, cb)),
        ],
    )
    outs = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, e), jnp.int32),
            jax.ShapeDtypeStruct((B, e), jnp.float32),
            jax.ShapeDtypeStruct((B, e), jnp.int32),
            jax.ShapeDtypeStruct((B, H), jnp.int32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    bi, bd, be, vi, vd, comps = outs
    return bi, bd, be > 0, vi, vd, comps[:, 0]
