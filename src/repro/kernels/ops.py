"""Dispatching wrappers over the Pallas kernels.

Every call site in ``repro.core`` goes through these functions, and the two
execution-surface policies of the framework are resolved HERE and only here:

* **dispatch** — which engine implementation runs.  One enum replaces the old
  tri-state ``use_pallas`` flag:

    - ``"auto"``      compiled Pallas kernel on TPU, pure-JAX reference
                      elsewhere (the old ``use_pallas=None``);
    - ``"pallas"``    the Pallas kernel — compiled on TPU, interpret mode
                      elsewhere (the old ``use_pallas=True``);
    - ``"interpret"`` the Pallas kernel in interpret mode everywhere (what
                      kernel-correctness tests sweep, even on TPU);
    - ``"reference"`` the pure-JAX reference path everywhere (the old
                      ``use_pallas=False``).

  The legacy ``use_pallas`` keyword is still accepted (None/True/False map to
  auto/pallas/reference); config-level deprecation lives in
  ``core.search.SearchConfig``.

* **precision** — which candidate representation the engine fetches
  (``"fp32"|"bf16"|"int8"|"pq"``, see ``kernels.precision``).  Callers pass
  the raw dataset ``x`` plus the compressed companion ``enc``; no call site
  ever handles dtypes itself.  ``"pq"`` composes as rank-then-rerank inside
  ``expand_step``: ADC first-pass rank on the fresh candidates, exact fp32
  distances for the surviving top ``rerank_keep`` — only exact distances
  enter the visited hash or the beam.

``sq_norms`` / ``x_sq_norms`` thread the graph-resident ``‖x‖²`` cache
(``KNNGraph.sq_norms``) into the blocked distance engine so no path — brute
force, seed gathers, or the expansion hot loop — recomputes norms per
iteration.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import compat
from repro.kernels import distance as _distance
from repro.kernels import expand as _expand
from repro.kernels import gather_dist as _gather_dist
from repro.kernels import precision as _precision
from repro.kernels import ref as _ref

Array = jax.Array

_on_tpu = compat.on_tpu

DISPATCHES = ("auto", "pallas", "interpret", "reference")


def resolve_dispatch(
    dispatch: Optional[str] = None, use_pallas: Optional[bool] = None
) -> tuple[bool, bool]:
    """The one resolution point for the execution-path enum.

    Returns ``(use_kernel, interpret)``.  ``dispatch=None`` falls back to the
    legacy ``use_pallas`` tri-state (None -> auto, True -> pallas, False ->
    reference) so old callers and old snapshots keep working.
    """
    if dispatch is None:
        if use_pallas is None:
            dispatch = "auto"
        else:
            dispatch = "pallas" if use_pallas else "reference"
    if dispatch == "auto":
        return _on_tpu(), False
    if dispatch == "pallas":
        return True, not _on_tpu()
    if dispatch == "interpret":
        return True, True
    if dispatch == "reference":
        return False, False
    raise ValueError(
        f"unknown dispatch {dispatch!r}; expected one of {DISPATCHES}"
    )


def pairwise_distance(
    q: Array,
    x: Array,
    metric: str = "l2",
    *,
    use_pallas: Optional[bool] = None,
    dispatch: Optional[str] = None,
    x_sq_norms: Optional[Array] = None,
    enc: Optional[_precision.EncodedData] = None,
    precision: str = "fp32",
    bm: int = 128,
    bn: int = 128,
    bd: int = 128,
) -> Array:
    """(m, d) x (n, d) -> (m, n) float32 distances.

    ``x_sq_norms``: optional cached ``‖x‖²`` of the x side (l2 consumes it;
    other metrics ignore it).  Compressed precisions run the reference
    engine regardless of dispatch — pairwise feeds seeding/brute-force
    tiles, not the expansion hot loop, and the Pallas pairwise kernel stays
    fp32-only.
    """
    use_kernel, _ = resolve_dispatch(dispatch, use_pallas)
    if enc is not None and precision != "fp32":
        return _ref.pairwise_distance(
            q, x, metric, x_sq_norms=x_sq_norms, enc=enc, precision=precision
        )
    if use_kernel:
        return _distance.pairwise_distance(
            q, x, metric=metric, x_sq_norms=x_sq_norms,
            bm=bm, bn=bn, bd=bd, interpret=not _on_tpu(),
        )
    return _ref.pairwise_distance(q, x, metric, x_sq_norms=x_sq_norms)


def gather_distance(
    q: Array,
    x: Array,
    idx: Array,
    metric: str = "l2",
    *,
    use_pallas: Optional[bool] = None,
    dispatch: Optional[str] = None,
    sq_norms: Optional[Array] = None,
    enc: Optional[_precision.EncodedData] = None,
    precision: str = "fp32",
) -> Array:
    """(b, d) queries vs rows x[idx] -> (b, c) float32; inf at idx < 0.

    ``sq_norms``: optional (n,) graph-resident ``‖x‖²`` cache feeding the
    blocked engine's norms decomposition.  ``enc``/``precision`` select the
    candidate representation: bf16/int8 ride the kernel *or* reference
    engine (per dispatch); ``"pq"`` is always the reference ADC rank — the
    in-kernel tile path has no code-table form, and the exact re-rank
    composes in ``expand_step``.
    """
    use_kernel, interpret = resolve_dispatch(dispatch, use_pallas)
    compressed = enc is not None and precision != "fp32"
    if compressed and precision == "pq":
        return _ref.gather_distance(
            q, x, idx, metric, sq_norms=sq_norms, enc=enc, precision=precision
        )
    if use_kernel:
        x_eng = enc.data if compressed else x
        row_scale = enc.scale if compressed and precision == "int8" else None
        return _gather_dist.gather_distance(
            q, x_eng, idx, metric=metric, sq_norms=sq_norms,
            row_scale=row_scale, interpret=interpret,
        )
    return _ref.gather_distance(
        q, x, idx, metric, sq_norms=sq_norms,
        enc=enc if compressed else None,
        precision=precision if compressed else "fp32",
    )


def merge_proposals(
    q: Array,
    xt: Array,
    hit_ids: Array,
    t_nbr_ids: Array,
    t_alive: Array,
    metric: str = "l2",
    *,
    dispatch: Optional[str] = None,
    sq_norms: Optional[Array] = None,
    hop_top: Optional[int] = None,
) -> tuple[Array, Array, Array]:
    """Second-hop merge candidates through the blocked distance engine.

    For each query row with cross-search hits ``hit_ids`` (target-LOCAL ids,
    -1 pad) against a target sub-graph, propose the hits' own neighbor lists
    (``t_nbr_ids[hit]``) as additional candidates — the 1908.00814 move that
    turns one EHC walk per query into a k²-wide neighborhood sample.  All
    candidate distances run through ``gather_distance`` (the one blocked
    engine), so proposal assembly stays on-device; dead targets are masked.

    Args:
      q: (B, d) query vectors (the searching side's points).
      xt: (n_t, d) target side's data.
      hit_ids: (B, k) target-LOCAL hit ids from the cross search.
      t_nbr_ids: (n_t, k_t) target graph forward lists (LOCAL ids).
      t_alive: (n_t,) target liveness.
      metric/dispatch/sq_norms: distance-engine routing (``sq_norms`` =
        target side's graph-resident norm cache).
      hop_top: expand only the nearest ``hop_top`` hits per query (hit
        lists arrive distance-sorted from the search).  The full k² fan-out
        is quadratic in candidate volume but the recall lives in the first
        few hits' neighborhoods; ``None`` expands every hit.

    Returns (cand_ids (B, h*k_t) LOCAL, cand_dist (B, h*k_t) with inf at
    masked lanes, n_comps () int32 — every evaluated lane charged), where
    ``h = min(hop_top, k)``.
    """
    B, k = hit_ids.shape
    if hop_top is not None and hop_top < k:
        hit_ids = hit_ids[:, :hop_top]
    hop = t_nbr_ids[jnp.maximum(hit_ids, 0)]  # (B, h, k_t)
    hop = jnp.where(hit_ids[:, :, None] >= 0, hop, -1).reshape(B, -1)
    hop = jnp.where((hop >= 0) & t_alive[jnp.maximum(hop, 0)], hop, -1)
    d = gather_distance(
        q, xt, hop, metric, dispatch=dispatch, sq_norms=sq_norms
    )
    live = hop >= 0
    return hop, jnp.where(live, d, jnp.inf), jnp.sum(live, dtype=jnp.int32)


def topk_smallest(dists: Array, ids: Array, k: int):
    """Row-wise smallest-k selection; see ref.topk_smallest."""
    return _ref.topk_smallest(dists, ids, k)


def expand_step(
    q: Array,
    x: Array,
    cands: Array,
    beam_ids: Array,
    beam_dist: Array,
    beam_exp: Array,
    vis_ids: Array,
    vis_dist: Array,
    *,
    metric: str = "l2",
    hash_probes: int = 8,
    sq_norms: Optional[Array] = None,
    use_pallas: Optional[bool] = None,
    dispatch: Optional[str] = None,
    enc: Optional[_precision.EncodedData] = None,
    precision: str = "fp32",
    rerank_keep: int = 0,
):
    """One EHC expansion step (Alg. 1/3 inner loop) for a batch of queries.

    Given masked candidate ids (``core.search._candidates_from_expansion``
    output), dedups them against the per-query visited hash, computes the
    surviving distances with the blocked MXU engine (``sq_norms`` = the
    graph-resident norm cache), records them into the hash, and merges them
    into the beam top-k.  Returns
    ``(beam_ids, beam_dist, beam_exp, vis_ids, vis_dist, comps)``.

    Precision: ``"bf16"``/``"int8"`` fetch candidate rows from the
    compressed table inside whichever engine dispatch selects.  ``"pq"``
    runs rank-then-rerank: the fresh candidates get an ADC first-pass rank
    from the code table, only the best ``rerank_keep`` go through the exact
    fp32 expansion, and the ADC-scanned-but-dropped candidates still charge
    ``comps`` (scanning-rate honesty — every fresh candidate was evaluated
    once).  Only exact distances ever enter the visited hash or the beam.
    """
    if enc is not None and precision == "pq":
        if rerank_keep <= 0:
            raise ValueError("pq expansion needs rerank_keep > 0")
        return _pq_rank_then_rerank(
            q, x, cands, beam_ids, beam_dist, beam_exp, vis_ids, vis_dist,
            metric=metric, hash_probes=hash_probes, sq_norms=sq_norms,
            use_pallas=use_pallas, dispatch=dispatch, enc=enc,
            rerank_keep=rerank_keep,
        )
    use_kernel, interpret = resolve_dispatch(dispatch, use_pallas)
    compressed = enc is not None and precision != "fp32"
    if use_kernel:
        return _expand.fused_expand(
            q, x, cands, beam_ids, beam_dist, beam_exp, vis_ids, vis_dist,
            metric=metric, probes=hash_probes, sq_norms=sq_norms,
            enc=enc if compressed else None,
            precision=precision if compressed else "fp32",
            interpret=interpret,
        )
    return _expand.expand_reference(
        q, x, cands, beam_ids, beam_dist, beam_exp, vis_ids, vis_dist,
        metric=metric, probes=hash_probes, sq_norms=sq_norms,
        enc=enc if compressed else None,
        precision=precision if compressed else "fp32",
    )


def _pq_rank_then_rerank(
    q, x, cands, beam_ids, beam_dist, beam_exp, vis_ids, vis_dist,
    *, metric, hash_probes, sq_norms, use_pallas, dispatch, enc, rerank_keep
):
    """ADC first-pass rank -> exact fp32 re-rank of the survivors.

    The prerank never touches the visited hash: the same ``hash_probe_state``
    the inner expansion will run classifies fresh candidates, the ADC ranks
    them, and everything below the top ``rerank_keep`` is masked to -1 before
    the (unchanged, exact) expansion step executes.  Dropped candidates are
    *not* recorded anywhere — they may be rediscovered by a later expansion,
    which re-charges them; that is the price of keeping the hash exact.
    """
    C = cands.shape[1]
    keep = min(rerank_keep, C)
    present, _, _ = _expand.hash_probe_state(vis_ids, cands, hash_probes)
    fresh = (cands >= 0) & ~present
    cand_ids = jnp.where(fresh, cands, -1)
    adc = _ref.gather_distance(
        q, x, cand_ids, metric, sq_norms=sq_norms, enc=enc, precision="pq"
    )  # (B, C); +inf at masked
    # survivors: the `keep` smallest ADC scores per row
    _, sel = jax.lax.top_k(-adc, keep)  # (B, keep)
    B_idx = jnp.broadcast_to(jnp.arange(q.shape[0])[:, None], sel.shape)
    survive = jnp.zeros(cands.shape, bool).at[B_idx, sel].set(True)
    cands_kept = jnp.where(survive, cands, -1)
    out = expand_step(
        q, x, cands_kept, beam_ids, beam_dist, beam_exp, vis_ids, vis_dist,
        metric=metric, hash_probes=hash_probes, sq_norms=sq_norms,
        use_pallas=use_pallas, dispatch=dispatch, enc=None, precision="fp32",
    )
    bi, bd, be, vi, vd, _comps_exact = out
    # scanning-rate honesty: every fresh candidate cost one (ADC) evaluation;
    # the exact re-ranks are a subset, not an addition.
    comps = jnp.sum(fresh, axis=1).astype(jnp.int32)
    return bi, bd, be, vi, vd, comps
