"""Dispatching wrappers over the Pallas kernels.

Every call site in ``repro.core`` goes through these functions.  On TPU the
Pallas kernels run compiled (``interpret=False``); on CPU the default is the
pure-jnp reference path (fast under XLA:CPU) while ``use_pallas=True`` forces
the interpreted kernel (what the correctness tests sweep).  The
interpret-vs-compiled decision is made HERE (and only here) and passed down
explicitly — the kernels' own ``interpret=None`` defaults merely resolve to
the same backend check for direct callers.

``sq_norms`` / ``x_sq_norms`` thread the graph-resident ``‖x‖²`` cache
(``KNNGraph.sq_norms``) into the blocked distance engine so no path — brute
force, seed gathers, or the expansion hot loop — recomputes norms per
iteration.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import compat
from repro.kernels import distance as _distance
from repro.kernels import expand as _expand
from repro.kernels import gather_dist as _gather_dist
from repro.kernels import ref as _ref

Array = jax.Array

_on_tpu = compat.on_tpu


def pairwise_distance(
    q: Array,
    x: Array,
    metric: str = "l2",
    *,
    use_pallas: Optional[bool] = None,
    x_sq_norms: Optional[Array] = None,
    bm: int = 128,
    bn: int = 128,
    bd: int = 128,
) -> Array:
    """(m, d) x (n, d) -> (m, n) float32 distances.

    ``x_sq_norms``: optional cached ``‖x‖²`` of the x side (l2 consumes it;
    other metrics ignore it).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _distance.pairwise_distance(
            q, x, metric=metric, x_sq_norms=x_sq_norms,
            bm=bm, bn=bn, bd=bd, interpret=not _on_tpu(),
        )
    return _ref.pairwise_distance(q, x, metric, x_sq_norms=x_sq_norms)


def gather_distance(
    q: Array,
    x: Array,
    idx: Array,
    metric: str = "l2",
    *,
    use_pallas: Optional[bool] = None,
    sq_norms: Optional[Array] = None,
) -> Array:
    """(b, d) queries vs rows x[idx] -> (b, c) float32; inf at idx < 0.

    ``sq_norms``: optional (n,) graph-resident ``‖x‖²`` cache feeding the
    blocked engine's norms decomposition.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _gather_dist.gather_distance(
            q, x, idx, metric=metric, sq_norms=sq_norms,
            interpret=not _on_tpu(),
        )
    return _ref.gather_distance(q, x, idx, metric, sq_norms=sq_norms)


def topk_smallest(dists: Array, ids: Array, k: int):
    """Row-wise smallest-k selection; see ref.topk_smallest."""
    return _ref.topk_smallest(dists, ids, k)


def expand_step(
    q: Array,
    x: Array,
    cands: Array,
    beam_ids: Array,
    beam_dist: Array,
    beam_exp: Array,
    vis_ids: Array,
    vis_dist: Array,
    *,
    metric: str = "l2",
    hash_probes: int = 8,
    sq_norms: Optional[Array] = None,
    use_pallas: Optional[bool] = None,
):
    """One EHC expansion step (Alg. 1/3 inner loop) for a batch of queries.

    Given masked candidate ids (``core.search._candidates_from_expansion``
    output), dedups them against the per-query visited hash, computes the
    surviving distances with the blocked MXU engine (``sq_norms`` = the
    graph-resident norm cache), records them into the hash, and merges them
    into the beam top-k.  Returns
    ``(beam_ids, beam_dist, beam_exp, vis_ids, vis_dist, comps)``.

    Three-way dispatch (the policy ``SearchConfig.use_pallas`` documents):
      * on TPU (``use_pallas`` None or True): the compiled fused Pallas
        kernel (``kernels.expand.fused_expand``);
      * ``use_pallas=True`` off-TPU: the same kernel in interpret mode (what
        the parity/correctness tests sweep);
      * otherwise: ``kernels.expand.expand_reference``, the pure-JAX op chain
        XLA fuses into the surrounding jitted search loop.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _expand.fused_expand(
            q, x, cands, beam_ids, beam_dist, beam_exp, vis_ids, vis_dist,
            metric=metric, probes=hash_probes, sq_norms=sq_norms,
            interpret=not _on_tpu(),
        )
    return _expand.expand_reference(
        q, x, cands, beam_ids, beam_dist, beam_exp, vis_ids, vis_dist,
        metric=metric, probes=hash_probes, sq_norms=sq_norms,
    )
