"""Version-portability shim for the Pallas/TPU surface (and adjacent JAX
API drift).

JAX renames and moves things between minor versions; every breakage the seed
suffered traced back to a call site touching a moved attribute directly
(``pltpu.CompilerParams`` vs ``pltpu.TPUCompilerParams``, ``jax.shard_map``
vs ``jax.experimental.shard_map.shard_map``, ``jax.sharding.AxisType``).
This module is the single point of truth: kernel and parallelism code imports
*only* from here, so the next rename is a one-line fix instead of a red
test suite.

Everything is resolved by feature detection at import time — no version
string parsing.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# ---------------------------------------------------------------------------
# Pallas TPU surface
# ---------------------------------------------------------------------------

# Memory spaces / DMA helpers — re-exported so kernel modules never touch
# pltpu attributes directly.
VMEM = pltpu.VMEM
SMEM = pltpu.SMEM
ANY = getattr(pltpu, "ANY", getattr(pl, "ANY", None))
SemaphoreType = pltpu.SemaphoreType
make_async_copy = pltpu.make_async_copy
PrefetchScalarGridSpec = pltpu.PrefetchScalarGridSpec

# Renamed in newer JAX: TPUCompilerParams -> CompilerParams.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def compiler_params(
    *, dimension_semantics: Optional[Sequence[str]] = None, **kwargs
):
    """TPU compiler params under whichever class name this JAX exposes."""
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    return _COMPILER_PARAMS_CLS(**kwargs)


def on_tpu() -> bool:
    """True when the default backend is a real TPU."""
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Pallas kernels run compiled on TPU, interpreted everywhere else."""
    return not on_tpu()


# ---------------------------------------------------------------------------
# shard_map / mesh drift
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):  # newer JAX: top-level, check_vma kwarg
    _shard_map_impl = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # older JAX: experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across its experimental -> stable migration.

    ``check`` maps to ``check_vma`` (new) / ``check_rep`` (old); both default
    off here because the k-NN wave step intentionally mixes replicated and
    sharded outputs.
    """
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: check},
    )


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    return jax.make_mesh(
        tuple(axis_shapes), tuple(axis_names),
        axis_types=(AxisType.Auto,) * len(tuple(axis_names)),
    )


def donation_enabled() -> bool:
    """True where jax buffer donation actually takes effect (TPU/GPU)."""
    return jax.default_backend() in ("tpu", "gpu")


def donating_jit(fun=None, *, static_argnames=(), donate_argnums=()):
    """``jax.jit`` that only donates where donation is implemented.

    Buffer donation is a no-op (plus a warning per compile) on CPU; dropping
    the donation there keeps logs clean and lets tests reuse inputs, while
    TPU/GPU get the in-place graph update the fused wave pipeline relies on.
    """
    if fun is None:
        return functools.partial(
            donating_jit,
            static_argnames=static_argnames,
            donate_argnums=donate_argnums,
        )

    # Resolved on first call, not at decoration time: deciding needs
    # ``jax.default_backend()``, and module import must never initialize
    # device state (the dry-run sets XLA_FLAGS after imports).
    cache: dict = {}

    @functools.wraps(fun)
    def wrapper(*args, **kwargs):
        if "jitted" not in cache:
            donate = donate_argnums if donation_enabled() else ()
            cache["jitted"] = jax.jit(
                fun, static_argnames=static_argnames, donate_argnums=donate
            )
        return cache["jitted"](*args, **kwargs)

    wrapper.clear_cache = cache.clear  # test hook
    return wrapper
