"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against
(``tests/test_kernels.py``) and the CPU execution path used by the rest of the
framework when no TPU is present.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import metrics

Array = jax.Array


def pairwise_distance(q: Array, x: Array, metric: str = "l2") -> Array:
    """(m, d) x (n, d) -> (m, n) distances.  Oracle for kernels.distance."""
    return metrics.pairwise(metric, q, x)


def gather_distance(q: Array, x: Array, idx: Array, metric: str = "l2") -> Array:
    """Fused gather + distance oracle.

    Args:
      q:   (b, d)  queries.
      x:   (n, d)  dataset.
      idx: (b, c)  int32 candidate ids per query; id < 0 means padding.

    Returns:
      (b, c) float32 distances; +inf at padded slots.
    """
    b, c = idx.shape
    safe = jnp.maximum(idx, 0)
    cand = x[safe]  # (b, c, d)

    def per_query(qi, ci):
        return metrics.pairwise(metric, qi[None, :], ci)[0]

    d = jax.vmap(per_query)(q, cand)
    return jnp.where(idx >= 0, d.astype(jnp.float32), jnp.inf)


def topk_smallest(dists: Array, ids: Array, k: int):
    """Row-wise smallest-k (distance, id) selection.  Oracle for merge ops.

    Args:
      dists: (m, c) distances (inf = padding).
      ids:   (m, c) ids aligned with dists.
      k:     number to keep.

    Returns:
      (m, k) dists sorted ascending, (m, k) ids.
    """
    neg, sel = jax.lax.top_k(-dists, k)
    return -neg, jnp.take_along_axis(ids, sel, axis=1)
