"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against
(``tests/test_kernels.py``) and the CPU execution path used by the rest of
the framework when no TPU is present.  The gather-distance oracle implements
the SAME norms-decomposed blocked formula as the Pallas engine
(``kernels.gather_dist.block_distance``) — ``‖q‖² + ‖x‖² − 2·q·x`` with the
``‖x‖²`` term served from the graph-resident cache when the caller has one —
so the CPU production path and the TPU kernel agree to float tolerance and
neither recomputes norms per iteration.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import metrics

Array = jax.Array


def pairwise_distance(
    q: Array, x: Array, metric: str = "l2", *, x_sq_norms: Optional[Array] = None
) -> Array:
    """(m, d) x (n, d) -> (m, n) distances.  Oracle for kernels.distance.

    ``x_sq_norms`` is the cached ``‖x‖²`` of the x side; when provided (l2)
    the decomposition consumes it instead of re-reducing x.
    """
    if x_sq_norms is not None and metric == "l2":
        qf = q.astype(jnp.float32)
        xf = x.astype(jnp.float32)
        qn = jnp.sum(qf * qf, axis=-1, keepdims=True)  # (m, 1)
        return jnp.maximum(
            qn + x_sq_norms.astype(jnp.float32)[None, :] - 2.0 * (qf @ xf.T), 0.0
        )
    return metrics.pairwise(metric, q, x)


def gather_distance(
    q: Array,
    x: Array,
    idx: Array,
    metric: str = "l2",
    *,
    sq_norms: Optional[Array] = None,
) -> Array:
    """Blocked gather + distance oracle (decomposed formula).

    Args:
      q:   (b, d)  queries.
      x:   (n, d)  dataset.
      idx: (b, c)  int32 candidate ids per query; id < 0 means padding.
      sq_norms: optional (n,) cached ``‖x‖²`` (the graph-resident cache);
        derived once per call when absent.

    Returns:
      (b, c) float32 distances; +inf at padded slots.
    """
    safe = jnp.clip(idx, 0, x.shape[0] - 1)
    if metric in ("l2", "ip", "dot", "cosine", "cos"):
        qf = q.astype(jnp.float32)
        if metric in ("cosine", "cos"):
            qf = qf / jnp.maximum(
                jnp.linalg.norm(qf, axis=-1, keepdims=True), 1e-12
            )
        cand = x[safe].astype(jnp.float32)  # (b, c, d)
        # broadcast-multiply + reduce rather than einsum: XLA:CPU fuses this
        # into one pass over the gathered tile, while the einsum/dot_general
        # lowering becomes a loop of (1, d) matvecs that is measurably slower
        # at the large-C shapes the engine targets (see the gather-engine
        # microbench); on TPU the Pallas kernel owns this computation anyway.
        dots = jnp.sum(qf[:, None, :] * cand, axis=-1)
        if metric in ("l2", "cosine", "cos"):
            if sq_norms is None:
                from repro.core.graph import squared_norms  # lazy: no cycle

                xn = squared_norms(cand)
            else:
                xn = sq_norms[safe].astype(jnp.float32)
            if metric == "l2":
                qn = jnp.sum(qf * qf, axis=-1, keepdims=True)
                d = jnp.maximum(qn + xn - 2.0 * dots, 0.0)
            else:
                d = 1.0 - dots / jnp.maximum(jnp.sqrt(xn), 1e-12)
        elif metric == "ip":
            d = -dots
        else:  # dot
            d = dots
    else:
        # VPU metrics (l1 / chi2): no matmul form — broadcast reduction.
        cand = x[safe]

        def per_query(qi, ci):
            return metrics.pairwise(metric, qi[None, :], ci)[0]

        d = jax.vmap(per_query)(q, cand)
    return jnp.where(idx >= 0, d.astype(jnp.float32), jnp.inf)


def topk_smallest(dists: Array, ids: Array, k: int):
    """Row-wise smallest-k (distance, id) selection.  Oracle for merge ops.

    Args:
      dists: (m, c) distances (inf = padding).
      ids:   (m, c) ids aligned with dists.
      k:     number to keep.

    Returns:
      (m, k) dists sorted ascending, (m, k) ids.
    """
    neg, sel = jax.lax.top_k(-dists, k)
    return -neg, jnp.take_along_axis(ids, sel, axis=1)
