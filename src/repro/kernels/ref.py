"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against
(``tests/test_kernels.py``) and the CPU execution path used by the rest of
the framework when no TPU is present.  The gather-distance oracle implements
the SAME norms-decomposed blocked formula as the Pallas engine
(``kernels.gather_dist.block_distance``) — ``‖q‖² + ‖x‖² − 2·q·x`` with the
``‖x‖²`` term served from the graph-resident cache when the caller has one —
so the CPU production path and the TPU kernel agree to float tolerance and
neither recomputes norms per iteration.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import metrics
from repro.kernels import precision as _precision

Array = jax.Array

# C-block width of the compressed gather's chunked contraction — matches the
# Pallas engine's _MAX_BLOCK_C, and keeps each dequantized f32 chunk
# L2-resident on CPU (the full-tile cast/dot was measured ~1.7x slower at
# the wave shape, see the precision microbench).
_CHUNK_C = 128


def _chunked_dots(qf: Array, cand: Array) -> Array:
    """Batched ``q·cand`` contraction over C-chunks of a gathered tile.

    ``cand`` is (B, C, d) in its *storage* dtype (bf16/int8); each chunk is
    cast to fp32 right before its dot so at most (B, _CHUNK_C, d) fp32 ever
    materializes.  Returns (B, C) float32.
    """
    B, C, d = cand.shape
    dn = (((1,), (2,)), ((0,), (0,)))
    if C <= _CHUNK_C or C % _CHUNK_C:
        return jax.lax.dot_general(
            qf, cand.astype(jnp.float32), dn,
            preferred_element_type=jnp.float32,
        )
    blocks = jnp.moveaxis(cand.reshape(B, C // _CHUNK_C, _CHUNK_C, d), 1, 0)

    def body(carry, blk):
        return carry, jax.lax.dot_general(
            qf, blk.astype(jnp.float32), dn,
            preferred_element_type=jnp.float32,
        )

    _, out = jax.lax.scan(body, 0, blocks)
    return jnp.moveaxis(out, 0, 1).reshape(B, C)


def pairwise_distance(
    q: Array,
    x: Array,
    metric: str = "l2",
    *,
    x_sq_norms: Optional[Array] = None,
    enc: Optional[_precision.EncodedData] = None,
    precision: str = "fp32",
) -> Array:
    """(m, d) x (n, d) -> (m, n) distances.  Oracle for kernels.distance.

    ``x_sq_norms`` is the cached ``‖x‖²`` of the x side; when provided (l2)
    the decomposition consumes it instead of re-reducing x.  ``enc`` /
    ``precision`` select a compressed x-side representation
    (``kernels.precision``); fp32 (or no ``enc``) is byte-identical to the
    pre-precision path.
    """
    if enc is not None and precision != "fp32":
        return _pairwise_distance_compressed(
            q, x, metric, x_sq_norms=x_sq_norms, enc=enc, precision=precision
        )
    if x_sq_norms is not None and metric == "l2":
        qf = q.astype(jnp.float32)
        xf = x.astype(jnp.float32)
        qn = jnp.sum(qf * qf, axis=-1, keepdims=True)  # (m, 1)
        return jnp.maximum(
            qn + x_sq_norms.astype(jnp.float32)[None, :] - 2.0 * (qf @ xf.T), 0.0
        )
    return metrics.pairwise(metric, q, x)


def _pairwise_distance_compressed(
    q: Array,
    x: Array,
    metric: str,
    *,
    x_sq_norms: Optional[Array],
    enc: _precision.EncodedData,
    precision: str,
) -> Array:
    """All-pairs distances against a compressed x side (bf16/int8/PQ-ADC)."""
    _precision.validate_precision(precision)
    qf = q.astype(jnp.float32)
    if x_sq_norms is None:
        from repro.core.graph import squared_norms  # lazy: no cycle

        x_sq_norms = squared_norms(x)
    xn = x_sq_norms.astype(jnp.float32)[None, :]  # (1, n)
    if precision == "pq":
        if metric == "cosine":
            qf = qf / jnp.maximum(
                jnp.linalg.norm(qf, axis=-1, keepdims=True), 1e-12
            )
        lut = _precision.adc_tables(qf, enc.codebook, metric)  # (m, M, K)
        lutm = jnp.moveaxis(lut, 1, 0)  # (M, m, K)
        terms = jax.vmap(lambda l, c: l[:, c])(lutm, enc.codes.T)  # (M, m, n)
        d = jnp.sum(terms, axis=0)
        if metric == "cosine":
            d = 1.0 - d / jnp.maximum(jnp.sqrt(xn), 1e-12)
        return d.astype(jnp.float32)
    if metric in ("l2", "ip", "dot", "cosine"):
        if metric == "cosine":
            qf = qf / jnp.maximum(
                jnp.linalg.norm(qf, axis=-1, keepdims=True), 1e-12
            )
        dots = qf @ enc.data.astype(jnp.float32).T
        if precision == "int8":
            s = enc.scale.astype(jnp.float32)
            dots = dots * jnp.where(s > 0, s, 1.0)[None, :]
        if metric == "l2":
            qn = jnp.sum(qf * qf, axis=-1, keepdims=True)
            return jnp.maximum(qn + xn - 2.0 * dots, 0.0)
        if metric == "cosine":
            return 1.0 - dots / jnp.maximum(jnp.sqrt(xn), 1e-12)
        return -dots if metric == "ip" else dots
    # VPU metrics: dequantize once, reuse the exact pairwise reduction.
    xf = enc.data.astype(jnp.float32)
    if precision == "int8":
        s = enc.scale.astype(jnp.float32)
        xf = xf * jnp.where(s > 0, s, 1.0)[:, None]
    return metrics.pairwise(metric, q, xf)


def gather_distance(
    q: Array,
    x: Array,
    idx: Array,
    metric: str = "l2",
    *,
    sq_norms: Optional[Array] = None,
    enc: Optional[_precision.EncodedData] = None,
    precision: str = "fp32",
) -> Array:
    """Blocked gather + distance oracle (decomposed formula).

    Args:
      q:   (b, d)  queries.
      x:   (n, d)  dataset.
      idx: (b, c)  int32 candidate ids per query; id < 0 means padding.
      sq_norms: optional (n,) cached ``‖x‖²`` (the graph-resident cache);
        derived once per call when absent.
      enc / precision: compressed companion table + which representation to
        fetch candidates from (``kernels.precision``).  ``"fp32"`` (or no
        ``enc``) takes the exact path below, byte-identical to before the
        precision API existed.  ``"pq"`` here is the pure ADC rank — the
        exact re-rank composes in ``kernels.ops.expand_step``.

    Returns:
      (b, c) float32 distances; +inf at padded slots.
    """
    if enc is not None and precision != "fp32":
        return _gather_distance_compressed(
            q, x, idx, metric, sq_norms=sq_norms, enc=enc, precision=precision
        )
    safe = jnp.clip(idx, 0, x.shape[0] - 1)
    if metric in ("l2", "ip", "dot", "cosine", "cos"):
        qf = q.astype(jnp.float32)
        if metric in ("cosine", "cos"):
            qf = qf / jnp.maximum(
                jnp.linalg.norm(qf, axis=-1, keepdims=True), 1e-12
            )
        cand = x[safe].astype(jnp.float32)  # (b, c, d)
        # broadcast-multiply + reduce rather than einsum: XLA:CPU fuses this
        # into one pass over the gathered tile, while the einsum/dot_general
        # lowering becomes a loop of (1, d) matvecs that is measurably slower
        # at the large-C shapes the engine targets (see the gather-engine
        # microbench); on TPU the Pallas kernel owns this computation anyway.
        dots = jnp.sum(qf[:, None, :] * cand, axis=-1)
        if metric in ("l2", "cosine", "cos"):
            if sq_norms is None:
                from repro.core.graph import squared_norms  # lazy: no cycle

                xn = squared_norms(cand)
            else:
                xn = sq_norms[safe].astype(jnp.float32)
            if metric == "l2":
                qn = jnp.sum(qf * qf, axis=-1, keepdims=True)
                d = jnp.maximum(qn + xn - 2.0 * dots, 0.0)
            else:
                d = 1.0 - dots / jnp.maximum(jnp.sqrt(xn), 1e-12)
        elif metric == "ip":
            d = -dots
        else:  # dot
            d = dots
    else:
        # VPU metrics (l1 / chi2): no matmul form — broadcast reduction.
        cand = x[safe]

        def per_query(qi, ci):
            return metrics.pairwise(metric, qi[None, :], ci)[0]

        d = jax.vmap(per_query)(q, cand)
    return jnp.where(idx >= 0, d.astype(jnp.float32), jnp.inf)


def _gather_distance_compressed(
    q: Array,
    x: Array,
    idx: Array,
    metric: str,
    *,
    sq_norms: Optional[Array],
    enc: _precision.EncodedData,
    precision: str,
) -> Array:
    """Reduced-precision candidate fetch + distance (bf16 / int8 / PQ-ADC).

    The structural twin of the fp32 path above: same decomposition, same
    masking convention, but the gathered tile is the 2-byte/1-byte encoded
    table — 2–4x fewer random-access bytes, the point of the compressed
    engine — and for the matmul metrics the contraction runs in
    ``_CHUNK_C``-wide chunks so the dequantized fp32 chunk stays cache
    resident.  The ``‖x‖²`` term always comes from the exact cache: only the
    ``q·x`` term carries quantization error (int8 rel err ~2e-3 at d=256).
    """
    _precision.validate_precision(precision)
    qf = q.astype(jnp.float32)
    if sq_norms is None:
        from repro.core.graph import squared_norms  # lazy: no cycle

        sq_norms = squared_norms(x)
    if precision == "pq":
        if metric in ("cosine", "cos"):
            qf = qf / jnp.maximum(
                jnp.linalg.norm(qf, axis=-1, keepdims=True), 1e-12
            )
        lut = _precision.adc_tables(qf, enc.codebook, metric)
        return _precision.adc_gather(lut, enc.codes, idx, metric, sq_norms)
    safe = jnp.clip(idx, 0, x.shape[0] - 1)
    cand = enc.data[safe]  # (b, c, d) bf16/int8 — the compressed fetch
    if metric in ("l2", "ip", "dot", "cosine", "cos"):
        if metric in ("cosine", "cos"):
            qf = qf / jnp.maximum(
                jnp.linalg.norm(qf, axis=-1, keepdims=True), 1e-12
            )
        dots = _chunked_dots(qf, cand)
        if precision == "int8":
            s = enc.scale[safe].astype(jnp.float32)
            dots = dots * jnp.where(s > 0, s, 1.0)
        xn = sq_norms[safe].astype(jnp.float32)
        if metric == "l2":
            qn = jnp.sum(qf * qf, axis=-1, keepdims=True)
            d = jnp.maximum(qn + xn - 2.0 * dots, 0.0)
        elif metric in ("cosine", "cos"):
            d = 1.0 - dots / jnp.maximum(jnp.sqrt(xn), 1e-12)
        elif metric == "ip":
            d = -dots
        else:  # dot
            d = dots
    else:
        # VPU metrics: dequantize the gathered tile, then the broadcast
        # reduction (same shape as the fp32 path's per-query vmap).
        candf = cand.astype(jnp.float32)
        if precision == "int8":
            s = enc.scale[safe].astype(jnp.float32)
            candf = candf * jnp.where(s > 0, s, 1.0)[..., None]

        def per_query(qi, ci):
            return metrics.pairwise(metric, qi[None, :], ci)[0]

        d = jax.vmap(per_query)(q, candf)
    return jnp.where(idx >= 0, d.astype(jnp.float32), jnp.inf)


def topk_smallest(dists: Array, ids: Array, k: int):
    """Row-wise smallest-k (distance, id) selection.  Oracle for merge ops.

    Args:
      dists: (m, c) distances (inf = padding).
      ids:   (m, c) ids aligned with dists.
      k:     number to keep.

    Returns:
      (m, k) dists sorted ascending, (m, k) ids.
    """
    neg, sel = jax.lax.top_k(-dists, k)
    return -neg, jnp.take_along_axis(ids, sel, axis=1)
