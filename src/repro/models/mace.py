"""MACE — higher-order equivariant message passing (arXiv:2206.07697).

Faithful configuration: n_layers=2, d_hidden=128, l_max=2, correlation
order ν=3, n_rbf=8, E(3) equivariance.

TPU adaptation (DESIGN.md hardware-adaptation): the reference MACE contracts
spherical irreps with Clebsch-Gordan coefficients (e3nn).  Sparse CG
contractions are scatter-heavy and MXU-hostile; here the equivariant features
are kept as **Cartesian tensors** (CACE-style: scalars (N,C), vectors
(N,3,C), traceless-symmetric rank-2 (N,3,3,C)), so every contraction in the
A→B product basis is a dense einsum the MXU executes directly.  E(3)
equivariance is preserved exactly (rotations act on the Cartesian indices);
``tests/test_gnn.py`` property-checks energy invariance / force equivariance
under random rotations.

Message passing is ``jax.ops.segment_sum`` over an explicit edge index —
JAX has no sparse adjacency path; the edge-list scatter IS the production
implementation (kernel_taxonomy §GNN).

The same forward serves all four assigned shapes: molecular point clouds
(positions given), and citation/social graphs (no geometry — positions are
a learned 3D embedding of node features, documented in DESIGN.md
§Arch-applicability; the systems-relevant structure, the edge-list scatter
at 10⁴..10⁸ edges, is identical).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import common

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2  # Cartesian ranks carried: 0, 1, 2
    correlation: int = 3  # ν — highest product order in the B-basis
    n_rbf: int = 8
    n_species: int = 8
    r_cut: float = 5.0
    d_node_feat: int = 0  # citation-graph shapes: raw feature width (0 = none)
    n_classes: int = 0  # >0 = node-classification head; 0 = energy head
    readout_hidden: int = 64
    param_dtype: str = "float32"

    def head_is_energy(self) -> bool:
        return self.n_classes == 0


# ---------------------------------------------------------------------------
# Radial / angular basis
# ---------------------------------------------------------------------------


def bessel_rbf(r: Array, n_rbf: int, r_cut: float) -> Array:
    """Bessel radial basis with smooth polynomial cutoff (MACE eq. 7)."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * r[..., None] / r_cut) / r[..., None]
    # polynomial cutoff envelope (p=6)
    u = jnp.clip(r / r_cut, 0.0, 1.0)
    env = 1.0 - 28.0 * u**6 + 48.0 * u**7 - 21.0 * u**8
    return basis * env[..., None]


def safe_norm(vec: Array) -> Array:
    """Norm with a defined (zero) gradient at vec = 0 (self-loop edges)."""
    sq = jnp.sum(vec * vec, axis=-1)
    return jnp.sqrt(jnp.maximum(sq, 1e-12))


def edge_harmonics(vec: Array) -> tuple[Array, Array]:
    """Cartesian 'spherical harmonics' of edge directions up to l=2.

    Returns (Y1 (E,3) unit vector, Y2 (E,3,3) traceless symmetric outer
    product) — the Cartesian carriers of the l=1,2 irreps.
    """
    r = safe_norm(vec)[..., None]
    u = vec / jnp.maximum(r, 1e-6)
    eye = jnp.eye(3, dtype=vec.dtype)
    y2 = u[..., :, None] * u[..., None, :] - eye / 3.0
    return u, y2


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_params(key: Array, cfg: MACEConfig) -> Dict[str, Any]:
    pd = jnp.dtype(cfg.param_dtype)
    C, L = cfg.d_hidden, cfg.n_layers
    names = ["species", "featproj", "radial", "mix", "update", "readout", "pos_embed"]
    ks = common.split_tree(key, {n: None for n in names})
    n_b0, n_b1, n_b2 = _n_basis(cfg.correlation)
    p: Dict[str, Any] = {
        "species": common.embed_init(ks["species"], (cfg.n_species, C), pd, 0.5),
        # per-layer radial MLPs: rbf -> 3 * C edge weights (one set per rank)
        "radial_w1": common.dense_init(ks["radial"], (L, cfg.n_rbf, 2 * C), pd),
        "radial_b1": jnp.zeros((L, 2 * C), pd),
        "radial_w2": common.dense_init(jax.random.fold_in(ks["radial"], 1), (L, 2 * C, 3 * C), pd),
        # B-basis linear mixing back to C channels per rank
        "mix0": common.dense_init(ks["mix"], (L, n_b0 * C, C), pd),
        "mix1": common.dense_init(jax.random.fold_in(ks["mix"], 1), (L, n_b1 * C, C), pd),
        "mix2": common.dense_init(jax.random.fold_in(ks["mix"], 2), (L, n_b2 * C, C), pd),
        # residual update (scalar channel)
        "upd0": common.dense_init(ks["update"], (L, C, C), pd),
        # per-layer scalar readouts
        "ro_w1": common.dense_init(ks["readout"], (L, C, cfg.readout_hidden), pd),
        "ro_b1": jnp.zeros((L, cfg.readout_hidden), pd),
        "ro_w2": common.dense_init(
            jax.random.fold_in(ks["readout"], 1),
            (L, cfg.readout_hidden, max(cfg.n_classes, 1)),
            pd,
        ),
    }
    if cfg.d_node_feat:
        p["featproj"] = common.dense_init(ks["featproj"], (cfg.d_node_feat, C), pd)
        p["pos_embed"] = common.dense_init(ks["pos_embed"], (cfg.d_node_feat, 3), pd)
    return p


def _n_basis(correlation: int) -> tuple[int, int, int]:
    """How many B-basis features feed each output rank (ν <= correlation)."""
    # rank 0: [A0] + ν2:[A0², A1·A1, A2:A2] + ν3:[A0³, A0(A1·A1), A1·A2·A1]
    # rank 1: [A1] + ν2:[A0A1, A2·A1]       + ν3:[A0²A1, (A1·A1)A1, A0 A2·A1]
    # rank 2: [A2] + ν2:[A0A2, sym(A1⊗A1)]  + ν3:[A0²A2, A0 sym(A1⊗A1)]
    if correlation >= 3:
        return 7, 6, 5
    if correlation == 2:
        return 4, 3, 3
    return 1, 1, 1


def param_pspecs(cfg: MACEConfig) -> Dict[str, Any]:
    """MACE params are tiny (<1M); replicate everything (DP-only arch)."""
    from jax.sharding import PartitionSpec as P

    specs = {
        "species": P(None, None),
        "radial_w1": P(None, None, None),
        "radial_b1": P(None, None),
        "radial_w2": P(None, None, None),
        "mix0": P(None, None, None),
        "mix1": P(None, None, None),
        "mix2": P(None, None, None),
        "upd0": P(None, None, None),
        "ro_w1": P(None, None, None),
        "ro_b1": P(None, None),
        "ro_w2": P(None, None, None),
    }
    if cfg.d_node_feat:
        specs["featproj"] = P(None, None)
        specs["pos_embed"] = P(None, None)
    return specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _product_basis(a0: Array, a1: Array, a2: Array, correlation: int):
    """ACE product basis: contractions of A-features up to order ν.

    a0 (N, C), a1 (N, 3, C), a2 (N, 3, 3, C).  Every product is channel-wise
    (the standard MACE 'channel-coupled' form) so all ops are elementwise /
    small einsums.
    """
    b0 = [a0]
    b1 = [a1]
    b2 = [a2]
    if correlation >= 2:
        dot11 = jnp.einsum("nic,nic->nc", a1, a1)  # A1·A1
        dot22 = jnp.einsum("nijc,nijc->nc", a2, a2)  # A2:A2
        a2a1 = jnp.einsum("nijc,njc->nic", a2, a1)  # A2·A1
        sym11 = jnp.einsum("nic,njc->nijc", a1, a1)
        sym11 = sym11 - jnp.trace(sym11, axis1=1, axis2=2)[:, None, None, :] * (
            jnp.eye(3)[None, :, :, None] / 3.0
        )
        b0 += [a0 * a0, dot11, dot22]
        b1 += [a0[:, None, :] * a1, a2a1]
        b2 += [a0[:, None, None, :] * a2, sym11]
        if correlation >= 3:
            b0 += [
                a0 * a0 * a0,
                a0 * dot11,
                jnp.einsum("nic,nijc,njc->nc", a1, a2, a1),  # A1·A2·A1
            ]
            b1 += [
                (a0 * a0)[:, None, :] * a1,
                dot11[:, None, :] * a1,
                a0[:, None, :] * a2a1,
            ]
            b2 += [(a0 * a0)[:, None, None, :] * a2, a0[:, None, None, :] * sym11]
    return (
        jnp.concatenate(b0, axis=-1),
        jnp.concatenate(b1, axis=-1),
        jnp.concatenate(b2, axis=-1),
    )


def forward(
    params: Dict[str, Any],
    positions: Array,  # (N, 3)
    species: Array,  # (N,) int32
    senders: Array,  # (E,) int32
    receivers: Array,  # (E,) int32
    cfg: MACEConfig,
    *,
    node_feat: Optional[Array] = None,  # (N, d_node_feat) citation shapes
    node_mask: Optional[Array] = None,  # (N,) bool — padding
    edge_mask: Optional[Array] = None,  # (E,) bool — padding
) -> Array:
    """Returns per-node readout: (N,) energies or (N, n_classes) logits."""
    N = positions.shape[0]
    C = cfg.d_hidden

    h0 = params["species"][species]  # (N, C)
    if cfg.d_node_feat and node_feat is not None:
        h0 = h0 + node_feat @ params["featproj"]
        positions = positions + node_feat @ params["pos_embed"]

    vec = positions[senders] - positions[receivers]  # (E, 3)
    r = safe_norm(vec)
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.r_cut)  # (E, n_rbf)
    y1, y2 = edge_harmonics(vec)

    h1 = jnp.zeros((N, 3, C), h0.dtype)
    h2 = jnp.zeros((N, 3, 3, C), h0.dtype)
    out_sum = None

    for layer in range(cfg.n_layers):
        # -- radial weights (per-edge, per-rank, per-channel) -----------------
        z = jax.nn.silu(rbf @ params["radial_w1"][layer] + params["radial_b1"][layer])
        rw = z @ params["radial_w2"][layer]  # (E, 3C)
        if edge_mask is not None:
            # padding edges must contribute zero *messages* (the radial MLP
            # has a bias, so masking rbf alone is not enough)
            rw = jnp.where(edge_mask[:, None], rw, 0.0)
        r0, r1, r2 = rw[:, :C], rw[:, C : 2 * C], rw[:, 2 * C :]

        # -- A-basis: aggregate rank-l messages -------------------------------
        hs = h0[senders]  # (E, C)
        m0 = r0 * hs
        m1 = r1[:, None, :] * y1[:, :, None] * hs[:, None, :]
        m2 = r2[:, None, None, :] * y2[:, :, :, None] * hs[:, None, None, :]
        a0 = jax.ops.segment_sum(m0, receivers, num_segments=N)
        a1 = jax.ops.segment_sum(m1, receivers, num_segments=N)
        a2 = jax.ops.segment_sum(m2, receivers, num_segments=N)
        # normalize by sqrt(degree) (MACE's avg_num_neighbors normalization,
        # per-node so arbitrary-degree citation graphs stay bounded)
        ones = jnp.ones_like(receivers, dtype=jnp.float32)
        if edge_mask is not None:
            ones = jnp.where(edge_mask, ones, 0.0)
        deg = jax.ops.segment_sum(ones, receivers, num_segments=N)
        inv = jax.lax.rsqrt(jnp.maximum(deg, 1.0))
        a0 = a0 * inv[:, None]
        a1 = a1 * inv[:, None, None]
        a2 = a2 * inv[:, None, None, None]

        # -- B-basis products (ν <= correlation) + linear mix ------------------
        b0, b1, b2 = _product_basis(a0, a1, a2, cfg.correlation)
        h0 = h0 @ params["upd0"][layer] + b0 @ params["mix0"][layer]
        h1 = h1 + jnp.einsum("nib,bc->nic", b1, params["mix1"][layer])
        h2 = h2 + jnp.einsum("nijb,bc->nijc", b2, params["mix2"][layer])
        h0 = jax.nn.silu(h0)

        # -- per-layer readout (MACE reads out every layer) --------------------
        ro = jax.nn.silu(h0 @ params["ro_w1"][layer] + params["ro_b1"][layer])
        ro = ro @ params["ro_w2"][layer]  # (N, n_out)
        out_sum = ro if out_sum is None else out_sum + ro

    if node_mask is not None:
        out_sum = jnp.where(node_mask[:, None], out_sum, 0.0)
    if cfg.head_is_energy():
        return out_sum[:, 0]  # (N,) per-atom energies
    return out_sum  # (N, n_classes) logits


def energy(params, positions, species, senders, receivers, cfg, **kw) -> Array:
    """Total energy of one structure (sum of per-atom contributions)."""
    return jnp.sum(forward(params, positions, species, senders, receivers, cfg, **kw))


def forces(params, positions, species, senders, receivers, cfg, **kw) -> Array:
    """F = -dE/dpos — the quantity MD consumers of MACE actually use."""
    return -jax.grad(energy, argnums=1)(
        params, positions, species, senders, receivers, cfg, **kw
    )


# ---------------------------------------------------------------------------
# Losses (per data regime)
# ---------------------------------------------------------------------------


def node_class_loss(params, batch: Dict[str, Array], cfg: MACEConfig):
    """Full-graph / sampled node classification (cora / reddit / products)."""
    logits = forward(
        params,
        batch["positions"],
        batch["species"],
        batch["senders"],
        batch["receivers"],
        cfg,
        node_feat=batch.get("node_feat"),
        node_mask=batch.get("node_mask"),
        edge_mask=batch.get("edge_mask"),
    )
    labels = batch["labels"]
    train_mask = batch.get("train_mask")
    if train_mask is not None:
        labels = jnp.where(train_mask, labels, -1)  # masked xent
    loss = common.softmax_xent(logits, labels)
    acc = jnp.mean(
        jnp.where(labels >= 0, (jnp.argmax(logits, -1) == labels), 0.0)
    )
    return loss, {"acc": acc}


def energy_loss(params, batch: Dict[str, Array], cfg: MACEConfig):
    """Batched molecules: MSE on total energy (vmap over the batch)."""

    def one(pos, spec, snd, rcv, e_ref):
        e = energy(params, pos, spec, snd, rcv, cfg)
        return (e - e_ref) ** 2

    per = jax.vmap(one)(
        batch["positions"],
        batch["species"],
        batch["senders"],
        batch["receivers"],
        batch["energy"],
    )
    loss = jnp.mean(per)
    return loss, {"rmse": jnp.sqrt(loss)}
