"""Shared building blocks for the architecture zoo.

Conventions:
  * params are plain nested dicts of jnp arrays (pytrees);
  * every initializer takes an explicit PRNG key and is ``jax.eval_shape``-
    safe (the dry-run never materializes the big configs);
  * layers annotate their own sharding through logical axis names resolved in
    ``repro.launch.mesh`` — models stay mesh-agnostic.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key: Array, shape: Sequence[int], dtype=jnp.float32, scale: float = 1.0):
    """Truncated-normal fan-in init (what most of the zoo's papers use)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: Array, shape: Sequence[int], dtype=jnp.float32, scale: float = 1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32, scale=1.0):
    del scale
    return jnp.zeros(shape, dtype)


def split_tree(key: Array, template: Dict[str, Any]) -> Dict[str, Array]:
    """One fresh key per leaf name."""
    names = sorted(template)
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# ---------------------------------------------------------------------------
# Normalization / activations
# ---------------------------------------------------------------------------


def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x: Array, gamma: Array, beta: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


ACTIVATIONS: Dict[str, Callable[[Array], Array]] = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def mlp_stack(
    key: Array,
    sizes: Sequence[int],
    dtype=jnp.float32,
) -> Dict[str, Array]:
    """Params for a plain MLP: sizes = [in, h1, ..., out]."""
    params = {}
    keys = jax.random.split(key, len(sizes) - 1)
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        params[f"w{i}"] = dense_init(keys[i], (a, b), dtype)
        params[f"b{i}"] = jnp.zeros((b,), dtype)
    return params


def mlp_apply(params: Dict[str, Array], x: Array, act: str = "relu", final_act: bool = False) -> Array:
    n = len([k for k in params if k.startswith("w")])
    fn = ACTIVATIONS[act]
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1 or final_act:
            x = fn(x)
    return x


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------


def softmax_xent(logits: Array, labels: Array, *, z_loss: float = 0.0) -> Array:
    """Token-level cross entropy in f32; labels < 0 are masked (padding)."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.clip(labels, 0, logits.shape[-1] - 1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse**2
    return jnp.sum(jnp.where(mask, loss, 0.0)) / jnp.maximum(jnp.sum(mask), 1)


def sigmoid_bce(logits: Array, labels: Array) -> Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def count_params(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


import numpy as np  # noqa: E402  (used by count_params only)
