"""Config-driven decoder-only LM covering the five assigned transformer archs.

One parameterization spans: mixtral-8x7b (GQA kv=8, SWA 4096, MoE 8e top-2),
arctic-480b (GQA kv=8, MoE 128e top-2 + parallel dense residual FFN),
stablelm-1.6b (MHA-ish GQA kv=32), qwen2.5-3b (GQA kv=2, QKV bias),
gemma3-1b (GQA kv=1, head_dim 256, 5:1 local:global attention).

Implementation notes (all production-motivated):
  * **scan-over-layers** with stacked (L, ...) params — compile time stays
    flat in depth, which the 40-cell dry-run depends on; per-layer attention
    patterns ride through the scan as a (L,) window vector;
  * **remat** (``jax.checkpoint``) around the scanned layer body — activation
    memory ~ O(L * B * S * d) at layer boundaries only;
  * attention is the chunked online-softmax of ``models.attention`` — no
    (S, S) score tensor, prefill_32k stays within HBM;
  * MoE is the sorted-capacity dispatch of ``models.moe``;
  * activations are computed in ``compute_dtype`` (bf16), params stored in
    ``param_dtype``; the loss/softmax runs in f32.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, common, moe as moe_lib
from repro.models.sharding import constrain

Array = jax.Array

FULL_WINDOW = 1 << 30  # "no window": i - j < 2^30 is always true in-range


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    d_head: Optional[int] = None  # default d_model // n_heads (gemma3: 256)
    act: str = "silu"
    qkv_bias: bool = False  # qwen2.5
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logit_softcap: float = 0.0
    # attention pattern
    window: Optional[int] = None  # sliding window (mixtral 4096); None = full
    local_global: Optional[Tuple[int, int]] = None  # gemma3: (5 local, 1 global)
    local_window: int = 1024
    # MoE
    moe: Optional[moe_lib.MoEConfig] = None
    moe_d_ff: int = 0  # expert hidden width (falls back to d_ff)
    dense_residual: bool = False  # arctic: parallel dense FFN
    dense_d_ff: int = 0
    moe_groups: int = 1  # shard-local dispatch groups (= data shards)
    # numerics / scheduling
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    q_chunk: int = 512
    kv_chunk: int = 512
    # dry-run / production-schedule mode: python-loop over layers with the
    # statically-tiled attention (tile skipping + faithful cost_analysis —
    # scan bodies are otherwise counted once, DESIGN.md §7)
    unrolled: bool = False
    # explicit ZeRO-3 weight use-constraints.  Measured (EXPERIMENTS §Perf
    # it.2B): cuts collectives 1.7x but GSPMD then *replicates* part of the
    # MoE einsum (3.4x FLOPs) — net loss, so default OFF; kept as a knob
    # because the trade flips for collective-bound meshes.
    zero3_use_constraints: bool = False
    # Megatron sequence parallelism: residual stream sharded over 'model' on
    # the sequence dim at layer boundaries (§Perf it.3)
    seq_shard: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    def window_by_layer(self) -> np.ndarray:
        """Static (L,) per-layer attention window (DESIGN.md: one scan body)."""
        L = self.n_layers
        if self.local_global is not None:
            nl, ng = self.local_global
            period = nl + ng
            pat = [self.local_window] * nl + [FULL_WINDOW] * ng
            w = [pat[i % period] for i in range(L)]
            return np.asarray(w, np.int32)
        if self.window is not None:
            return np.full((L,), self.window, np.int32)
        return np.full((L,), FULL_WINDOW, np.int32)

    def param_count(self) -> int:
        d, dh = self.d_model, self.head_dim
        attn = self.n_layers * (
            d * (self.n_heads * dh)
            + 2 * d * (self.n_kv_heads * dh)
            + (self.n_heads * dh) * d
        )
        if self.moe is not None:
            f = self.moe_d_ff or self.d_ff
            ffn = self.n_layers * self.moe.n_experts * 3 * d * f
            ffn += self.n_layers * d * self.moe.n_experts
            if self.dense_residual:
                ffn += self.n_layers * 3 * d * (self.dense_d_ff or self.d_ff)
        else:
            ffn = self.n_layers * 3 * d * self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return attn + ffn + emb + self.n_layers * 2 * d + d

    def active_param_count(self) -> int:
        """6·N_active·D counting for MoE rooflines."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        f = self.moe_d_ff or self.d_ff
        total = self.param_count()
        all_exp = self.n_layers * self.moe.n_experts * 3 * d * f
        act_exp = self.n_layers * self.moe.top_k * 3 * d * f
        return total - all_exp + act_exp


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_params(key: Array, cfg: TransformerConfig) -> Dict[str, Any]:
    pd = jnp.dtype(cfg.param_dtype)
    d, dh, H, KV, L = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    ks = common.split_tree(
        key,
        {n: None for n in [
            "embed", "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
            "router", "head", "dense_gate", "dense_up", "dense_down",
        ]},
    )
    p: Dict[str, Any] = {
        "embed": common.embed_init(ks["embed"], (cfg.vocab, d), pd),
        "ln1": jnp.zeros((L, d), pd),
        "ln2": jnp.zeros((L, d), pd),
        "ln_f": jnp.zeros((d,), pd),
        "wq": common.dense_init(ks["wq"], (L, d, H * dh), pd),
        "wk": common.dense_init(ks["wk"], (L, d, KV * dh), pd),
        "wv": common.dense_init(ks["wv"], (L, d, KV * dh), pd),
        "wo": common.dense_init(ks["wo"], (L, H * dh, d), pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((L, H * dh), pd)
        p["bk"] = jnp.zeros((L, KV * dh), pd)
        p["bv"] = jnp.zeros((L, KV * dh), pd)
    if cfg.moe is not None:
        E = cfg.moe.n_experts
        f = cfg.moe_d_ff or cfg.d_ff
        p["router"] = common.dense_init(ks["router"], (L, d, E), jnp.float32)
        p["w_gate"] = common.dense_init(ks["w_gate"], (L, E, d, f), pd)
        p["w_up"] = common.dense_init(ks["w_up"], (L, E, d, f), pd)
        p["w_down"] = common.dense_init(ks["w_down"], (L, E, f, d), pd)
        if cfg.dense_residual:
            df = cfg.dense_d_ff or cfg.d_ff
            p["dense_gate"] = common.dense_init(ks["dense_gate"], (L, d, df), pd)
            p["dense_up"] = common.dense_init(ks["dense_up"], (L, d, df), pd)
            p["dense_down"] = common.dense_init(ks["dense_down"], (L, df, d), pd)
    else:
        p["w_gate"] = common.dense_init(ks["w_gate"], (L, d, cfg.d_ff), pd)
        p["w_up"] = common.dense_init(ks["w_up"], (L, d, cfg.d_ff), pd)
        p["w_down"] = common.dense_init(ks["w_down"], (L, cfg.d_ff, d), pd)
    if not cfg.tie_embeddings:
        p["head"] = common.dense_init(ks["head"], (d, cfg.vocab), pd)
    return p


def param_pspecs(cfg: TransformerConfig, fsdp: bool = False) -> Dict[str, Any]:
    """Megatron TP rules (+ optional FSDP on the d_model axis of big mats)."""
    from jax.sharding import PartitionSpec as P

    dp = "data" if fsdp else None
    specs: Dict[str, Any] = {
        "embed": P("model", None),
        "ln1": P(None, None),
        "ln2": P(None, None),
        "ln_f": P(None),
        "wq": P(None, dp, "model"),
        "wk": P(None, dp, "model"),
        "wv": P(None, dp, "model"),
        "wo": P(None, "model", dp),
    }
    if cfg.qkv_bias:
        specs["bq"] = P(None, "model")
        specs["bk"] = P(None, "model")
        specs["bv"] = P(None, "model")
    if cfg.moe is not None:
        E = cfg.moe.n_experts
        specs["router"] = P(None, None, None)
        if E >= 16:  # expert parallelism (arctic: 128 experts / 16 = 8 per chip)
            specs["w_gate"] = P(None, "model", dp, None)
            specs["w_up"] = P(None, "model", dp, None)
            specs["w_down"] = P(None, "model", None, dp)
        else:  # per-expert tensor parallelism (mixtral: 8 experts < 16 chips)
            specs["w_gate"] = P(None, None, dp, "model")
            specs["w_up"] = P(None, None, dp, "model")
            specs["w_down"] = P(None, None, "model", dp)
        if cfg.dense_residual:
            specs["dense_gate"] = P(None, dp, "model")
            specs["dense_up"] = P(None, dp, "model")
            specs["dense_down"] = P(None, "model", dp)
    else:
        specs["w_gate"] = P(None, dp, "model")
        specs["w_up"] = P(None, dp, "model")
        specs["w_down"] = P(None, "model", dp)
    if not cfg.tie_embeddings:
        specs["head"] = P(None, "model")
    return specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _layer(cfg: TransformerConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def body(h: Array, lp: Dict[str, Array], window, positions: Array):
        B, S, d = h.shape
        a = common.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q = a @ lp["wq"].astype(cd)
        k = a @ lp["wk"].astype(cd)
        v = a @ lp["wv"].astype(cd)
        if cfg.qkv_bias:
            q = q + lp["bq"].astype(cd)
            k = k + lp["bk"].astype(cd)
            v = v + lp["bv"].astype(cd)
        q = q.reshape(B, S, H, dh)
        k = k.reshape(B, S, KV, dh)
        v = v.reshape(B, S, KV, dh)
        q = attention.rope(q, positions, cfg.rope_theta)
        k = attention.rope(k, positions, cfg.rope_theta)
        q = constrain(q, "batch", None, "model", None)
        if cfg.unrolled:
            o = attention.tiled_causal_attention(
                q, k, v, int(window), q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
            )
        else:
            o = attention.chunked_causal_attention(
                q, k, v, window, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
            )
        o = o.reshape(B, S, H * dh) @ lp["wo"].astype(cd)
        h = h + constrain(o, "batch", None, None)

        m = common.rms_norm(h, lp["ln2"], cfg.norm_eps)
        aux = {}
        if cfg.moe is not None:
            flat = m.reshape(B * S, d)
            mo, aux = moe_lib.apply_moe(
                {k2: lp[k2] for k2 in ("router", "w_gate", "w_up", "w_down")},
                flat,
                cfg.moe,
                act=cfg.act,
                groups=cfg.moe_groups,
            )
            out = mo.reshape(B, S, d)
            if cfg.dense_residual:
                fn = common.ACTIVATIONS[cfg.act]
                dz = fn(m @ lp["dense_gate"].astype(cd)) * (m @ lp["dense_up"].astype(cd))
                out = out + dz @ lp["dense_down"].astype(cd)
        else:
            fn = common.ACTIVATIONS[cfg.act]
            z = fn(m @ lp["w_gate"].astype(cd)) * (m @ lp["w_up"].astype(cd))
            z = constrain(z, "batch", None, "model")
            out = z @ lp["w_down"].astype(cd)
        h = h + constrain(out, "batch", None, None)
        return h, aux

    return body


_LAYER_KEYS = (
    "ln1", "ln2", "wq", "wk", "wv", "wo", "bq", "bk", "bv",
    "router", "w_gate", "w_up", "w_down", "dense_gate", "dense_up", "dense_down",
)


def _use_constrain_layer(lp: Dict[str, Array], cfg: TransformerConfig) -> Dict[str, Array]:
    """ZeRO-3 semantics made explicit: storage sharding (FSDP, d over data)
    differs from USE sharding (replicated over data, split over model).

    Without this, GSPMD may resolve a data-sharded contraction dim by
    ALL-REDUCING the (huge) activation instead of all-gathering the (small)
    weight — measured 70 GiB x 64 all-reduces on the mixtral train cell
    (EXPERIMENTS.md §Perf iteration 2).  Constraining each weight to its use
    sharding forces the cheap side: one weight all-gather per use.

    MEASURED OUTCOME (EXPERIMENTS.md §Perf iteration 2B): collectives drop
    1027->594 GB/step(2L probe) but the MoE einsum partially REPLICATES
    (1166->3937 TF) — GSPMD mis-costs the constrained einsum.  Net loss on
    compute-bound cells, so gated behind cfg.zero3_use_constraints.
    """
    if not cfg.zero3_use_constraints:
        return lp
    specs = {
        "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
        "wo": ("model", None),
        "dense_gate": (None, "model"), "dense_up": (None, "model"),
        "dense_down": ("model", None),
    }
    if cfg.moe is not None:
        if cfg.moe.n_experts >= 16:  # expert parallelism
            specs.update({
                "w_gate": ("model", None, None), "w_up": ("model", None, None),
                "w_down": ("model", None, None),
            })
        else:  # per-expert tensor parallelism
            specs.update({
                "w_gate": (None, None, "model"), "w_up": (None, None, "model"),
                "w_down": (None, "model", None),
            })
    else:
        specs.update({
            "w_gate": (None, "model"), "w_up": (None, "model"),
            "w_down": ("model", None),
        })
    out = dict(lp)
    for k, sp in specs.items():
        if k in out:
            out[k] = constrain(out[k], *sp)
    return out


def _split_layer_params(params):
    layer = {k: v for k, v in params.items() if k in _LAYER_KEYS}
    rest = {k: v for k, v in params.items() if k not in _LAYER_KEYS}
    return layer, rest


def forward(params: Dict[str, Any], tokens: Array, cfg: TransformerConfig) -> Array:
    """tokens (B, S) -> logits (B, S, vocab)."""
    cd = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    layer_params, rest = _split_layer_params(params)
    h = rest["embed"].astype(cd)[tokens] * jnp.asarray(
        np.sqrt(cfg.d_model), cd
    )
    h = constrain(h, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    windows = jnp.asarray(cfg.window_by_layer())
    body = _layer(cfg)

    def scan_fn(carry, xs):
        lp, w = xs
        out, aux = body(carry, lp, w, positions)
        return out, aux

    if cfg.unrolled:
        # python layer loop: static windows (tile skipping) + faithful HLO
        win_np = cfg.window_by_layer()
        aux_list = []
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable,
                            static_argnums=(2,)) if cfg.remat else body
        for li in range(cfg.n_layers):
            lp = _use_constrain_layer(
                jax.tree.map(lambda a: a[li], layer_params), cfg)
            if cfg.seq_shard:  # Megatron-SP: boundary activations S-sharded
                h = constrain(h, "batch", "model", None)
            h, aux_i = fn(h, lp, int(win_np[li]), positions)
            aux_list.append(aux_i)
        aux = jax.tree.map(lambda *xs: jnp.stack(xs), *aux_list) if aux_list and aux_list[0] else {}
    else:
        if cfg.remat:
            scan_fn = jax.checkpoint(
                scan_fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        h, aux = jax.lax.scan(scan_fn, h, (layer_params, windows))
    h = common.rms_norm(h, rest["ln_f"], cfg.norm_eps)
    head = rest["head"] if not cfg.tie_embeddings else rest["embed"].T
    logits = h @ head.astype(cd)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return constrain(logits, "batch", None, "model"), aux


def loss_fn(params, tokens: Array, cfg: TransformerConfig):
    """Next-token cross entropy (tokens double as labels, shifted)."""
    logits, aux = forward(params, tokens, cfg)
    loss = common.softmax_xent(logits[:, :-1], tokens[:, 1:])
    extra = 0.0
    if cfg.moe is not None:
        extra = jnp.sum(aux["moe_aux_loss"])  # summed over scanned layers
    metrics = {"xent": loss}
    if cfg.moe is not None:
        metrics["moe_drop_rate"] = jnp.mean(aux["moe_drop_rate"])
    return loss + extra, metrics


# ---------------------------------------------------------------------------
# Prefill (serve: populate the KV cache, return next-token logits)
# ---------------------------------------------------------------------------


def prefill(params, tokens: Array, cfg: TransformerConfig):
    """tokens (B, S) -> (last-position logits (B, vocab), KV cache).

    The ``prefill_32k`` cells lower this: full chunked-causal attention over
    the prompt, per-layer K/V emitted through the scan's ys (so the cache
    materializes once, already stacked (L, B, S, KV, dh)).
    """
    cd = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    layer_params, rest = _split_layer_params(params)
    h = rest["embed"].astype(cd)[tokens] * jnp.asarray(np.sqrt(cfg.d_model), cd)
    h = constrain(h, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    windows = jnp.asarray(cfg.window_by_layer())

    def body(h, xs):
        lp, w = xs
        B, S, d = h.shape
        a = common.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q = a @ lp["wq"].astype(cd)
        k = a @ lp["wk"].astype(cd)
        v = a @ lp["wv"].astype(cd)
        if cfg.qkv_bias:
            q = q + lp["bq"].astype(cd)
            k = k + lp["bk"].astype(cd)
            v = v + lp["bv"].astype(cd)
        q = attention.rope(q.reshape(B, S, H, dh), positions, cfg.rope_theta)
        k = attention.rope(k.reshape(B, S, KV, dh), positions, cfg.rope_theta)
        v = v.reshape(B, S, KV, dh)
        if cfg.unrolled:
            o = attention.tiled_causal_attention(
                q, k, v, int(w), q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
            )
        else:
            o = attention.chunked_causal_attention(
                q, k, v, w, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
            )
        o = o.reshape(B, S, H * dh) @ lp["wo"].astype(cd)
        h = h + o
        m = common.rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            mo, _ = moe_lib.apply_moe(
                {k2: lp[k2] for k2 in ("router", "w_gate", "w_up", "w_down")},
                m.reshape(B * S, d),
                cfg.moe,
                act=cfg.act,
                groups=cfg.moe_groups,
            )
            out = mo.reshape(B, S, d)
            if cfg.dense_residual:
                fn = common.ACTIVATIONS[cfg.act]
                dz = fn(m @ lp["dense_gate"].astype(cd)) * (m @ lp["dense_up"].astype(cd))
                out = out + dz @ lp["dense_down"].astype(cd)
        else:
            fn = common.ACTIVATIONS[cfg.act]
            z = fn(m @ lp["w_gate"].astype(cd)) * (m @ lp["w_up"].astype(cd))
            out = z @ lp["w_down"].astype(cd)
        h = h + out
        return h, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    if cfg.unrolled:
        win_np = cfg.window_by_layer()
        ks, vs = [], []
        for li in range(cfg.n_layers):
            lp = _use_constrain_layer(
                jax.tree.map(lambda a: a[li], layer_params), cfg)
            h, (k_i, v_i) = body(h, (lp, int(win_np[li])))
            ks.append(k_i)
            vs.append(v_i)
        kc, vc = jnp.stack(ks), jnp.stack(vs)
    else:
        h, (kc, vc) = jax.lax.scan(body, h, (layer_params, windows))
    h = common.rms_norm(h[:, -1], rest["ln_f"], cfg.norm_eps)
    head = rest["head"] if not cfg.tie_embeddings else rest["embed"].T
    logits = (h @ head.astype(cd)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    cache = {"k": kc, "v": vc, "len": jnp.full((B,), S, jnp.int32)}
    return logits, cache


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def init_split_cache(cfg: TransformerConfig, batch: int, max_seq: int,
                     dtype=jnp.bfloat16):
    """Windowed ring-buffer caches for local-attention layers (§Perf it.4).

    A layer with window w never reads K/V older than w tokens, so its cache
    is a ring of w slots instead of max_seq — EXACT attention semantics,
    cache bytes shrink by  (n_loc·w + n_glob·S) / (L·S)  (gemma3 decode_32k:
    6.2x; mixtral long_500k: 128x).  Only meaningful with bounded windows;
    falls back to the dense cache when every layer is global.
    """
    wins = cfg.window_by_layer()
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    loc = [i for i, w in enumerate(wins) if int(w) < max_seq]
    glob = [i for i, w in enumerate(wins) if int(w) >= max_seq]
    if not loc:
        return init_cache(cfg, batch, max_seq, dtype)
    w_max = max(int(wins[i]) for i in loc)
    cache = {
        "k_loc": jnp.zeros((len(loc), batch, w_max, KV, dh), dtype),
        "v_loc": jnp.zeros((len(loc), batch, w_max, KV, dh), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if glob:
        cache["k_glob"] = jnp.zeros((len(glob), batch, max_seq, KV, dh), dtype)
        cache["v_glob"] = jnp.zeros((len(glob), batch, max_seq, KV, dh), dtype)
    return cache


def ring_decode_attention(
    q: Array,  # (B, 1, H, Dh)
    k_ring: Array,  # (B, W, KV, Dh) — ring buffer, slot p%W holds position p
    v_ring: Array,
    cache_len: Array,  # (B,) — the new token's position
    window: int,
    *,
    softmax_scale: Optional[float] = None,
) -> Array:
    """Decode attention over a ring-buffered window cache (exact SWA)."""
    b, _, h, dh = q.shape
    W = k_ring.shape[1]
    kv_heads = k_ring.shape[2]
    groups = h // kv_heads
    scale = softmax_scale if softmax_scale is not None else dh**-0.5
    kk = _repeat_kv(k_ring, groups)
    vv = _repeat_kv(v_ring, groups)
    scores = jnp.einsum(
        "bqhd,bshd->bhqs", q * scale, kk, preferred_element_type=jnp.float32
    )
    # slot i holds position p = len - ((len - i) mod W); p < 0 = never written
    slot = jnp.arange(W)[None, :]
    ln = cache_len[:, None]
    p = ln - jnp.mod(ln - slot, W)
    delta = ln - p
    mask = (delta >= 0) & (delta < window) & (p >= 0)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    prob = jax.nn.softmax(scores, axis=-1).astype(vv.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", prob, vv, preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


from repro.models.attention import NEG_INF, _repeat_kv  # noqa: E402  (ring decode)


def decode_step_split(params, cache, tokens: Array, cfg: TransformerConfig):
    """decode_step over split (ring local + dense global) caches.

    Python layer loop (per-layer cache shapes differ).  Output is bit-
    equivalent to decode_step with a full cache — verified in
    tests/test_models.py::test_split_cache_decode_matches_full.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    layer_params, rest = _split_layer_params(params)
    h = rest["embed"].astype(cd)[tokens][:, None, :] * jnp.asarray(
        np.sqrt(cfg.d_model), cd)
    positions = cache["len"][:, None]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    wins = cfg.window_by_layer()
    if "k_loc" not in cache:  # all-global config: plain dense path
        return decode_step(params, cache, tokens, cfg)
    max_seq = cache["k_glob"].shape[2] if "k_glob" in cache else None
    W = cache["k_loc"].shape[2]
    loc_map, glob_map = {}, {}
    for i, w in enumerate(wins):
        if max_seq is None or int(w) < max_seq:
            loc_map[i] = len(loc_map)
        else:
            glob_map[i] = len(glob_map)

    new_kl, new_vl = list(range(len(loc_map))), list(range(len(loc_map)))
    new_kg, new_vg = list(range(len(glob_map))), list(range(len(glob_map)))
    bidx = jnp.arange(B)
    for li in range(cfg.n_layers):
        lp = _use_constrain_layer(
            jax.tree.map(lambda a: a[li], layer_params), cfg)
        a = common.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q = a @ lp["wq"].astype(cd)
        k = a @ lp["wk"].astype(cd)
        v = a @ lp["wv"].astype(cd)
        if cfg.qkv_bias:
            q = q + lp["bq"].astype(cd)
            k = k + lp["bk"].astype(cd)
            v = v + lp["bv"].astype(cd)
        q = attention.rope(q.reshape(B, 1, H, dh), positions, cfg.rope_theta)
        k = attention.rope(k.reshape(B, 1, KV, dh), positions, cfg.rope_theta)
        v = v.reshape(B, 1, KV, dh)
        if li in loc_map:
            ci = loc_map[li]
            kc, vc = cache["k_loc"][ci], cache["v_loc"][ci]
            slot = jnp.mod(cache["len"], W)
            kc = kc.at[bidx, slot].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[bidx, slot].set(v[:, 0].astype(vc.dtype))
            o = ring_decode_attention(q, kc, vc, cache["len"], int(wins[li]))
            new_kl[ci], new_vl[ci] = kc, vc
        else:
            ci = glob_map[li]
            kc, vc = cache["k_glob"][ci], cache["v_glob"][ci]
            kc = kc.at[bidx, cache["len"]].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[bidx, cache["len"]].set(v[:, 0].astype(vc.dtype))
            o = attention.decode_attention(q, kc, vc, cache["len"], int(wins[li]))
            new_kg[ci], new_vg[ci] = kc, vc
        o = o.reshape(B, 1, H * dh) @ lp["wo"].astype(cd)
        h = h + o
        m = common.rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            mo, _ = moe_lib.apply_moe(
                {k2: lp[k2] for k2 in ("router", "w_gate", "w_up", "w_down")},
                m.reshape(B, cfg.d_model), cfg.moe, act=cfg.act,
                groups=cfg.moe_groups,
            )
            out = mo.reshape(B, 1, cfg.d_model)
            if cfg.dense_residual:
                fn = common.ACTIVATIONS[cfg.act]
                dz = fn(m @ lp["dense_gate"].astype(cd)) * (m @ lp["dense_up"].astype(cd))
                out = out + dz @ lp["dense_down"].astype(cd)
        else:
            fn = common.ACTIVATIONS[cfg.act]
            z = fn(m @ lp["w_gate"].astype(cd)) * (m @ lp["w_up"].astype(cd))
            out = z @ lp["w_down"].astype(cd)
        h = h + out

    hf = common.rms_norm(h[:, 0], rest["ln_f"], cfg.norm_eps)
    head = rest["head"] if not cfg.tie_embeddings else rest["embed"].T
    logits = (hf @ head.astype(cd)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    new_cache = {
        "k_loc": jnp.stack(new_kl), "v_loc": jnp.stack(new_vl),
        "len": cache["len"] + 1,
    }
    if glob_map:
        new_cache["k_glob"] = jnp.stack(new_kg)
        new_cache["v_glob"] = jnp.stack(new_vg)
    return logits, new_cache


def decode_step(params, cache, tokens: Array, cfg: TransformerConfig):
    """One decode step: tokens (B,) -> (logits (B, vocab), updated cache).

    The new token attends to cache[:len] plus itself; each layer's K/V are
    written at position ``len``.  O(S) per token — the long_500k and
    decode_32k shapes lower this function.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    layer_params, rest = _split_layer_params(params)
    h = rest["embed"].astype(cd)[tokens][:, None, :] * jnp.asarray(
        np.sqrt(cfg.d_model), cd
    )  # (B, 1, d)
    positions = cache["len"][:, None]  # (B, 1)
    windows = jnp.asarray(cfg.window_by_layer())
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def layer_step(h, xs):
        lp, w, kc, vc = xs  # kc/vc: (B, S, KV, dh)
        B, _, d = h.shape
        a = common.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q = a @ lp["wq"].astype(cd)
        k = a @ lp["wk"].astype(cd)
        v = a @ lp["wv"].astype(cd)
        if cfg.qkv_bias:
            q = q + lp["bq"].astype(cd)
            k = k + lp["bk"].astype(cd)
            v = v + lp["bv"].astype(cd)
        q = attention.rope(q.reshape(B, 1, H, dh), positions, cfg.rope_theta)
        k = attention.rope(k.reshape(B, 1, KV, dh), positions, cfg.rope_theta)
        v = v.reshape(B, 1, KV, dh)
        # write into the cache at position len (per batch row)
        bidx = jnp.arange(B)
        kc = kc.at[bidx, cache["len"]].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[bidx, cache["len"]].set(v[:, 0].astype(vc.dtype))
        o = attention.decode_attention(q, kc, vc, cache["len"], w)
        o = o.reshape(B, 1, H * dh) @ lp["wo"].astype(cd)
        h = h + o
        m = common.rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            mo, _ = moe_lib.apply_moe(
                {k2: lp[k2] for k2 in ("router", "w_gate", "w_up", "w_down")},
                m.reshape(B, d),
                cfg.moe,
                groups=cfg.moe_groups,
            )
            out = mo.reshape(B, 1, d)
            if cfg.dense_residual:
                fn = common.ACTIVATIONS[cfg.act]
                dz = fn(m @ lp["dense_gate"].astype(cd)) * (m @ lp["dense_up"].astype(cd))
                out = out + dz @ lp["dense_down"].astype(cd)
        else:
            fn = common.ACTIVATIONS[cfg.act]
            z = fn(m @ lp["w_gate"].astype(cd)) * (m @ lp["w_up"].astype(cd))
            out = z @ lp["w_down"].astype(cd)
        h = h + out
        return h, (kc, vc)

    if cfg.unrolled:
        win_np = cfg.window_by_layer()
        ks, vs = [], []
        for li in range(cfg.n_layers):
            lp = _use_constrain_layer(
                jax.tree.map(lambda a: a[li], layer_params), cfg)
            h, (kc_i, vc_i) = layer_step(
                h, (lp, int(win_np[li]), cache["k"][li], cache["v"][li])
            )
            ks.append(kc_i)
            vs.append(vc_i)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    else:
        h, (k_new, v_new) = jax.lax.scan(
            layer_step, h, (layer_params, windows, cache["k"], cache["v"])
        )
    h = common.rms_norm(h[:, 0], rest["ln_f"], cfg.norm_eps)
    head = rest["head"] if not cfg.tie_embeddings else rest["embed"].T
    logits = h @ head.astype(cd)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    new_cache = {"k": k_new, "v": v_new, "len": cache["len"] + 1}
    return logits.astype(jnp.float32), new_cache
