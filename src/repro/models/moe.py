"""Mixture-of-Experts FFN: top-k routing with sort-based ragged dispatch.

Covers mixtral-8x7b (8 experts, top-2) and arctic-480b (128 experts, top-2,
plus a parallel dense residual FFN).

Dispatch is the MegaBlocks/MaxText-style sorted-capacity scheme — no
(tokens, experts, capacity) one-hot ever materializes:

  route -> flatten (token, expert) assignments -> argsort by expert ->
  segment-rank -> keep rank < capacity -> gather to (E, C, d) -> grouped
  GEMMs -> weighted scatter-add back.

Sharding: expert weights carry an ``E`` leading axis; the launcher shards it
over 'model' when E >= mesh['model'] (expert parallelism: arctic), otherwise
shards d_ff over 'model' (per-expert tensor parallelism: mixtral).  Tokens
dropped at capacity overflow are counted in aux metrics; the auxiliary
load-balancing loss is the standard Switch/GShard form.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import segments
from repro.models import common

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


def init_moe_params(key, d_model: int, d_ff: int, cfg: MoEConfig, dtype) -> Dict[str, Array]:
    ks = jax.random.split(key, 4)
    E = cfg.n_experts
    return {
        "router": common.dense_init(ks[0], (d_model, E), jnp.float32),
        "w_gate": common.dense_init(ks[1], (E, d_model, d_ff), dtype),
        "w_up": common.dense_init(ks[2], (E, d_model, d_ff), dtype),
        "w_down": common.dense_init(ks[3], (E, d_ff, d_model), dtype),
    }


def apply_moe(
    params: Dict[str, Array],
    x: Array,  # (T, d) — flattened tokens
    cfg: MoEConfig,
    *,
    act: str = "silu",
    capacity: Optional[int] = None,
    groups: int = 1,
) -> tuple[Array, Dict[str, Array]]:
    """Returns (output (T, d), aux dict with load-balance loss + drop rate).

    ``groups > 1`` runs the dispatch independently per token group (vmap),
    with the group axis sharded over the data axes.  This is the
    production-critical choice: a single global argsort over (T*K,) is
    unpartitionable (GSPMD replicates it — measured 25x FLOP inflation on the
    mixtral train cell, EXPERIMENTS.md §Perf iteration 1), while per-group
    dispatch keeps routing entirely shard-local, which is exactly the
    per-device-capacity semantics real MoE systems (GShard/MaxText) use.
    """
    T, d = x.shape
    if groups > 1 and T % groups == 0 and T // groups >= 8:
        from repro.models.sharding import constrain

        xg = x.reshape(groups, T // groups, d)
        xg = constrain(xg, "batch", None, None)
        out, aux = jax.vmap(
            lambda xx: apply_moe(params, xx, cfg, act=act, capacity=capacity)
        )(xg)
        out = constrain(out, "batch", None, None).reshape(T, d)
        return out, {k: jnp.mean(v) for k, v in aux.items()}
    E, K = cfg.n_experts, cfg.top_k
    if capacity is None:
        capacity = int(cfg.capacity_factor * T * K / E)
        capacity = max(8, -(-capacity // 8) * 8)
    C = capacity

    logits = (x.astype(jnp.float32) @ params["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- flatten assignments and sort by expert -----------------------------
    flat_e = expert_ids.reshape(-1)  # (T*K,)
    flat_t = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None], (T, K)).reshape(-1)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]

    # ---- gather tokens into (E, C, d) ---------------------------------------
    # T is the token sentinel -> zero row of xz
    (buf_tok, buf_gate), counts = segments.grouped_top_r(
        se, [st, sg], [T, 0.0], E, C
    )
    dropped = jnp.sum(jnp.maximum(counts - C, 0))
    drop_rate = dropped.astype(jnp.float32) / se.shape[0]
    xz = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = xz[buf_tok]  # (E, C, d)

    # ---- grouped expert GEMMs ----------------------------------------------
    fn = common.ACTIVATIONS[act]
    h = fn(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, params["w_up"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, C, d)

    # ---- weighted scatter back ----------------------------------------------
    ye = ye * buf_gate[..., None].astype(ye.dtype)
    out = jnp.zeros((T + 1, d), ye.dtype)
    out = out.at[buf_tok.reshape(-1)].add(ye.reshape(-1, d), mode="drop")
    out = out[:T]

    # ---- aux load-balancing loss (Switch eq. 4-6) ---------------------------
    # fraction of tokens routed to e (top-1 assignment) * mean router prob
    top1 = expert_ids[:, 0]
    frac = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux_loss = cfg.aux_loss_weight * E * jnp.sum(frac * mean_prob)
    return out.astype(x.dtype), {"moe_aux_loss": aux_loss, "moe_drop_rate": drop_rate}
