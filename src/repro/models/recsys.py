"""The four assigned recsys architectures.

  * **deepfm**  (arXiv:1703.04247): FM 1st+2nd order over 39 field embeddings
    (dim 10) ∥ deep MLP 400-400-400, summed logits.
  * **xdeepfm** (arXiv:1803.05170): CIN 200-200-200 (compressed interaction
    network — the outer-product-and-compress op is contracted as one einsum,
    never materializing the (B, H, F, D) tensor) ∥ MLP 400-400.
  * **bst**     (arXiv:1905.06874): behavior-sequence transformer — 1 block,
    8 heads over the 20-item history + target, MLP 1024-512-256.
  * **mind**    (arXiv:1904.08030): multi-interest capsule routing (4
    interests, 3 dynamic-routing iterations) + label-aware attention; its
    serving path is candidate retrieval — the one recsys arch where the
    paper's LGD graph is the serving index (DESIGN.md §5).

All embedding access goes through ``models.embedding`` (take + segment_sum
EmbeddingBag).  Tables are row-sharded over 'model' (DLRM pattern); the
dense towers are small and replicated.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common, embedding

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = "deepfm"  # deepfm | xdeepfm | bst | mind
    n_sparse: int = 39
    n_dense: int = 13
    vocab_per_field: int = 1_000_000
    embed_dim: int = 10
    mlp: Tuple[int, ...] = (400, 400, 400)
    # xdeepfm
    cin_layers: Tuple[int, ...] = ()
    # bst
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    # mind
    n_interests: int = 4
    capsule_iters: int = 3
    param_dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        return self.n_sparse * self.vocab_per_field

    def table(self) -> embedding.TableConfig:
        return embedding.TableConfig(rows=self.total_rows, dim=self.embed_dim)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_params(key: Array, cfg: RecsysConfig) -> Dict[str, Any]:
    pd = jnp.dtype(cfg.param_dtype)
    ks = common.split_tree(
        key, {n: None for n in ["table", "lin", "mlp", "cin", "attn", "caps", "dense"]}
    )
    p: Dict[str, Any] = {}
    D = cfg.embed_dim

    if cfg.name in ("deepfm", "xdeepfm"):
        p["table"] = embedding.init_table(ks["table"], cfg.table(), pd)
        p["lin_table"] = embedding.init_table(
            jax.random.fold_in(ks["lin"], 0),
            embedding.TableConfig(rows=cfg.total_rows, dim=1),
            pd,
        )
        p["dense_proj"] = common.dense_init(ks["dense"], (cfg.n_dense, cfg.n_sparse * D), pd)
        mlp_in = cfg.n_sparse * D
        p["mlp"] = common.mlp_stack(ks["mlp"], [mlp_in, *cfg.mlp, 1], pd)
        if cfg.name == "xdeepfm":
            widths = [cfg.n_sparse, *cfg.cin_layers]
            cin = {}
            for i, (hin, hout) in enumerate(zip(widths[:-1], widths[1:])):
                cin[f"w{i}"] = common.dense_init(
                    jax.random.fold_in(ks["cin"], i), (hout, hin, cfg.n_sparse), pd,
                    scale=math.sqrt(hin * cfg.n_sparse) / math.sqrt(hin),
                )
            p["cin"] = cin
            p["cin_out"] = common.dense_init(
                jax.random.fold_in(ks["cin"], 99), (sum(cfg.cin_layers), 1), pd
            )
    elif cfg.name == "bst":
        p["table"] = embedding.init_table(
            ks["table"], embedding.TableConfig(rows=cfg.vocab_per_field, dim=D), pd
        )
        p["pos"] = common.embed_init(
            jax.random.fold_in(ks["table"], 1), (cfg.seq_len + 1, D), pd, 0.02
        )
        H = cfg.n_heads
        p["attn"] = {
            "wq": common.dense_init(ks["attn"], (cfg.n_blocks, D, D), pd),
            "wk": common.dense_init(jax.random.fold_in(ks["attn"], 1), (cfg.n_blocks, D, D), pd),
            "wv": common.dense_init(jax.random.fold_in(ks["attn"], 2), (cfg.n_blocks, D, D), pd),
            "wo": common.dense_init(jax.random.fold_in(ks["attn"], 3), (cfg.n_blocks, D, D), pd),
            "ff1": common.dense_init(jax.random.fold_in(ks["attn"], 4), (cfg.n_blocks, D, 4 * D), pd),
            "ff2": common.dense_init(jax.random.fold_in(ks["attn"], 5), (cfg.n_blocks, 4 * D, D), pd),
            "ln1": jnp.zeros((cfg.n_blocks, D), pd),
            "ln2": jnp.zeros((cfg.n_blocks, D), pd),
        }
        mlp_in = (cfg.seq_len + 1) * D
        p["mlp"] = common.mlp_stack(ks["mlp"], [mlp_in, *cfg.mlp, 1], pd)
    elif cfg.name == "mind":
        p["table"] = embedding.init_table(
            ks["table"], embedding.TableConfig(rows=cfg.vocab_per_field, dim=D), pd
        )
        p["caps_bilinear"] = common.dense_init(ks["caps"], (D, D), pd)
        p["mlp"] = common.mlp_stack(ks["mlp"], [D, *cfg.mlp, D], pd)
    else:
        raise ValueError(cfg.name)
    return p


def param_pspecs(cfg: RecsysConfig) -> Dict[str, Any]:
    """Row-shard the big tables over 'model'; everything else replicated."""
    from jax.sharding import PartitionSpec as P

    def rep(tree):
        return jax.tree.map(lambda v: P(*([None] * v.ndim)), tree)

    p = init_params(jax.random.PRNGKey(0), _tiny_like(cfg))
    specs = rep(p)
    specs["table"] = P("model", None)
    if "lin_table" in p:
        specs["lin_table"] = P("model", None)
    return specs


def _tiny_like(cfg: RecsysConfig) -> RecsysConfig:
    """Same pytree structure, tiny tables (pspec derivation only)."""
    return dataclasses.replace(cfg, vocab_per_field=8)


# ---------------------------------------------------------------------------
# Interaction blocks
# ---------------------------------------------------------------------------


def fm_second_order(emb: Array) -> Array:
    """(B, F, D) -> (B,) : ½[(Σ_f v)² − Σ_f v²] summed over D."""
    s = jnp.sum(emb, axis=1)
    s2 = jnp.sum(emb * emb, axis=1)
    return 0.5 * jnp.sum(s * s - s2, axis=-1)


def cin(emb: Array, params: Dict[str, Array], widths: Tuple[int, ...]) -> Array:
    """Compressed Interaction Network: (B, F, D) -> (B, sum(widths)).

    x^k_h = Σ_{i,j} W^k_{h i j} (x^{k-1}_i ∘ x^0_j); one einsum per layer —
    the (B, H, F, D) outer product is contracted inline, which is the memory
    adaptation that makes the 65k train batch feasible.
    """
    x0 = emb
    xk = emb
    pools = []
    for i, _ in enumerate(widths):
        w = params[f"w{i}"]  # (hout, hin, F)
        xk = jnp.einsum("bhd,bfd,ohf->bod", xk, x0, w)
        pools.append(jnp.sum(xk, axis=-1))  # sum-pool over D -> (B, hout)
    return jnp.concatenate(pools, axis=-1)


def _bst_block(h: Array, bp: Dict[str, Array], i: int, n_heads: int) -> Array:
    """One post-LN transformer block over the (B, S+1, D) behavior sequence."""
    B, S, D = h.shape
    dh = D // n_heads
    q = (h @ bp["wq"][i]).reshape(B, S, n_heads, dh)
    k = (h @ bp["wk"][i]).reshape(B, S, n_heads, dh)
    v = (h @ bp["wv"][i]).reshape(B, S, n_heads, dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
    att = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, S, D)
    h = common.layer_norm(h + o @ bp["wo"][i], 1.0 + bp["ln1"][i], jnp.zeros_like(bp["ln1"][i]))
    f = jax.nn.relu(h @ bp["ff1"][i]) @ bp["ff2"][i]
    h = common.layer_norm(h + f, 1.0 + bp["ln2"][i], jnp.zeros_like(bp["ln2"][i]))
    return h


def capsule_routing(
    hist_emb: Array,  # (B, S, D) behavior capsules (zeros at padding)
    hist_mask: Array,  # (B, S)
    bilinear: Array,  # (D, D)
    n_interests: int,
    iters: int,
) -> Array:
    """MIND's B2I dynamic routing -> (B, K, D) interest capsules."""
    B, S, D = hist_emb.shape
    u = hist_emb @ bilinear  # (B, S, D) behavior->interest projections
    # fixed (untrainable) random logit init, shared across batch (MIND §4.2)
    b = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(7), (1, S, n_interests)), (B, S, n_interests)
    )

    def squash(z):
        n2 = jnp.sum(z * z, axis=-1, keepdims=True)
        return (n2 / (1.0 + n2)) * z / jnp.sqrt(jnp.maximum(n2, 1e-9))

    caps = None
    for _ in range(iters):
        w = jax.nn.softmax(b, axis=-1)  # routing over interests
        w = jnp.where(hist_mask[..., None], w, 0.0)
        z = jnp.einsum("bsk,bsd->bkd", w, u)
        caps = squash(z)  # (B, K, D)
        b = b + jnp.einsum("bsd,bkd->bsk", u, caps)
    return caps


# ---------------------------------------------------------------------------
# Forward / losses
# ---------------------------------------------------------------------------


def ctr_logits(params: Dict[str, Any], batch: Dict[str, Array], cfg: RecsysConfig) -> Array:
    """deepfm / xdeepfm pointwise CTR score."""
    F, D = cfg.n_sparse, cfg.embed_dim
    ids = batch["sparse"] + jnp.arange(F, dtype=jnp.int32)[None, :] * cfg.vocab_per_field
    emb = embedding.lookup(params["table"], ids)  # (B, F, D)
    lin = embedding.lookup(params["lin_table"], ids)[..., 0]  # (B, F)
    first = jnp.sum(lin, axis=1)
    deep_in = emb.reshape(emb.shape[0], F * D)
    deep_in = deep_in + batch["dense"] @ params["dense_proj"]
    deep = common.mlp_apply(params["mlp"], deep_in, act="relu")[:, 0]
    if cfg.name == "deepfm":
        return first + fm_second_order(emb) + deep
    feats = cin(emb, params["cin"], cfg.cin_layers)
    return first + (feats @ params["cin_out"])[:, 0] + deep


def bst_logits(params: Dict[str, Any], batch: Dict[str, Array], cfg: RecsysConfig) -> Array:
    hist, target = batch["hist"], batch["target"]
    seq = jnp.concatenate([hist, target[:, None]], axis=1)  # (B, S+1)
    h = embedding.lookup(params["table"], seq) + params["pos"][None]
    for i in range(cfg.n_blocks):
        h = _bst_block(h, params["attn"], i, cfg.n_heads)
    flat = h.reshape(h.shape[0], -1)
    return common.mlp_apply(params["mlp"], flat, act="relu")[:, 0]


def mind_interests(params: Dict[str, Any], hist: Array, cfg: RecsysConfig) -> Array:
    """User history -> (B, K, D) interest vectors (the serving-side encoder)."""
    mask = hist >= 0
    emb = embedding.lookup(params["table"], hist)
    caps = capsule_routing(
        emb, mask, params["caps_bilinear"], cfg.n_interests, cfg.capsule_iters
    )
    B, K, D = caps.shape
    out = common.mlp_apply(params["mlp"], caps.reshape(B * K, D), act="relu")
    return out.reshape(B, K, D)


def mind_logits(params: Dict[str, Any], batch: Dict[str, Array], cfg: RecsysConfig) -> Array:
    """Label-aware attention (pow=2) over interests vs the target item."""
    interests = mind_interests(params, batch["hist"], cfg)  # (B, K, D)
    t = embedding.lookup(params["table"], batch["target"])  # (B, D)
    scores = jnp.einsum("bkd,bd->bk", interests, t)
    att = jax.nn.softmax(scores * 2.0, axis=-1)  # label-aware attention
    user = jnp.einsum("bk,bkd->bd", att, interests)
    return jnp.sum(user * t, axis=-1)


def loss_fn(params, batch: Dict[str, Array], cfg: RecsysConfig):
    if cfg.name in ("deepfm", "xdeepfm"):
        logits = ctr_logits(params, batch, cfg)
    elif cfg.name == "bst":
        logits = bst_logits(params, batch, cfg)
    else:
        logits = mind_logits(params, batch, cfg)
    loss = common.sigmoid_bce(logits, batch["label"])
    acc = jnp.mean(((logits > 0) == (batch["label"] > 0.5)).astype(jnp.float32))
    return loss, {"acc": acc}


def serve_scores(params, batch: Dict[str, Array], cfg: RecsysConfig) -> Array:
    """Pointwise inference (serve_p99 / serve_bulk shapes)."""
    if cfg.name in ("deepfm", "xdeepfm"):
        return jax.nn.sigmoid(ctr_logits(params, batch, cfg))
    if cfg.name == "bst":
        return jax.nn.sigmoid(bst_logits(params, batch, cfg))
    return jax.nn.sigmoid(mind_logits(params, batch, cfg))


def retrieval_scores(
    params, hist: Array, candidates: Array, cfg: RecsysConfig
) -> Array:
    """retrieval_cand shape: one user's interests vs N candidate embeddings.

    Brute path: (K, D) x (N, D) GEMM, max over interests -> (N,) scores.
    (The ANN path over the same candidates lives in serve/retrieval.py and
    uses the paper's LGD graph with metric='ip'.)
    """
    interests = mind_interests(params, hist, cfg)[0]  # (K, D)
    scores = candidates @ interests.T  # (N, K)
    return jnp.max(scores, axis=-1)


def ctr_retrieval_scores(
    params, batch: Dict[str, Array], cfg: RecsysConfig
) -> Array:
    """deepfm/xdeepfm retrieval_cand: one user context x N candidate items.

    Pointwise CTR models have no two-tower factorization, so every candidate
    runs the full interaction+MLP — but the user-side embedding gather
    happens ONCE (1 row) and is broadcast; only the item field varies.
    batch: dense (1, n_dense), sparse (1, F) user fields, cand (N,) item ids
    for field 0.
    """
    F, D = cfg.n_sparse, cfg.embed_dim
    N = batch["cand"].shape[0]
    ids = batch["sparse"] + jnp.arange(F, dtype=jnp.int32)[None, :] * cfg.vocab_per_field
    user_emb = embedding.lookup(params["table"], ids)  # (1, F, D)
    user_lin = embedding.lookup(params["lin_table"], ids)[..., 0]  # (1, F)
    cand_emb = embedding.lookup(params["table"], batch["cand"])  # (N, D) field 0
    cand_lin = embedding.lookup(params["lin_table"], batch["cand"])[..., 0]  # (N,)
    emb = jnp.broadcast_to(user_emb, (N, F, D)).at[:, 0, :].set(cand_emb)
    first = jnp.sum(user_lin[0, 1:]) + cand_lin
    deep_in = emb.reshape(N, F * D) + batch["dense"] @ params["dense_proj"]
    deep = common.mlp_apply(params["mlp"], deep_in, act="relu")[:, 0]
    if cfg.name == "deepfm":
        return first + fm_second_order(emb) + deep
    feats = cin(emb, params["cin"], cfg.cin_layers)
    return first + (feats @ params["cin_out"])[:, 0] + deep


def bst_retrieval_scores(
    params, batch: Dict[str, Array], cfg: RecsysConfig
) -> Array:
    """bst retrieval_cand: one history x N candidate targets.

    The candidate sits in the sequence, so the transformer block runs per
    candidate (N, S+1, D) — the honest cost of sequence-conditioned scoring.
    History embeddings are gathered once and broadcast.
    """
    hist, cand = batch["hist"], batch["cand"]  # (1, S), (N,)
    N = cand.shape[0]
    S = cfg.seq_len
    h_hist = embedding.lookup(params["table"], hist)  # (1, S, D)
    h_cand = embedding.lookup(params["table"], cand)[:, None, :]  # (N, 1, D)
    h = jnp.concatenate(
        [jnp.broadcast_to(h_hist, (N, S, cfg.embed_dim)), h_cand], axis=1
    )
    h = h + params["pos"][None]
    for i in range(cfg.n_blocks):
        h = _bst_block(h, params["attn"], i, cfg.n_heads)
    return common.mlp_apply(params["mlp"], h.reshape(N, -1), act="relu")[:, 0]
