"""Attention for the LM zoo: GQA + RoPE + windowed causal masking.

Covers, via one parameterization:
  * full causal attention (stablelm, qwen, arctic) — window >= seq;
  * sliding-window attention (mixtral, window 4096);
  * gemma3's 5:1 local:global alternation — the window is a *per-layer
    scalar* so the whole stack still runs as one scan-over-layers (the
    mask formula ``(i >= j) & (i - j < window)`` is shared; only the
    window value varies across scanned layers);
  * KV-cache decode (one token against a cache of seq_len).

Prefill/train uses a chunked two-level online-softmax (flash-style in pure
XLA): the (S, S) score matrix never materializes — required for the 32k
prefill shapes, where full scores would be ~TBs.  On TPU the inner block is
MXU-shaped (q_chunk x kv_chunk = 512 x 512 by default).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (B, S, H, Dh), positions: (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _repeat_kv(k: Array, groups: int) -> Array:
    """(B, S, KV, Dh) -> (B, S, KV*groups, Dh)."""
    if groups == 1:
        return k
    b, s, kv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, dh)).reshape(
        b, s, kv * groups, dh
    )


def chunked_causal_attention(
    q: Array,  # (B, S, H, Dh)
    k: Array,  # (B, S, KV, Dh)
    v: Array,  # (B, S, KV, Dh)
    window,  # scalar (static or traced): attend to j with 0 <= i-j < window
    *,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    softmax_scale: Optional[float] = None,
) -> Array:
    """Flash-style attention: scan over kv chunks with running (max, sum).

    Memory high-water: (B, H, q_chunk, kv_chunk) scores per step — the full
    (S, S) matrix never exists.  ``window`` may be traced, enabling
    per-scanned-layer local/global behaviour.
    """
    b, s, h, dh = q.shape
    kv_heads = k.shape[2]
    groups = h // kv_heads
    scale = softmax_scale if softmax_scale is not None else dh**-0.5
    qc = min(q_chunk, s)
    kc = min(kv_chunk, s)
    nq = -(-s // qc)
    nk = -(-s // kc)
    sp = nq * qc

    qp = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - s), (0, 0), (0, 0)))
    kp = _repeat_kv(kp, groups)
    vp = _repeat_kv(vp, groups)

    # (B, H, nq, qc, Dh)
    qb = qp.reshape(b, nq, qc, h, dh).transpose(0, 3, 1, 2, 4) * scale
    kb = kp.reshape(b, nk, kc, h, dh).transpose(0, 3, 1, 2, 4)
    vb = vp.reshape(b, nk, kc, h, dh).transpose(0, 3, 1, 2, 4)

    q_pos = jnp.arange(sp).reshape(nq, qc)
    k_pos = jnp.arange(nk * kc).reshape(nk, kc)

    def per_q_chunk(qi, q_tile):
        # q_tile: (B, H, qc, Dh)
        qpos = q_pos[qi]  # (qc,)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_tile = kb[:, :, ki]  # (B, H, kc, Dh)
            v_tile = vb[:, :, ki]
            scores = jnp.einsum(
                "bhqd,bhkd->bhqk", q_tile, k_tile, preferred_element_type=jnp.float32
            )
            kpos = k_pos[ki]
            delta = qpos[:, None] - kpos[None, :]
            mask = (delta >= 0) & (delta < window) & (kpos < s)[None, :]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_tile.dtype), v_tile,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        a0 = jnp.zeros((b, h, qc, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(
        lambda qi: per_q_chunk(qi, qb[:, :, qi]), jnp.arange(nq)
    )  # (nq, B, H, qc, Dh)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, sp, h, dh)[:, :s]
    return out.astype(q.dtype)


def tiled_causal_attention(
    q: Array,  # (B, S, H, Dh)
    k: Array,  # (B, S, KV, Dh)
    v: Array,  # (B, S, KV, Dh)
    window: int,  # STATIC window (0 < w; FULL_WINDOW for none)
    *,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    softmax_scale: Optional[float] = None,
) -> Array:
    """Statically-tiled flash attention: python tile loops with *static
    causal/window tile skipping*.

    Functionally identical to ``chunked_causal_attention`` but (a) tiles that
    are fully masked (k entirely after q, or entirely outside the window)
    are never emitted — the same schedule a production flash kernel runs,
    worth ~2x on causal and ~S/w on windowed shapes; (b) every tile is
    first-class HLO, so ``cost_analysis`` counts the true FLOPs (scan bodies
    are counted once — DESIGN.md §7).  Used by the dry-run lowering and
    available as a run-time option.
    """
    b, s, h, dh = q.shape
    kv_heads = k.shape[2]
    groups = h // kv_heads
    scale = softmax_scale if softmax_scale is not None else dh**-0.5
    qc = min(q_chunk, s)
    kc = min(kv_chunk, s)
    nq = -(-s // qc)
    nk = -(-s // kc)
    sp = nq * qc
    qp = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - s), (0, 0), (0, 0)))
    kp = _repeat_kv(kp, groups)
    vp = _repeat_kv(vp, groups)
    qb = qp.reshape(b, nq, qc, h, dh).transpose(0, 3, 1, 2, 4) * scale
    kb = kp.reshape(b, nk, kc, h, dh).transpose(0, 3, 1, 2, 4)
    vb = vp.reshape(b, nk, kc, h, dh).transpose(0, 3, 1, 2, 4)

    outs = []
    for qi in range(nq):
        q_tile = qb[:, :, qi]  # (B, H, qc, Dh)
        q_lo, q_hi = qi * qc, (qi + 1) * qc - 1
        m = jnp.full((b, h, qc), NEG_INF, jnp.float32)
        l = jnp.zeros((b, h, qc), jnp.float32)
        acc = jnp.zeros((b, h, qc, dh), jnp.float32)
        for ki in range(nk):
            k_lo, k_hi = ki * kc, (ki + 1) * kc - 1
            if k_lo > q_hi:  # entirely in the future — causal skip
                continue
            if k_hi < q_lo - window + 1:  # entirely before the window
                continue
            k_tile = kb[:, :, ki]
            v_tile = vb[:, :, ki]
            scores = jnp.einsum(
                "bhqd,bhkd->bhqk", q_tile, k_tile, preferred_element_type=jnp.float32
            )
            k_pos = k_lo + jnp.arange(kc)
            delta = (q_lo + jnp.arange(qc))[:, None] - k_pos[None, :]
            mask = (delta >= 0) & (delta < window) & (k_pos < s)[None, :]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_tile.dtype), v_tile,
                preferred_element_type=jnp.float32,
            )
            m = m_new
        outs.append(acc / jnp.maximum(l[..., None], 1e-30))
    out = jnp.stack(outs, axis=2)  # (B, H, nq, qc, Dh)
    out = out.transpose(0, 2, 3, 1, 4).reshape(b, sp, h, dh)[:, :s]
    return out.astype(q.dtype)


def decode_attention(
    q: Array,  # (B, 1, H, Dh) — the new token's query
    k_cache: Array,  # (B, S, KV, Dh)
    v_cache: Array,  # (B, S, KV, Dh)
    cache_len: Array,  # (B,) valid prefix length (new token goes at cache_len)
    window,  # scalar
    *,
    softmax_scale: Optional[float] = None,
) -> Array:
    """One-step decode: new query vs the whole KV cache (O(S) per token)."""
    b, _, h, dh = q.shape
    s = k_cache.shape[1]
    kv_heads = k_cache.shape[2]
    groups = h // kv_heads
    scale = softmax_scale if softmax_scale is not None else dh**-0.5
    kk = _repeat_kv(k_cache, groups)
    vv = _repeat_kv(v_cache, groups)
    scores = jnp.einsum(
        "bqhd,bshd->bhqs", q * scale, kk, preferred_element_type=jnp.float32
    )  # (B, H, 1, S)
    pos = jnp.arange(s)[None, :]  # (1, S)
    qpos = cache_len[:, None]  # (B, 1) — query position
    delta = qpos - pos
    mask = (delta >= 0) & (delta < window)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(vv.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", p, vv, preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
