"""Activation-sharding hooks: models stay mesh-agnostic.

The launcher installs a mesh + logical rules; models call
``constrain(x, spec)`` at the few places that matter (post-embed, attention
output, FFN intermediate, logits).  Outside a mesh context this is a no-op,
so unit tests and the CPU examples run unchanged.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def batch_axes() -> tuple:
    """Axes that jointly play the data-parallel role."""
    if _ACTIVE_MESH is None:
        return ("data",)
    names = _ACTIVE_MESH.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity.

    spec entries: None, an axis name, or a tuple of axis names; the special
    string "batch" resolves to ``batch_axes()`` (pod+data under multi-pod).
    """
    if _ACTIVE_MESH is None:
        return x
    resolved = tuple(batch_axes() if s == "batch" else s for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACTIVE_MESH, P(*resolved))
    )


def named(*spec) -> P:
    return P(*tuple(() if s is None else s for s in spec))
