"""EmbeddingBag and sharded-table lookup — the recsys hot path.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse; per the assignment
this is implemented from primitives and IS part of the system:

  * single-hot lookup  = ``jnp.take`` rows;
  * multi-hot bag      = gather + ``jax.ops.segment_sum`` (sum/mean modes),
    ids < 0 are padding and contribute zero;
  * sharded tables     = rows partitioned over the 'model' mesh axis (the
    DLRM pattern).  Under pjit the gather over a row-sharded operand lowers
    to partial gathers + a small all-reduce — visible in the dry-run
    collective schedule (EXPERIMENTS.md §Roofline discusses it).

Tables use the quotient-remainder trick optionally (``hash_rows``) so a
10⁹-id space fits a 10⁶..10⁸-row table — the production memory/recall trade.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TableConfig:
    rows: int
    dim: int
    hash_rows: int = 0  # 0 = direct indexing; >0 = QR-hash into this many rows


def init_table(key: Array, cfg: TableConfig, dtype=jnp.float32) -> Array:
    rows = cfg.hash_rows or cfg.rows
    return common.embed_init(key, (rows, cfg.dim), dtype, scale=0.05)


def _resolve_ids(ids: Array, cfg: TableConfig) -> Array:
    if cfg.hash_rows:
        # quotient-remainder: (id % H + id // H) mod H keeps collisions spread
        h = cfg.hash_rows
        return ((ids % h) + (ids // h)) % h
    return ids


def lookup(table: Array, ids: Array, cfg: Optional[TableConfig] = None) -> Array:
    """Single-hot rows: ids (...,) -> (..., dim); ids < 0 give zeros."""
    if cfg is not None:
        ids = jnp.where(ids >= 0, _resolve_ids(jnp.maximum(ids, 0), cfg), -1)
    safe = jnp.maximum(ids, 0)
    out = jnp.take(table, safe, axis=0)
    return jnp.where((ids >= 0)[..., None], out, 0.0)


def embedding_bag(
    table: Array,
    ids: Array,  # (B, L) int32, -1 = padding
    *,
    mode: str = "sum",
    weights: Optional[Array] = None,  # (B, L) per-sample weights
    cfg: Optional[TableConfig] = None,
) -> Array:
    """torch.nn.EmbeddingBag equivalent: (B, L) multi-hot -> (B, dim).

    gather + segment-reduce; the segment ids are the batch rows, so the
    reduction is a single ``segment_sum`` over the flattened (B*L, dim)
    gather — XLA fuses the gather into the scatter-add on TPU.
    """
    B, L = ids.shape
    emb = lookup(table, ids, cfg)  # (B, L, dim) zeros at padding
    if weights is not None:
        emb = emb * weights[..., None]
    seg = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None], (B, L)).reshape(-1)
    out = jax.ops.segment_sum(emb.reshape(B * L, -1), seg, num_segments=B)
    if mode == "mean":
        cnt = jnp.sum((ids >= 0).astype(jnp.float32), axis=1, keepdims=True)
        out = out / jnp.maximum(cnt, 1.0)
    elif mode != "sum":
        raise ValueError(f"mode {mode!r}")
    return out
