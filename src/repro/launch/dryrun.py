import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the very first lines above: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

For every cell this prints/records:
  * compiled.memory_analysis()  — proves the program fits per-device HBM;
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline;
  * collective bytes parsed from the optimized HLO (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute) — cost_analysis does not
    report them;
  * the three roofline terms + dominant bottleneck (§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single    # 16x16 only
  PYTHONPATH=src python -m repro.launch.dryrun --knn            # include the paper's cells
  PYTHONPATH=src python -m repro.launch.dryrun --out results.json
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro import configs
from repro.configs import cells
from repro.launch import mesh as mesh_lib
from repro.launch import roofline


def run_cell(arch: str, shape: str, mesh, mesh_name: str, skip_reason=None,
             lower_only: bool = False) -> dict:
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name}
    if skip_reason:
        rec["status"] = "skipped"
        rec["reason"] = skip_reason
        return rec
    t0 = time.time()
    try:
        cell = cells.plan(arch, shape, mesh)
        with mesh:
            lowered = cells.lower(cell)
            if lower_only:
                rec["status"] = "lowered"
                rec["wall_s"] = round(time.time() - t0, 1)
                return rec
            compiled = lowered.compile()
        rec.update(roofline.analyze(
            compiled, mesh, model_flops=cell.model_flops,
            loop_factor=cell.loop_factor,
        ))
        rec["kind"] = cell.kind
        rec["notes"] = cell.notes
        rec["status"] = "ok"
    except Exception as e:  # a failing cell is a bug in the system — surface it
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--knn", action="store_true", help="include the paper's k-NN cells")
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--lower-only", action="store_true",
                    help="fast validation: lower every cell, skip compile")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run needs the 512 placeholder devices"

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single-pod-16x16", mesh_lib.make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi-pod-2x16x16", mesh_lib.make_production_mesh(multi_pod=True)))

    cell_list = configs.all_cells(include_knn=args.knn)
    if args.arch:
        cell_list = [c for c in cell_list if c[0] == args.arch]
        if args.arch.startswith("knn-"):
            mod = configs.get(args.arch)
            cell_list = [(args.arch, s, mod.SKIP.get(s)) for s in mod.SHAPES]
    if args.shape:
        cell_list = [c for c in cell_list if c[1] == args.shape]

    records = []
    n_fail = 0
    for mesh_name, mesh in meshes:
        for arch, shape, skip in cell_list:
            rec = run_cell(arch, shape, mesh, mesh_name, skip,
                           lower_only=args.lower_only)
            records.append(rec)
            status = rec["status"]
            if status == "lowered":
                line = f"[{mesh_name}] {arch} x {shape}: LOWER-OK ({rec['wall_s']}s)"
            elif status == "ok":
                line = (
                    f"[{mesh_name}] {arch} x {shape}: OK "
                    f"({rec['wall_s']}s) bytes/dev={rec['bytes_per_device']/2**30:.2f}GiB "
                    f"flops={rec['hlo_gflops']:.1f}G coll={rec['collective_gbytes']:.3f}GB "
                    f"dominant={rec['dominant']}"
                )
            elif status == "skipped":
                line = f"[{mesh_name}] {arch} x {shape}: SKIP ({rec['reason'][:60]}...)"
            else:
                n_fail += 1
                line = f"[{mesh_name}] {arch} x {shape}: FAIL {rec['error']}"
            print(line, flush=True)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    print(f"done: {sum(r['status'] == 'ok' for r in records)} ok, "
          f"{sum(r['status'] == 'skipped' for r in records)} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
