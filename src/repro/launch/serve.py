"""Serving launcher: batched requests against a built index or model.

    # ANN retrieval over an LGD index (the paper's serving story):
    PYTHONPATH=src python -m repro.launch.serve --mode retrieval \
        --n-items 8000 --d 16 --requests 20 --topk 10

    # same, but sharded over 4 OnlineIndex shards and served through the
    # router, with a snapshot save -> restore before serving:
    PYTHONPATH=src python -m repro.launch.serve --mode retrieval \
        --shards 4 --snapshot /tmp/idx_snap

    # LM decode micro-serving (smoke config, KV-cache decode loop):
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch gemma3-1b \
        --batch 4 --prompt-len 32 --gen 16

Retrieval serving runs on the index lifecycle subsystem (``repro.index``):
a single ``OnlineIndex`` for ``--shards 1``, the fan-out/merge
``ShardedIndex`` router above it otherwise; ``--snapshot PATH`` exercises
the versioned save/restore path before taking traffic.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs


def serve_retrieval(args):
    from repro.index import OnlineIndex, ShardedIndex
    from repro.obs import JsonlTracker
    from repro.serve import retrieval
    from repro.serve.loop import ServeLoopConfig, ServingLoop

    tracker = None
    if args.trace:
        tracker = JsonlTracker(
            args.trace,
            run_meta={"launcher": "serve_retrieval", "mode": "retrieval",
                      "n_items": args.n_items, "shards": args.shards},
        )

    key = jax.random.PRNGKey(0)
    items = jax.random.normal(key, (args.n_items, args.d))
    items = items / jnp.linalg.norm(items, axis=1, keepdims=True)
    t0 = time.time()
    if args.shards > 1:
        index = ShardedIndex.build(
            items, args.shards, k=16, metric="ip", wave=512,
            key=jax.random.PRNGKey(1),
        )
        print(f"indexed {args.n_items} items over {args.shards} shards "
              f"in {time.time()-t0:.1f}s")
    else:
        index = retrieval.build_index(items, k=16, metric="ip", wave=512,
                                      key=jax.random.PRNGKey(1))
        print(f"indexed {args.n_items} items in {time.time()-t0:.1f}s")

    if args.snapshot:  # versioned save -> restore before taking traffic
        t0 = time.time()
        index.save(args.snapshot)
        cls = ShardedIndex if args.shards > 1 else OnlineIndex
        index = cls.load(args.snapshot)
        print(f"snapshot round trip ({args.snapshot}) in {time.time()-t0:.1f}s")

    if args.shards > 1:
        # router fan-out path: per-shard spans land in the trace; latency
        # is measured around the merged answer like before
        if tracker is not None:
            index.tracker = tracker
            for sh in index.shards:
                sh.tracker = tracker
        lat = []
        for r in range(args.requests):
            q = jax.random.normal(jax.random.fold_in(key, 100 + r), (4, args.d))
            t0 = time.time()
            ids, scores = index.retrieve(q, args.topk, beam=48)
            jax.block_until_ready(jnp.asarray(scores))
            lat.append(time.time() - t0)
        lat_ms = np.asarray(lat[2:]) * 1e3  # drop warmup
        print(f"{args.requests} requests: p50={np.percentile(lat_ms,50):.1f}ms "
              f"p99={np.percentile(lat_ms,99):.1f}ms")
    else:
        # single index: traffic runs through the instrumented ServingLoop —
        # pow2-coalesced waves, enqueue->synced-result latency, reservoir
        # recall audit, all reported through the tracker
        loop = ServingLoop(
            index,
            ServeLoopConfig(top_k=args.topk, beam=48, max_batch=16),
            tracker=tracker,
        )
        for r in range(args.requests):
            q = jax.random.normal(jax.random.fold_in(key, 100 + r), (4, args.d))
            loop.submit(np.asarray(q))
            loop.step()
            if r == 1:  # drop compile warmup from the reported window
                loop.reset_window()
        rec = loop.report(audit_k=min(args.topk, 10))
        print(f"{loop.served} queries in {rec['n_waves']} waves: "
              f"p50={rec['p50_latency_ms']:.1f}ms "
              f"p99={rec['p99_latency_ms']:.1f}ms qps={rec['qps']:.1f} "
              f"recall@{min(args.topk, 10)}="
              f"{rec.get(f'recall_at_{min(args.topk, 10)}', float('nan')):.3f} "
              f"scan_rate={rec['scanning_rate']:.4f}")
    if tracker is not None:
        tracker.finish()
        print(f"trace written to {args.trace}")


def serve_lm(args):
    from repro.models import transformer as tfm

    cfg = configs.get(args.arch).smoke_config()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    logits, cache = tfm.prefill(params, prompt, cfg)
    # grow cache for generation
    cache = {
        "k": jnp.pad(cache["k"], ((0, 0), (0, 0), (0, args.gen), (0, 0), (0, 0))),
        "v": jnp.pad(cache["v"], ((0, 0), (0, 0), (0, args.gen), (0, 0), (0, 0))),
        "len": cache["len"],
    }
    decode = jax.jit(lambda p, c, t: tfm.decode_step(p, c, t, cfg))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    total = args.batch * args.gen
    print(f"prefill {args.prompt_len} + decode {args.gen} tokens x {args.batch} "
          f"in {dt:.2f}s ({total/dt:.0f} tok/s); sample: "
          f"{np.asarray(jnp.stack(out, 1))[0][:8].tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["retrieval", "lm"], default="retrieval")
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--n-items", type=int, default=8000)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--shards", type=int, default=1,
                    help="serve through the ShardedIndex router (>1)")
    ap.add_argument("--snapshot", type=str, default=None, metavar="PATH",
                    help="save + restore the index through a snapshot "
                         "before serving")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--trace", type=str, default=None, metavar="PATH",
                    help="write an obs.JsonlTracker event trace "
                         "(spans + metrics) of the serving run")
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "retrieval":
        serve_retrieval(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
