"""k-NN graph construction launcher (the paper's pipeline, end to end).

    PYTHONPATH=src python -m repro.launch.build_graph \
        --n 20000 --d 32 --k 20 --algo lgd --ckpt /tmp/gck --eval

Builds online (OLG/LGD), checkpointing at wave boundaries; ``--resume``
restarts from the last committed wave (fault-tolerance demo).  ``--eval``
reports graph recall vs exact ground truth and the scanning rate (Eq. 2).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.core import brute, construct
from repro.core.graph import empty_graph
from repro.data import synthetic
from repro.train import checkpoint as ckpt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--metric", default="l2")
    ap.add_argument("--kind", default="clustered", choices=list(synthetic.GENERATORS))
    ap.add_argument("--algo", default="lgd", choices=["lgd", "olg"])
    ap.add_argument("--seed-mode", default="random", choices=["random", "coarse"],
                    help="entry-point seeding for the insertion searches: "
                         "'coarse' routes through a landmark level "
                         "(core.hierarchy) — polylog scanning rate at scale")
    ap.add_argument("--coarse-landmarks", type=int, default=None, metavar="L",
                    help="landmark count for --seed-mode coarse (default ~4·√n)")
    ap.add_argument("--wave", type=int, default=512)
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "int8", "pq"],
                    help="distance-engine representation for the insertion "
                         "searches (kernels.precision): compressed tiles "
                         "(bf16/int8) or PQ rank-then-rerank")
    ap.add_argument("--parallel-shards", type=int, default=1, metavar="S",
                    help="divide-and-conquer build: S concurrent sub-graphs "
                         "merged via core.merge.symmetric_merge (S=1: the "
                         "sequential online build)")
    ap.add_argument("--refine-rounds", type=int, default=1,
                    help="NN-Descent sweeps after the merge (parallel builds)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=8, help="waves between checkpoints")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--eval", action="store_true")
    args = ap.parse_args()

    if args.parallel_shards > 1 and args.resume:
        raise SystemExit("--resume is a sequential-build feature "
                         "(parallel builds restart their sub-builds)")

    x = synthetic.make(args.kind, jax.random.PRNGKey(0), args.n, args.d)
    cfg = construct.BuildConfig(
        k=args.k, metric=args.metric, wave=args.wave,
        lgd=(args.algo == "lgd"), beam=max(40, args.k), dispatch="reference",
        precision=args.precision,
        seed_mode=args.seed_mode, coarse_landmarks=args.coarse_landmarks,
    )

    initial = None
    if args.resume and args.ckpt and os.path.exists(os.path.join(args.ckpt, "manifest.json")):
        like = empty_graph(args.n, args.k, cfg.rev_cap or 2 * args.k)
        g0, _ = ckpt_lib.restore_graph(args.ckpt, like)
        initial = (g0, int(g0.n_valid))
        print(f"resumed with {int(g0.n_valid)} rows already committed")

    def cb(widx, g):
        ckpt_lib.save_graph(args.ckpt, g, int(g.n_valid), cfg.__dict__)
        print(f"  wave {widx}: checkpointed at row {int(g.n_valid)}", flush=True)

    t0 = time.time()
    if args.parallel_shards > 1:
        if args.ckpt:
            print("note: periodic wave checkpoints do not apply to parallel "
                  "builds; only the final graph is saved to --ckpt")
        g, stats = construct.build_parallel(
            x, cfg, jax.random.PRNGKey(1),
            shards=args.parallel_shards,
            refine_rounds=args.refine_rounds,
        )
    else:
        g, stats = construct.build(
            x, cfg, jax.random.PRNGKey(1),
            wave_callback=cb if args.ckpt else None,
            callback_stride=args.ckpt_every,
            initial=initial,
        )
    dt = time.time() - t0
    c = construct.scanning_rate(stats, args.n)
    mode = (f"{args.parallel_shards}-shard parallel"
            if args.parallel_shards > 1 else "sequential")
    print(f"built {args.algo.upper()} graph ({mode}): n={args.n} d={args.d} "
          f"k={args.k} metric={args.metric} in {dt:.1f}s, "
          f"scanning rate c={c:.5f}")
    if args.ckpt:
        ckpt_lib.save_graph(args.ckpt, g, args.n, cfg.__dict__)

    if args.eval:
        tids, _ = brute.brute_force_knn(
            x, x, args.k, args.metric,
            exclude_ids=jnp.arange(args.n, dtype=jnp.int32),
            dispatch="reference")
        r1 = float(brute.recall_at_k(g.nbr_ids[:, :1], tids[:, :1], 1))
        rk = float(brute.recall_at_k(g.nbr_ids, tids, args.k))
        print(f"graph recall@1={r1:.4f} recall@{args.k}={rk:.4f}")


if __name__ == "__main__":
    main()
