"""Production mesh definitions (TPU v5e-256 pods).

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any device query).
Mesh creation goes through ``kernels.compat.make_mesh`` so the
``axis_types`` API drift is handled in one place."""

from __future__ import annotations

import jax

from repro.kernels import compat


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod; (2, 16, 16) pod x data x model for 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n // 2, 2) if n % 2 == 0 and n > 1 else (n, 1)
    return compat.make_mesh(shape, axes)
