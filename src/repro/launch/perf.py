import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: compile variants of the three chosen cells on the
single-pod production mesh and record roofline deltas.

    PYTHONPATH=src python -m repro.launch.perf --cell gemma-decode
    PYTHONPATH=src python -m repro.launch.perf --cell mixtral-train
    PYTHONPATH=src python -m repro.launch.perf --cell knn-search

Each cell runs {baseline, variants...} and appends JSON records to
perf_results.json — the §Perf before/after evidence.
"""

import argparse
import dataclasses
import json
import time

import jax

from repro.configs import cells
from repro.launch import mesh as mesh_lib
from repro.launch import roofline


def measure(cell, mesh, tag):
    t0 = time.time()
    with mesh:
        comp = cells.lower(cell).compile()
    rec = roofline.analyze(comp, mesh, model_flops=cell.model_flops,
                           loop_factor=cell.loop_factor)
    rec.update(arch=cell.arch, shape=cell.shape, variant=tag,
               wall_s=round(time.time() - t0, 1), notes=cell.notes)
    print(f"[{tag}] t_comp={rec['t_compute_s']:.4f}s t_mem={rec['t_memory_s']:.4f}s "
          f"t_coll={rec['t_collective_s']:.4f}s dom={rec['dominant']} "
          f"peak={rec['bytes_per_device']/2**30:.2f}GiB "
          f"roofline_frac={rec.get('roofline_fraction', float('nan')):.4f}",
          flush=True)
    return rec


def gemma_decode(mesh):
    out = []
    out.append(measure(cells.plan("gemma3-1b", "decode_32k", mesh), mesh, "baseline-dense-cache"))
    out.append(measure(cells.plan("gemma3-1b", "decode_32k", mesh,
                                  opts={"split_cache": True}), mesh, "ring-local-cache"))
    out.append(measure(cells.plan("gemma3-1b", "long_500k", mesh), mesh, "long500k-baseline"))
    out.append(measure(cells.plan("gemma3-1b", "long_500k", mesh,
                                  opts={"split_cache": True}), mesh, "long500k-ring"))
    return out


def mixtral_train(mesh):
    out = []
    out.append(measure(cells.plan("mixtral-8x7b", "train_4k", mesh), mesh, "baseline"))
    # variant: sequence-parallel residual stream (Megatron-SP): h sharded on
    # S over 'model' between blocks -> memory + smaller boundary collectives
    from repro.models import transformer as tfm
    import repro.configs.mixtral_8x7b as mix

    orig = mix.full_config
    try:
        mix.full_config = lambda: dataclasses.replace(orig(), seq_shard=True)
        out.append(measure(cells.plan("mixtral-8x7b", "train_4k", mesh), mesh,
                           "seq-parallel-h"))
    finally:
        mix.full_config = orig
    # variant: ring cache for decode shapes rides the SWA window
    out.append(measure(cells.plan("mixtral-8x7b", "long_500k", mesh), mesh,
                       "long500k-baseline"))
    out.append(measure(cells.plan("mixtral-8x7b", "long_500k", mesh,
                                  opts={"split_cache": True}), mesh, "long500k-ring"))
    return out


def knn_search(mesh):
    out = []
    out.append(measure(cells.plan("knn-lgd", "search_4k", mesh), mesh, "baseline"))
    import repro.configs.knn_lgd as kl

    orig = kl.full_config
    try:
        # variant: bf16 candidate storage (distance accumulation stays f32)
        kl.full_config = lambda: dataclasses.replace(orig(), data_bf16=True)
        out.append(measure(cells.plan("knn-lgd", "search_4k", mesh), mesh, "bf16-data"))
    finally:
        kl.full_config = orig
    try:
        # variant: leaner beam/hash (quality measured separately on CPU)
        kl.full_config = lambda: dataclasses.replace(
            orig(), beam=24, hash_slots=1024)
        out.append(measure(cells.plan("knn-lgd", "search_4k", mesh), mesh,
                           "beam24-hash1024"))
    finally:
        kl.full_config = orig
    return out


CELLS = {
    "gemma-decode": gemma_decode,
    "mixtral-train": mixtral_train,
    "knn-search": knn_search,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--out", default="perf_results.json")
    args = ap.parse_args()
    mesh = mesh_lib.make_production_mesh(multi_pod=False)
    recs = CELLS[args.cell](mesh)
    existing = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    with open(args.out, "w") as f:
        json.dump(existing + recs, f, indent=1, default=str)
    print(f"appended {len(recs)} records to {args.out}")


if __name__ == "__main__":
    main()
