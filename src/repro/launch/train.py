"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a REAL training loop (CPU-scale by default: the smoke config) with the
full production substrate: sharded train step (pjit), deterministic
skip-ahead data, periodic checkpointing, resume-from-checkpoint.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --steps 200 --batch 8 --seq 128 --ckpt /tmp/ck --ckpt-every 50

``--full-config`` selects the published configuration (needs a real pod);
the default smoke config trains on one CPU in minutes.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import loader, recsys_data
from repro.models import sharding as sharding_lib
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.names(include_knn=False))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    mod = configs.get(args.arch)
    fam = mod.FAMILY
    key = jax.random.PRNGKey(0)

    if fam == "lm":
        from repro.models import transformer as tfm

        cfg = mod.full_config() if args.full_config else mod.smoke_config()
        params = tfm.init_params(key, cfg)
        loss = lambda p, b: tfm.loss_fn(p, b["tokens"], cfg)
        data = loader.lm_batches(args.batch, args.seq, cfg.vocab)
    elif fam == "recsys":
        from repro.models import recsys as rec

        cfg = mod.full_config() if args.full_config else mod.smoke_config()
        params = rec.init_params(key, cfg)
        loss = lambda p, b: rec.loss_fn(p, b, cfg)
        if cfg.name in ("deepfm", "xdeepfm"):
            data = loader.LoaderSpec(lambda k: recsys_data.ctr_batch(
                k, args.batch, cfg.n_sparse, cfg.vocab_per_field))
        else:
            data = loader.LoaderSpec(lambda k: recsys_data.behavior_batch(
                k, args.batch, cfg.seq_len, cfg.vocab_per_field))
    elif fam == "gnn":
        from repro.data import graphs
        from repro.models import mace as mace_lib

        cfg = mod.full_config("full_graph_sm") if args.full_config else mod.smoke_config("full_graph_sm")
        params = mace_lib.init_params(key, cfg)
        g = graphs.random_graph(jax.random.PRNGKey(1), 256, 2048, cfg.d_node_feat,
                                n_classes=cfg.n_classes)
        static_batch = dict(
            positions=jnp.zeros((256, 3)), species=jnp.zeros((256,), jnp.int32),
            senders=g.senders, receivers=g.receivers, node_feat=g.features,
            labels=g.labels,
        )
        loss = lambda p, b: mace_lib.node_class_loss(p, b, cfg)
        data = loader.LoaderSpec(lambda k: static_batch)
    else:
        raise SystemExit(f"--arch {args.arch}: use launch.build_graph for knn archs")

    ocfg = opt_lib.OptConfig(name="adamw", lr=args.lr)
    opt_state = opt_lib.init_opt_state(params, ocfg)
    step_fn = jax.jit(train_loop.make_train_step(loss, ocfg))

    start = 0
    if args.resume and args.ckpt and os.path.exists(os.path.join(args.ckpt, "manifest.json")):
        (params, opt_state), start = ckpt_lib.restore(
            args.ckpt, (params, opt_state))
        print(f"resumed from step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = data.batch(step)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            ms = {k: float(v) for k, v in m.items()}
            print(f"step {step:5d} " + " ".join(f"{k}={v:.4f}" for k, v in ms.items()),
                  flush=True)
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt, (params, opt_state), step=step + 1)
    print(f"trained {args.steps - start} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
