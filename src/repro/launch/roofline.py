"""Roofline-term extraction from a compiled (dry-run) executable.

Per (arch x shape x mesh):

  compute    = HLO_FLOPs / (chips x 197e12  bf16 FLOP/s)     [v5e MXU]
  memory     = HLO_bytes / (chips x 819e9   B/s HBM)
  collective = collective_bytes / (chips x n_links x 50e9 B/s ICI)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
there, so the optimized HLO text is parsed: we sum the *operand* sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.  Cross-pod traffic (replica groups spanning
pods on the 'pod' axis) would ride DCN, but at this granularity we charge
everything to ICI — a conservative (pessimistic-for-us) collective term.

MODEL_FLOPS (6·N·D style) versus HLO_FLOPs gives the useful-compute ratio —
values << 1 flag remat recompute or redundant work; values > 1 flag an
analytical undercount (documented per cell).
"""

from __future__ import annotations

import re
from typing import Optional

import numpy as np

PEAK_FLOPS = 197e12  # bf16 per chip, TPU v5e
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
ICI_LINKS = 4  # v5e: 4 ICI links per chip (2D torus, 2 axes x 2 directions)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_OP_RE = re.compile(
    r"=\s*(?P<result>.*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<variant>-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shapes_in(type_str: str):
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out.append(n * _DTYPE_BYTES[dtype])
    return out


def collective_bytes(hlo_text: str) -> dict:
    """Per-device interconnect traffic from the optimized (post-SPMD) HLO.

    Shapes in partitioned HLO are device-local.  Conventions (ring algos,
    g = replica-group size):
      all-gather        : result bytes x (g-1)/g     (received)
      all-reduce        : 2 x bytes x (g-1)/g        (reduce-scatter + AG)
      reduce-scatter    : result bytes x (g-1)       (sends everyone's shard)
      all-to-all        : bytes x (g-1)/g            (keeps own shard)
      collective-permute: result bytes
    '-done' variants are skipped (the '-start' op carries the shapes).
    Returns {kind: bytes, '_total': ..., '_count': n_ops}.
    """
    out: dict = {}
    n_ops = 0
    for line in hlo_text.splitlines():
        m = _COLL_OP_RE.search(line)
        if not m:
            continue
        if m.group("variant") == "-done":
            continue
        kind = m.group("kind")
        shapes = _shapes_in(m.group("result"))
        if not shapes:
            continue
        # -start ops return (operand_alias, output, ...): use the largest
        b = max(shapes) if m.group("variant") else sum(shapes)
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else 2
        g = max(g, 2)
        if kind == "all-gather":
            traffic = b * (g - 1) / g
        elif kind == "all-reduce":
            traffic = 2.0 * b * (g - 1) / g
        elif kind == "reduce-scatter":
            traffic = b * (g - 1)
        elif kind == "all-to-all":
            traffic = b * (g - 1) / g
        else:  # collective-permute
            traffic = float(b)
        out[kind] = out.get(kind, 0.0) + traffic
        n_ops += 1
    out["_total"] = sum(v for k, v in out.items() if not k.startswith("_"))
    out["_count"] = n_ops
    return out


def analyze(compiled, mesh, model_flops: Optional[float] = None,
            loop_factor: float = 1.0) -> dict:
    """Roofline record for one compiled cell.

    ``loop_factor`` corrects while-loop-dominated programs (cost_analysis
    counts loop bodies once; the EHC search loop runs ~max_iters times).
    """
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0)) * loop_factor
    # bytes accessed: sum the per-memory-space entries when present
    byts = float(cost.get("bytes accessed", 0.0)) * loop_factor
    mem = compiled.memory_analysis()
    # peak live-buffer footprint (what must fit HBM); arguments reported
    # separately (params/opt state are resident across steps)
    bytes_per_dev = int(getattr(mem, "peak_memory_in_bytes", 0))
    arg_bytes = int(getattr(mem, "argument_size_in_bytes", 0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # cost_analysis flops are whole-program per-device on SPMD-partitioned HLO
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll["_total"] / (ICI_LINKS * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    rec = {
        "chips": chips,
        "hlo_gflops": flops / 1e9,
        "hlo_gbytes": byts / 1e9,
        "collective_gbytes": coll["_total"] / 1e9,
        "collective_breakdown": {k: v for k, v in coll.items() if not k.startswith("_")},
        "bytes_per_device": bytes_per_dev,
        "arg_bytes_per_device": arg_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_time_bound_s": max(terms.values()),
    }
    if model_flops:
        # model_flops is whole-job; HLO flops are per-device
        rec["model_flops"] = model_flops
        rec["useful_ratio"] = model_flops / chips / max(flops, 1.0)
        peak_time = model_flops / chips / PEAK_FLOPS
        rec["roofline_fraction"] = peak_time / max(max(terms.values()), 1e-30)
    return rec
