"""Paper Table III + Fig. 7: scanning rate & graph quality per dataset type.

Real datasets are offline-unavailable; calibrated synthetic stand-ins with
matched (d, metric, intrinsic-dimension regime) are used — DESIGN.md §8.6:
  SIFT-like  = clustered d=128 l2      GloVe-like = heavy_tailed d=100 cosine
  NUSW-like  = histogram d=500 chi2    Rand       = uniform d=100 l2
"""

from __future__ import annotations

import argparse

import jax

from benchmarks import common
from repro.core import construct, nndescent

DATASETS = [
    ("SIFT-like", "clustered", 128, "l2"),
    ("GloVe-like", "heavy_tailed", 100, "cosine"),
    ("NUSW-like", "histogram", 500, "chi2"),
    ("Rand", "uniform", 100, "l2"),
]


def run(n: int = 10_000, k: int = 20, seed: int = 0, datasets=DATASETS):
    tbl = common.Table(
        "datasets: scanning rate + graph recall (Table III / Fig 7)",
        ["dataset", "metric", "algo", "recall@1", "recall@10", "scan_rate"],
    )
    for name, kind, d, metric in datasets:
        x = common.dataset(kind, n, d, seed)
        true_ids = common.ground_truth(x, x, k + 1, metric)[:, 1:]
        for algo, lgd in (("OLG", False), ("LGD", True)):
            cfg = construct.BuildConfig(
                k=k, metric=metric, wave=256, lgd=lgd, beam=max(k, 40),
                n_seeds=8, dispatch="reference",
            )
            g, stats = construct.build(x, cfg, jax.random.PRNGKey(seed))
            tbl.add(
                name, metric, algo,
                common.graph_recall(g, true_ids, 1),
                common.graph_recall(g, true_ids, 10),
                construct.scanning_rate(stats, n),
            )
        ncfg = nndescent.NNDescentConfig(
            k=k, metric=metric, max_iters=10, use_pallas=False, node_chunk=1024
        )
        g, st = nndescent.build(x, ncfg, jax.random.PRNGKey(seed))
        tbl.add(
            name, metric, "NN-Desc",
            common.graph_recall(g, true_ids, 1),
            common.graph_recall(g, true_ids, 10),
            st["scanning_rate"],
        )
    tbl.show()
    return tbl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(2000 if args.quick else args.n,
        datasets=DATASETS[:2] if args.quick else DATASETS)


if __name__ == "__main__":
    main()
