"""Paper Table IV: brute-force (exhaustive) search timing per dataset.

The absolute times define the speed-up denominators of Fig. 9/10; reported
per dataset stand-in at the harness scale (scale with --n)."""

from __future__ import annotations

import argparse

from benchmarks import common
from repro.core import brute

DATASETS = [
    ("SIFT-like", "clustered", 128, "l2", 1024),
    ("GloVe-like", "heavy_tailed", 100, "cosine", 256),
    ("NUSW-like", "histogram", 500, "chi2", 256),
    ("Rand", "uniform", 100, "l2", 256),
]


def run(n: int = 10_000, seed: int = 0, datasets=DATASETS):
    tbl = common.Table(
        "brute force timing (Table IV)",
        ["dataset", "metric", "n", "n_q", "total_s", "ms/query"],
    )
    for name, kind, d, metric, n_q in datasets:
        x = common.dataset(kind, n, d, seed)
        q = common.dataset(kind, n_q, d, seed + 1)
        t = common.timeit(
            lambda: brute.brute_force_knn(x, q, 10, metric, use_pallas=False), iters=2
        )
        tbl.add(name, metric, n, n_q, t, 1e3 * t / n_q)
    tbl.show()
    return tbl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(2000 if args.quick else args.n,
        datasets=DATASETS[:2] if args.quick else DATASETS)


if __name__ == "__main__":
    main()
