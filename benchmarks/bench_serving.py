"""Sustained-load serving benchmark: the instrumented ServingLoop under CI.

    PYTHONPATH=src python -m benchmarks.bench_serving

The paper's central claim is *online* operation — the graph answers queries
while it is being churned — and ``serve.loop.ServingLoop`` is the serving
front end that exercises it: queries arrive in bursts, are coalesced into
pow2-bucketed waves, and churn (insert + remove) lands between waves.  This
benchmark drives that loop under a sustained arrival pattern and emits the
``serving_load`` record:

  * ``recall_at_10``     — fresh-search recall of the loop's query reservoir
    against alive-aware brute force on the post-churn index.  HARD CI gate
    (floor in baseline_ci.json): a serving path that degrades recall has
    lost the paper's property regardless of its speed.
  * ``p50/p99_latency_ms``, ``qps`` — enqueue→synced-result percentiles and
    sustained throughput.  Wall-clock on shared CI runners is too noisy to
    floor, so these are *recorded* — the in-repo trajectory every later perf
    PR reads — and only their SHAPE is gated:
  * ``p99_p50_ratio``    — sanity ceiling.  The loop serves a steady
    synthetic arrival pattern with warm caches; a p99 hundreds of times p50
    means the measurement is broken (compile inside the timed window, a
    stray host sync in the hot path), not that the machine is slow.  The
    ceiling is deliberately generous — it polices the harness, not the
    hardware.

Churn here is deliberately light (~1.5% of the catalog per churn event):
the churn-torture number lives in ``bench_lifecycle`` (whose 0.90 floor
reflects 19%-of-catalog churn); serving measures steady-state quality, so
its floor holds at 0.95.

A ``JsonlTracker`` trace (spans + per-wave metrics) is written next to the
CI artifact when ``--trace`` / ``trace_path`` is given; the bench-smoke job
uploads it alongside BENCH_ci.json.
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import construct
from repro.index import OnlineIndex
from repro.obs import JsonlTracker
from repro.serve.loop import ServeLoopConfig, ServingLoop


def serving_bench(
    n: int = 4096,
    d: int = 20,
    k: int = 20,
    rounds: int = 24,
    burst: int = 40,
    churn: int = 16,
    churn_every: int = 4,
    top_k: int = 10,
    beam: int = 64,
    max_batch: int = 64,
    seed: int = 0,
    trace_path: Optional[str] = None,
) -> dict:
    """Drive a ServingLoop under sustained load; see module doc.

    Each round submits a ``burst`` of queries and pumps the loop; every
    ``churn_every``-th round also removes ``churn`` random live rows and
    inserts ``churn`` fresh ones (buffered — the loop flushes them at the
    next wave boundary, which is what the interleave is supposed to absorb).
    An untimed warm-up round compiles every shape on the path; the measured
    window starts from a ``reset_window``.
    """
    n_churn_events = rounds // churn_every + 1
    pool = common.dataset("uniform", n + n_churn_events * churn, d, seed)
    base, fresh = pool[:n], pool[n:]
    queries = common.dataset("uniform", (rounds + 1) * burst, d, seed + 1)
    cfg = construct.BuildConfig(
        k=k, metric="l2", wave=256, lgd=True, beam=40, n_seeds=8,
        dispatch="reference",
    )
    t0 = time.perf_counter()
    idx = OnlineIndex.build(base, cfg, key=jax.random.PRNGKey(seed))
    t_build = time.perf_counter() - t0

    tracker = None
    if trace_path:
        tracker = JsonlTracker(
            trace_path,
            run_meta={**common.run_meta(), "bench": "serving_load", "n": n},
        )
    loop = ServingLoop(
        idx,
        ServeLoopConfig(
            top_k=top_k, beam=beam, max_batch=max_batch,
            recall_reservoir=96, recall_sample_every=5,
        ),
        tracker=tracker,
        seed=seed + 2,
    )
    rng = np.random.RandomState(seed)

    def round_(r: int, with_churn: bool):
        if with_churn:
            alive = np.flatnonzero(np.asarray(idx.graph.alive))
            victims = rng.choice(alive, churn, replace=False)
            loop.remove(jnp.asarray(victims, jnp.int32))
            loop.add(fresh[r * churn : (r + 1) * churn])
        loop.submit(queries[r * burst : (r + 1) * burst])
        loop.pump()

    # warm-up: compiles the search at every pow2 bucket the bursts hit plus
    # the churn path, so the measured window holds steady-state costs only
    round_(0, with_churn=True)
    loop.pump()
    loop.reset_window()

    churn_events = 0
    for r in range(1, rounds + 1):
        with_churn = r % churn_every == 0
        churn_events += int(with_churn)
        round_(r, with_churn)

    rec = loop.report(audit_k=10)
    p50, p99 = rec["p50_latency_ms"], rec["p99_latency_ms"]
    out = {
        "n": n, "d": d, "rounds": rounds, "burst": burst,
        "churn": churn, "churn_events": churn_events,
        "top_k": top_k, "beam": beam, "max_batch": max_batch,
        "t_build_s": t_build,
        "n_served": rec["n_served"],
        "n_waves": rec["n_waves"],
        "qps": rec["qps"],
        "p50_latency_ms": p50,
        "p99_latency_ms": p99,
        "p99_p50_ratio": p99 / p50 if p50 > 0 else 0.0,
        "comps_per_query": rec["comps_per_query"],
        "scanning_rate": rec["scanning_rate"],
        "hash_saturation_ratio": rec["hash_saturation_ratio"],
        "recall_at_10": rec["recall_at_10"],
        "recall_at_10_served": rec["recall_at_10_served"],
        "n_audited": rec["n_audited"],
    }
    if tracker is not None:
        tracker.log_metrics({f"record/{k_}": v for k_, v in out.items()})
        tracker.finish()
    return out


def serving_gate(
    n: int = 2048, d: int = 20, seed: int = 0,
    trace_path: Optional[str] = None,
) -> dict:
    """The canonical CI sustained-load measurement.  ``benchmarks.ci_gate``
    fails the benchmark-smoke job when ``recall_at_10`` drops below
    ``serving_recall_at_10_min`` or ``p99_p50_ratio`` exceeds
    ``serving_p99_p50_ratio_max`` (baseline_ci.json); latency/QPS are
    recorded ungated.

    Shape rationale: n≈2k/d=20 matches the build-quality and churn gates so
    the three recalls are comparable, and the loop over-searches at
    ``top_k=32`` while the audit scores recall@10 — the EHC termination
    horizon is the search k (beam width beyond it does not change the walk),
    so serving quality is bought with a deeper walk, the same
    over-search-then-cut protocol ``bench_lifecycle`` gates.  Measured on
    the reference setup: k=20 walks hold ~0.94 recall@10 under this churn
    (the churn gate's regime), k=32 walks ~0.99 at ~1.4x the comps — the
    0.95 floor then has real headroom instead of sitting on the measurement."""
    return serving_bench(
        n=n, d=d, seed=seed, top_k=32, trace_path=trace_path
    )


def run(n: int = 4096, trace: Optional[str] = None, **kw):
    tbl = common.Table(
        "serving: sustained load (pow2-coalesced waves + interleaved churn)",
        ["n", "served", "waves", "qps", "p50_ms", "p99_ms", "recall@10",
         "scan_rate"],
    )
    rec = serving_bench(n=n, trace_path=trace, **kw)
    tbl.add(rec["n"], rec["n_served"], rec["n_waves"], rec["qps"],
            rec["p50_latency_ms"], rec["p99_latency_ms"],
            rec["recall_at_10"], rec["scanning_rate"])
    tbl.show()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--trace", type=str, default=None,
                    help="write a JsonlTracker trace to this path")
    args = ap.parse_args()
    run(args.n, rounds=args.rounds, trace=args.trace)


if __name__ == "__main__":
    main()
