"""Paper Fig. 6/7 + Table II: graph quality vs dimension at matched scanning
rates, OLG / LGD / NN-Descent, l1 and l2.

Synthetic uniform data (intrinsic dim == d), the paper's Rand100K protocol at
CPU-scale n (default 10k; --n scales up).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import construct, nndescent

DIMS = (2, 5, 10, 20)


def run(n: int = 10_000, dims=DIMS, metrics=("l2", "l1"), k: int = 10, seed: int = 0):
    tbl = common.Table(
        "construction: recall vs dim at matched scanning rate (Fig 6/7, Table II)",
        ["metric", "d", "algo", "recall@1", "recall@10", "scan_rate"],
    )
    for metric in metrics:
        for d in dims:
            x = common.dataset("uniform", n, d, seed)
            true_ids = common.ground_truth(x, x, k + 1, metric)[:, 1:]  # drop self

            kk = min(max(d, 10), 50)  # paper: k close to dim, <= 50
            bcfg = construct.BuildConfig(
                k=kk, metric=metric, wave=256, beam=max(kk, 20),
                n_seeds=8, use_pallas=False,
            )
            for name, lgd in (("OLG", False), ("LGD", True)):
                cfg = construct.BuildConfig(**{**bcfg.__dict__, "lgd": lgd})
                g, stats = construct.build(x, cfg, jax.random.PRNGKey(seed))
                c = construct.scanning_rate(stats, n)
                r1 = common.graph_recall(g, true_ids, 1)
                r10 = common.graph_recall(g, true_ids, min(10, kk))
                tbl.add(metric, d, name, r1, r10, c)

            ncfg = nndescent.NNDescentConfig(
                k=kk, metric=metric, max_iters=10, use_pallas=False, node_chunk=1024
            )
            g, st = nndescent.build(x, ncfg, jax.random.PRNGKey(seed))
            r1 = common.graph_recall(g, true_ids, 1)
            r10 = common.graph_recall(g, true_ids, min(10, kk))
            tbl.add(metric, d, "NN-Desc", r1, r10, st["scanning_rate"])
    tbl.show()
    return tbl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--dims", type=int, nargs="+", default=list(DIMS))
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    dims = args.dims[:2] if args.quick else args.dims
    run(args.n if not args.quick else 2000, dims)


if __name__ == "__main__":
    main()
