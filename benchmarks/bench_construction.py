"""Paper Fig. 6/7 + Table II: graph quality vs dimension at matched scanning
rates, OLG / LGD / NN-Descent, l1 and l2 — plus wave throughput of the fused
jit pipeline.

Synthetic uniform data (intrinsic dim == d), the paper's Rand100K protocol at
CPU-scale n (default 10k; --n scales up).

The construction timing runs on the fused ``construct.wave_step`` loop: the
whole build executes as one compiled call per wave with a device-side stats
carry, so the host syncs at most once per ``wave_callback`` stride (default:
no callback, i.e. a single sync when the final stats are read).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import brute, construct, nndescent

DIMS = (2, 5, 10, 20)


def timed_build(x, cfg, seed: int, callback_stride: int = 0):
    """Build on the fused wave pipeline; returns (graph, stats, seconds,
    waves/sec).  ``callback_stride > 0`` installs a progress callback at that
    stride — the only per-stride host sync; 0 syncs once, at the end."""
    n = x.shape[0]
    kwargs = {}
    if callback_stride > 0:
        kwargs = {
            "wave_callback": lambda i, g: jax.block_until_ready(g.n_valid),
            "callback_stride": callback_stride,
        }
    # warm the jit caches at the REAL shapes (jit keys on shapes, so a small
    # prefix would not hit): one seed graph + one wave_step over the full x,
    # then the timed run measures steady-state wave throughput
    n_seed = min(cfg.n_seed_init, n)
    g0 = brute.exact_seed_graph(
        x, n_seed, cfg.k, cfg.metric, rev_capacity=cfg.rev_cap,
        dispatch=cfg.dispatch,
    )
    jax.block_until_ready(
        construct.wave_step(
            g0, x, jnp.asarray(n_seed, jnp.int32), jax.random.PRNGKey(seed),
            construct.zero_stats(), cfg,
        )[0]
    )
    t0 = time.perf_counter()
    g, stats = construct.build(x, cfg, jax.random.PRNGKey(seed), **kwargs)
    jax.block_until_ready(g)
    dt = time.perf_counter() - t0
    n_waves = int(stats.n_waves)
    return g, stats, dt, (n_waves / dt if dt > 0 else float("inf"))


def quality_gate(n: int = 2000, d: int = 20, seed: int = 0) -> dict:
    """The canonical CI quality measurement: LGD build recall@10 on uniform
    data at a fixed shape.  ``benchmarks.ci_gate`` fails the benchmark-smoke
    job when this regresses below the committed baseline
    (benchmarks/baseline_ci.json)."""
    x = common.dataset("uniform", n, d, seed)
    true_ids = common.ground_truth(x, x, 11, "l2")[:, 1:]  # drop self
    cfg = construct.BuildConfig(
        k=20, metric="l2", wave=256, beam=40, n_seeds=8, lgd=True,
        dispatch="reference",
    )
    g, stats = construct.build(x, cfg, jax.random.PRNGKey(seed))
    return {
        "n": n, "d": d, "k": 10,
        "recall_at_10": common.graph_recall(g, true_ids, 10),
        "scanning_rate": construct.scanning_rate(stats, n),
    }


def merge_build_gate(
    n: int = 2000, d: int = 20, seed: int = 0, shards: int = 2
) -> dict:
    """The canonical CI record for the divide-and-conquer build path.

    Same shape as ``quality_gate`` (n=2000/d=20, LGD) so the two floors are
    directly comparable: ``recall_at_10`` of the merged+refined parallel
    build is GATED at the sequential build-quality floor; the wall-clock
    ratio vs the sequential build rides along UNGATED (shared 2-core CI
    runners give host threads little genuine overlap — the ratio is
    informational there and meaningful on real multi-core/multi-device
    hosts).  Both pipelines are warmed at the measured shapes first, so the
    ratio compares steady-state builds, not compile time.
    """
    x = common.dataset("uniform", n, d, seed)
    true_ids = common.ground_truth(x, x, 11, "l2")[:, 1:]  # drop self
    cfg = construct.BuildConfig(
        k=20, metric="l2", wave=256, beam=40, n_seeds=8, lgd=True,
        dispatch="reference",
    )

    def seq():
        g, _ = construct.build(x, cfg, jax.random.PRNGKey(seed))
        return g

    def par():
        g, _ = construct.build_parallel(
            x, cfg, jax.random.PRNGKey(seed), shards=shards, refine_rounds=1
        )
        return g

    # warm the jit caches of both pipelines at the real shapes
    jax.block_until_ready(seq().nbr_ids)
    jax.block_until_ready(par().nbr_ids)
    t0 = time.perf_counter()
    g_seq = seq()
    jax.block_until_ready(g_seq.nbr_ids)
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    g_par = par()
    jax.block_until_ready(g_par.nbr_ids)
    t_par = time.perf_counter() - t0
    return {
        "n": n, "d": d, "k": 10, "shards": shards,
        "recall_at_10": common.graph_recall(g_par, true_ids, 10),
        "recall_at_10_seq": common.graph_recall(g_seq, true_ids, 10),
        "build_s_seq": t_seq,
        "build_s_par": t_par,
        "wallclock_ratio": t_par / t_seq if t_seq > 0 else float("inf"),
    }


def parallel_gate(
    n: int = 4000, d: int = 20, seed: int = 0, shards: int = 2
) -> dict:
    """The two-sided CI record for ``build_parallel`` vs ``build``.

    Unlike ``merge_build_gate`` (same cfg both sides, ratio informational),
    this gate runs the parallel path the way it is meant to be run — light
    sub-builds (``sub_cfg``: capped insertion-search iterations, coarse
    seeding so leaf levels exist) folded by shallow coarse-seeded cross
    searches (``merge_scfg``: beam == k, few EHC iterations) widened by the
    second-hop proposals — and gates BOTH sides of the bargain:

      * ``recall_at_10`` >= the sequential quality floor (0.95): the cheap
        path may not cost quality.  Deterministic at the pinned seed.
      * ``wallclock_ratio`` < 1.0: the parallel build must actually beat
        the sequential build wall-clock, even on a single core, because it
        does LESS TOTAL WORK — sub-builds cap their search depth and the
        merge repairs boundary and interior alike.  Timed as the median of
        5 alternating warmed runs so scheduler hiccups cannot flip the
        gate; ``run_meta()`` stamps the host CPU count so records from
        multi-core runners (where thread overlap widens the gap) stay
        interpretable.
    """
    x = common.dataset("uniform", n, d, seed)
    true_ids = common.ground_truth(x, x, 11, "l2")[:, 1:]  # drop self
    cfg = construct.BuildConfig(
        k=20, metric="l2", wave=256, beam=40, n_seeds=8, lgd=True,
        dispatch="reference",
    )
    sub_cfg = dataclasses.replace(
        cfg, max_iters=12, seed_mode="coarse", coarse_landmarks=64,
        coarse_members=8,
    )
    merge_scfg = dataclasses.replace(
        cfg.search_config(), beam=cfg.k, max_iters=4,
        coarse_beam=8, coarse_iters=4,
    )

    def seq():
        g, _ = construct.build(x, cfg, jax.random.PRNGKey(seed))
        return g

    def par():
        g, _ = construct.build_parallel(
            x, cfg, jax.random.PRNGKey(seed), shards=shards,
            refine_rounds=0, search_chunk=1024,
            sub_cfg=sub_cfg, merge_scfg=merge_scfg,
        )
        return g

    # warm both pipelines at the real shapes, then alternate timed runs
    jax.block_until_ready(seq().nbr_ids)
    jax.block_until_ready(par().nbr_ids)
    t_seq, t_par = [], []
    for _ in range(5):
        t0 = time.perf_counter()
        g_seq = seq()
        jax.block_until_ready(g_seq.nbr_ids)
        t_seq.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        g_par = par()
        jax.block_until_ready(g_par.nbr_ids)
        t_par.append(time.perf_counter() - t0)
    med = lambda ts: sorted(ts)[len(ts) // 2]
    return {
        "n": n, "d": d, "k": 10, "shards": shards,
        "recall_at_10": common.graph_recall(g_par, true_ids, 10),
        "recall_at_10_seq": common.graph_recall(g_seq, true_ids, 10),
        "build_s_seq": med(t_seq),
        "build_s_par": med(t_par),
        "build_s_seq_all": t_seq,
        "build_s_par_all": t_par,
        "wallclock_ratio": med(t_par) / med(t_seq) if med(t_seq) > 0
        else float("inf"),
    }


def run(n: int = 10_000, dims=DIMS, metrics=("l2", "l1"), k: int = 10, seed: int = 0):
    tbl = common.Table(
        "construction: recall vs dim at matched scanning rate (Fig 6/7, Table II)",
        ["metric", "d", "algo", "recall@1", "recall@10", "scan_rate",
         "build_s", "waves_per_s", "pts_per_s"],
    )
    for metric in metrics:
        for d in dims:
            x = common.dataset("uniform", n, d, seed)
            true_ids = common.ground_truth(x, x, k + 1, metric)[:, 1:]  # drop self

            kk = min(max(d, 10), 50)  # paper: k close to dim, <= 50
            bcfg = construct.BuildConfig(
                k=kk, metric=metric, wave=256, beam=max(kk, 20),
                n_seeds=8, dispatch="reference",
            )
            for name, lgd in (("OLG", False), ("LGD", True)):
                cfg = construct.BuildConfig(**{**bcfg.__dict__, "lgd": lgd})
                g, stats, dt, wps = timed_build(x, cfg, seed)
                c = construct.scanning_rate(stats, n)
                r1 = common.graph_recall(g, true_ids, 1)
                r10 = common.graph_recall(g, true_ids, min(10, kk))
                tbl.add(metric, d, name, r1, r10, c, dt, wps, wps * cfg.wave)

            ncfg = nndescent.NNDescentConfig(
                k=kk, metric=metric, max_iters=10, use_pallas=False, node_chunk=1024
            )
            # one-iteration warm-up at the same shapes compiles the join round
            jax.block_until_ready(
                nndescent.build(
                    x, dataclasses.replace(ncfg, max_iters=1),
                    jax.random.PRNGKey(seed),
                )[0]
            )
            t0 = time.perf_counter()
            g, st = nndescent.build(x, ncfg, jax.random.PRNGKey(seed))
            jax.block_until_ready(g)
            dt = time.perf_counter() - t0
            r1 = common.graph_recall(g, true_ids, 1)
            r10 = common.graph_recall(g, true_ids, min(10, kk))
            tbl.add(metric, d, "NN-Desc", r1, r10, st["scanning_rate"],
                    dt, float("nan"), n / dt if dt > 0 else float("inf"))
    tbl.show()
    return tbl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--dims", type=int, nargs="+", default=list(DIMS))
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    dims = args.dims[:2] if args.quick else args.dims
    run(args.n if not args.quick else 2000, dims)


if __name__ == "__main__":
    main()
