"""Paper §IV-D: NN-Descent-style local-join refinement of an online graph.

Shows the recall recovered per refinement round and its scanning-rate cost
(the trade the paper describes: 'a trade-off between efficiency and graph
quality')."""

from __future__ import annotations

import argparse

import jax

from benchmarks import common
from repro.core import construct, nndescent


def run(n: int = 10_000, d: int = 32, k: int = 20, seed: int = 0, rounds: int = 3):
    x = common.dataset("uniform", n, d, seed)
    true_ids = common.ground_truth(x, x, k + 1, "l2")[:, 1:]
    cfg = construct.BuildConfig(
        k=k, metric="l2", wave=256, lgd=True, beam=max(k, 40), dispatch="reference"
    )
    g, stats = construct.build(x, cfg, jax.random.PRNGKey(seed))
    c0 = construct.scanning_rate(stats, n)

    tbl = common.Table(
        "refinement: local-join rounds on the LGD graph (sec IV-D)",
        ["round", "recall@1", "recall@10", "cum_scan_rate"],
    )
    tbl.add(0, common.graph_recall(g, true_ids, 1),
            common.graph_recall(g, true_ids, 10), c0)
    total = c0 * (n * (n - 1) / 2)
    for r in range(1, rounds + 1):
        g, comps = nndescent.local_join_refine(g, x, "l2", rounds=1, node_chunk=1024)
        total += comps
        tbl.add(r, common.graph_recall(g, true_ids, 1),
                common.graph_recall(g, true_ids, 10),
                total / (n * (n - 1) / 2))
    tbl.show()
    return tbl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(2000 if args.quick else args.n, rounds=1 if args.quick else 3)


if __name__ == "__main__":
    main()
