"""Shared benchmark utilities: timing, ground truth, CSV emit.

Scale note: the paper benchmarks 100K-10M points on a 3.6GHz workstation
over hours; this harness defaults to CPU-friendly sizes (n=10-20k) so the
whole suite runs in minutes, and every entry point takes --n/--d to scale to
the paper's sizes on real hardware.  Quality metrics (recall, scanning rate)
are size-comparable; wall-clock speed-ups are reported against brute force
measured on the SAME machine, mirroring the paper's protocol (Table IV).
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import brute
from repro.data import synthetic

_DATA_CACHE: Dict = {}


def dataset(kind: str, n: int, d: int, seed: int = 0) -> jax.Array:
    key = (kind, n, d, seed)
    if key not in _DATA_CACHE:
        _DATA_CACHE[key] = synthetic.make(kind, jax.random.PRNGKey(seed), n, d)
    return _DATA_CACHE[key]


def dataset_with_queries(kind: str, n: int, n_q: int, d: int, seed: int = 0):
    """(reference set, query set) from ONE draw — queries share the data
    manifold (the paper's protocol: query sets are held-out samples of the
    same distribution, not an independent distribution)."""
    full = dataset(kind, n + n_q, d, seed)
    return full[:n], full[n:]


def ground_truth(x, q, k: int, metric: str):
    ids, _ = brute.brute_force_knn(x, q, k, metric, use_pallas=False)
    return jax.device_get(ids)


def graph_recall(g, true_ids, k: int) -> float:
    pred = jax.device_get(g.nbr_ids[: true_ids.shape[0], :k])
    hits = 0
    for i in range(true_ids.shape[0]):
        hits += len(set(pred[i]) & set(true_ids[i][:k]) - {-1})
    return hits / (true_ids.shape[0] * k)


def search_recall(pred_ids, true_ids, k: int) -> float:
    pred = np.asarray(pred_ids)[:, :k]
    hits = 0
    for i in range(pred.shape[0]):
        hits += len(set(pred[i].tolist()) & set(true_ids[i][:k].tolist()) - {-1})
    return hits / (pred.shape[0] * k)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3,
           reduce: str = "median") -> float:
    """Wall seconds of fn(*args) with jax sync.

    ``reduce="median"`` (default) for macro timings; ``"min"`` for
    microbenchmarks on shared/noisy machines (e.g. CI runners), where the
    minimum is the least-contended estimate of the true cost.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts) if reduce == "min" else np.median(ts))


class Table:
    """Collects rows and prints an aligned table + CSV line format."""

    def __init__(self, name: str, columns: List[str]):
        self.name = name
        self.columns = columns
        self.rows: List[list] = []

    def add(self, *vals):
        assert len(vals) == len(self.columns)
        self.rows.append(list(vals))

    def records(self) -> List[dict]:
        """Rows as JSON-ready dicts (the machine-readable emit path)."""
        return [
            {c: _jsonable(v) for c, v in zip(self.columns, row)}
            for row in self.rows
        ]

    def show(self) -> str:
        out = [f"== {self.name} =="]
        widths = [
            max(len(str(c)), *(len(_fmt(r[i])) for r in self.rows)) if self.rows else len(str(c))
            for i, c in enumerate(self.columns)
        ]
        out.append("  ".join(str(c).ljust(w) for c, w in zip(self.columns, widths)))
        for r in self.rows:
            out.append("  ".join(_fmt(v).ljust(w) for v, w in zip(r, widths)))
        s = "\n".join(out)
        print(s, flush=True)
        return s


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


# ---------------------------------------------------------------------------
# Machine-readable emit (the CI benchmark artifact)
# ---------------------------------------------------------------------------


def _jsonable(v):
    """Coerce numpy/jax scalars to plain python for json.dump."""
    if isinstance(v, (jax.Array, np.ndarray, np.generic)):
        arr = np.asarray(v)
        if arr.ndim == 0:
            return arr.item()
        return arr.tolist()
    return v


def _git_sha() -> str:
    """Current commit SHA, or "unknown" outside a git checkout (artifact
    tarballs, pip installs) — provenance must never fail a benchmark run."""
    import os
    import subprocess

    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5,
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def run_meta() -> dict:
    """Provenance stamped into every emitted benchmark file: records from
    different machines/commits are comparable only if each says where it
    came from (jax version, backend, device count, commit)."""
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        # wall-clock records (e.g. parallel_gate's wallclock_ratio) read
        # differently on 1-core vs multi-core hosts — stamp the count so
        # ratio records stay interpretable across runners
        "host_cpus": os.cpu_count(),
        "git_sha": _git_sha(),
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }


def emit_json(path: str, payload: dict) -> str:
    """Write a benchmark payload as JSON (e.g. BENCH_ci.json for the CI
    benchmark-smoke job).  Adds a ``meta`` provenance block; returns path."""
    def _default(o):
        coerced = _jsonable(o)
        return coerced if coerced is not o else str(o)

    doc = {"meta": run_meta()}
    doc.update({k: _jsonable(v) for k, v in payload.items()})
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True, default=_default)
        f.write("\n")
    return path


def load_json(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
