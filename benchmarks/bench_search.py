"""Paper Fig. 9/10: NN-search recall vs speed-up over brute force.

OLG / LGD (update ops off — the paper's protocol) vs NN-Descent-graph search,
sweeping the beam width to trace the recall/speed-up curve.  Speed-up
denominator is brute force timed on the SAME machine (Table IV protocol).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import brute, construct, nndescent
from repro.core import search as search_lib

DATASETS = [
    ("SIFT-like", "clustered", 128, "l2"),
    ("GloVe-like", "heavy_tailed", 100, "cosine"),
    ("Rand", "uniform", 100, "l2"),
]


def run(n: int = 10_000, n_q: int = 256, k: int = 20, seed: int = 0, datasets=DATASETS):
    tbl = common.Table(
        "search: recall@1 vs speed-up over brute force (Fig 9/10)",
        ["dataset", "graph", "beam", "recall@1", "speedup", "ms/query"],
    )
    for name, kind, d, metric in datasets:
        x, q = common.dataset_with_queries(kind, n, n_q, d, seed)
        true_ids = common.ground_truth(x, q, 1, metric)
        t_brute = common.timeit(
            lambda: brute.brute_force_knn(x, q, 1, metric, use_pallas=False), iters=2
        )

        graphs = {}
        for algo, lgd in (("OLG", False), ("LGD", True)):
            cfg = construct.BuildConfig(
                k=k, metric=metric, wave=256, lgd=lgd, beam=max(k, 40),
                n_seeds=8, use_pallas=False,
            )
            graphs[algo], _ = construct.build(x, cfg, jax.random.PRNGKey(seed))
        ncfg = nndescent.NNDescentConfig(
            k=k, metric=metric, max_iters=10, use_pallas=False, node_chunk=1024
        )
        graphs["NN-Desc"], _ = nndescent.build(x, ncfg, jax.random.PRNGKey(seed))

        for gname, g in graphs.items():
            for beam in (8, 16, 32, 64):
                scfg = search_lib.SearchConfig(
                    k=beam, beam=beam, n_seeds=8, metric=metric,
                    use_lgd_mask=(gname == "LGD"), use_pallas=False,
                )
                fn = lambda: search_lib.search(g, x, q, jax.random.PRNGKey(3), scfg)
                t = common.timeit(fn, iters=2)
                res = fn()
                rec = common.search_recall(jax.device_get(res.ids), true_ids, 1)
                tbl.add(name, gname, beam, rec, t_brute / t, 1e3 * t / n_q)
    tbl.show()
    return tbl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(2000 if args.quick else args.n,
        datasets=DATASETS[:1] if args.quick else DATASETS)


if __name__ == "__main__":
    main()
