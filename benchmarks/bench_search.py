"""Paper Fig. 9/10: NN-search recall vs speed-up over brute force — plus the
fused-vs-unfused EHC expansion-step microbenchmark.

OLG / LGD (update ops off — the paper's protocol) vs NN-Descent-graph search,
sweeping the beam width to trace the recall/speed-up curve.  Speed-up
denominator is brute force timed on the SAME machine (Table IV protocol).

``expansion_bench`` isolates the Alg. 1/3 inner loop the fused Pallas kernel
targets: one EHC expansion per iteration, fused (a single compiled call —
the Pallas kernel on TPU, the XLA-fused reference elsewhere) vs unfused (the
same op chain as six separately-compiled stages with host dispatch between
them, i.e. the pre-fusion execution shape).  Its record lands in
``BENCH_ci.json`` and gates CI (benchmarks.ci_gate).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import brute, construct, metrics, nndescent
from repro.core import search as search_lib
from repro.kernels import expand as expand_lib
from repro.kernels import ops
from repro.kernels import precision as precision_lib

DATASETS = [
    ("SIFT-like", "clustered", 128, "l2"),
    ("GloVe-like", "heavy_tailed", 100, "cosine"),
    ("Rand", "uniform", 100, "l2"),
]


def run(n: int = 10_000, n_q: int = 256, k: int = 20, seed: int = 0, datasets=DATASETS):
    tbl = common.Table(
        "search: recall@1 vs speed-up over brute force (Fig 9/10)",
        ["dataset", "graph", "beam", "recall@1", "speedup", "ms/query"],
    )
    for name, kind, d, metric in datasets:
        x, q = common.dataset_with_queries(kind, n, n_q, d, seed)
        true_ids = common.ground_truth(x, q, 1, metric)
        t_brute = common.timeit(
            lambda: brute.brute_force_knn(x, q, 1, metric, use_pallas=False), iters=2
        )

        graphs = {}
        for algo, lgd in (("OLG", False), ("LGD", True)):
            cfg = construct.BuildConfig(
                k=k, metric=metric, wave=256, lgd=lgd, beam=max(k, 40),
                n_seeds=8, dispatch="reference",
            )
            graphs[algo], _ = construct.build(x, cfg, jax.random.PRNGKey(seed))
        ncfg = nndescent.NNDescentConfig(
            k=k, metric=metric, max_iters=10, use_pallas=False, node_chunk=1024
        )
        graphs["NN-Desc"], _ = nndescent.build(x, ncfg, jax.random.PRNGKey(seed))

        for gname, g in graphs.items():
            for beam in (8, 16, 32, 64):
                scfg = search_lib.SearchConfig(
                    k=beam, beam=beam, n_seeds=8, metric=metric,
                    use_lgd_mask=(gname == "LGD"), dispatch="reference",
                )
                fn = lambda: search_lib.search(g, x, q, jax.random.PRNGKey(3), scfg)
                t = common.timeit(fn, iters=2)
                res = fn()
                rec = common.search_recall(jax.device_get(res.ids), true_ids, 1)
                tbl.add(name, gname, beam, rec, t_brute / t, 1e3 * t / n_q)
    tbl.show()
    return tbl


# ---------------------------------------------------------------------------
# Hierarchical entry-point gate (the coarse-seeding tentpole measurement)
# ---------------------------------------------------------------------------


def hier_gate(
    n: int = 100_000,
    d: int = 20,
    k: int = 20,
    n_eval: int = 1024,
    seed: int = 0,
    include_random_baseline: bool = True,
) -> dict:
    """The canonical record for hierarchical (coarse-landmark) seeding.

    Builds the LGD graph at paper scale (n=10^5) with
    ``seed_mode="coarse"`` — insertion searches route through the landmark
    level (core.hierarchy) instead of random entry points — and reports the
    build scanning rate (Eq. 2) plus graph recall@10 on ``n_eval`` sampled
    rows against exact ground truth (full n x n brute force is off the table
    at this scale; the sample estimator's noise is ~±0.007 at n_eval=1024).

    Dataset: ``clustered`` (SIFT/YFCC-like, intrinsic dim 16) — the regime
    the paper reports its headline numbers on and the one hierarchical
    seeding targets: landmarks summarize real density structure.  Uniform
    U[0,1)^20 has intrinsic dimension == 20, where NO graph method reaches
    0.95 recall inside a 0.02-scanning budget at this n (measured here:
    0.84 for both seed modes — the graph itself saturates); gating on it
    would gate the dataset, not the seeding.

    CI floors (benchmarks.ci_gate): ``hier_recall_at_10_min`` and
    ``scanning_rate_max`` — recall must hold while the scanning rate stays
    polylog-small.  The ``baseline_random`` record rides along ungated: the
    same build with random seeding, so the coarse level's effect is measured
    against its own codebase, not a remembered number.

    Minutes-long at the canonical n — this runs in the bench-smoke CI job
    (``benchmarks.run --hier``), never in tier-1.
    """
    records = {}
    modes = ["coarse"] + (["random"] if include_random_baseline else [])
    x = common.dataset("clustered", n, d, seed)
    rows = jax.random.choice(
        jax.random.PRNGKey(seed + 1), n, shape=(min(n_eval, n),), replace=False
    ).astype(jnp.int32)
    true_ids, _ = brute.brute_force_knn(
        x, x[rows], 10, "l2", exclude_ids=rows, use_pallas=False
    )
    for mode in modes:
        cfg = construct.BuildConfig(
            k=k, metric="l2", wave=256, beam=max(40, k), n_seeds=8, lgd=True,
            use_pallas=False, seed_mode=mode,
        )
        t0 = time.perf_counter()
        g, stats = construct.build(x, cfg, jax.random.PRNGKey(seed))
        jax.block_until_ready(g.nbr_ids)
        records[mode] = {
            "n": n, "d": d, "k": 10, "seed_mode": mode, "dataset": "clustered",
            "recall_at_10": float(
                brute.recall_at_k(g.nbr_ids[rows, :10], true_ids, 10)
            ),
            "scanning_rate": construct.scanning_rate(stats, n),
            "n_comps": float(stats.n_comps),
            "build_s": time.perf_counter() - t0,
        }
        print(f"hier_gate[{mode}]: n={n} recall@10="
              f"{records[mode]['recall_at_10']:.4f} "
              f"scan={records[mode]['scanning_rate']:.5f} "
              f"({records[mode]['build_s']:.0f}s)", flush=True)
    rec = records["coarse"]
    if include_random_baseline:
        rec["baseline_random"] = records["random"]
    return rec


# ---------------------------------------------------------------------------
# Fused-vs-unfused expansion-step throughput (the tentpole measurement)
# ---------------------------------------------------------------------------


def expansion_bench(
    n: int = 5000,
    d: int = 20,
    B: int = 16,
    k: int = 20,
    steps: int = 6,
    metric: str = "l2",
    seed: int = 0,
) -> dict:
    """Measure EHC expansion-step throughput, fused vs unfused.

    Both paths run the identical op chain from the same initial state:
      * fused — the production execution shape: the whole expansion loop as
        one compiled call with the carry updated in place (on TPU the step
        is the Pallas kernel; elsewhere XLA fuses the reference chain);
      * unfused — candidate gather, hash probe, distance gather, hash
        record, beam merge, and convergence as separately-jitted calls,
        every intermediate (including the (B, H) visited tables) allocated
        and round-tripped through device memory per stage.

    The default ``B=16`` is the serving shape: ``serve.retrieval.retrieve``
    searches one user's MIND interest vectors (a handful of queries), which
    is where per-step dispatch/materialization overhead — the thing fusion
    removes — dominates.  Construction waves (B=256+) amortize dispatch
    across the wave, so the CPU fused-vs-unfused gap narrows there; pass
    ``B=256`` to measure that regime (the CI record carries both).

    Timings use min-of-iters: CI runners are contended, and the minimum is
    the least-noisy estimate of true step cost.  Returns a machine-readable
    record incl. an arithmetic-intensity estimate for the roofline report.
    """
    x, q = common.dataset_with_queries("uniform", n, B, d, seed)
    g = brute.exact_seed_graph(x, n, k, metric, use_pallas=False)
    cfg = search_lib.SearchConfig(
        k=k, beam=2 * k, n_seeds=8, hash_slots=2048, max_iters=steps,
        metric=metric,
    )
    key = jax.random.PRNGKey(seed)
    st0 = jax.block_until_ready(search_lib.init_state(g, x, q, key, cfg))

    # fused: the production execution shape — the whole expansion loop is one
    # compiled call (exactly what search's lax.while_loop runs, with the
    # convergence predicate replaced by a fixed trip count so both paths do
    # identical work), carry updated in place.
    step = search_lib._make_step(g, x, q, cfg)

    @jax.jit
    def fused_loop(st):
        return jax.lax.fori_loop(0, steps, lambda i, s: step(s), st)

    # -- unfused: the pre-fusion op chain — every stage its own compiled
    # call (one dispatch + a device-memory round trip of its intermediates):
    # select-r, candidate gather (G[r] ∪ Ḡ[r] + masking), hash probe,
    # distance gather, hash record, beam top-k merge, dedupe, convergence.
    probes = cfg.hash_probes
    e, H = cfg.beam, cfg.hash_slots

    def _select_r(st):
        sel_dist = jnp.where(st.beam_exp, jnp.inf, st.beam_dist)
        r_slot = jnp.argmin(sel_dist, axis=1)
        r_best = jnp.take_along_axis(sel_dist, r_slot[:, None], axis=1)[:, 0]
        has_r = jnp.isfinite(r_best) & ~st.done
        r_id = jnp.where(
            has_r,
            jnp.take_along_axis(st.beam_ids, r_slot[:, None], axis=1)[:, 0],
            -1,
        )
        beam_exp = st.beam_exp.at[jnp.arange(B), r_slot].set(
            st.beam_exp[jnp.arange(B), r_slot] | has_r
        )
        return r_id, has_r, beam_exp

    s_select = jax.jit(_select_r)
    s_cands = jax.jit(
        lambda r_id, has_r: search_lib._candidates_from_expansion(
            g, r_id, has_r, cfg
        )
    )
    s_probe = jax.jit(
        lambda vis_ids, cands: expand_lib.hash_probe_state(vis_ids, cands, probes)
    )
    # pre-fusion dispatch: auto (Pallas gather kernel on TPU, ref elsewhere),
    # so the baseline is the op chain as it actually ran before fusion; both
    # paths consume the graph-resident norm cache — the comparison isolates
    # fusion, not the norm decomposition
    s_dist = jax.jit(
        lambda qq, cand_ids: ops.gather_distance(
            qq, x, cand_ids, cfg.metric, sq_norms=g.sq_norms,
            dispatch=cfg.dispatch,
        )
    )

    def _record(vis_ids, vis_dist, do_ins, cand_ids, dists, insert_slot):
        B_idx = jnp.broadcast_to(jnp.arange(B)[:, None], cand_ids.shape)
        slot = jnp.where(do_ins, insert_slot, H)
        vis_ids = vis_ids.at[B_idx, slot].set(
            jnp.where(do_ins, cand_ids, -1), mode="drop"
        )
        vis_dist = vis_dist.at[B_idx, slot].set(
            jnp.where(do_ins, dists, jnp.inf), mode="drop"
        )
        return vis_ids, vis_dist

    s_record = jax.jit(_record)

    def _beam_merge(bi, bd, be, cand_ids, dists):
        cat_ids = jnp.concatenate([bi, cand_ids], axis=1)
        cat_dist = jnp.concatenate([bd, dists], axis=1)
        cat_exp = jnp.concatenate(
            [be, jnp.zeros_like(cand_ids, bool) | (cand_ids < 0)], axis=1
        )
        neg, sel = jax.lax.top_k(-cat_dist, e)
        return (
            jnp.take_along_axis(cat_ids, sel, axis=1),
            -neg,
            jnp.take_along_axis(cat_exp, sel, axis=1),
        )

    s_beam_merge = jax.jit(_beam_merge)
    s_dedupe = jax.jit(
        lambda bi, bd, be: expand_lib.dedupe_beam(bi, bd, be)
    )

    def _converge(st, bi, bd, be, vi, vd, comps):
        best_unexp = jnp.min(jnp.where(be, jnp.inf, bd), axis=1)
        newly_done = ~(best_unexp < bd[:, cfg.k - 1])
        return st._replace(
            beam_ids=bi, beam_dist=bd, beam_exp=be, vis_ids=vi, vis_dist=vd,
            n_comps=st.n_comps + comps,
            n_iters=st.n_iters + (~st.done).astype(jnp.int32),
            done=st.done | newly_done, it=st.it + 1,
        )

    s_converge = jax.jit(_converge)

    def unfused_step(st):
        r_id, has_r, beam_exp = s_select(st)
        cands = s_cands(r_id, has_r)
        present, insert_ok, insert_slot = s_probe(st.vis_ids, cands)
        fresh = (cands >= 0) & ~present
        cand_ids = jnp.where(fresh, cands, -1)
        dists = s_dist(q, cand_ids)
        vi, vd = s_record(
            st.vis_ids, st.vis_dist, fresh & insert_ok, cand_ids, dists,
            insert_slot,
        )
        bi, bd, be = s_beam_merge(
            st.beam_ids, st.beam_dist, beam_exp, cand_ids, dists
        )
        bi, bd, be = s_dedupe(bi, bd, be)
        comps = jnp.sum(fresh, axis=1).astype(jnp.int32)
        return s_converge(st, bi, bd, be, vi, vd, comps)

    def drive_unfused():
        st = st0
        for _ in range(steps):
            st = unfused_step(st)
        return st.beam_dist

    t_fused = common.timeit(lambda: fused_loop(st0), iters=7, reduce="min")
    t_unfused = common.timeit(drive_unfused, iters=7, reduce="min")

    # arithmetic-intensity estimate of one expansion step (l2), blocked
    # engine: the q·x GEMM dominates flops (2d MACs/candidate + the norm
    # fold); candidate rows + both hash tables dominate bytes.  The cached
    # ‖x‖² adds 4 B/candidate of reads but removes the d-element norm
    # re-reduction the rowwise engine paid per candidate.
    C = k + g.rev_capacity
    H, e = cfg.hash_slots, cfg.beam
    flops = B * C * (2 * d + 4)
    bytes_moved = (
        B * C * d * 4  # candidate rows
        + B * C * 4  # cached ‖x‖² per candidate
        + B * 2 * H * 8 * 2  # vis_ids/vis_dist read + write
        + B * 3 * e * 4 * 2  # beam triple read + write
    )
    spf = B * steps / t_fused
    spu = B * steps / t_unfused
    return {
        "n": n, "d": d, "B": B, "k": k, "steps": steps, "metric": metric,
        "t_fused_s": t_fused,
        "t_unfused_s": t_unfused,
        "fused_expansions_per_s": spf,
        "unfused_expansions_per_s": spu,
        "speedup": t_unfused / t_fused,
        "flops_per_step": flops,
        "bytes_per_step": bytes_moved,
        "arith_intensity": flops / bytes_moved,
    }


def run_expansion(batches=(16, 256), **kw):
    """Expansion microbench at the serving batch (gated) and the
    construction-wave batch (recorded).  Returns {B: record}."""
    tbl = common.Table(
        "EHC expansion step: fused kernel vs unfused op chain",
        ["B", "path", "expansions/s", "ms/step", "speedup", "arith_int"],
    )
    recs = {}
    for B in batches:
        rec = expansion_bench(B=B, **kw)
        recs[B] = rec
        steps = rec["steps"]
        tbl.add(B, "fused", rec["fused_expansions_per_s"],
                1e3 * rec["t_fused_s"] / steps, rec["speedup"],
                rec["arith_intensity"])
        tbl.add(B, "unfused", rec["unfused_expansions_per_s"],
                1e3 * rec["t_unfused_s"] / steps, 1.0, rec["arith_intensity"])
    tbl.show()
    return recs


# ---------------------------------------------------------------------------
# Blocked-vs-rowwise gather-distance engine (the PR-3 tentpole measurement)
# ---------------------------------------------------------------------------


def gather_engine_bench(
    n: int = 8192,
    B: int = 16,
    dims: tuple = (16, 64, 256),
    Cs: tuple = (32, 128, 512),
    metric: str = "l2",
    seed: int = 0,
) -> list:
    """Blocked MXU distance engine vs the rowwise engine it replaced.

    Both paths are jitted and compute (B, C) candidate distances from (B, C)
    gathered ids:

      * blocked — the production path (``ops.gather_distance``): one fused
        ``q·x`` contraction pass over each gathered candidate tile, folded
        with the graph-resident ``‖x‖²`` cache (the norms decomposition;
        GEMM-shaped and MXU-resident in the Pallas kernel on TPU);
      * rowwise — the pre-PR-3 engine *verbatim* (per-query ``vmap`` over
        ``metrics.pairwise``): it re-reduces every gathered candidate's
        norm on each call and pays a second pass over the (C, d) tile for
        it — exactly the per-candidate cost the norm cache deletes.

    Sweeps d x C; the d=256/C=512 record is the CI-gated one (the regime
    where the cache's saved pass is structural, not dispatch noise).
    min-of-iters timing, same rationale as ``expansion_bench``.
    """
    records = []
    for d in dims:
        x, q = common.dataset_with_queries("uniform", n, B, d, seed)
        sq = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
        key = jax.random.PRNGKey(seed)
        for C in Cs:
            idx = jax.random.randint(key, (B, C), 0, n, dtype=jnp.int32)

            blocked = jax.jit(
                lambda qq, ii: ops.gather_distance(
                    qq, x, ii, metric, sq_norms=sq, use_pallas=False
                )
            )

            @jax.jit
            def rowwise(qq, ii):
                # the pre-PR-3 ref.gather_distance body, kept verbatim as the
                # baseline: norms re-reduced per call, per-query dispatch
                cand = x[jnp.maximum(ii, 0)]  # (B, C, d)

                def per_query(qi, ci):
                    return metrics.pairwise(metric, qi[None, :], ci)[0]

                dist = jax.vmap(per_query)(qq, cand)
                return jnp.where(ii >= 0, dist.astype(jnp.float32), jnp.inf)

            t_blocked = common.timeit(
                lambda: blocked(q, idx), iters=20, reduce="min"
            )
            t_rowwise = common.timeit(
                lambda: rowwise(q, idx), iters=20, reduce="min"
            )
            records.append({
                "n": n, "B": B, "d": d, "C": C, "metric": metric,
                "t_blocked_s": t_blocked,
                "t_rowwise_s": t_rowwise,
                "speedup": t_rowwise / t_blocked,
            })
    return records


def run_gather_engine(**kw) -> dict:
    """Gather-distance engine sweep; returns {"records": [...], "gated": rec}
    where ``gated`` is the d=256/C=512 record the CI floor applies to."""
    records = gather_engine_bench(**kw)
    tbl = common.Table(
        "gather-distance engine: blocked (norms decomposition) vs rowwise",
        ["d", "C", "blocked_us", "rowwise_us", "speedup"],
    )
    for r in records:
        tbl.add(r["d"], r["C"], 1e6 * r["t_blocked_s"],
                1e6 * r["t_rowwise_s"], r["speedup"])
    tbl.show()
    # the CI floor applies to the canonical d=256/C=512 record and nothing
    # else — fail loudly if a reduced/extended sweep no longer produces it
    gated = [r for r in records if r["d"] == 256 and r["C"] == 512]
    if not gated:
        raise ValueError(
            "gather-engine sweep lost its gated d=256/C=512 record; keep that "
            "shape in the sweep or update baseline_ci.json's floor shape"
        )
    return {"records": records, "gated": gated[0]}


def precision_bench(
    n: int = 262_144,
    B: int = 256,
    d: int = 256,
    C: int = 512,
    metric: str = "l2",
    seed: int = 0,
    rounds: int = 8,
) -> dict:
    """The compressed-engine gather record (PR 7): fp32 vs bf16/int8 tables.

    All variants run the SAME reference engine (``ops.gather_distance``,
    ``dispatch="reference"``) so the comparison isolates the candidate
    representation — bytes fetched per candidate — not a kernel change.  The
    shape (B=256 construction wave, d=256, C=512 over n=2^18 rows) puts the
    fp32 table at 256 MB and the int8 table at 64 MB: BOTH far past LLC, so
    every variant streams from DRAM and the ratio is a memory-bandwidth
    fact.  n matters here — at n=2^17 the 32 MB int8 table fits LLC in a
    clean process but gets evicted in a long-running one, so the measured
    ratio swings ~35% with process history (2.24x isolated vs 1.63x after
    nine minutes of preceding benchmarks, measured); at 2^18 the same
    experiment moves it only 2.04x -> 1.88x.

    Cold rotating id sets: each timed pass walks ``rounds`` disjoint (B, C)
    id sets, so no candidate tile is re-fetched warm within a pass — the
    replayed-single-gather alternative would let the fp32 tile ride in cache
    and understate exactly the effect being measured.

    The int8 record's ``speedup`` is CI-gated (``int8_gather_speedup_min``);
    bf16 rides along ungated — off-TPU the bf16→fp32 cast is a software
    conversion that can cost more than the bytes it saves (measured ~0.5x on
    CPU), while on TPU the cast is free inside the MXU pipeline; the record
    exists so that hardware difference stays measured, not assumed.
    """
    x, q = common.dataset_with_queries("uniform", n, B, d, seed)
    sq = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), rounds)
    idx_sets = [
        jax.random.randint(kk, (B, C), 0, n, dtype=jnp.int32) for kk in keys
    ]

    def timed(fn):
        compiled = jax.jit(fn)

        def drive():
            out = None
            for ii in idx_sets:
                out = compiled(q, ii)
            return out

        return common.timeit(drive, iters=3, reduce="min") / rounds

    t_fp32 = timed(
        lambda qq, ii: ops.gather_distance(
            qq, x, ii, metric, sq_norms=sq, dispatch="reference"
        )
    )
    records = {"n": n, "B": B, "d": d, "C": C, "metric": metric,
               "rounds": rounds, "t_fp32_s": t_fp32}
    for prec in ("bf16", "int8"):
        enc = precision_lib.encode_dataset(x, prec)
        t = timed(
            lambda qq, ii, enc=enc, prec=prec: ops.gather_distance(
                qq, x, ii, metric, sq_norms=sq, dispatch="reference",
                enc=enc, precision=prec,
            )
        )
        records[f"t_{prec}_s"] = t
        records[f"{prec}_speedup"] = t_fp32 / t
    records["speedup"] = records["int8_speedup"]  # the gated alias
    return records


def rerank_gate(
    n: int = 2000, d: int = 20, n_q: int = 512, k: int = 10, seed: int = 0
) -> dict:
    """PQ rank-then-rerank quality vs the fp32 search, on one fp32-built
    graph at the canonical quality-gate shape (n=2000/d=20, uniform).

    ``recall_delta`` = recall@10(fp32) - recall@10(pq rank-then-rerank) is
    CEILING-gated (``rerank_recall_delta_max``): the cheap ADC first pass
    may drop at most a point of recall, since every survivor is re-ranked
    with exact fp32 distances (``rerank_factor``·k of them per step).
    """
    x, q = common.dataset_with_queries("uniform", n, n_q, d, seed)
    true_ids = common.ground_truth(x, q, k, "l2")
    cfg = construct.BuildConfig(
        k=20, metric="l2", wave=256, beam=40, n_seeds=8, lgd=True,
        dispatch="reference",
    )
    g, _ = construct.build(x, cfg, jax.random.PRNGKey(seed))
    base = search_lib.SearchConfig(
        k=k, beam=40, n_seeds=8, metric="l2", dispatch="reference",
    )
    rec = {}
    for name, scfg in (
        ("fp32", base),
        ("pq", dataclasses.replace(base, precision="pq", rerank_factor=4)),
    ):
        res = search_lib.search(g, x, q, jax.random.PRNGKey(seed + 1), scfg)
        rec[f"recall_at_{k}_{name}"] = common.search_recall(
            jax.device_get(res.ids), true_ids, k
        )
        rec[f"comps_{name}"] = float(jnp.mean(res.n_comps))
    rec["recall_delta"] = rec[f"recall_at_{k}_fp32"] - rec[f"recall_at_{k}_pq"]
    return rec


def run_precision(**kw) -> dict:
    """Compressed-engine record: gather throughput (int8 gated) + PQ
    rank-then-rerank quality (delta ceiling-gated)."""
    gather = precision_bench(**kw)
    rerank = rerank_gate()
    tbl = common.Table(
        "compressed distance engine: bytes/candidate vs throughput",
        ["precision", "bytes/dim", "us/pass", "speedup"],
    )
    for prec in ("fp32", "bf16", "int8"):
        t = gather[f"t_{prec}_s"] if prec != "fp32" else gather["t_fp32_s"]
        spd = gather.get(f"{prec}_speedup", 1.0)
        tbl.add(prec, precision_lib.bytes_per_dim(prec), 1e6 * t, spd)
    tbl.show()
    print(f"  pq rank-then-rerank: recall@10 {rerank['recall_at_10_pq']:.4f} "
          f"vs fp32 {rerank['recall_at_10_fp32']:.4f} "
          f"(delta {rerank['recall_delta']:+.4f})")
    return {"gather": gather, "rerank": rerank}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--expansion", action="store_true",
                    help="only the fused-vs-unfused expansion microbench")
    ap.add_argument("--gather-engine", action="store_true",
                    help="only the blocked-vs-rowwise gather-distance sweep")
    ap.add_argument("--hier", action="store_true",
                    help="only the hierarchical-seeding gate (minutes at the "
                         "canonical n=100k; combine with --n to shrink)")
    ap.add_argument("--precision", action="store_true",
                    help="only the compressed-engine record (int8 gather "
                         "speedup + PQ rank-then-rerank recall delta)")
    args = ap.parse_args()
    if args.expansion:
        run_expansion()
        return
    if args.gather_engine:
        run_gather_engine()
        return
    if args.precision:
        run_precision()
        return
    if args.hier:
        hier_gate(n=args.n if args.n != 10_000 else 100_000)
        return
    run(2000 if args.quick else args.n,
        datasets=DATASETS[:1] if args.quick else DATASETS)
    run_expansion()
    run_gather_engine()


if __name__ == "__main__":
    main()
