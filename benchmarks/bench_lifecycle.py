"""Sustained-churn benchmark for the index lifecycle subsystem.

    PYTHONPATH=src python -m benchmarks.bench_lifecycle

The paper's headline capability is an index that lives online; the lifecycle
layer (``repro.index.OnlineIndex``) is what lets it live *long*: removed
rows are recycled through the free-slot ledger + compaction instead of
leaking capacity, and inserts ride the same fused wave pipeline as the
build.  This benchmark measures the serving-relevant composite: interleaved
insert / remove / query rounds at a FIXED capacity — every round must
reclaim the slots the previous round freed, so compaction runs in the hot
loop, not as an offline pass.

Reported:
  * ``churn_ops_per_s`` — sustained (insert + remove + query) operations per
    second across the whole loop, compactions included (ungated: wall-clock
    on shared CI runners is informational);
  * ``recall_at_10``   — brute-force-checked (alive-aware) recall@10 of the
    post-churn index.  This is the hard CI gate: an index that degrades
    under churn has lost the paper's online property, whatever its speed.

The canonical CI shape is n=2000 / d=20, matching the build-quality gate
(``bench_construction.quality_gate``) so the two recalls are comparable:
churn recall sits below build recall only by what the churn itself costs.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import brute, construct
from repro.index import OnlineIndex


def churn_bench(
    n: int = 2000,
    d: int = 20,
    k: int = 20,
    rounds: int = 6,
    batch: int = 64,
    n_q: int = 64,
    beam: int = 64,
    search_k: int = 20,
    seed: int = 0,
) -> dict:
    """Interleaved insert/remove/query at fixed capacity; see module doc."""
    pool = common.dataset("uniform", n + (rounds + 1) * batch, d, seed)
    base, fresh = pool[:n], pool[n:]
    q = common.dataset("uniform", n_q, d, seed + 1)
    cfg = construct.BuildConfig(
        k=k, metric="l2", wave=256, lgd=True, beam=40, n_seeds=8,
        dispatch="reference",
    )
    t0 = time.perf_counter()
    idx = OnlineIndex.build(base, cfg, key=jax.random.PRNGKey(seed))
    t_build = time.perf_counter() - t0
    assert idx.capacity == n  # churn must recycle, not grow

    rng = np.random.RandomState(seed)
    key = jax.random.PRNGKey(seed + 2)

    def churn_round(r: int):
        nonlocal key
        alive = np.flatnonzero(np.asarray(idx.graph.alive))
        victims = rng.choice(alive, batch, replace=False)
        idx.remove(jnp.asarray(victims, jnp.int32))
        key, k1, k2 = jax.random.split(key, 3)
        idx.add(fresh[r * batch : (r + 1) * batch], key=k1, flush=True)
        res = idx.search(q, search_k, beam=beam, key=k2)
        jax.block_until_ready(res.ids)

    # one untimed round warms every compilation cache on the churn path
    # (remove, compact, insert wave, search), so the clock below measures
    # sustained throughput, not first-call tracing
    churn_round(0)

    t0 = time.perf_counter()
    for r in range(1, rounds + 1):
        churn_round(r)
    t_churn = time.perf_counter() - t0
    assert idx.capacity == n, "churn loop grew the index"

    # post-churn quality, alive-aware exact ground truth
    true_ids, _ = brute.brute_force_knn(
        idx.items, q, 10, idx.metric,
        n_valid=idx.graph.n_valid, alive=idx.graph.alive,
    )
    # search deeper than the reported cut (search_k > 10): the EHC
    # termination horizon is the search k, so recall@10 is measured on a
    # k=search_k walk — the serving configuration, and the paper's protocol
    # of over-searching for quality
    res = idx.search(q, search_k, beam=beam, key=jax.random.PRNGKey(seed + 3))
    recall = float(brute.recall_at_k(res.ids, true_ids, 10))

    ops = rounds * (2 * batch + n_q)  # removals + inserts + queries
    return {
        "n": n, "d": d, "k": k, "rounds": rounds, "batch": batch,
        "n_q": n_q, "beam": beam, "search_k": search_k,
        "t_build_s": t_build,
        "t_churn_s": t_churn,
        "churn_ops_per_s": ops / t_churn,
        "recall_at_10": recall,
        "capacity": idx.capacity,
        "n_items": idx.n_items,
    }


def churn_gate(n: int = 2000, d: int = 20, seed: int = 0) -> dict:
    """The canonical CI churn measurement (shape matches the build-quality
    gate).  ``benchmarks.ci_gate`` fails the benchmark-smoke job when
    ``recall_at_10`` drops below ``churn_recall_at_10_min`` in
    benchmarks/baseline_ci.json; ``churn_ops_per_s`` is recorded ungated."""
    return churn_bench(n=n, d=d, seed=seed)


def run(n: int = 2000, **kw):
    tbl = common.Table(
        "lifecycle: sustained churn (insert+remove+query, fixed capacity)",
        ["n", "d", "rounds", "batch", "churn_ops/s", "recall@10", "build_s"],
    )
    rec = churn_bench(n=n, **kw)
    tbl.add(rec["n"], rec["d"], rec["rounds"], rec["batch"],
            rec["churn_ops_per_s"], rec["recall_at_10"], rec["t_build_s"])
    tbl.show()
    return tbl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--rounds", type=int, default=6)
    args = ap.parse_args()
    run(args.n, rounds=args.rounds)


if __name__ == "__main__":
    main()
