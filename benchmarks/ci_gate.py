"""Benchmark regression gate for CI.

    PYTHONPATH=src python -m benchmarks.ci_gate BENCH_ci.json [baseline.json]

Reads the machine-readable record ``benchmarks.run --ci-out`` emitted and
compares it against the committed floors in ``benchmarks/baseline_ci.json``:

  * ``recall_at_10_min`` — LGD build quality at the canonical shape
    (bench_construction.quality_gate); drops mean the construction path
    regressed;
  * ``expansion_speedup_min`` — fused-vs-unfused EHC expansion throughput
    (bench_search.expansion_bench); drops mean the fused step lost its edge;
  * ``gather_engine_speedup_min`` — blocked (norms-decomposed) vs rowwise
    gather-distance at d=256/C=512 (bench_search.gather_engine_bench); drops
    mean the blocked MXU engine lost its edge over the per-row formula it
    replaced;
  * ``churn_recall_at_10_min`` — post-churn search recall@10 after sustained
    interleaved insert/remove/query at fixed capacity
    (bench_lifecycle.churn_gate); drops mean the online property regressed —
    removal repair, slot recycling, or compaction is damaging the graph.
    The churn record's throughput (``churn_ops_per_s``) rides along ungated.
  * ``merge_recall_at_10_min`` — merged+refined recall@10 of the
    divide-and-conquer build (bench_construction.merge_build_gate, same
    n=2000/d=20 shape as the sequential quality gate); drops mean the
    sub-graph merge or the refinement sweep regressed.  The record's
    ``wallclock_ratio`` (parallel vs sequential build) rides along ungated —
    shared CI runners compress thread overlap.

Exit code 0 = all floors hold; 1 = regression (fails the CI job).  The
BENCH_ci.json artifact is uploaded either way so regressions come with data.
"""

from __future__ import annotations

import os
import sys

from benchmarks import common

_DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline_ci.json")


def check(bench: dict, baseline: dict) -> list[tuple[str, float, float, bool]]:
    """Returns (name, measured, floor, ok) per gated metric."""
    results = []
    rec = float(bench["quality"]["recall_at_10"])
    results.append(
        ("recall_at_10", rec, float(baseline["recall_at_10_min"]),
         rec >= float(baseline["recall_at_10_min"]))
    )
    spd = float(bench["expansion"]["speedup"])
    results.append(
        ("expansion_speedup", spd, float(baseline["expansion_speedup_min"]),
         spd >= float(baseline["expansion_speedup_min"]))
    )
    gspd = float(bench["gather_engine"]["gated"]["speedup"])
    results.append(
        ("gather_engine_speedup", gspd,
         float(baseline["gather_engine_speedup_min"]),
         gspd >= float(baseline["gather_engine_speedup_min"]))
    )
    crec = float(bench["lifecycle_churn"]["recall_at_10"])
    results.append(
        ("churn_recall_at_10", crec,
         float(baseline["churn_recall_at_10_min"]),
         crec >= float(baseline["churn_recall_at_10_min"]))
    )
    mrec = float(bench["merge_build"]["recall_at_10"])
    results.append(
        ("merge_recall_at_10", mrec,
         float(baseline["merge_recall_at_10_min"]),
         mrec >= float(baseline["merge_recall_at_10_min"]))
    )
    return results


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    bench = common.load_json(sys.argv[1])
    baseline = common.load_json(
        sys.argv[2] if len(sys.argv) > 2 else _DEFAULT_BASELINE
    )
    failed = False
    for name, measured, floor, ok in check(bench, baseline):
        status = "OK  " if ok else "FAIL"
        print(f"[{status}] {name}: {measured:.4g} (floor {floor:.4g})")
        failed |= not ok
    if failed:
        print("benchmark regression gate FAILED")
        return 1
    print("benchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
