"""Benchmark regression gate for CI.

    PYTHONPATH=src python -m benchmarks.ci_gate BENCH_ci.json [baseline.json]

Reads the machine-readable record ``benchmarks.run --ci-out`` emitted and
compares it against the committed floors in ``benchmarks/baseline_ci.json``:

  * ``recall_at_10_min`` — LGD build quality at the canonical shape
    (bench_construction.quality_gate); drops mean the construction path
    regressed;
  * ``expansion_speedup_min`` — fused-vs-unfused EHC expansion throughput
    (bench_search.expansion_bench); drops mean the fused step lost its edge;
  * ``gather_engine_speedup_min`` — blocked (norms-decomposed) vs rowwise
    gather-distance at d=256/C=512 (bench_search.gather_engine_bench); drops
    mean the blocked MXU engine lost its edge over the per-row formula it
    replaced;
  * ``churn_recall_at_10_min`` — post-churn search recall@10 after sustained
    interleaved insert/remove/query at fixed capacity
    (bench_lifecycle.churn_gate); drops mean the online property regressed —
    removal repair, slot recycling, or compaction is damaging the graph.
    The churn record's throughput (``churn_ops_per_s``) rides along ungated.
  * ``merge_recall_at_10_min`` — merged+refined recall@10 of the
    divide-and-conquer build (bench_construction.merge_build_gate, same
    n=2000/d=20 shape as the sequential quality gate); drops mean the
    sub-graph merge or the refinement sweep regressed.  The record's
    ``wallclock_ratio`` (parallel vs sequential build) rides along ungated —
    shared CI runners compress thread overlap.
  * ``parallel_recall_at_10`` (floor ``merge_recall_at_10_min``) +
    ``parallel_wallclock_ratio`` (ceiling ``parallel_wallclock_ratio_max``)
    — the tuned divide-and-conquer path (bench_construction.parallel_gate,
    n=4000/d=20, light sub-builds + shallow coarse-seeded merge searches +
    second-hop proposals): merged recall@10 must hold the SAME 0.95 floor
    as the merge gate WHILE the parallel/sequential wall-clock ratio stays
    below 1.0 — "build_parallel beats build" as a regression-checked claim.
    Median-of-3 alternating warmed runs; run_meta stamps host_cpus so the
    ratio reads correctly across runners.  Opt-in record (``benchmarks.run
    --parallel``) with the usual absent-record rule.
  * ``hier_recall_at_10_min`` + ``scanning_rate_max`` — hierarchical
    (coarse-landmark) seeding at paper scale (bench_search.hier_gate,
    n=10^5/d=20): recall@10 on sampled rows must hold the quality floor
    WHILE the build scanning rate (Eq. 2) stays below the ceiling — the
    two-sided gate is what makes "kills the scanning rate" a regression-
    checked claim, not a one-off measurement.  The record's
    ``baseline_random`` (same build, random entry points) rides along
    ungated.  The record is opt-in (``benchmarks.run --hier``; minutes at
    canonical n) — an ABSENT record skips both checks, a present one is
    always gated.
  * ``int8_gather_speedup_min`` + ``rerank_recall_delta_max`` — the
    compressed distance engine (bench_search.run_precision, opt-in via
    ``benchmarks.run --precision``, same absent-record rule as --hier):
    the int8 candidate table must keep its memory-bandwidth edge over the
    fp32 table at the memory-bound B=256/d=256/C=512 shape (floor), AND the
    PQ rank-then-rerank search may lose at most ``rerank_recall_delta_max``
    recall@10 vs the fp32 search on the same graph (CEILING — the exact
    re-rank is what makes the cheap ADC first pass admissible).  The bf16
    record rides along ungated.
  * ``serving_recall_at_10_min`` + ``serving_p99_p50_ratio_max`` — the
    sustained-load serving record (bench_serving.serving_gate, opt-in via
    ``benchmarks.run --serving``, same absent-record rule): fresh-search
    recall@10 of the ServingLoop's query reservoir against alive-aware
    brute force must hold the floor under interleaved query bursts + light
    churn, AND the p99/p50 latency ratio must stay under a generous sanity
    CEILING — the loop serves a steady warm-cache arrival pattern, so a
    blown ratio means the measurement itself broke (compile inside the
    timed window, a stray host sync in the hot path), which floors on raw
    wall-clock could never distinguish from a slow runner.  p50/p99
    latency, QPS and scanning rate ride along ungated — they are the
    recorded trajectory later perf PRs diff against.

Exit code 0 = all floors hold; 1 = regression (fails the CI job).  The
BENCH_ci.json artifact is uploaded either way so regressions come with data.
"""

from __future__ import annotations

import os
import sys

from benchmarks import common

_DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline_ci.json")


def check(bench: dict, baseline: dict) -> list[tuple[str, float, float, bool]]:
    """Returns (name, measured, floor, ok) per gated metric."""
    results = []
    rec = float(bench["quality"]["recall_at_10"])
    results.append(
        ("recall_at_10", rec, float(baseline["recall_at_10_min"]),
         rec >= float(baseline["recall_at_10_min"]))
    )
    spd = float(bench["expansion"]["speedup"])
    results.append(
        ("expansion_speedup", spd, float(baseline["expansion_speedup_min"]),
         spd >= float(baseline["expansion_speedup_min"]))
    )
    gspd = float(bench["gather_engine"]["gated"]["speedup"])
    results.append(
        ("gather_engine_speedup", gspd,
         float(baseline["gather_engine_speedup_min"]),
         gspd >= float(baseline["gather_engine_speedup_min"]))
    )
    crec = float(bench["lifecycle_churn"]["recall_at_10"])
    results.append(
        ("churn_recall_at_10", crec,
         float(baseline["churn_recall_at_10_min"]),
         crec >= float(baseline["churn_recall_at_10_min"]))
    )
    mrec = float(bench["merge_build"]["recall_at_10"])
    results.append(
        ("merge_recall_at_10", mrec,
         float(baseline["merge_recall_at_10_min"]),
         mrec >= float(baseline["merge_recall_at_10_min"]))
    )
    if "parallel_gate" in bench:  # opt-in record (benchmarks.run
        # --parallel); absent record skips, present record gates two-sided:
        # recall floor (shared with merge_build) + wallclock ratio ceiling
        prec = float(bench["parallel_gate"]["recall_at_10"])
        results.append(
            ("parallel_recall_at_10", prec,
             float(baseline["merge_recall_at_10_min"]),
             prec >= float(baseline["merge_recall_at_10_min"]))
        )
        pratio = float(bench["parallel_gate"]["wallclock_ratio"])
        results.append(
            ("parallel_wallclock_ratio", pratio,
             float(baseline["parallel_wallclock_ratio_max"]),
             pratio <= float(baseline["parallel_wallclock_ratio_max"]))
        )
    if "hier_gate" in bench:  # opt-in record (minutes at n=10^5); absent in
        # quick --ci-out runs — but when present it is always gated, and the
        # scanning-rate check is a CEILING, not a floor
        hrec = float(bench["hier_gate"]["recall_at_10"])
        results.append(
            ("hier_recall_at_10", hrec,
             float(baseline["hier_recall_at_10_min"]),
             hrec >= float(baseline["hier_recall_at_10_min"]))
        )
        hscan = float(bench["hier_gate"]["scanning_rate"])
        results.append(
            ("hier_scanning_rate", hscan,
             float(baseline["scanning_rate_max"]),
             hscan <= float(baseline["scanning_rate_max"]))
        )
    if "precision_gate" in bench:  # opt-in record (benchmarks.run
        # --precision); absent record skips, present record always gates
        pspd = float(bench["precision_gate"]["gather"]["speedup"])
        results.append(
            ("int8_gather_speedup", pspd,
             float(baseline["int8_gather_speedup_min"]),
             pspd >= float(baseline["int8_gather_speedup_min"]))
        )
        pdelta = float(bench["precision_gate"]["rerank"]["recall_delta"])
        results.append(
            ("rerank_recall_delta", pdelta,
             float(baseline["rerank_recall_delta_max"]),
             pdelta <= float(baseline["rerank_recall_delta_max"]))
        )
    if "serving_load" in bench:  # opt-in record (benchmarks.run --serving);
        # absent record skips, present record gates two-sided: recall floor
        # + p99/p50 ratio sanity ceiling
        srec = float(bench["serving_load"]["recall_at_10"])
        results.append(
            ("serving_recall_at_10", srec,
             float(baseline["serving_recall_at_10_min"]),
             srec >= float(baseline["serving_recall_at_10_min"]))
        )
        sratio = float(bench["serving_load"]["p99_p50_ratio"])
        results.append(
            ("serving_p99_p50_ratio", sratio,
             float(baseline["serving_p99_p50_ratio_max"]),
             sratio <= float(baseline["serving_p99_p50_ratio_max"]))
        )
    return results


# metrics whose bound is a CEILING (measured must stay <= the baseline);
# "_rate"-suffixed names are ceilings by convention, the rest are listed here
_CEILINGS = frozenset({
    "rerank_recall_delta", "serving_p99_p50_ratio",
    "parallel_wallclock_ratio",
})


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    bench = common.load_json(sys.argv[1])
    baseline = common.load_json(
        sys.argv[2] if len(sys.argv) > 2 else _DEFAULT_BASELINE
    )
    failed = False
    for name, measured, floor, ok in check(bench, baseline):
        status = "OK  " if ok else "FAIL"
        bound = ("ceiling" if name.endswith("_rate") or name in _CEILINGS
                 else "floor")
        print(f"[{status}] {name}: {measured:.4g} ({bound} {floor:.4g})")
        failed |= not ok
    if failed:
        print("benchmark regression gate FAILED")
        return 1
    print("benchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
