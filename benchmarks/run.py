"""Benchmark driver: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--n N]

Sections:
  Table IV  bench_brute            exhaustive-search timing
  Fig 5     bench_search_baseline  EHC vs HC, approx vs true graph
  Fig 6/7 + Table II  bench_construction  recall vs dim, scanning rates
  Table III bench_datasets         per-dataset scanning rate + recall
  Fig 9/10  bench_search           recall vs speed-up over brute
  §IV-D     bench_refine           local-join refinement rounds
  §IV-C     bench_lifecycle        sustained churn (insert/remove/query)

The dry-run/roofline numbers (EXPERIMENTS.md §Dry-run/§Roofline) come from
``repro.launch.dryrun`` — they need the 512-device XLA flag and therefore a
fresh interpreter, not this driver.
"""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5_000,
                    help="dataset size (paper scale: 100k+; default fits CI)")
    ap.add_argument("--quick", action="store_true", help="tiny sizes, smoke only")
    ap.add_argument("--ci-out", type=str, default=None, metavar="PATH",
                    help="write the machine-readable benchmark record "
                         "(BENCH_ci.json) for benchmarks.ci_gate")
    ap.add_argument("--hier", action="store_true",
                    help="include the hierarchical-seeding gate "
                         "(bench_search.hier_gate, n=10^5 — minutes-long; "
                         "bench-smoke CI only, never tier-1)")
    ap.add_argument("--hier-n", type=int, default=100_000, metavar="N",
                    help="dataset size for --hier (floors are calibrated at "
                         "the canonical 100000)")
    ap.add_argument("--precision", action="store_true",
                    help="include the compressed-engine gate "
                         "(bench_search.run_precision: int8 gather speedup "
                         "at n=2^17/d=256/C=512 + PQ rank-then-rerank recall "
                         "delta — large-allocation bench, opt-in like --hier)")
    ap.add_argument("--parallel", action="store_true",
                    help="include the parallel-build gate "
                         "(bench_construction.parallel_gate: build_parallel "
                         "vs build at n=4000/d=20 — wallclock_ratio ceiling-"
                         "gated below 1.0 AND merged recall@10 floor-gated; "
                         "opt-in like --hier, bench-smoke runs it)")
    ap.add_argument("--serving", action="store_true",
                    help="include the sustained-load serving gate "
                         "(bench_serving.serving_gate: ServingLoop under "
                         "interleaved query bursts + churn; recall@10 "
                         "floored, p99/p50 ratio ceiling-gated, latency/QPS "
                         "recorded; writes the tracker JSONL trace next to "
                         "--ci-out)")
    args = ap.parse_args()
    n = 2000 if args.quick else args.n

    from benchmarks import (
        bench_brute,
        bench_construction,
        bench_datasets,
        bench_lifecycle,
        bench_refine,
        bench_search,
        bench_search_baseline,
        bench_serving,
        common,
    )

    t0 = time.time()
    # the compressed-engine gate allocates a 256 MB fp32 table plus its
    # bf16/int8 companions, so it is opt-in like --hier; it is also measured
    # FIRST, before the suite churns the allocator and LLC — the gated
    # quantity is a DRAM-bandwidth ratio, and measuring it against a clean
    # memory system is the reproducible ordering (when the record is present
    # ci_gate always applies both its bounds)
    precision = (bench_search.run_precision()
                 if args.precision and args.ci_out else None)
    tables = {}
    tables["brute"] = bench_brute.run(
        n, datasets=bench_brute.DATASETS[: 2 if args.quick else 4])
    tables["search_baseline"] = bench_search_baseline.run(n)
    tables["construction"] = bench_construction.run(
        n, dims=(2, 5) if args.quick else (2, 5, 10, 20))
    tables["datasets"] = bench_datasets.run(
        n, datasets=bench_datasets.DATASETS[: 2 if args.quick else 4])
    tables["search"] = bench_search.run(
        n, datasets=bench_search.DATASETS[: 1 if args.quick else 3])
    tables["refine"] = bench_refine.run(n, rounds=1 if args.quick else 3)
    tables["lifecycle"] = bench_lifecycle.run(
        min(n, 2000), rounds=3 if args.quick else 6)

    if args.ci_out:
        # gate metrics run at their FIXED canonical shapes (n=5k/d=20 for the
        # expansion kernel, n=2k/d=20 for build quality, n=8192 with
        # d∈{16,64,256} x C∈{32,128,512} for the distance engine),
        # independent of --n, so the committed baseline stays comparable
        expansion = bench_search.run_expansion()
        quality = bench_construction.quality_gate()
        gather_engine = bench_search.run_gather_engine()
        lifecycle_churn = bench_lifecycle.churn_gate()
        merge_build = bench_construction.merge_build_gate()
        # the hierarchical-seeding gate runs at paper scale (n=10^5) and is
        # therefore opt-in: the bench-smoke CI job passes --hier; quick local
        # --ci-out runs skip it and ci_gate tolerates the absent record
        hier = bench_search.hier_gate(n=args.hier_n) if args.hier else None
        # the serving gate drives the instrumented ServingLoop and writes its
        # JsonlTracker trace next to the CI artifact (uploaded together by
        # the bench-smoke job); opt-in with the same absent-record rule
        # the parallel-build gate times build_parallel against build at its
        # tuned shape (median-of-5, both pipelines warmed); opt-in with the
        # same absent-record rule
        parallel = (bench_construction.parallel_gate()
                    if args.parallel else None)
        serving = None
        if args.serving:
            trace_path = os.path.splitext(args.ci_out)[0] + "_trace.jsonl"
            serving = bench_serving.serving_gate(trace_path=trace_path)
            print(f"wrote {trace_path}")
        payload = {
            "expansion": expansion[16],  # serving batch — the gated record
            "expansion_wave": expansion[256],  # construction wave — recorded
            "quality": quality,
            "gather_engine": gather_engine,  # blocked-vs-rowwise (gated)
            # sustained-churn record: recall gated, throughput informational
            "lifecycle_churn": lifecycle_churn,
            # divide-and-conquer build: merged+refined recall gated at the
            # sequential floor, wall-clock ratio informational
            "merge_build": merge_build,
            "sections": {
                name: t.records()
                for name, t in tables.items()
                if hasattr(t, "records")
            },
        }
        if hier is not None:
            # coarse-seeding quality at n=10^5: recall AND scanning rate
            # both gated; the random-seed baseline rides along inside
            payload["hier_gate"] = hier
        if parallel is not None:
            # divide-and-conquer build, tuned path: wallclock_ratio gated
            # as a CEILING (< 1.0 = parallel actually wins) AND merged
            # recall@10 gated at the same 0.95 floor as merge_build
            payload["parallel_gate"] = parallel
        if precision is not None:
            # compressed engine: int8 gather speedup floor-gated, PQ
            # rank-then-rerank recall delta ceiling-gated; bf16 informational
            payload["precision_gate"] = precision
        if serving is not None:
            # sustained-load serving: recall@10 floored, p99/p50 ratio
            # ceiling-gated (harness sanity); latency + QPS informational
            payload["serving_load"] = serving
        common.emit_json(args.ci_out, payload)
        print(f"wrote {args.ci_out}")
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s (n={n})")


if __name__ == "__main__":
    main()
