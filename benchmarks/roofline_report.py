"""Render the §Roofline table from dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.roofline_report dryrun_single.json
"""

from __future__ import annotations

import json
import sys


def fmt_t(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.1f}us"


def render(records: list[dict]) -> str:
    rows = []
    head = (
        "| arch | shape | mesh | t_compute | t_memory (lo–hi) | t_collective | dominant "
        "| peak GiB/dev | useful | roofline frac |"
    )
    rows.append(head)
    rows.append("|" + "---|" * 10)
    for r in records:
        if r.get("status") == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"SKIP | — | — | — |"
            )
            continue
        if r.get("status") != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"FAIL: {r.get('error','')[:40]} | — | — | — |"
            )
            continue
        # memory bounds: t_memory (cost_analysis "bytes accessed") assumes
        # every HLO op round-trips HBM — an UPPER bound under fusion; the
        # LOWER bound touches each resident byte once (peak + args)
        lower = (r["bytes_per_device"] + r.get("arg_bytes_per_device", 0)) / 819e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_t(r['t_compute_s'])} | {fmt_t(lower)}–{fmt_t(r['t_memory_s'])} "
            f"| {fmt_t(r['t_collective_s'])} | **{r['dominant']}** "
            f"| {r['bytes_per_device']/2**30:.2f} "
            f"| {r.get('useful_ratio', float('nan')):.3f} "
            f"| {r.get('roofline_fraction', float('nan')):.3f} |"
        )
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_single.json"
    with open(path) as f:
        records = json.load(f)
    print(render(records))


if __name__ == "__main__":
    main()
