"""Render the §Roofline table from dry-run JSON records — and the
fused-expansion roofline from a BENCH_ci.json benchmark record.

    PYTHONPATH=src python -m benchmarks.roofline_report dryrun_single.json
    PYTHONPATH=src python -m benchmarks.roofline_report BENCH_ci.json

The input kind is sniffed: a list is a dry-run record set; a dict with an
``expansion`` key is a ``benchmarks.run --ci-out`` emit, rendered as the
fused-vs-unfused expansion throughput + arithmetic-intensity table.
"""

from __future__ import annotations

import json
import sys


def fmt_t(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.1f}us"


def render(records: list[dict]) -> str:
    rows = []
    head = (
        "| arch | shape | mesh | t_compute | t_memory (lo–hi) | t_collective | dominant "
        "| peak GiB/dev | useful | roofline frac |"
    )
    rows.append(head)
    rows.append("|" + "---|" * 10)
    for r in records:
        if r.get("status") == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"SKIP | — | — | — |"
            )
            continue
        if r.get("status") != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"FAIL: {r.get('error','')[:40]} | — | — | — |"
            )
            continue
        # memory bounds: t_memory (cost_analysis "bytes accessed") assumes
        # every HLO op round-trips HBM — an UPPER bound under fusion; the
        # LOWER bound touches each resident byte once (peak + args)
        lower = (r["bytes_per_device"] + r.get("arg_bytes_per_device", 0)) / 819e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_t(r['t_compute_s'])} | {fmt_t(lower)}–{fmt_t(r['t_memory_s'])} "
            f"| {fmt_t(r['t_collective_s'])} | **{r['dominant']}** "
            f"| {r['bytes_per_device']/2**30:.2f} "
            f"| {r.get('useful_ratio', float('nan')):.3f} "
            f"| {r.get('roofline_fraction', float('nan')):.3f} |"
        )
    return "\n".join(rows)


def render_expansion(rec: dict) -> str:
    """Roofline view of the fused EHC expansion step (bench_search
    .expansion_bench record): throughput per path, the fused speed-up, and
    the step's arithmetic intensity — at ~0.05 flop/byte the expansion is
    deeply memory-bound, which is exactly why fusing away the per-stage HBM
    round trips (not adding flops) is the lever on scanning rate."""
    rows = [
        "### Fused expansion step "
        f"(n={rec['n']}, d={rec['d']}, B={rec['B']}, {rec['metric']})",
        "| path | expansions/s | ms/step | speedup | flops/step | bytes/step | arith intensity |",
        "|" + "---|" * 7,
    ]
    steps = rec["steps"]
    for path_name, t_key, tp_key, spd in (
        ("fused (one kernel/step)", "t_fused_s", "fused_expansions_per_s",
         rec["speedup"]),
        ("unfused op chain", "t_unfused_s", "unfused_expansions_per_s", 1.0),
    ):
        rows.append(
            f"| {path_name} | {rec[tp_key]:.3g} "
            f"| {1e3 * rec[t_key] / steps:.3f} | {spd:.2f}x "
            f"| {rec['flops_per_step']:.3g} | {rec['bytes_per_step']:.3g} "
            f"| {rec['arith_intensity']:.4f} |"
        )
    return "\n".join(rows)


def render_gather_engine(rec: dict) -> str:
    """Blocked-vs-rowwise gather-distance table (bench_search
    .gather_engine_bench records): the norms-decomposed GEMM engine against
    the per-row difference reduction it replaced, across d x C.  The blocked
    engine's flops are the same order — the win is doing them in GEMM shape
    (MXU-eligible, one reduction pass per block) with the ‖x‖² term served
    from the graph-resident cache instead of re-reduced per candidate."""
    rows = [
        "### Gather-distance engine: blocked (norms decomposition) vs rowwise",
        "| d | C | blocked | rowwise | speedup |",
        "|" + "---|" * 5,
    ]
    for r in rec["records"]:
        rows.append(
            f"| {r['d']} | {r['C']} | {fmt_t(r['t_blocked_s'])} "
            f"| {fmt_t(r['t_rowwise_s'])} | {r['speedup']:.2f}x |"
        )
    g = rec["gated"]
    rows.append(
        f"\nGated record (d={g['d']}, C={g['C']}): "
        f"{g['speedup']:.2f}x blocked-vs-rowwise."
    )
    return "\n".join(rows)


def render_precision(rec: dict) -> str:
    """Compressed-engine table (bench_search.run_precision record): bytes
    fetched per candidate per precision against measured gather throughput.
    The expansion loop sits at ~0.05 flop/byte, so bytes/candidate IS the
    roofline lever — the table shows how much of each representation's byte
    ratio survives the dequant ALU cost (int8 gated; bf16 informational;
    PQ's per-candidate fetch is the code table, whose first-pass rank is
    bounded by the rerank recall delta instead of a throughput floor)."""
    from repro.kernels import precision as precision_lib

    g = rec["gather"]
    d = g["d"]
    rows = [
        "### Compressed distance engine "
        f"(n={g['n']}, d={d}, B={g['B']}, C={g['C']}, cold rotating ids)",
        "| precision | bytes/dim | bytes/candidate | t/pass | speedup vs fp32 |",
        "|" + "---|" * 5,
    ]
    for prec in ("fp32", "bf16", "int8", "pq"):
        bpd = precision_lib.bytes_per_dim(prec)
        t_key = "t_fp32_s" if prec == "fp32" else f"t_{prec}_s"
        t = fmt_t(g[t_key]) if t_key in g else "—"
        spd = (f"{g[f'{prec}_speedup']:.2f}x" if f"{prec}_speedup" in g
               else ("1.00x" if prec == "fp32" else "—"))
        rows.append(f"| {prec} | {bpd:g} | {bpd * d:g} | {t} | {spd} |")
    r = rec["rerank"]
    rows.append(
        f"\nPQ rank-then-rerank: recall@10 {r['recall_at_10_pq']:.4f} vs "
        f"fp32 {r['recall_at_10_fp32']:.4f} "
        f"(delta {r['recall_delta']:+.4f}, ceiling-gated)."
    )
    return "\n".join(rows)


def render_serving(rec: dict) -> str:
    """Sustained-load serving table (bench_serving.serving_gate record):
    latency percentiles, throughput, and the Eq.-2-extended serving scanning
    rate side by side.  Latency and QPS are wall-clock (informational on
    shared runners); the scanning rate and comps/query are exact device
    counts — the pair is the serving roofline: comps/query is the work, the
    latency percentiles are what the machine made of it."""
    rows = [
        "### Sustained-load serving "
        f"(n={rec['n']}, d={rec['d']}, {rec['rounds']} rounds x "
        f"{rec['burst']}-query bursts, churn {rec['churn']}-in/"
        f"{rec['churn']}-out x{rec['churn_events']}, search k={rec['top_k']})",
        "| served | waves | QPS | p50 | p99 | p99/p50 | comps/q "
        "| scan rate | hash sat | recall@10 (fresh / served) |",
        "|" + "---|" * 10,
        (
            f"| {rec['n_served']} | {rec['n_waves']} | {rec['qps']:.1f} "
            f"| {rec['p50_latency_ms']:.1f}ms | {rec['p99_latency_ms']:.1f}ms "
            f"| {rec['p99_p50_ratio']:.2f} | {rec['comps_per_query']:.0f} "
            f"| {rec['scanning_rate']:.4f} "
            f"| {rec['hash_saturation_ratio']:.3f} "
            f"| {rec['recall_at_10']:.4f} / {rec['recall_at_10_served']:.4f} |"
        ),
    ]
    rows.append(
        f"\nGated: recall@10 {rec['recall_at_10']:.4f} (floored), "
        f"p99/p50 {rec['p99_p50_ratio']:.2f} (sanity ceiling); latency/QPS "
        f"recorded ungated."
    )
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_single.json"
    with open(path) as f:
        records = json.load(f)
    if isinstance(records, dict) and "expansion" in records:
        print(render_expansion(records["expansion"]))
        if "expansion_wave" in records:
            print()
            print(render_expansion(records["expansion_wave"]))
        if "gather_engine" in records:
            print()
            print(render_gather_engine(records["gather_engine"]))
        if "precision_gate" in records:
            print()
            print(render_precision(records["precision_gate"]))
        if "serving_load" in records:
            print()
            print(render_serving(records["serving_load"]))
        return
    print(render(records))


if __name__ == "__main__":
    main()
