"""Paper Fig. 5: the baseline search algorithm (EHC, Alg. 1) vs plain HC,
on an NN-Descent graph and on the TRUE k-NN graph.

Shows (a) reverse edges buy recall at equal beam budgets, (b) approximate
vs true graph makes little difference — both paper claims.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import brute, nndescent
from repro.core import search as search_lib
from repro.core.graph import KNNGraph, rebuild_reverse, row_scales, squared_norms


def true_graph(x, k: int, metric: str) -> KNNGraph:
    n = x.shape[0]
    sq = squared_norms(x)
    ids, dists = brute.brute_force_knn(
        x, x, k, metric, exclude_ids=jnp.arange(n, dtype=jnp.int32),
        use_pallas=False, sq_norms=sq,
    )
    g = KNNGraph(
        nbr_ids=ids,
        nbr_dist=dists,
        nbr_lam=jnp.zeros_like(ids),
        rev_ids=jnp.full((n, 2 * k), -1, jnp.int32),
        rev_lam=jnp.zeros((n, 2 * k), jnp.int32),
        rev_ptr=jnp.zeros((n,), jnp.int32),
        alive=jnp.ones((n,), bool),
        n_valid=jnp.asarray(n, jnp.int32),
        sq_norms=sq,
        row_scale=row_scales(x),
    )
    return rebuild_reverse(g)


def run(n: int = 10_000, d: int = 32, n_q: int = 200, k: int = 20, metric: str = "l2", seed: int = 0):
    x, q = common.dataset_with_queries("clustered", n, n_q, d, seed)
    true_ids = common.ground_truth(x, q, 1, metric)

    ncfg = nndescent.NNDescentConfig(k=k, metric=metric, max_iters=10, use_pallas=False, node_chunk=1024)
    g_nnd, _ = nndescent.build(x, ncfg, jax.random.PRNGKey(seed))
    g_true = true_graph(x, k, metric)

    tbl = common.Table(
        "baseline search: EHC vs HC on approx/true graphs (Fig 5)",
        ["graph", "algo", "beam", "recall@1", "avg_comps", "ms/query"],
    )
    for gname, g in (("NN-Descent", g_nnd), ("true-kNN", g_true)):
        for algo, use_rev in (("EHC", True), ("HC", False)):
            for beam in (8, 16, 32, 64):
                # k == beam: the termination horizon IS the search-depth
                # knob the paper sweeps (recall measured at top-1)
                scfg = search_lib.SearchConfig(
                    k=beam, beam=beam, n_seeds=8, metric=metric,
                    use_reverse=use_rev, dispatch="reference",
                )
                fn = lambda: search_lib.search(g, x, q, jax.random.PRNGKey(7), scfg)
                t = common.timeit(fn, iters=2)
                res = fn()
                rec = common.search_recall(jax.device_get(res.ids), true_ids, 1)
                comps = float(jnp.mean(res.n_comps))
                tbl.add(gname, algo, beam, rec, comps, 1e3 * t / n_q)
    tbl.show()
    return tbl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(2000 if args.quick else args.n)


if __name__ == "__main__":
    main()
