"""knn-search iteration 5b: attack the measured bottleneck (per-iteration
hash/beam bookkeeping bytes, NOT vector data — it.5a refuted bf16-data).

Variant: probes 8->4, reverse-λ twin lookup off (saves two (B,R,k) gathers
per expansion), beam 40->32.  Search quality at these settings is measured
separately on CPU (see EXPERIMENTS §Perf it.5 quality check).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import sys
import time

import jax

sys.path.insert(0, "src")
from repro.configs import cells  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import roofline  # noqa: E402
import repro.configs.knn_lgd as kl  # noqa: E402
from repro.core import search as search_lib  # noqa: E402
from repro.core import construct as construct_lib  # noqa: E402

mesh = mesh_lib.make_production_mesh(multi_pod=False)

orig = kl.full_config


def lean():
    return dataclasses.replace(orig(), beam=32, hash_slots=2048)


# monkeypatch the search config the cell builds: fewer probes + no rev-λ
_orig_sc = construct_lib.BuildConfig.search_config


def lean_sc(self):
    sc = _orig_sc(self)
    return dataclasses.replace(sc, hash_probes=4, lgd_rev_lambda=False)


kl.full_config = lean
construct_lib.BuildConfig.search_config = lean_sc

c = cells.plan("knn-lgd", "search_4k", mesh)
t0 = time.time()
with mesh:
    comp = cells.lower(c).compile()
rec = roofline.analyze(comp, mesh, model_flops=c.model_flops, loop_factor=c.loop_factor)
print(f"[lean-bookkeeping] t_comp={rec['t_compute_s']:.4f}s t_mem={rec['t_memory_s']:.4f}s "
      f"t_coll={rec['t_collective_s']:.4f}s peak={rec['bytes_per_device']/2**30:.3f}GiB "
      f"({time.time()-t0:.0f}s compile)")
