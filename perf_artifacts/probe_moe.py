"""Fast A/B probe for the mixtral train cell sharding (2 layers, 8 devices).

Variants:
  a) baseline: FSDP + activation constraints, NO weight use-constraints
  b) + weight use-constraints (_use_constrain_layer)
  c) b) but model-only param sharding (no FSDP)

Reports flops/bytes/collectives per variant; per-layer marginal cost via a
3-layer minus 2-layer diff would isolate embed/head, but 2 layers at 1/16 the
full depth is enough to rank variants.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import sys
import time

import jax
from jax.sharding import AxisType

sys.path.insert(0, "src")
from repro.configs import cells  # noqa: E402
from repro.launch import roofline  # noqa: E402
from repro.models import transformer as tfm  # noqa: E402

mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,) * 2)

VARIANT = sys.argv[1] if len(sys.argv) > 1 else "b"
N_LAYERS = int(sys.argv[2]) if len(sys.argv) > 2 else 2

import repro.configs.mixtral_8x7b as mix

orig_full = mix.full_config


def small_full():
    return dataclasses.replace(orig_full(), n_layers=N_LAYERS)


mix.full_config = small_full

if VARIANT == "a":
    tfm._use_constrain_layer_orig = tfm._use_constrain_layer
    tfm._use_constrain_layer = lambda lp, cfg: lp
elif VARIANT == "d":
    # MoE weights only
    _orig = tfm._use_constrain_layer
    def _moe_only(lp, cfg):
        out = _orig(lp, cfg)
        for k in ("wq", "wk", "wv", "wo", "dense_gate", "dense_up", "dense_down"):
            if k in lp:
                out[k] = lp[k]
        return out
    tfm._use_constrain_layer = _moe_only
elif VARIANT == "e":
    # attention weights only
    _orig = tfm._use_constrain_layer
    def _attn_only(lp, cfg):
        out = _orig(lp, cfg)
        for k in ("w_gate", "w_up", "w_down"):
            if k in lp:
                out[k] = lp[k]
        return out
    tfm._use_constrain_layer = _attn_only

c = cells.plan("mixtral-8x7b", "train_4k", mesh)
if VARIANT == "c":
    # model-only param sharding
    pspecs = tfm.param_pspecs(small_full(), fsdp=False)
    from repro.train import optimizer as opt_lib
    ocfg = opt_lib.OptConfig(name="adamw")
    params_shapes = c.args[0]
    opt_specs = opt_lib.opt_state_pspecs(pspecs, params_shapes, ocfg)
    c = dataclasses.replace(
        c, in_shardings=(cells._ns(mesh, pspecs), cells._ns(mesh, opt_specs),
                         c.in_shardings[2]))

t0 = time.time()
with mesh:
    comp = cells.lower(c).compile()
rec = roofline.analyze(comp, mesh, model_flops=None)
print(f"variant={VARIANT} L={N_LAYERS}: {time.time()-t0:.0f}s "
      f"TF/dev={rec['hlo_gflops']/1e3:.1f} GBacc={rec['hlo_gbytes']:.0f} "
      f"peakGiB={rec['bytes_per_device']/2**30:.1f} "
      f"coll={rec['collective_gbytes']:.0f}GB "
      f"breakdown={{k: round(v/1e9) for k, v in rec['collective_breakdown'].items()}}")
print("  breakdown:", {k: round(v / 1e9) for k, v in rec["collective_breakdown"].items()})
