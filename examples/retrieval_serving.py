"""End-to-end retrieval serving: MIND interests -> LGD-graph ANN index.

    PYTHONPATH=src python examples/retrieval_serving.py

The paper's own production scenario (§IV-C e-shopping): a live item catalog
indexed by online LGD construction, queried by the MIND recommender's
interest vectors, with items joining and leaving the catalog — no rebuilds.
Compares the graph path against exact brute-force retrieval.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import recsys
from repro.serve import retrieval

N_ITEMS, D = 8000, 16


def main():
    key = jax.random.PRNGKey(0)

    # a trained-ish MIND encoder (random params suffice for the demo)
    cfg = recsys.RecsysConfig(
        name="mind", vocab_per_field=N_ITEMS, embed_dim=D,
        n_interests=4, capsule_iters=3, mlp=(32,), seq_len=12,
    )
    params = recsys.init_params(key, cfg)
    items = params["table"][:N_ITEMS]  # serve directly from the item table
    items = items / jnp.maximum(jnp.linalg.norm(items, axis=1, keepdims=True), 1e-9)

    t0 = time.time()
    index = retrieval.build_index(
        items, k=16, metric="ip", wave=512, capacity=N_ITEMS + 2000,
        key=jax.random.PRNGKey(1),
    )
    print(f"indexed {N_ITEMS} items with online LGD in {time.time()-t0:.1f}s")

    # a user arrives: history -> 4 interest vectors -> ANN retrieval
    hist = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, N_ITEMS)
    interests = recsys.mind_interests(params, hist, cfg)[0]
    interests = interests / jnp.maximum(
        jnp.linalg.norm(interests, axis=1, keepdims=True), 1e-9)

    t0 = time.time()
    ids, scores = retrieval.retrieve(index, interests, 20, beam=48)
    t_ann = time.time() - t0
    t0 = time.time()
    bids, _ = retrieval.retrieve_brute(index, interests, 20)
    t_brute = time.time() - t0
    overlap = len(set(np.asarray(ids).tolist()) & set(np.asarray(bids).tolist()))
    print(f"top-20 via LGD graph: overlap {overlap}/20 with exact, "
          f"{t_brute/max(t_ann,1e-9):.1f}x speed-up ({t_ann*1e3:.0f}ms vs {t_brute*1e3:.0f}ms)")

    # catalog churn: 300 new products listed, 200 withdrawn — no rebuild
    new_items = jax.random.normal(jax.random.PRNGKey(3), (300, D))
    new_items = new_items / jnp.linalg.norm(new_items, axis=1, keepdims=True)
    index = retrieval.add_items(index, new_items, key=jax.random.PRNGKey(4))
    index = retrieval.remove_items(index, jnp.arange(200, dtype=jnp.int32))
    ids2, _ = retrieval.retrieve(index, interests, 20, beam=48)
    assert not (set(np.asarray(ids2).tolist()) & set(range(200)))
    print(f"catalog churn applied online: +300 / -200 items, retrieval still "
          f"serving (no withdrawn items returned)")


if __name__ == "__main__":
    main()
