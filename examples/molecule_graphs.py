"""The paper's technique inside the GNN pipeline: k-NN graphs for MACE.

    PYTHONPATH=src python examples/molecule_graphs.py

For large point clouds, MACE's neighbor graph is built with the paper's
online LGD construction instead of brute force — the same index then serves
structure-similarity queries.  Demonstrates DESIGN.md §5 (mace row).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import BuildConfig, build
from repro.core import brute
from repro.models import mace

N_ATOMS, K = 3000, 8


def main():
    key = jax.random.PRNGKey(0)
    # one large periodic-ish structure: clustered atom positions
    pos = jax.random.uniform(key, (N_ATOMS, 3)) * 30.0
    species = jax.random.randint(jax.random.fold_in(key, 1), (N_ATOMS,), 0, 4)

    # --- neighbor graph via the paper's online construction -----------------
    cfg = BuildConfig(k=K, metric="l2", wave=256, lgd=True, dispatch="reference")
    t0 = time.time()
    g, stats = build(pos, cfg, key)
    c = float(stats.n_comps) / (N_ATOMS * (N_ATOMS - 1) / 2)
    print(f"LGD neighbor graph over {N_ATOMS} atoms in {time.time()-t0:.1f}s "
          f"(scanning rate {c:.4f})")

    tids, _ = brute.brute_force_knn(
        pos, pos, K, "l2", exclude_ids=jnp.arange(N_ATOMS, dtype=jnp.int32),
        use_pallas=False)
    rec = float(brute.recall_at_k(g.nbr_ids, tids, K))
    print(f"edge recall vs exact radius graph: {rec:.3f}")

    # --- consume the graph in MACE ------------------------------------------
    nbr = np.asarray(g.nbr_ids)
    valid = nbr >= 0
    receivers = np.repeat(np.arange(N_ATOMS, dtype=np.int32), K)[valid.reshape(-1)]
    senders = nbr.reshape(-1)[valid.reshape(-1)].astype(np.int32)
    mcfg = mace.MACEConfig(n_layers=2, d_hidden=32, n_rbf=8, n_species=4,
                           readout_hidden=16, r_cut=6.0)
    params = mace.init_params(jax.random.PRNGKey(2), mcfg)
    t0 = time.time()
    e = mace.energy(params, pos, species, jnp.asarray(senders),
                    jnp.asarray(receivers), mcfg)
    f = mace.forces(params, pos, species, jnp.asarray(senders),
                    jnp.asarray(receivers), mcfg)
    print(f"MACE energy {float(e):.3f} + forces {f.shape} over the LGD graph "
          f"in {time.time()-t0:.1f}s (max |F| = {float(jnp.max(jnp.abs(f))):.3f})")


if __name__ == "__main__":
    main()
