"""Index lifecycle end to end: build -> snapshot -> restore -> churn -> compact.

    PYTHONPATH=src python examples/lifecycle.py

The paper's index is online — samples join and leave without a rebuild — and
the lifecycle subsystem (``repro.index``) makes it long-lived too: the graph
survives the process through versioned snapshots, removed rows are recycled
instead of leaking capacity, and small inserts coalesce into one wave.  This
walks a serving replica through its whole life at fixed capacity.
"""

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import OnlineIndex
from repro.core import brute
from repro.serve import retrieval

N, D, K = 4000, 16, 16


def recall(idx: OnlineIndex, q, k=10) -> float:
    true_ids, _ = brute.brute_force_knn(
        idx.items, q, k, idx.metric,
        n_valid=idx.graph.n_valid, alive=idx.graph.alive,
    )
    res = idx.search(q, 2 * k, beam=64, key=jax.random.PRNGKey(5))
    return float(brute.recall_at_k(res.ids, true_ids, k))


def main():
    key = jax.random.PRNGKey(0)
    items = jax.random.normal(key, (N, D))
    q = jax.random.normal(jax.random.PRNGKey(1), (32, D))

    # -- build: online LGD construction, no capacity headroom on purpose ----
    t0 = time.time()
    idx = retrieval.build_index(items, k=K, metric="l2", wave=512,
                                key=jax.random.PRNGKey(2))
    print(f"built {N}-item index in {time.time()-t0:.1f}s "
          f"(capacity {idx.capacity}), recall@10 {recall(idx, q):.3f}")

    # -- snapshot -> restore: the serving-replica handoff -------------------
    path = tempfile.mkdtemp(prefix="knn_snapshot_")
    t0 = time.time()
    idx.save(path)
    replica = OnlineIndex.load(path)
    ids_a, _ = retrieval.retrieve(idx, q[:4], 10, key=jax.random.PRNGKey(7))
    ids_b, _ = retrieval.retrieve(replica, q[:4], 10, key=jax.random.PRNGKey(7))
    assert np.array_equal(np.asarray(ids_a), np.asarray(ids_b))
    print(f"snapshot round trip ({path}) in {time.time()-t0:.1f}s — "
          f"restored replica serves bit-identical results")

    # -- churn: interleaved withdraw/list at FIXED capacity -----------------
    # removals feed the free-slot ledger; the next over-capacity insert
    # recycles those slots via compact() instead of growing the arrays
    rng = np.random.RandomState(3)
    t0 = time.time()
    for step in range(4):
        alive = np.flatnonzero(np.asarray(replica.graph.alive))
        replica.remove(jnp.asarray(rng.choice(alive, 128, replace=False)))
        new = jax.random.normal(jax.random.fold_in(key, 10 + step), (128, D))
        replica.add(new, key=jax.random.fold_in(key, 20 + step), flush=True)
    assert replica.capacity == N  # recycled, never grew
    print(f"4 rounds of 128-out/128-in churn in {time.time()-t0:.1f}s at "
          f"fixed capacity {replica.capacity}, "
          f"recall@10 {recall(replica, q):.3f}")

    # -- micro-batched ingest: trickling inserts coalesce into one wave -----
    for i in range(replica.ingest_batch - 1):
        replica.add(jax.random.normal(jax.random.fold_in(key, 100 + i), (1, D)))
    print(f"{replica.n_pending} single-item adds buffered "
          f"(graph untouched: n_valid {int(replica.graph.n_valid)})")
    n0 = int(replica.graph.n_valid)
    replica.add(jax.random.normal(jax.random.fold_in(key, 999), (1, D)))
    print(f"threshold hit -> ONE coalesced insertion wave "
          f"(n_valid {n0} -> {int(replica.graph.n_valid)})")

    # -- explicit compact: reclaim the tail after a big withdrawal ----------
    alive = np.flatnonzero(np.asarray(replica.graph.alive))
    replica.remove(jnp.asarray(alive[: len(alive) // 4]))
    print(f"withdrew 25%: {replica.free_slots} slots in the free ledger")
    id_map = replica.compact()
    moved = int((np.asarray(id_map) >= 0).sum())
    print(f"compact(): {moved} alive rows re-packed, "
          f"{replica.capacity - int(replica.graph.n_valid)} slots reclaimed, "
          f"recall@10 {recall(replica, q):.3f}")


if __name__ == "__main__":
    main()
