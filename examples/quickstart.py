"""Quickstart: build a k-NN graph online (LGD), search it, update it.

    PYTHONPATH=src python examples/quickstart.py

Covers the paper's full loop in ~a minute on CPU:
  1. online LGD construction over 5k clustered vectors (Alg. 3);
  2. k-NN search with EHC (Alg. 1) and recall vs exact brute force;
  3. dynamic updates: insert new samples / remove old ones (§IV-C).
"""

import time

import jax
import jax.numpy as jnp

from repro.core import BuildConfig, SearchConfig, brute, build, dynamic, search
from repro.core.graph import grow_graph
from repro.data import synthetic

N, D, K = 5000, 32, 10


def main():
    key = jax.random.PRNGKey(0)
    x = synthetic.clustered(key, N, D)

    # -- 1. online construction (the paper's contribution) -------------------
    cfg = BuildConfig(k=K, metric="l2", wave=256, lgd=True, use_pallas=False)
    t0 = time.time()
    g, stats = build(x, cfg, key)
    c = float(stats.n_comps) / (N * (N - 1) / 2)
    print(f"LGD graph built in {time.time()-t0:.1f}s — scanning rate c={c:.4f} "
          f"(brute force would be c=1.0)")

    tids, _ = brute.brute_force_knn(
        x, x, K, "l2", exclude_ids=jnp.arange(N, dtype=jnp.int32), use_pallas=False)
    rec = float(brute.recall_at_k(g.nbr_ids, tids, K))
    print(f"graph recall@{K} vs exact: {rec:.3f}")

    # -- 2. k-NN search over the graph ----------------------------------------
    q = synthetic.clustered(jax.random.PRNGKey(7), 100, D)
    scfg = SearchConfig(k=K, beam=40, use_lgd_mask=True, use_pallas=False)
    t0 = time.time()
    res = search(g, x, q, jax.random.PRNGKey(1), scfg)
    t_graph = time.time() - t0
    tq, _ = brute.brute_force_knn(x, q, 1, "l2", use_pallas=False)
    rec1 = float(brute.recall_at_k(res.ids[:, :1], tq, 1))
    comps = float(jnp.mean(res.n_comps))
    print(f"search recall@1 = {rec1:.3f} at {comps:.0f} distance comps/query "
          f"(vs {N} brute) in {t_graph*1e3:.0f}ms for 100 queries")

    # -- 3. dynamic updates ----------------------------------------------------
    extra = synthetic.clustered(jax.random.PRNGKey(9), 500, D)
    # grow_graph carries every field — incl. the ‖x‖² cache — forward
    grown = grow_graph(g, N + 500)
    x2 = jnp.concatenate([x, extra])
    g2, _ = dynamic.insert(grown, x2, 500, cfg, jax.random.PRNGKey(2))
    print(f"inserted 500 new samples online -> n_valid={int(g2.n_valid)}")

    g3 = dynamic.remove(g2, x2, jnp.arange(100, dtype=jnp.int32), "l2")
    print(f"removed 100 samples (λ repaired, §IV-C) — alive rows: "
          f"{int(jnp.sum(g3.alive))}")


if __name__ == "__main__":
    main()
