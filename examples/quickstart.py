"""Quickstart: build a k-NN graph online (LGD), search it, update it.

    PYTHONPATH=src python examples/quickstart.py

Covers the paper's full loop in ~a minute on CPU:
  1. online LGD construction over 5k clustered vectors (Alg. 3), with the
     two-level coarse entry-point structure (landmark sub-graph) built
     alongside — insertion searches seed from the coarse level instead of
     random rows, which is what keeps the scanning rate polylog-small at
     large n (ROADMAP item 1; gated at n=10^5 in CI);
  2. k-NN search with EHC (Alg. 1), coarse-seeded, and recall vs exact
     brute force;
  3. dynamic updates: insert new samples / remove old ones (§IV-C).
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import BuildConfig, SearchConfig, build, search
from repro.core import brute, dynamic
from repro.core.graph import grow_graph
from repro.data import synthetic

N, D, K = 5000, 32, 10


def main():
    key = jax.random.PRNGKey(0)
    # one draw, split into reference set + held-out queries (the paper's
    # protocol: queries share the data manifold)
    full = synthetic.clustered(key, N + 100, D)
    x, q = full[:N], full[N:]

    # -- 1. online construction (the paper's contribution) -------------------
    # seed_mode="coarse" builds a landmark sub-graph (core.hierarchy) with
    # the same machinery and routes every insertion search through it; the
    # coarse work is charged to n_comps, so the scanning rate below is honest.
    cfg = BuildConfig(k=K, metric="l2", wave=256, lgd=True, dispatch="reference",
                      seed_mode="coarse")
    t0 = time.time()
    g, stats, coarse = build(x, cfg, key, return_coarse=True)
    c = float(stats.n_comps) / (N * (N - 1) / 2)
    print(f"LGD graph built in {time.time()-t0:.1f}s — scanning rate c={c:.4f} "
          f"(brute force would be c=1.0); coarse level: "
          f"{coarse.n_landmarks} landmarks")

    tids, _ = brute.brute_force_knn(
        x, x, K, "l2", exclude_ids=jnp.arange(N, dtype=jnp.int32), use_pallas=False)
    rec = float(brute.recall_at_k(g.nbr_ids, tids, K))
    print(f"graph recall@{K} vs exact: {rec:.3f}")

    # -- 2. k-NN search over the graph ----------------------------------------
    scfg = SearchConfig(k=K, beam=40, use_lgd_mask=True, dispatch="reference",
                        seed_mode="coarse")
    t0 = time.time()
    res = search(g, x, q, jax.random.PRNGKey(1), scfg, coarse=coarse)
    t_graph = time.time() - t0
    tq, _ = brute.brute_force_knn(x, q, 1, "l2", use_pallas=False)
    rec1 = float(brute.recall_at_k(res.ids[:, :1], tq, 1))
    comps = float(jnp.mean(res.n_comps))
    print(f"coarse-seeded search recall@1 = {rec1:.3f} at {comps:.0f} distance "
          f"comps/query (vs {N} brute) in {t_graph*1e3:.0f}ms for 100 queries")

    # the same search with random seeding, for the delta the coarse level buys
    rres = search(g, x, q, jax.random.PRNGKey(1),
                  dataclasses.replace(scfg, seed_mode="random"))
    rrec1 = float(brute.recall_at_k(rres.ids[:, :1], tq, 1))
    print(f"random-seeded baseline:  recall@1 = {rrec1:.3f} at "
          f"{float(jnp.mean(rres.n_comps)):.0f} comps/query")

    # -- 3. dynamic updates ----------------------------------------------------
    extra = synthetic.clustered(jax.random.PRNGKey(9), 500, D)
    # grow_graph carries every field — incl. the ‖x‖² cache — forward
    grown = grow_graph(g, N + 500)
    x2 = jnp.concatenate([x, extra])
    g2, _, coarse = dynamic.insert(
        grown, x2, 500, cfg, jax.random.PRNGKey(2), coarse=coarse)
    print(f"inserted 500 new samples online -> n_valid={int(g2.n_valid)} "
          f"(coarse members appended in the same waves)")

    g3 = dynamic.remove(g2, x2, jnp.arange(100, dtype=jnp.int32), "l2")
    print(f"removed 100 samples (λ repaired, §IV-C) — alive rows: "
          f"{int(jnp.sum(g3.alive))}")


if __name__ == "__main__":
    main()
