"""Divide-and-conquer construction: partition -> build -> merge -> refine -> serve.

    PYTHONPATH=src python examples/parallel_build.py

The paper builds its k-NN graph by sequential online insertion, which caps
construction throughput at one wave pipeline.  The divide-and-conquer path
(PR 5) partitions the dataset, builds an independent sub-graph per partition
through the SAME fused wave pipeline (concurrently — host threads here, a
device mesh via ``construct.build_parallel(mesh=...)`` on real hardware),
folds the sub-graphs together with ``merge.symmetric_merge`` (each side's
points search the other side's graph; joint top-k per row; reverse lists
rebuilt canonically), and closes the residual recall gap with a bounded
NN-Descent sweep (``nndescent.refine``).

The merged graph lives in the same id space as a sequential build and keeps
the online property: inserts, removals, snapshots and sharded serving all
ride on it unchanged — demonstrated at the end by collapsing a sharded
router onto one index with ``ShardedIndex.merge_shards``.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ShardedIndex
from repro.core import brute, construct, merge, nndescent

N, D, K, SHARDS = 6000, 16, 16, 4


def graph_recall(g, x, k=10):
    true_ids, _ = brute.brute_force_knn(
        x, x, k, "l2", exclude_ids=jnp.arange(x.shape[0], dtype=jnp.int32),
        use_pallas=False,
    )
    return float(brute.recall_at_k(g.nbr_ids[:, :k], true_ids, k))


def main():
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    cfg = construct.BuildConfig(k=K, metric="l2", wave=256, dispatch="reference")

    # -- 1. sequential baseline: one wave pipeline --------------------------
    t0 = time.perf_counter()
    g_seq, _ = construct.build(x, cfg, jax.random.PRNGKey(1))
    t_seq = time.perf_counter() - t0
    print(f"sequential build: {t_seq:.1f}s  recall@10={graph_recall(g_seq, x):.4f}")

    # -- 2. partition + concurrent sub-builds + merge + refine, in one call -
    t0 = time.perf_counter()
    g_par, stats = construct.build_parallel(
        x, cfg, jax.random.PRNGKey(1), shards=SHARDS, refine_rounds=1
    )
    t_par = time.perf_counter() - t0
    print(f"{SHARDS}-shard parallel build: {t_par:.1f}s  "
          f"recall@10={graph_recall(g_par, x):.4f}  "
          f"scanning rate c={construct.scanning_rate(stats, N):.4f}")

    # -- 3. the same phases, spelled out ------------------------------------
    bounds = construct.partition_bounds(N, 2)
    ga, _ = construct.build(x[: bounds[1]], cfg, jax.random.PRNGKey(2))
    gb, _ = construct.build(x[bounds[1] :], cfg, jax.random.PRNGKey(3))
    g, _ = merge.symmetric_merge(ga, gb, x, cfg.search_config(),
                                 jax.random.PRNGKey(4))
    print(f"pairwise merge only:   recall@10={graph_recall(g, x):.4f}")
    g, _ = nndescent.refine(g, x, cfg.metric, rounds=1)
    print(f"after 1 refine round:  recall@10={graph_recall(g, x):.4f}")

    # -- 4. serving-side collapse: a sharded router becomes one index -------
    router = ShardedIndex.build(x, SHARDS, cfg, key=jax.random.PRNGKey(5))
    q = jax.random.normal(jax.random.PRNGKey(6), (4, D))
    exact_fan = [router.retrieve(q[i : i + 1], 10, brute=True)[0] for i in range(4)]
    router.merge_shards(refine_rounds=1, key=jax.random.PRNGKey(8))
    hits = 0
    for i in range(4):
        exact_one, _ = router.retrieve(q[i : i + 1], 10, brute=True)
        assert np.array_equal(exact_fan[i], exact_one)  # same catalog, same ids
        ids_g, _ = router.retrieve(q[i : i + 1], 10, beam=64,
                                   key=jax.random.PRNGKey(7))
        hits += len(set(ids_g.tolist()) & set(exact_one.tolist()))
    print(f"router collapse: {SHARDS} shards -> 1, exact results identical, "
          f"graph serving recall {hits}/40 (global ids preserved)")

    # the merged index stays online: churn keeps working
    gids = router.add(jax.random.normal(jax.random.PRNGKey(9), (8, D)))
    router.remove(np.asarray(gids[:4]))
    print(f"post-merge churn ok: n_items={router.n_items}")


if __name__ == "__main__":
    main()
