"""End-to-end driver: train a ~100M-param decoder LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

Uses the full production substrate — config zoo (gemma3-style local:global
attention), AdamW, deterministic skip-ahead loader, periodic checkpointing
with resume.  ``--tiny`` drops to a 2M model for CI-speed smoke runs.
"""

import argparse
import os
import tempfile
import time

import jax

from repro.data import loader
from repro.models import transformer as tfm
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train import train_loop


def make_config(tiny: bool) -> tfm.TransformerConfig:
    if tiny:
        return tfm.TransformerConfig(
            name="lm-2m", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
            d_ff=256, vocab=2048, local_global=(1, 1), local_window=64,
            remat=False, q_chunk=64, kv_chunk=64,
        )
    # ~100M params: 12L x 768, vocab 32k (GPT-2-small-ish with GQA + SWA mix)
    return tfm.TransformerConfig(
        name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab=32_000, local_global=(3, 1), local_window=256,
        remat=False, q_chunk=128, kv_chunk=128,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = make_config(args.tiny)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    ocfg = opt_lib.OptConfig(name="adamw", lr=3e-4 if not args.tiny else 3e-3)
    opt_state = opt_lib.init_opt_state(params, ocfg)
    step_fn = jax.jit(train_loop.make_train_step(
        lambda p, b: tfm.loss_fn(p, b["tokens"], cfg), ocfg))
    data = loader.lm_batches(args.batch, args.seq, cfg.vocab)

    ckpt_dir = args.ckpt or os.path.join(tempfile.gettempdir(), "lm_ckpt")
    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        params, opt_state, m = step_fn(params, opt_state, data.batch(step))
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if step % 20 == 0:
            tok_s = (step + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step:4d} loss {loss:.4f} ({tok_s:,.0f} tok/s)", flush=True)
        if (step + 1) % 100 == 0:
            ckpt_lib.save(ckpt_dir, (params, opt_state), step=step + 1)
    print(f"done: loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"in {time.time()-t0:.0f}s; checkpoints in {ckpt_dir}")
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
