"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates a REDUCED same-family config and runs one
forward/train step on CPU, asserting output shapes and no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import graphs, recsys_data
from repro.models import mace as mace_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.train import optimizer as opt_lib, train_loop

LM_ARCHS = ["mixtral-8x7b", "arctic-480b", "stablelm-1.6b", "qwen2.5-3b", "gemma3-1b"]
REC_ARCHS = ["deepfm", "xdeepfm", "bst", "mind"]


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(tree)
               if jnp.issubdtype(v.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestLMArchSmoke:
    def test_train_step(self, arch):
        cfg = configs.get(arch).smoke_config()
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        ocfg = opt_lib.OptConfig(name="adamw", lr=1e-3)
        opt = opt_lib.init_opt_state(params, ocfg)
        step = jax.jit(train_loop.make_train_step(
            lambda p, b: tfm.loss_fn(p, b["tokens"], cfg), ocfg))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
        params, opt, m = step(params, opt, {"tokens": tokens})
        assert np.isfinite(float(m["loss"])), arch
        assert _finite(params), arch

    def test_forward_shapes(self, arch):
        cfg = configs.get(arch).smoke_config()
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        tokens = jnp.zeros((2, 32), jnp.int32)
        logits, _ = tfm.forward(params, tokens, cfg)
        assert logits.shape == (2, 32, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_decode_step(self, arch):
        cfg = configs.get(arch).smoke_config()
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        cache = tfm.init_cache(cfg, 2, 16)
        logits, cache2 = tfm.decode_step(params, cache, jnp.zeros((2,), jnp.int32), cfg)
        assert logits.shape == (2, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert int(cache2["len"][0]) == 1


class TestMaceSmoke:
    def test_molecule(self):
        cfg = configs.get("mace").smoke_config("molecule")
        p = mace_lib.init_params(jax.random.PRNGKey(0), cfg)
        pos, spec = graphs.molecules(jax.random.PRNGKey(1), 4, 10)
        snds, rcvs = jax.vmap(lambda x: graphs.knn_edges_from_positions(x, 3))(pos)
        batch = dict(positions=pos, species=spec, senders=snds, receivers=rcvs,
                     energy=jnp.zeros((4,)))
        loss, m = mace_lib.energy_loss(p, batch, cfg)
        assert np.isfinite(float(loss))

    @pytest.mark.parametrize("shape", ["full_graph_sm", "minibatch_lg", "ogb_products"])
    def test_citation_regimes(self, shape):
        cfg = configs.get("mace").smoke_config(shape)
        p = mace_lib.init_params(jax.random.PRNGKey(0), cfg)
        g = graphs.random_graph(jax.random.PRNGKey(1), 60, 240, cfg.d_node_feat,
                                n_classes=cfg.n_classes)
        batch = dict(
            positions=jnp.zeros((60, 3)), species=jnp.zeros((60,), jnp.int32),
            senders=g.senders, receivers=g.receivers, node_feat=g.features,
            labels=g.labels,
        )
        loss, m = mace_lib.node_class_loss(p, batch, cfg)
        assert np.isfinite(float(loss)) and np.isfinite(float(m["acc"]))


@pytest.mark.parametrize("arch", REC_ARCHS)
class TestRecsysArchSmoke:
    def test_train_and_serve(self, arch):
        cfg = configs.get(arch).smoke_config()
        p = recsys_lib.init_params(jax.random.PRNGKey(0), cfg)
        if arch in ("deepfm", "xdeepfm"):
            b = recsys_data.ctr_batch(jax.random.PRNGKey(1), 32, cfg.n_sparse,
                                      cfg.vocab_per_field)
        else:
            b = recsys_data.behavior_batch(jax.random.PRNGKey(1), 32, cfg.seq_len,
                                           cfg.vocab_per_field)
        loss, m = recsys_lib.loss_fn(p, b, cfg)
        assert np.isfinite(float(loss)), arch
        s = recsys_lib.serve_scores(p, b, cfg)
        assert s.shape == (32,) and bool(jnp.all((s >= 0) & (s <= 1)))


class TestKnnArchSmoke:
    @pytest.mark.parametrize("arch", ["knn-lgd", "knn-olg"])
    def test_build_and_search(self, arch):
        from repro.core import brute, construct
        from repro.core import search as search_lib

        cfg = configs.get(arch).smoke_config()
        x = jax.random.uniform(jax.random.PRNGKey(0), (400, 12))
        g, stats = construct.build(x, cfg, jax.random.PRNGKey(1))
        assert int(g.n_valid) == 400
        tids, _ = brute.brute_force_knn(x, x[:50], 1, "l2", use_pallas=False)
        res = search_lib.search(g, x, x[:50], jax.random.PRNGKey(2), cfg.search_config())
        rec = float(brute.recall_at_k(res.ids[:, :1], tids, 1))
        assert rec > 0.8, (arch, rec)


class TestRegistry:
    def test_all_cells_enumerates_40(self):
        cells = configs.all_cells(include_knn=False)
        assert len(cells) == 40  # 10 archs x 4 shapes
        skipped = [c for c in cells if c[2]]
        assert len(skipped) == 3  # full-attention long_500k skips

    def test_every_arch_has_full_and_smoke(self):
        for arch in configs.names():
            mod = configs.get(arch)
            assert callable(mod.full_config) and callable(mod.smoke_config)
            assert mod.SHAPES and isinstance(mod.SKIP, dict)
