"""Visited-hash (D array) saturation accounting.

Pins the PR-6 bugfix: a saturated per-lane hash used to fail SILENTLY — the
search kept charging n_comps for evaluations it could no longer record (and
could re-evaluate), so the scanning-rate ledger drifted with no signal.  Now:

  * ``SearchConfig.hash_slots=None`` auto-sizes H from (beam, max_iters) —
    and the formula deliberately lands on the historical H=2048 for both
    long-standing default shapes, so nothing recompiles or slows down;
  * ``SearchResult.hash_full`` is the per-lane ground truth: True iff some
    computed distance was NOT recorded (saturation or slot collision).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import construct
from repro.core import search as search_lib
from repro.core.search import SearchConfig, auto_hash_slots

N, D = 400, 8


@pytest.fixture(scope="module")
def graph_and_data():
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.rand(N, D).astype(np.float32))
    cfg = construct.BuildConfig(
        k=8, metric="l2", wave=128, lgd=True, beam=24, n_seeds=4,
        hash_slots=512, max_iters=32,
    )
    g, _ = construct.build(x, cfg, jax.random.PRNGKey(0))
    return g, x


class TestAutoSize:
    def test_formula_and_clamps(self):
        assert auto_hash_slots(64, 64) == 2048  # old SearchConfig default
        assert auto_hash_slots(40, 60) == 2048  # old BuildConfig default
        assert auto_hash_slots(8, 8) == 1024  # floor clamp
        assert auto_hash_slots(1024, 1024) == 1 << 16  # ceiling clamp

    def test_none_resolves_explicit_respected(self):
        assert SearchConfig(k=8, beam=16).hash_slots == auto_hash_slots(16, 64)
        assert SearchConfig(k=8, beam=16, hash_slots=256).hash_slots == 256
        big = SearchConfig(k=8, beam=256, max_iters=512)
        assert big.hash_slots == auto_hash_slots(256, 512) == 1 << 16

    def test_non_pow2_rejected(self):
        with pytest.raises(AssertionError, match="2\\^h"):
            SearchConfig(k=8, beam=16, hash_slots=300)

    def test_bogus_seed_mode_rejected(self):
        with pytest.raises(AssertionError):
            SearchConfig(seed_mode="hierarchical")


class TestHashFull:
    def test_small_hash_saturates_and_flags(self, graph_and_data):
        """An undersized D array must raise the flag, not lie: with H far
        below the evaluation count every lane saturates; with generous H no
        lane does and n_comps equals the recorded uniques exactly."""
        g, x = graph_and_data
        q = jnp.asarray(np.random.RandomState(5).rand(8, D).astype(np.float32))
        starve = SearchConfig(
            k=8, beam=32, n_seeds=8, hash_slots=32, max_iters=32,
            metric="l2", use_pallas=False,
        )
        res = search_lib.search(g, x, q, jax.random.PRNGKey(1), starve)
        assert bool(jnp.all(res.hash_full)), (
            "32-slot hash with 32x32 search shape must saturate every lane"
        )
        roomy = SearchConfig(
            k=8, beam=32, n_seeds=8, hash_slots=4096, max_iters=32,
            metric="l2", use_pallas=False,
        )
        res2 = search_lib.search(g, x, q, jax.random.PRNGKey(1), roomy)
        assert not bool(jnp.any(res2.hash_full))
        fill = np.asarray((res2.vis_ids >= 0).sum(axis=1))
        np.testing.assert_array_equal(np.asarray(res2.n_comps), fill)

    def test_flag_off_on_default_shapes(self, graph_and_data):
        """The auto-sized default must not saturate on an ordinary search —
        the flag exists for genuine starvation, not routine traffic."""
        g, x = graph_and_data
        q = jnp.asarray(np.random.RandomState(6).rand(4, D).astype(np.float32))
        cfg = SearchConfig(k=8, beam=24, n_seeds=4, metric="l2",
                           use_pallas=False)
        res = search_lib.search(g, x, q, jax.random.PRNGKey(2), cfg)
        assert not bool(jnp.any(res.hash_full))
