"""PR-7 precision tolerance suite: the compressed distance engine vs oracles.

Two tiers, per precision:

  * **defined-computation oracle** (tight): decode the library's own codes in
    float64 NumPy and mirror the engine's documented decomposition (exact
    ``‖x‖²`` cache for the norm terms, per-row scale applied to the *dot*).
    The engine may only lose fp32-accumulation ulps against this oracle, so
    any drift here is an implementation bug, not quantization.
  * **true-distance bound** (analytic/loose): the compressed distances vs the
    exact float64 distances, bounded by the representation's worst-case
    quantization error.  This pins the *quality* of the compression, which
    the tight oracle alone cannot.

Data is drawn non-negative (uniform [0, 1)) so ``chi2`` is well defined and
the int8 error bound is exercised away from the trivial all-zero case.
Candidate-count sweeps cross the engine's 128-wide block boundaries, and id
arrays carry ``-1`` padding lanes (must map to ``+inf`` in every precision).
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import construct
from repro.core import search as search_lib
from repro.core.graph import squared_norms
from repro.kernels import ops
from repro.kernels import precision as precision_lib

METRICS = ["l2", "ip", "cosine", "l1", "chi2"]


# ---------------------------------------------------------------------------
# float64 oracles (NumPy only — independent of every jitted path under test)
# ---------------------------------------------------------------------------


def _make_case(seed, n, d, b, c):
    rng = np.random.RandomState(seed)
    x = rng.rand(n, d).astype(np.float32)
    q = rng.rand(b, d).astype(np.float32)
    idx = rng.randint(0, n, size=(b, c)).astype(np.int32)
    idx[:, :: max(c // 7, 1)] = -1  # padding lanes interleaved
    return x, q, idx


def _oracle_true(q, x, idx, metric):
    """Exact float64 distances (the no-compression ground truth)."""
    q = q.astype(np.float64)
    x = x.astype(np.float64)
    safe = np.clip(idx, 0, x.shape[0] - 1)
    cand = x[safe]  # (b, c, d)
    if metric == "l2":
        d = ((q[:, None, :] - cand) ** 2).sum(-1)
    elif metric == "ip":
        d = -(q[:, None, :] * cand).sum(-1)
    elif metric == "cosine":
        qn = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        cn = cand / np.maximum(
            np.linalg.norm(cand, axis=-1, keepdims=True), 1e-12
        )
        d = 1.0 - (qn[:, None, :] * cn).sum(-1)
    elif metric == "l1":
        d = np.abs(q[:, None, :] - cand).sum(-1)
    elif metric == "chi2":
        num = (q[:, None, :] - cand) ** 2
        den = q[:, None, :] + cand
        d = np.where(den > 1e-12, num / np.maximum(den, 1e-12), 0.0).sum(-1)
    else:
        raise KeyError(metric)
    return np.where(idx >= 0, d, np.inf)


def _decode_tile(x, idx, enc, precision):
    """Decode the library's own codes for the gathered tile, float64."""
    safe = np.clip(idx, 0, x.shape[0] - 1)
    if precision == "bf16":
        dec = np.asarray(enc.data.astype(jnp.float32)).astype(np.float64)
        return dec[safe], None
    codes = np.asarray(enc.data).astype(np.float64)
    scale = np.asarray(enc.scale).astype(np.float64)
    s = np.where(scale[safe] > 0, scale[safe], 1.0)  # (b, c)
    return codes[safe], s


def _oracle_compressed(q, x, idx, metric, enc, precision):
    """float64 mirror of the engine's defined bf16/int8 computation:
    compressed tile feeds the dot / elementwise term, exact norms feed the
    norm terms, int8 scales multiply the dot (not the tile) for matmul
    metrics."""
    q64 = q.astype(np.float64)
    xn_all = (x.astype(np.float64) ** 2).sum(-1)
    safe = np.clip(idx, 0, x.shape[0] - 1)
    cand, s = _decode_tile(x, idx, enc, precision)
    if metric in ("l2", "ip", "cosine"):
        qf = q64
        if metric == "cosine":
            qf = qf / np.maximum(
                np.linalg.norm(qf, axis=-1, keepdims=True), 1e-12
            )
        dots = (qf[:, None, :] * cand).sum(-1)
        if s is not None:
            dots = dots * s
        xn = xn_all[safe]
        if metric == "l2":
            qn = (qf * qf).sum(-1)[:, None]
            d = np.maximum(qn + xn - 2.0 * dots, 0.0)
        elif metric == "cosine":
            d = 1.0 - dots / np.maximum(np.sqrt(xn), 1e-12)
        else:
            d = -dots
    else:  # VPU metrics dequantize the tile itself
        candf = cand if s is None else cand * s[..., None]
        if metric == "l1":
            d = np.abs(q64[:, None, :] - candf).sum(-1)
        else:  # chi2
            num = (q64[:, None, :] - candf) ** 2
            den = q64[:, None, :] + candf
            d = np.where(den > 1e-12, num / np.maximum(den, 1e-12), 0.0).sum(-1)
    return np.where(idx >= 0, d, np.inf)


def _oracle_pq(q, x, idx, metric, enc):
    """float64 mirror of the ADC rank path (per-subspace LUT + code gather)."""
    codes = np.asarray(enc.codes)
    cb = np.asarray(enc.codebook).astype(np.float64)
    M, K, dsub = cb.shape
    q64 = q.astype(np.float64)
    if metric == "cosine":
        q64 = q64 / np.maximum(np.linalg.norm(q64, axis=-1, keepdims=True), 1e-12)
    qs = q64.reshape(q.shape[0], M, dsub)
    if metric == "l2":
        qn = (qs * qs).sum(-1)[:, :, None]
        cn = (cb * cb).sum(-1)[None]
        dots = np.einsum("bmd,mkd->bmk", qs, cb)
        lut = np.maximum(qn + cn - 2.0 * dots, 0.0)
    elif metric in ("ip", "cosine"):
        dots = np.einsum("bmd,mkd->bmk", qs, cb)
        lut = -dots if metric == "ip" else dots
    elif metric == "l1":
        lut = np.abs(qs[:, :, None, :] - cb[None]).sum(-1)
    else:  # chi2
        num = (cb[None] - qs[:, :, None, :]) ** 2
        den = cb[None] + qs[:, :, None, :]
        lut = np.where(den > 1e-12, num / np.maximum(den, 1e-12), 0.0).sum(-1)
    safe = np.clip(idx, 0, x.shape[0] - 1)
    cand_codes = codes[safe]  # (b, c, M)
    b, c = idx.shape
    terms = lut[
        np.arange(b)[:, None, None], np.arange(M)[None, None, :], cand_codes
    ]
    d = terms.sum(-1)
    if metric == "cosine":
        xn = (x.astype(np.float64) ** 2).sum(-1)[safe]
        d = 1.0 - d / np.maximum(np.sqrt(xn), 1e-12)
    return np.where(idx >= 0, d, np.inf)


def _engine(q, x, idx, metric, precision, enc):
    return np.asarray(
        ops.gather_distance(
            jnp.asarray(q), jnp.asarray(x), jnp.asarray(idx), metric,
            dispatch="reference", sq_norms=squared_norms(jnp.asarray(x)),
            enc=enc, precision=precision,
        )
    )


def _finite_close(got, want, rtol, atol, msg):
    np.testing.assert_array_equal(np.isinf(got), np.isinf(want), err_msg=msg)
    f = np.isfinite(want)
    np.testing.assert_allclose(got[f], want[f], rtol=rtol, atol=atol, err_msg=msg)


# ---------------------------------------------------------------------------
# tier 1: defined-computation oracles (tight)
# ---------------------------------------------------------------------------


class TestDefinedOracle:
    @pytest.mark.parametrize("metric", METRICS)
    def test_fp32_baseline(self, metric):
        x, q, idx = _make_case(0, 500, 32, 4, 200)
        got = _engine(q, x, idx, metric, "fp32", None)
        _finite_close(got, _oracle_true(q, x, idx, metric), 2e-4, 2e-5,
                      f"fp32 {metric} drifted from the exact oracle")

    @pytest.mark.parametrize("metric", METRICS)
    @pytest.mark.parametrize("precision", ["bf16", "int8"])
    def test_compressed_metric_sweep(self, metric, precision):
        x, q, idx = _make_case(1, 500, 32, 4, 129)
        enc = precision_lib.encode_dataset(jnp.asarray(x), precision)
        got = _engine(q, x, idx, metric, precision, enc)
        want = _oracle_compressed(q, x, idx, metric, enc, precision)
        _finite_close(got, want, 1e-3, 1e-3,
                      f"{precision} {metric} drifted from its defined oracle")

    @pytest.mark.parametrize("metric", METRICS)
    def test_pq_metric_sweep(self, metric):
        x, q, idx = _make_case(2, 500, 32, 4, 129)
        enc = precision_lib.encode_dataset(jnp.asarray(x), "pq")
        got = _engine(q, x, idx, metric, "pq", enc)
        want = _oracle_pq(q, x, idx, metric, enc)
        _finite_close(got, want, 1e-3, 1e-3,
                      f"pq {metric} drifted from the ADC oracle")

    @pytest.mark.parametrize("d", [4, 8, 96])
    @pytest.mark.parametrize("precision", ["bf16", "int8", "pq"])
    def test_dim_sweep(self, d, precision):
        x, q, idx = _make_case(3, 400, d, 3, 200)
        enc = precision_lib.encode_dataset(jnp.asarray(x), precision)
        got = _engine(q, x, idx, "l2", precision, enc)
        want = (_oracle_pq(q, x, idx, "l2", enc) if precision == "pq"
                else _oracle_compressed(q, x, idx, "l2", enc, precision))
        _finite_close(got, want, 1e-3, 1e-3,
                      f"{precision} l2 drifted at d={d}")

    @pytest.mark.parametrize("c", [1, 127, 128, 129, 300])
    @pytest.mark.parametrize("precision", ["bf16", "int8"])
    def test_block_boundary_sweep(self, c, precision):
        """The chunked dequant-dot must be seamless across its 128-wide
        chunk edges (and at C=1, below one chunk)."""
        x, q, idx = _make_case(4, 500, 32, 3, c)
        enc = precision_lib.encode_dataset(jnp.asarray(x), precision)
        got = _engine(q, x, idx, "l2", precision, enc)
        want = _oracle_compressed(q, x, idx, "l2", enc, precision)
        _finite_close(got, want, 1e-3, 1e-3,
                      f"{precision} l2 drifted at C={c}")


# ---------------------------------------------------------------------------
# tier 2: true-distance bounds (the compression-quality pin)
# ---------------------------------------------------------------------------


class TestTrueDistanceBounds:
    def test_bf16_within_two_percent(self):
        x, q, idx = _make_case(5, 500, 32, 4, 200)
        enc = precision_lib.encode_dataset(jnp.asarray(x), "bf16")
        for metric, atol in (("l2", 0.05), ("cosine", 0.02), ("ip", 0.05)):
            got = _engine(q, x, idx, metric, "bf16", enc)
            want = _oracle_true(q, x, idx, metric)
            f = np.isfinite(want)
            np.testing.assert_allclose(
                got[f], want[f], rtol=0.02, atol=atol,
                err_msg=f"bf16 {metric} strayed >2% from the true distance",
            )

    def test_int8_analytic_bound(self):
        """|d_int8 - d_true| <= 2 * (s/2) * Σ|q|: only the dot carries
        quantization error, at most half a step per dimension."""
        x, q, idx = _make_case(6, 500, 64, 4, 200)
        enc = precision_lib.encode_dataset(jnp.asarray(x), "int8")
        got = _engine(q, x, idx, "l2", "int8", enc)
        want = _oracle_true(q, x, idx, "l2")
        safe = np.clip(idx, 0, x.shape[0] - 1)
        s = np.asarray(enc.scale).astype(np.float64)[safe]  # (b, c)
        bound = 2.0 * (s / 2.0) * np.abs(q.astype(np.float64)).sum(-1)[:, None]
        f = np.isfinite(want)
        err = np.abs(got - want)[f]
        assert np.all(err <= bound[f] * (1 + 1e-3) + 1e-4), (
            f"int8 l2 error {err.max():.5f} exceeds the analytic bound "
            f"{bound[f].max():.5f}"
        )

    def test_pq_rank_quality(self):
        """ADC is a *rank* heuristic, not a distance estimate: its top picks
        must be systematically closer than the candidate pool average."""
        x, q, idx = _make_case(7, 500, 32, 6, 200)
        idx = np.abs(idx)  # full pool, no padding, for a clean average
        enc = precision_lib.encode_dataset(jnp.asarray(x), "pq")
        adc = _engine(q, x, idx, "l2", "pq", enc)
        true = _oracle_true(q, x, idx, "l2")
        top = np.argsort(adc, axis=1)[:, :8]
        picked = np.take_along_axis(true, top, axis=1).mean(axis=1)
        assert np.all(picked < true.mean(axis=1)), (
            "ADC top-8 candidates are no closer than a random draw"
        )


# ---------------------------------------------------------------------------
# end-to-end composition + API contract
# ---------------------------------------------------------------------------


class TestEndToEnd:
    def _built(self, precision="fp32", **over):
        rng = np.random.RandomState(11)
        x = rng.rand(400, 16).astype(np.float32)
        cfg = construct.BuildConfig(
            k=8, metric="l2", wave=64, beam=16, n_seeds=4, max_iters=20,
            dispatch="reference", precision=precision, **over,
        )
        g, _ = construct.build(jnp.asarray(x), cfg, jax.random.PRNGKey(0))
        return g, x, cfg

    def test_pq_rerank_keep_all_equals_fp32(self):
        """With rerank_keep >= C nothing is dropped by the ADC prerank, so
        rank-then-rerank must reproduce the fp32 search bit-for-bit (only
        exact distances ever enter the hash or the beam)."""
        g, x, _ = self._built("fp32")
        q = np.random.RandomState(12).rand(16, 16).astype(np.float32)
        base = search_lib.SearchConfig(
            k=8, beam=16, n_seeds=4, metric="l2", dispatch="reference",
        )
        res32 = search_lib.search(
            g, jnp.asarray(x), jnp.asarray(q), jax.random.PRNGKey(3), base)
        cfg_pq = dataclasses.replace(base, precision="pq", rerank_factor=1000)
        respq = search_lib.search(
            g, jnp.asarray(x), jnp.asarray(q), jax.random.PRNGKey(3), cfg_pq)
        np.testing.assert_array_equal(np.asarray(res32.ids), np.asarray(respq.ids))
        np.testing.assert_array_equal(
            np.asarray(res32.dists), np.asarray(respq.dists))

    def test_compressed_search_tracks_fp32(self):
        """bf16/int8/pq searches on an fp32-built graph stay within a few
        percent of the fp32 result set (top-k id overlap)."""
        g, x, _ = self._built("fp32")
        q = np.random.RandomState(13).rand(32, 16).astype(np.float32)
        base = search_lib.SearchConfig(
            k=8, beam=24, n_seeds=4, metric="l2", dispatch="reference",
        )
        ids32 = np.asarray(search_lib.search(
            g, jnp.asarray(x), jnp.asarray(q), jax.random.PRNGKey(5), base).ids)
        for precision in ("bf16", "int8", "pq"):
            cfg = dataclasses.replace(base, precision=precision)
            ids = np.asarray(search_lib.search(
                g, jnp.asarray(x), jnp.asarray(q), jax.random.PRNGKey(5), cfg).ids)
            overlap = np.mean([
                len(set(a.tolist()) & set(b.tolist())) / ids32.shape[1]
                for a, b in zip(ids, ids32)
            ])
            assert overlap >= 0.9, f"{precision} overlap {overlap:.3f} < 0.9"

    def test_compressed_build_works(self):
        """An int8-precision build produces a structurally valid graph whose
        recall matches an fp32 build on the same data."""
        from repro.core import brute
        g8, x, _ = self._built("int8")
        g32, _, _ = self._built("fp32")
        tids, _ = brute.brute_force_knn(
            jnp.asarray(x), jnp.asarray(x), 8, "l2",
            exclude_ids=jnp.arange(400, dtype=jnp.int32), dispatch="reference")
        r8 = float(brute.recall_at_k(g8.nbr_ids, tids, 8))
        r32 = float(brute.recall_at_k(g32.nbr_ids, tids, 8))
        assert r8 >= r32 - 0.05, f"int8 build recall {r8:.3f} << fp32 {r32:.3f}"


class TestDispatchDeprecation:
    @pytest.mark.parametrize("cls", [search_lib.SearchConfig, construct.BuildConfig])
    def test_use_pallas_warns_and_maps(self, cls):
        with pytest.warns(DeprecationWarning, match="use_pallas is deprecated"):
            cfg = cls(use_pallas=False)
        assert cfg.dispatch == "reference" and cfg.use_pallas is None
        with pytest.warns(DeprecationWarning):
            cfg = cls(use_pallas=True)
        assert cfg.dispatch == "pallas"

    @pytest.mark.parametrize("cls", [search_lib.SearchConfig, construct.BuildConfig])
    def test_replace_does_not_rewarn(self, cls):
        with pytest.warns(DeprecationWarning):
            cfg = cls(use_pallas=False)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            cfg2 = dataclasses.replace(cfg, k=12)
        assert cfg2.dispatch == "reference"

    def test_explicit_dispatch_wins(self):
        with pytest.warns(DeprecationWarning):
            cfg = search_lib.SearchConfig(use_pallas=True, dispatch="reference")
        assert cfg.dispatch == "reference"

    def test_bad_values_rejected(self):
        with pytest.raises(AssertionError):
            search_lib.SearchConfig(dispatch="gpu")
        with pytest.raises(ValueError):
            search_lib.SearchConfig(precision="fp8")
        with pytest.raises(AssertionError):
            search_lib.SearchConfig(rerank_factor=0)

    def test_deprecated_path_bitwise_equals_new(self):
        """use_pallas=False and dispatch='reference' are the same engine."""
        rng = np.random.RandomState(21)
        x = rng.rand(300, 12).astype(np.float32)
        cfg_new = construct.BuildConfig(
            k=6, wave=64, beam=16, n_seeds=4, max_iters=15,
            dispatch="reference")
        with pytest.warns(DeprecationWarning):
            cfg_old = construct.BuildConfig(
                k=6, wave=64, beam=16, n_seeds=4, max_iters=15,
                use_pallas=False)
        g_new, _ = construct.build(jnp.asarray(x), cfg_new, jax.random.PRNGKey(2))
        g_old, _ = construct.build(jnp.asarray(x), cfg_old, jax.random.PRNGKey(2))
        for field in ("nbr_ids", "nbr_dist", "rev_ids", "row_scale"):
            np.testing.assert_array_equal(
                np.asarray(getattr(g_new, field)),
                np.asarray(getattr(g_old, field)),
                err_msg=f"dispatch compat shim changed the build ({field})",
            )
