"""Hypothesis property tests on the system's core invariants.

The batched merge (``insertG``), the reverse ring buffers, the segmented
group-by core, the removal path and the norm cache are the load-bearing
primitives of the whole framework — every wave commit, NN-Descent round,
sub-graph merge and refinement pass goes through them.

Strategies only draw small integers (seeds + shapes); the data-shaped case
construction and the checkers live in ``tests/prop_util.py``, shared with
the fixed-seed leg (``tests/test_property_fixed.py``) that runs where
Hypothesis is absent.  CI installs ``hypothesis`` and runs this suite under
the pinned ``ci`` profile: derandomized (no flaky example schedules on
shared runners) with the deadline disabled (jit compile time would trip any
per-example deadline).
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import prop_util  # tests/ is on sys.path under pytest's rootdir insertion

settings.register_profile(
    "ci", max_examples=25, deadline=None, derandomize=True
)
settings.load_profile("ci")

seeds = st.integers(0, 2**31 - 1)


@given(seeds, st.integers(5, 16), st.integers(2, 5))
def test_generated_graph_invariants(seed, n, k):
    """Exact generated graphs satisfy every structural + cache invariant."""
    prop_util.check_generated_graph_invariants(seed, n, k)


@given(seeds, st.integers(6, 14), st.integers(2, 4), st.integers(1, 4))
def test_remove_preserves_invariants(seed, n, k, n_rm):
    """dynamic.remove preserves invariants for arbitrary victim sets."""
    prop_util.check_remove_preserves_invariants(seed, n, k, n_rm)


@given(seeds, st.integers(5, 12), st.integers(2, 4), st.integers(1, 8))
def test_grow_trim_cache_carry(seed, n, k, extra):
    """grow_graph carries the norm cache; trim inverts grow bit-for-bit."""
    prop_util.check_grow_trim_cache_carry(seed, n, k, extra)


@given(seeds, st.integers(32, 64), st.integers(4, 16), st.integers(3, 6))
@settings(max_examples=6)  # each case runs a full build + churn cycle
def test_scale_table_lifecycle(seed, n0, extra, k):
    """row_scale stays exact-or-zero through build/grow/insert/remove/compact."""
    prop_util.check_scale_table_lifecycle(seed, n0, extra, k)


@given(seeds, st.integers(5, 12), st.integers(2, 4))
def test_reverse_structural_contract(seed, n, k):
    """rebuild_reverse: membership, min(in_degree, R) fill, exact rev_lam
    snapshots, rev_ptr counts."""
    prop_util.check_reverse_structural_contract(seed, n, k)


@given(seeds, st.integers(4, 12), st.integers(2, 5), st.integers(1, 40))
def test_merge_invariants(seed, cap, k, t):
    case = prop_util.make_merge_case(seed, cap, k, t)
    prop_util.check_merge_candidates_invariants(case)


@given(seeds, st.integers(4, 12), st.integers(2, 5), st.integers(1, 40))
def test_merge_matches_sequential_topk(seed, cap, k, t):
    """Batched merge == per-row sequential top-k insertion."""
    case = prop_util.make_merge_case(seed, cap, k, t)
    prop_util.check_merge_candidates_oracle(case)


@given(seeds, st.integers(2, 6), st.integers(1, 30))
def test_append_reverse_ring(seed, R, t):
    prop_util.check_append_reverse_ring(seed, R, t)


@given(seeds, st.integers(16, 24), st.integers(3, 6), st.integers(1, 4))
@settings(max_examples=10)  # each distinct shape compiles a search; keep the
# schedule small — the oracle itself sweeps every lane of every example
def test_search_comps_accounting(seed, n, k, B):
    """n_comps == unique distance evaluations per lane (D-array oracle),
    incl. the seed-graph pre-charge in construct.zero_stats."""
    prop_util.check_search_comps_accounting(seed, n, k, B)


@given(seeds, st.integers(16, 24), st.integers(3, 6), st.integers(1, 4))
@settings(max_examples=6)  # each distinct shape compiles a build + search
def test_tracker_transparency(seed, n, k, B):
    """Telemetry on == telemetry off, bitwise (fp32): graphs and searches."""
    prop_util.check_tracker_transparency(seed, n, k, B)


@given(seeds, st.integers(1, 6), st.integers(1, 20), st.integers(1, 8))
def test_topk_smallest_matches_numpy(seed, m, c, k):
    prop_util.check_topk_smallest_matches_numpy(seed, m, c, k)


@given(seeds, st.integers(2, 8), st.integers(1, 5), st.integers(0, 60))
def test_grouped_top_r_matches_numpy(seed, num_segments, r, t):
    prop_util.check_grouped_top_r_matches_numpy(seed, num_segments, r, t)


@given(seeds, st.integers(0, 8))
@settings(max_examples=5)  # each case builds + folds four coarse shards
def test_merged_coarse_fold_invariants(seed, n_rm):
    """4-shard coarse fold (with pre-merge churn) preserves every
    CoarseLevel invariant in the union id space."""
    prop_util.check_merged_coarse_fold_invariants(seed, n_rm)
