"""Hypothesis property tests on the system's core invariants.

The batched merge (``insertG``), the reverse ring buffers and the top-k
selection are the load-bearing primitives of the whole framework — every
wave commit, NN-Descent round and refinement pass goes through them.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import merge
from repro.core.graph import empty_graph, graph_invariants_ok, rebuild_reverse
from repro.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def merge_case(draw):
    cap = draw(st.integers(4, 12))
    k = draw(st.integers(2, 5))
    t = draw(st.integers(1, 40))
    rng = np.random.RandomState(draw(st.integers(0, 2**31 - 1)))
    # existing rows: sorted unique neighbors
    ids = np.full((cap, k), -1, np.int32)
    dist = np.full((cap, k), np.inf, np.float32)
    for r in range(cap):
        nfill = rng.randint(0, k + 1)
        if nfill:
            cands = rng.choice([i for i in range(cap) if i != r],
                               size=min(nfill, cap - 1), replace=False)
            ds = np.sort(rng.rand(len(cands)).astype(np.float32))
            ids[r, : len(cands)] = cands
            dist[r, : len(cands)] = ds
    v = rng.randint(-1, cap, size=t).astype(np.int32)
    q = rng.randint(0, cap, size=t).astype(np.int32)
    # distances are a deterministic function of the pair (as in reality —
    # duplicate (v, q) proposals always carry the same m(v, q))
    pair_d = rng.rand(cap + 1, cap).astype(np.float32)
    d = pair_d[np.maximum(v, 0), q]
    return cap, k, ids, dist, v, q, d


@given(merge_case())
def test_merge_invariants(case):
    cap, k, ids, dist, v, q, d = case
    lam = np.zeros_like(ids)
    res = merge.merge_candidates(
        jnp.asarray(ids), jnp.asarray(dist), jnp.asarray(lam),
        jnp.asarray(v), jnp.asarray(q), jnp.asarray(d),
    )
    m_ids = np.asarray(res.nbr_ids)
    m_dist = np.asarray(res.nbr_dist)
    for r in range(cap):
        row = m_dist[r]
        assert np.all(np.diff(row[np.isfinite(row)]) >= 0)  # sorted
        real = m_ids[r][m_ids[r] >= 0]
        assert len(set(real.tolist())) == len(real)  # unique
        assert r not in real.tolist()  # no self loop


@given(merge_case())
def test_merge_matches_sequential_topk(case):
    """Batched merge == per-row 'insert each candidate sequentially'."""
    cap, k, ids, dist, v, q, d = case
    lam = np.zeros_like(ids)
    res = merge.merge_candidates(
        jnp.asarray(ids), jnp.asarray(dist), jnp.asarray(lam),
        jnp.asarray(v), jnp.asarray(q), jnp.asarray(d),
    )
    m_ids = np.asarray(res.nbr_ids)
    m_dist = np.asarray(res.nbr_dist)
    for r in range(cap):
        # sequential reference: existing list + qualified candidates,
        # dedupe by id keeping the smallest distance, then top-k
        pool = {}
        for j in range(k):
            if ids[r, j] >= 0:
                pool[int(ids[r, j])] = float(dist[r, j])
        for t_i in range(len(v)):
            if v[t_i] == r and q[t_i] != r and q[t_i] >= 0:
                if int(q[t_i]) not in pool:
                    pool[int(q[t_i])] = float(d[t_i])
        want = sorted(pool.items(), key=lambda kv: kv[1])[:k]
        got = [(int(i), float(x)) for i, x in zip(m_ids[r], m_dist[r]) if i >= 0]
        want_d = np.asarray([x for _, x in want], np.float32)
        got_d = np.asarray([x for _, x in got], np.float32)
        np.testing.assert_allclose(got_d, want_d[: len(got_d)], rtol=1e-6)
        assert len(got) == len(want)


@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(1, 30))
def test_append_reverse_ring(seed, R, t):
    rng = np.random.RandomState(seed)
    cap = 8
    rev = jnp.full((cap, R), -1, jnp.int32)
    lam = jnp.zeros((cap, R), jnp.int32)
    ptr = jnp.zeros((cap,), jnp.int32)
    owner = rng.randint(0, cap, size=t).astype(np.int32)
    member = rng.randint(-1, cap, size=t).astype(np.int32)
    rev2, _, ptr2 = merge.append_reverse(
        rev, lam, ptr, jnp.asarray(owner), jnp.asarray(member)
    )
    rev2 = np.asarray(rev2)
    ptr2 = np.asarray(ptr2)
    for m in range(cap):
        n_app = int(np.sum((member == m) & (owner >= 0)))
        assert ptr2[m] == n_app
        # the last min(R, n_app) appends for m are present
        owners_for_m = owner[(member == m) & (owner >= 0)]
        expect = set(owners_for_m[-min(R, n_app):].tolist()) if n_app else set()
        got = set(int(x) for x in rev2[m] if x >= 0)
        assert expect <= got | set(owners_for_m.tolist())
        assert len(got) <= R


@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
def test_topk_smallest_matches_numpy(seed, k):
    rng = np.random.RandomState(seed)
    m, c = 5, 16
    d = rng.rand(m, c).astype(np.float32)
    ids = rng.randint(0, 1000, size=(m, c)).astype(np.int32)
    kk = min(k, c)
    got_d, got_i = ref.topk_smallest(jnp.asarray(d), jnp.asarray(ids), kk)
    want = np.sort(d, axis=1)[:, :kk]
    np.testing.assert_allclose(np.asarray(got_d), want, rtol=1e-6)
    # ids consistent with distances
    for r in range(m):
        for j in range(kk):
            assert d[r][np.where(ids[r] == np.asarray(got_i)[r, j])[0]].min() <= want[r, j] + 1e-6


@given(st.integers(0, 2**31 - 1))
def test_rebuild_reverse_consistent(seed):
    """rebuild_reverse(g) contains every forward edge's reverse (up to R)."""
    rng = np.random.RandomState(seed)
    cap, k = 10, 3
    g = empty_graph(cap, k, rev_capacity=2 * k)
    ids = np.full((cap, k), -1, np.int32)
    dist = np.full((cap, k), np.inf, np.float32)
    for r in range(cap):
        cands = rng.choice([i for i in range(cap) if i != r], size=k, replace=False)
        ids[r] = cands
        dist[r] = np.sort(rng.rand(k))
    g = g._replace(
        nbr_ids=jnp.asarray(ids), nbr_dist=jnp.asarray(dist),
        alive=jnp.ones((cap,), bool), n_valid=jnp.asarray(cap, jnp.int32),
    )
    g = rebuild_reverse(g)
    inv = graph_invariants_ok(g)
    assert all(bool(jnp.all(v)) for v in inv.values())
    rev = np.asarray(g.rev_ids)
    R = g.rev_capacity
    owners = {j: [r for r in range(cap) if j in ids[r].tolist()] for j in range(cap)}
    for j in range(cap):
        got = [int(x) for x in rev[j] if x >= 0]
        # every stored reverse edge is a true forward edge's reverse...
        assert set(got) <= set(owners[j])
        # ...and the buffer holds min(in_degree, R) of them
        assert len(got) == min(len(owners[j]), R)
