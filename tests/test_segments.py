"""Unit tests for core.segments — the shared segmented-scan/group-by core.

Every batched commit in the system (wave merge, reverse ring buffers,
NN-Descent reverse sampling, MoE dispatch) sits on these primitives, so they
are cross-checked against a transparent pure-NumPy reference over randomized
cases including ties, empty segments, all-padding inputs and single-element
runs.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import segments


# ---------------------------------------------------------------------------
# NumPy references
# ---------------------------------------------------------------------------


def ref_segment_rank(sorted_keys):
    out, prev, r = [], None, 0
    for k in sorted_keys:
        r = r + 1 if k == prev else 0
        out.append(r)
        prev = k
    return np.asarray(out, np.int32)


def ref_grouped_top_r(sorted_keys, payloads, fills, num_segments, r):
    bufs = [np.full((num_segments, r), f, np.asarray(p).dtype)
            for p, f in zip(payloads, fills)]
    counts = np.zeros((num_segments,), np.int32)
    rank = ref_segment_rank(sorted_keys)
    for i, key in enumerate(sorted_keys):
        if key >= num_segments:
            continue
        counts[key] += 1
        if rank[i] < r:
            for buf, p in zip(bufs, payloads):
                buf[key, rank[i]] = p[i]
    return bufs, counts


def ref_segment_max(values, starts):
    out = np.empty_like(values)
    cur = None
    for i, (v, s) in enumerate(zip(values, starts)):
        cur = v if (s or cur is None) else max(cur, v)
        out[i] = cur
    return out


CASES = [np.random.RandomState(s).randint(0, 9, size=t)
         for s, t in [(0, 1), (1, 7), (2, 40), (3, 200), (4, 513)]]


# ---------------------------------------------------------------------------
# segment_rank / starts / scans
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", range(len(CASES)))
def test_segment_rank_matches_reference(case):
    keys = np.sort(CASES[case])
    got = np.asarray(segments.segment_rank(jnp.asarray(keys)))
    np.testing.assert_array_equal(got, ref_segment_rank(keys))


def test_segment_rank_all_equal_and_all_distinct():
    same = np.zeros(17, np.int32)
    np.testing.assert_array_equal(
        np.asarray(segments.segment_rank(jnp.asarray(same))), np.arange(17)
    )
    distinct = np.arange(17, dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(segments.segment_rank(jnp.asarray(distinct))), np.zeros(17)
    )


def test_segment_starts():
    keys = jnp.asarray([0, 0, 2, 2, 2, 5, 7, 7])
    got = np.asarray(segments.segment_starts(keys))
    np.testing.assert_array_equal(
        got, [True, False, True, False, False, True, True, False]
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_segment_max_min_reset_at_starts(seed):
    rng = np.random.RandomState(seed)
    n = 64
    vals = rng.randn(n).astype(np.float32)
    starts = rng.rand(n) < 0.25
    starts[0] = True
    got_max = np.asarray(segments.segment_max(jnp.asarray(vals), jnp.asarray(starts)))
    got_min = np.asarray(segments.segment_min(jnp.asarray(vals), jnp.asarray(starts)))
    np.testing.assert_allclose(got_max, ref_segment_max(vals, starts))
    np.testing.assert_allclose(got_min, -ref_segment_max(-vals, starts))


def test_running_max_is_prefix_max():
    v = jnp.asarray([3.0, 1.0, 4.0, 1.0, 5.0, 0.0])
    np.testing.assert_allclose(
        np.asarray(segments.running_max(v)), [3, 3, 4, 4, 5, 5]
    )


# ---------------------------------------------------------------------------
# grouped_top_r
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_grouped_top_r_matches_reference(seed):
    rng = np.random.RandomState(seed)
    num_segments = rng.randint(1, 12)
    t = rng.randint(1, 60)
    r = rng.randint(1, 6)
    # sentinel num_segments marks padding; sorted ascending as required
    keys = np.sort(rng.randint(0, num_segments + 1, size=t)).astype(np.int32)
    ids = rng.randint(0, 1000, size=t).astype(np.int32)
    dist = rng.rand(t).astype(np.float32)
    (got_ids, got_dist), got_counts = segments.grouped_top_r(
        jnp.asarray(keys), [jnp.asarray(ids), jnp.asarray(dist)],
        [-1, np.inf], num_segments, r,
    )
    (want_ids, want_dist), want_counts = ref_grouped_top_r(
        keys, [ids, dist], [-1, np.inf], num_segments, r
    )
    np.testing.assert_array_equal(np.asarray(got_ids), want_ids)
    np.testing.assert_allclose(np.asarray(got_dist), want_dist)
    np.testing.assert_array_equal(np.asarray(got_counts), want_counts)


def test_grouped_top_r_empty_segments():
    """Segments with no elements stay at the fill value, count 0."""
    keys = jnp.asarray([2, 2, 5], jnp.int32)  # segments 0,1,3,4 empty
    (ids,), counts = segments.grouped_top_r(
        keys, [jnp.asarray([7, 8, 9], jnp.int32)], [-1], 6, 2
    )
    want = np.full((6, 2), -1, np.int32)
    want[2, :2] = [7, 8]
    want[5, 0] = 9
    np.testing.assert_array_equal(np.asarray(ids), want)
    np.testing.assert_array_equal(np.asarray(counts), [0, 0, 2, 0, 0, 1])


def test_grouped_top_r_all_padding():
    """All-padding input: buffers untouched, counts all zero."""
    keys = jnp.full((8,), 4, jnp.int32)  # == num_segments sentinel
    (ids,), counts = segments.grouped_top_r(
        keys, [jnp.arange(8, dtype=jnp.int32)], [-1], 4, 3
    )
    assert np.all(np.asarray(ids) == -1)
    assert np.all(np.asarray(counts) == 0)


def test_grouped_top_r_overflow_truncates_but_counts_all():
    """More than r elements in a segment: first r kept, count uncapped."""
    keys = jnp.zeros((5,), jnp.int32)
    (ids,), counts = segments.grouped_top_r(
        keys, [jnp.asarray([10, 11, 12, 13, 14], jnp.int32)], [-1], 2, 3
    )
    np.testing.assert_array_equal(np.asarray(ids)[0], [10, 11, 12])
    np.testing.assert_array_equal(np.asarray(counts), [5, 0])


def test_grouped_top_r_ties_keep_sort_order():
    """Equal keys: payload order (the caller's sort order) is preserved."""
    keys = jnp.asarray([1, 1, 1], jnp.int32)
    dist = jnp.asarray([0.5, 0.5, 0.5], jnp.float32)  # exact ties
    ids = jnp.asarray([3, 1, 2], jnp.int32)
    (got_ids, got_dist), _ = segments.grouped_top_r(
        keys, [ids, dist], [-1, np.inf], 3, 3
    )
    np.testing.assert_array_equal(np.asarray(got_ids)[1], [3, 1, 2])


def test_grouped_top_r_keep_mask():
    keys = jnp.asarray([0, 0, 1, 1], jnp.int32)
    payload = jnp.asarray([5, 6, 7, 8], jnp.int32)
    keep = jnp.asarray([True, False, True, True])
    (ids,), counts = segments.grouped_top_r(
        keys, [payload], [-1], 2, 2, keep=keep
    )
    np.testing.assert_array_equal(np.asarray(ids), [[5, -1], [7, 8]])
    # counts ignore the keep mask (occurrence counts, not kept counts)
    np.testing.assert_array_equal(np.asarray(counts), [2, 2])


def test_segment_counts_drops_sentinel():
    keys = jnp.asarray([0, 0, 1, 3, 3, 3, 4, 4], jnp.int32)
    got = np.asarray(segments.segment_counts(keys, 4))
    np.testing.assert_array_equal(got, [2, 1, 0, 3])
