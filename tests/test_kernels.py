"""Per-kernel correctness sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import distance as distance_kernel
from repro.kernels import gather_dist as gather_kernel
from repro.kernels import ref

METRICS = ["l2", "ip", "cosine", "l1", "chi2"]
SHAPES = [
    (8, 8, 16),  # tiny
    (17, 53, 96),  # ragged, sub-tile
    (64, 130, 128),  # crosses the n tile boundary
    (130, 64, 200),  # d > one feature tile
]
DTYPES = ["float32", "bfloat16"]


def _data(m, n, d, metric, dtype, seed=0):
    rng = np.random.RandomState(seed)
    q = rng.randn(m, d).astype(np.float32)
    x = rng.randn(n, d).astype(np.float32)
    if metric == "chi2":
        q, x = np.abs(q), np.abs(x)
    return jnp.asarray(q).astype(dtype), jnp.asarray(x).astype(dtype)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pairwise_distance_matches_oracle(metric, shape, dtype):
    m, n, d = shape
    q, x = _data(m, n, d, metric, dtype)
    got = distance_kernel.pairwise_distance(q, x, metric=metric, interpret=True)
    want = ref.pairwise_distance(q.astype(jnp.float32), x.astype(jnp.float32), metric)
    assert got.shape == (m, n)
    tol = 5e-2 if dtype == "bfloat16" else 2e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("shape", [(8, 64, 32), (17, 200, 100)])
def test_gather_distance_matches_oracle(metric, shape):
    b, n, d = shape
    rng = np.random.RandomState(1)
    q = rng.randn(b, d).astype(np.float32)
    x = rng.randn(n, d).astype(np.float32)
    if metric == "chi2":
        q, x = np.abs(q), np.abs(x)
    c = 24
    idx = rng.randint(-1, n, size=(b, c)).astype(np.int32)
    got = gather_kernel.gather_distance(
        jnp.asarray(q), jnp.asarray(x), jnp.asarray(idx), metric=metric, interpret=True
    )
    want = ref.gather_distance(jnp.asarray(q), jnp.asarray(x), jnp.asarray(idx), metric)
    mask = idx >= 0
    np.testing.assert_allclose(
        np.asarray(got)[mask], np.asarray(want)[mask], rtol=2e-4, atol=2e-3
    )
    assert np.all(np.isinf(np.asarray(got)[~mask]))


def test_block_shape_sweep():
    """Distance kernel must be invariant to tiling choices."""
    q, x = _data(33, 70, 144, "l2", np.float32)
    want = ref.pairwise_distance(q, x, "l2")
    for bm, bn, bd in [(8, 8, 144), (16, 32, 128), (128, 128, 128), (32, 8, 16)]:
        got = distance_kernel.pairwise_distance(
            q, x, metric="l2", bm=bm, bn=bn, bd=bd, interpret=True
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_topk_smallest():
    rng = np.random.RandomState(2)
    d = rng.rand(10, 30).astype(np.float32)
    ids = rng.randint(0, 1000, size=(10, 30)).astype(np.int32)
    dd, ii = ref.topk_smallest(jnp.asarray(d), jnp.asarray(ids), 5)
    want = np.sort(d, axis=1)[:, :5]
    np.testing.assert_allclose(np.asarray(dd), want, rtol=1e-6)
