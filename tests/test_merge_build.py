"""Divide-and-conquer construction: parallel sub-builds + symmetric merge.

Covers the PR-5 tentpole end to end:

  * ``merge.symmetric_merge`` structure: stacked id spaces, cross edges in
    both directions, canonical reverse rebuild, gathered norm cache;
  * the brute-force oracle recall matrix — the merged+refined graph must
    stay within 0.02 recall@10 of the sequential online build across
    metrics and odd shard splits (uneven sizes, n not divisible);
  * ``ShardedIndex.merge_shards`` — serving equivalence over the union,
    global-id stability, snapshot round trip;
  * the online property after a merged build: ``dynamic.insert`` → ``remove``
    round trips preserve the norm-cache and liveness invariants.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import prop_util
from repro.core import brute, construct, dynamic, merge
from repro.core.graph import graph_invariants_ok, trim_graph
from repro.index import OnlineIndex
from repro.index.router import ShardedIndex


def small_cfg(metric="l2", k=10):
    return construct.BuildConfig(
        k=k, metric=metric, wave=64, n_seed_init=64, beam=20, n_seeds=4,
        hash_slots=512, max_iters=30, use_pallas=False,
    )


def uniform(n, d, seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), (n, d), jnp.float32)


def graph_recall(g, x, metric, k):
    n = x.shape[0]
    tids, _ = brute.brute_force_knn(
        x, x, k, metric, exclude_ids=jnp.arange(n, dtype=jnp.int32),
        use_pallas=False,
    )
    return float(brute.recall_at_k(g.nbr_ids[:, :k], tids, k))


# ---------------------------------------------------------------------------
# symmetric_merge unit behavior
# ---------------------------------------------------------------------------


def test_symmetric_merge_structure():
    """Cross edges exist in both directions, invariants + cache hold."""
    n, d = 300, 8
    x = uniform(n, d)
    cfg = small_cfg(k=8)
    na = 137  # deliberately uneven
    ga, _ = construct.build(x[:na], cfg, jax.random.PRNGKey(1))
    gb, _ = construct.build(x[na:], cfg, jax.random.PRNGKey(2))
    g, comps = merge.symmetric_merge(
        ga, gb, x, cfg.search_config(), jax.random.PRNGKey(3)
    )
    assert g.capacity == n and int(g.n_valid) == n
    assert int(comps) > 0
    prop_util.assert_invariants(g, "(symmetric_merge)")
    prop_util.assert_norm_cache(g, np.asarray(x), "(symmetric_merge)")
    ids = np.asarray(g.nbr_ids)
    # a-side rows hold b-side ids and vice versa
    a_cross = (ids[:na] >= na).any()
    b_cross = ((ids[na:] >= 0) & (ids[na:] < na)).any()
    assert a_cross and b_cross, "merge produced no cross-partition edges"


def test_symmetric_merge_rejects_partial_graphs():
    x = uniform(80, 6)
    cfg = small_cfg(k=4)
    g, _ = construct.build(x[:40], cfg, jax.random.PRNGKey(0))
    partial = brute.exact_seed_graph(x[40:], 16, 4)  # n_valid=16 < cap=40
    with pytest.raises(ValueError, match="fully-allocated"):
        merge.stack_subgraphs(g, partial, 40)
    with pytest.raises(ValueError, match="rows"):
        merge.symmetric_merge(g, g, x[:79], cfg.search_config())


def test_trim_graph_guards_allocated_rows():
    x = uniform(60, 6)
    g, _ = construct.build(x, small_cfg(k=4), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="n_valid"):
        trim_graph(g, 59)
    assert trim_graph(g, 60) is g  # no-op at capacity


def test_build_parallel_shards_1_is_sequential():
    x = uniform(200, 8)
    cfg = small_cfg(k=6)
    g1, s1 = construct.build(x, cfg, jax.random.PRNGKey(7))
    g2, s2 = construct.build_parallel(x, cfg, jax.random.PRNGKey(7), shards=1)
    for f in ("nbr_ids", "nbr_dist", "nbr_lam", "rev_ids", "alive"):
        np.testing.assert_array_equal(
            np.asarray(getattr(g1, f)), np.asarray(getattr(g2, f))
        )
    assert int(s1.n_comps) == int(s2.n_comps)


def test_partition_bounds_validation():
    with pytest.raises(ValueError):
        construct.partition_bounds(10, 11)
    with pytest.raises(ValueError):
        construct.partition_bounds(10, 0)
    b = construct.partition_bounds(320, 3)
    assert b[0] == 0 and b[-1] == 320 and len(b) == 4
    sizes = np.diff(b)
    assert sizes.min() >= 106 and sizes.max() <= 107  # balanced ±1


# ---------------------------------------------------------------------------
# Brute-force oracle recall matrix (metric x shard split)
# ---------------------------------------------------------------------------

# (metric, n, shards): odd splits on purpose — uneven sizes and n not
# divisible by shards both appear
ORACLE_MATRIX = [
    ("l2", 320, 2),
    ("ip", 320, 3),
    ("cosine", 301, 2),
    ("l1", 320, 3),
]


@pytest.mark.parametrize("metric,n,shards", ORACLE_MATRIX)
def test_merge_recall_matches_sequential(metric, n, shards):
    """Merged+refined recall@10 within 0.02 of the sequential online build."""
    d = 10
    x = uniform(n, d, seed=11)
    cfg = small_cfg(metric=metric)
    g_seq, _ = construct.build(x, cfg, jax.random.PRNGKey(1))
    g_par, _ = construct.build_parallel(
        x, cfg, jax.random.PRNGKey(1), shards=shards, refine_rounds=1
    )
    prop_util.assert_invariants(g_par, f"({metric}, {shards} shards)")
    r_seq = graph_recall(g_seq, x, metric, 10)
    r_par = graph_recall(g_par, x, metric, 10)
    assert r_par >= r_seq - 0.02, (
        f"{metric}/{shards} shards: merged recall {r_par:.4f} fell more than "
        f"0.02 below sequential {r_seq:.4f}"
    )


# ---------------------------------------------------------------------------
# ShardedIndex.merge_shards
# ---------------------------------------------------------------------------


def router_fixture(n=240, d=8, shards=3, k=8):
    items = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    cfg = small_cfg(k=k)
    r = ShardedIndex.build(items, shards, cfg, key=jax.random.PRNGKey(1))
    return r, items, cfg


def test_merge_shards_serving_matches_union_index():
    r, items, cfg = router_fixture()
    q = jax.random.normal(jax.random.PRNGKey(2), (3, items.shape[1]))
    union = OnlineIndex.build(items, cfg, key=jax.random.PRNGKey(3))
    r.merge_shards(refine_rounds=1, key=jax.random.PRNGKey(4))
    assert r.n_shards == 1
    # exact serving: the merged index over the union answers brute queries
    # identically to an OnlineIndex built over the union outright
    for i in range(q.shape[0]):
        ids_m, s_m = r.retrieve(q[i : i + 1], 10, brute=True)
        ids_u, s_u = ShardedIndex(
            [union], [np.arange(union.capacity, dtype=np.int64)],
            next_gid=union.capacity,
        ).retrieve(q[i : i + 1], 10, brute=True)
        np.testing.assert_array_equal(ids_m, ids_u)
        np.testing.assert_allclose(np.asarray(s_m), np.asarray(s_u), rtol=1e-6)
    # graph serving stays near-exact on the merged graph
    ids_g, _ = r.retrieve(q[:1], 10, key=jax.random.PRNGKey(5))
    ids_b, _ = r.retrieve(q[:1], 10, brute=True)
    overlap = len(set(ids_g.tolist()) & set(ids_b.tolist()))
    assert overlap >= 8, f"graph serving recall collapsed post-merge: {overlap}/10"


def test_merge_shards_preserves_global_ids():
    r, items, _ = router_fixture()
    # churn BEFORE the merge: new ids handed out, some ids withdrawn
    new_vecs = jax.random.normal(jax.random.PRNGKey(9), (6, items.shape[1]))
    new_gids = r.add(new_vecs)
    assert r.remove(np.arange(20, 40)) == 20
    want = {}  # gid -> vector, via the pre-merge tables
    for s, shard in enumerate(r.shards):
        table = r.gids[s]
        xs = np.asarray(shard.items)
        alive = np.asarray(shard.graph.alive)
        for row in range(int(shard.graph.n_valid)):
            if table[row] >= 0 and alive[row]:
                want[int(table[row])] = xs[row]
    r.merge_shards(key=jax.random.PRNGKey(4))
    merged = r.shards[0]
    table = r.gids[0]
    xs = np.asarray(merged.items)
    got = {
        int(table[row]): xs[row]
        for row in range(int(merged.graph.n_valid))
        if table[row] >= 0
    }
    assert set(got) == set(want), "global id set changed across merge_shards"
    for gid, vec in want.items():
        np.testing.assert_array_equal(got[gid], vec)
    # ids handed out before the merge keep resolving for removal
    assert r.remove(np.asarray(new_gids[:2])) == 2
    assert r.n_items == len(want) - 2


def test_merge_shards_snapshot_roundtrip_bit_exact(tmp_path):
    r, items, _ = router_fixture(n=180, shards=2)
    r.merge_shards(key=jax.random.PRNGKey(4))
    path = r.save(str(tmp_path / "merged_router"))
    r2 = ShardedIndex.load(path)
    assert r2.n_shards == 1 and r2.next_gid == r.next_gid
    np.testing.assert_array_equal(r2.gids[0], r.gids[0])
    g, g2 = r.shards[0].graph, r2.shards[0].graph
    for f in ("nbr_ids", "nbr_dist", "nbr_lam", "rev_ids", "rev_lam",
              "rev_ptr", "alive", "sq_norms", "row_scale"):
        np.testing.assert_array_equal(
            np.asarray(getattr(g, f)), np.asarray(getattr(g2, f)),
            err_msg=f"graph field {f} drifted across save/load",
        )
    np.testing.assert_array_equal(np.asarray(r.shards[0].items),
                                  np.asarray(r2.shards[0].items))
    q = jax.random.normal(jax.random.PRNGKey(5), (2, items.shape[1]))
    ids_a, s_a = r.retrieve(q[:1], 8, key=jax.random.PRNGKey(6))
    ids_b, s_b = r2.retrieve(q[:1], 8, key=jax.random.PRNGKey(6))
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_b))


def test_merge_shards_single_shard_noop():
    items = jax.random.normal(jax.random.PRNGKey(0), (120, 6))
    r = ShardedIndex.build(items, 1, small_cfg(k=6), key=jax.random.PRNGKey(1))
    g0 = r.shards[0].graph
    r.merge_shards()
    assert r.n_shards == 1
    np.testing.assert_array_equal(
        np.asarray(g0.nbr_ids), np.asarray(r.shards[0].graph.nbr_ids)
    )


# ---------------------------------------------------------------------------
# Online property after a merged build (satellite: insert -> remove round trip)
# ---------------------------------------------------------------------------


def test_insert_remove_round_trip_on_merged_graph():
    """dynamic.insert after a merged build preserves the norm-cache and
    liveness invariants through insert -> remove -> recycle-insert."""
    n, d = 300, 10
    x = uniform(n, d, seed=21)
    cfg = small_cfg(k=8)
    g, _ = construct.build_parallel(
        x, cfg, jax.random.PRNGKey(1), shards=3, refine_rounds=1
    )
    oi = OnlineIndex(graph=g, items=x, build_cfg=cfg)

    def assert_online_invariants(tag):
        prop_util.assert_invariants(oi.graph, tag)
        prop_util.assert_norm_cache(oi.graph, np.asarray(oi.items), tag)

    assert_online_invariants("(merged build)")
    # growth insert: capacity == n, so this exercises grow_graph + insert
    oi.add(jax.random.normal(jax.random.PRNGKey(5), (24, d)), flush=True)
    assert_online_invariants("(after growth insert)")
    # removal wave (λ repair + reverse purge on the merged lists)
    oi.remove(np.arange(0, 60, 4))
    assert_online_invariants("(after remove)")
    # recycle path: compaction reclaims the ledger, then the insert lands
    oi.add(jax.random.normal(jax.random.PRNGKey(6), (8, d)), flush=True)
    assert_online_invariants("(after recycle insert)")
    assert oi.n_items == n + 24 - 15 + 8


def test_merge_with_dead_rows_keeps_them_dead():
    """A removed sample must not re-enter anyone's list through a merge."""
    n, d = 260, 8
    x = uniform(n, d, seed=31)
    cfg = small_cfg(k=6)
    na = 130
    ga, _ = construct.build(x[:na], cfg, jax.random.PRNGKey(1))
    gb, _ = construct.build(x[na:], cfg, jax.random.PRNGKey(2))
    ga = dynamic.remove(ga, x[:na], jnp.asarray([3, 50, 77], jnp.int32), "l2")
    gb = dynamic.remove(gb, x[na:], jnp.asarray([10, 99], jnp.int32), "l2")
    g, _ = merge.symmetric_merge(
        ga, gb, x, cfg.search_config(), jax.random.PRNGKey(3)
    )
    prop_util.assert_invariants(g, "(merge with dead rows)")
    prop_util.assert_norm_cache(g, np.asarray(x), "(merge with dead rows)")
    dead = [3, 50, 77, na + 10, na + 99]
    ids = np.asarray(g.nbr_ids)
    alive = np.asarray(g.alive)
    for v in dead:
        assert not alive[v]
        assert (ids[v] == -1).all(), f"dead row {v} grew a list in the merge"
        assert not (ids == v).any(), f"dead row {v} re-entered a list"
