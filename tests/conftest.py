"""Shared test fixtures: deterministic PRNG seeding for every test.

Tier-1 runs ``pytest -x -q`` (optionally ``-m "not slow"``); determinism
comes from re-seeding NumPy's global PRNG before each test so that module
order / ``-x`` early exits / ``-k`` selections never change what any single
test sees.  JAX keys are explicit everywhere (``jax.random.PRNGKey``), so
they need no fixture.
"""

import numpy as np
import pytest

GLOBAL_SEED = 0


@pytest.fixture(autouse=True)
def fixed_seed():
    """Reset the global NumPy PRNG before every test (autouse)."""
    np.random.seed(GLOBAL_SEED)
    yield


@pytest.fixture
def rng():
    """A fresh, fixed-seed Generator for tests that want a local PRNG."""
    return np.random.RandomState(GLOBAL_SEED)
