"""Counter64 / BuildStats counter-exactness regression tests.

The bug class being pinned: float32 accumulation stalls at 2^24 (adding 1 to
16777216.0 returns 16777216.0), which silently froze ``BuildStats.n_comps``
on production-scale builds.  ``Counter64`` must keep exact counts across the
float32 limit and across the uint32 word boundary, under jit and inside
lax-loop carries (how the build loop actually uses it).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import construct
from repro.core.counters import Counter64


class TestCounter64:
    def test_float32_actually_loses_counts(self):
        """Documents the failure mode this type exists to fix."""
        c = jnp.float32(2**24)
        assert float(c + 1.0) == float(c)  # the old BuildStats behavior

    def test_exact_past_float32_limit(self):
        c = Counter64.of(2**24)
        for _ in range(5):
            c = c.add(jnp.asarray(1, jnp.int32))
        assert int(c) == 2**24 + 5

    def test_carry_across_word_boundary(self):
        c = Counter64.of(2**32 - 3)
        c = c.add(jnp.asarray(10, jnp.int32))
        assert int(c) == 2**32 + 7

    def test_large_single_increments(self):
        c = Counter64.zero()
        big = np.uint32(2**31 + 12345)  # > int32 range, < 2^32
        c = c.add(jnp.asarray(big, jnp.uint32))
        c = c.add(jnp.asarray(big, jnp.uint32))
        assert int(c) == 2 * (2**31 + 12345)

    def test_fold_inside_jitted_loop(self):
        """The build-loop usage pattern: a lax.fori_loop carry under jit."""

        @jax.jit
        def fold(c0):
            def body(_, c):
                return c.add(jnp.asarray(3, jnp.int32))

            return jax.lax.fori_loop(0, 1000, body, c0)

        c = fold(Counter64.of(2**32 - 1500))
        assert int(c) == 2**32 - 1500 + 3000

    def test_of_round_trip_and_views(self):
        v = (7 << 32) + 123456789
        c = Counter64.of(v)
        assert int(c) == v
        assert float(c) == float(v)
        np.testing.assert_allclose(float(c.to_float()), float(v), rtol=1e-7)

    def test_rejects_negative(self):
        with pytest.raises(ValueError):
            Counter64.of(-1)

    def test_is_a_pytree_of_arrays(self):
        leaves = jax.tree.leaves(Counter64.of(42))
        assert len(leaves) == 2
        assert all(isinstance(l, jax.Array) for l in leaves)


class TestBuildStatsCounters:
    def test_zero_stats_prefill_exact(self):
        stats = construct.zero_stats(2**24 + 1)
        assert int(stats.n_comps) == 2**24 + 1  # float32 would round this

    def test_scanning_rate_reads_counter(self):
        stats = construct.zero_stats(4950)  # 100 * 99 / 2
        assert construct.scanning_rate(stats, 100) == pytest.approx(1.0)

    def test_build_counts_survive_donated_carry(self):
        """A real (tiny) build: counters fold across jitted wave steps."""
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.rand(400, 4).astype(np.float32))
        cfg = construct.BuildConfig(
            k=8, wave=64, lgd=False, beam=16, n_seeds=4, hash_slots=512,
            max_iters=16,
        )
        _, stats = construct.build(x, cfg, jax.random.PRNGKey(0))
        n_seed = 256 * 255 // 2
        assert int(stats.n_comps) > n_seed  # seed charge + wave comps
        assert int(stats.n_inserted_edges) > 0
        assert int(stats.n_waves) == (400 - 256 + 63) // 64


class TestRefineCompsExact:
    """Regression: ``nndescent.refine`` returned comps as float (``0.0`` /
    ``float(c)`` accumulation), violating the exact-count policy the wave
    pipeline pays Counter64 for — ``build_parallel`` papered over it with
    ``int(refine_comps)``.  The refine path must thread exact python ints."""

    def _tiny(self):
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.rand(64, 6).astype(np.float32))
        cfg = construct.BuildConfig(
            k=4, wave=32, lgd=True, beam=8, n_seeds=2, hash_slots=256,
            max_iters=12,
        )
        g, _ = construct.build(x, cfg, jax.random.PRNGKey(0))
        return g, x

    def test_refine_returns_exact_int(self):
        from repro.core import nndescent

        g, x = self._tiny()
        g2, comps = nndescent.refine(g, x, "l2", rounds=1, node_chunk=64)
        assert isinstance(comps, int) and comps > 0
        g3, comps0 = nndescent.refine(g, x, "l2", rounds=0)
        assert isinstance(comps0, int) and comps0 == 0
        assert g3 is g  # rounds=0 is a true no-op

    def test_refine_comps_exact_past_2_24(self, monkeypatch):
        """>2^24 join comps per round: the total must come back as an exact
        python int (float32 accumulation would stall; the per-round counts
        here even cross the int32 word boundary when summed)."""
        from repro.core import nndescent

        g, x = self._tiny()
        big = 2**31 - 1  # one round's worth of join comps, int32-max
        real = nndescent._join_round

        def inflated(*args, **kw):
            ids, dist, is_new, _total, ins = real(*args, **kw)
            return ids, dist, is_new, jnp.asarray(big, jnp.int32), ins

        monkeypatch.setattr(nndescent, "_join_round", inflated)
        g2, comps = nndescent.refine(g, x, "l2", rounds=2, node_chunk=64)
        assert isinstance(comps, int)

        # exact expectation: 2 inflated join rounds + the λ-recompute charge
        # (#{l < i} member pairs with both ids live, from the final lists)
        ids = np.asarray(g2.nbr_ids)
        k = ids.shape[1]
        live = ids >= 0
        lam_pairs = 0
        for i in range(k):
            for ll in range(i):
                lam_pairs += int(np.sum(live[:, i] & live[:, ll]))
        assert comps == 2 * big + lam_pairs
        assert comps > 2**32  # past the uint32 word boundary, still exact
