"""LM model-zoo tests: attention equivalences, MoE dispatch, decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention, moe as moe_lib, transformer as tfm


def _cfg(**kw):
    base = dict(
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=97,
        remat=False, q_chunk=16, kv_chunk=16, compute_dtype="float32",
    )
    base.update(kw)
    return tfm.TransformerConfig(**base)


class TestAttention:
    @pytest.mark.parametrize("window", [tfm.FULL_WINDOW, 8])
    def test_tiled_equals_chunked(self, window):
        key = jax.random.PRNGKey(0)
        b, s, h, kv, dh = 2, 48, 4, 2, 8
        q = jax.random.normal(key, (b, s, h, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, dh))
        o1 = attention.chunked_causal_attention(q, k, v, window, q_chunk=16, kv_chunk=16)
        o2 = attention.tiled_causal_attention(q, k, v, window, q_chunk=16, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5, atol=2e-5)

    def test_chunked_equals_reference_softmax(self):
        key = jax.random.PRNGKey(1)
        b, s, h, dh = 1, 32, 2, 8
        q = jax.random.normal(key, (b, s, h, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
        o = attention.chunked_causal_attention(q, k, v, tfm.FULL_WINDOW, q_chunk=8, kv_chunk=8)
        # reference full-softmax causal
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_window_masks_past(self):
        key = jax.random.PRNGKey(2)
        b, s, h, dh = 1, 32, 2, 8
        q = jax.random.normal(key, (b, s, h, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
        o_full = attention.chunked_causal_attention(q, k, v, tfm.FULL_WINDOW, q_chunk=8, kv_chunk=8)
        o_win = attention.chunked_causal_attention(q, k, v, 4, q_chunk=8, kv_chunk=8)
        # early positions (< window) agree; late differ
        np.testing.assert_allclose(np.asarray(o_full[:, :4]), np.asarray(o_win[:, :4]), rtol=1e-5, atol=1e-5)
        assert not np.allclose(np.asarray(o_full[:, -1]), np.asarray(o_win[:, -1]))


class TestTransformer:
    @pytest.mark.parametrize("moe", [None, moe_lib.MoEConfig(n_experts=4, top_k=2)])
    def test_forward_and_unrolled_agree(self, moe):
        cfg = _cfg(moe=moe, moe_d_ff=64)
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
        lo1, _ = tfm.forward(params, tokens, cfg)
        import dataclasses
        lo2, _ = tfm.forward(params, tokens, dataclasses.replace(cfg, unrolled=True))
        np.testing.assert_allclose(np.asarray(lo1), np.asarray(lo2), rtol=2e-4, atol=2e-4)

    def test_loss_decreases(self):
        cfg = _cfg()
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        from repro.train import optimizer as opt_lib, train_loop
        ocfg = opt_lib.OptConfig(name="adamw", lr=1e-2)
        opt = opt_lib.init_opt_state(params, ocfg)
        step = jax.jit(train_loop.make_train_step(
            lambda p, b: tfm.loss_fn(p, b["tokens"], cfg), ocfg))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
        losses = []
        for _ in range(8):
            params, opt, m = step(params, opt, {"tokens": tokens})
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.9, losses

    def test_prefill_decode_matches_forward(self):
        """prefill(S) + decode(1 step) == forward(S+1) at the last logit."""
        cfg = _cfg(local_global=(1, 1), local_window=8)
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(3), (2, 17), 0, cfg.vocab)
        logits_pre, cache = tfm.prefill(params, toks[:, :16], cfg)
        # pad cache to allow one more token
        cache = {
            "k": jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0))),
            "v": jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0))),
            "len": cache["len"],
        }
        logits_dec, _ = tfm.decode_step(params, cache, toks[:, 16], cfg)
        logits_full, _ = tfm.forward(params, toks, cfg)
        # decode attends over a padded cache, so XLA reassociates the f32
        # reductions differently than the full forward — allow that noise
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_full[:, -1]), rtol=5e-3, atol=5e-3
        )
        np.testing.assert_allclose(
            np.asarray(logits_pre), np.asarray(logits_full[:, 15]), rtol=5e-3, atol=5e-3
        )

    def test_split_cache_decode_matches_full(self):
        """Ring-buffer windowed cache == dense cache, bit-for-bit semantics,
        including after the ring wraps (len > window)."""
        cfg = _cfg(local_global=(2, 1), local_window=6)
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(3), (2, 20), 0, cfg.vocab)
        full = tfm.init_cache(cfg, 2, 24, dtype=jnp.float32)
        split = tfm.init_split_cache(cfg, 2, 24, dtype=jnp.float32)
        assert split["k_loc"].shape[2] == 6  # ring = window, not max_seq
        for t in range(20):  # decode past the wrap point (> 6)
            lf, full = tfm.decode_step(params, full, toks[:, t], cfg)
            ls, split = tfm.decode_step_split(params, split, toks[:, t], cfg)
            np.testing.assert_allclose(
                np.asarray(lf), np.asarray(ls), rtol=2e-4, atol=2e-4,
                err_msg=f"step {t}")

    def test_qkv_bias_and_softcap_and_untied(self):
        cfg = _cfg(qkv_bias=True, logit_softcap=10.0, tie_embeddings=False)
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        assert "bq" in params and "head" in params
        logits, _ = tfm.forward(params, jnp.zeros((1, 8), jnp.int32), cfg)
        assert float(jnp.max(jnp.abs(logits))) <= 10.0


class TestMoE:
    def test_grouped_dispatch_close_to_global(self):
        """Per-group dispatch == global dispatch when capacity is ample."""
        cfg = moe_lib.MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0)
        key = jax.random.PRNGKey(0)
        params = moe_lib.init_moe_params(key, 16, 32, cfg, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (64, 16))
        o1, _ = moe_lib.apply_moe(params, x, cfg)
        o2, _ = moe_lib.apply_moe(params, x, cfg, groups=4)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)

    def test_capacity_drops_counted(self):
        cfg = moe_lib.MoEConfig(n_experts=4, top_k=2, capacity_factor=0.25)
        key = jax.random.PRNGKey(0)
        params = moe_lib.init_moe_params(key, 16, 32, cfg, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (64, 16))
        _, aux = moe_lib.apply_moe(params, x, cfg)
        assert float(aux["moe_drop_rate"]) > 0.0

    def test_identical_tokens_identical_outputs(self):
        cfg = moe_lib.MoEConfig(n_experts=4, top_k=1, capacity_factor=8.0)
        params = moe_lib.init_moe_params(jax.random.PRNGKey(0), 16, 32, cfg, jnp.float32)
        x = jnp.tile(jax.random.normal(jax.random.PRNGKey(1), (1, 16)), (8, 1))
        o, _ = moe_lib.apply_moe(params, x, cfg)
        np.testing.assert_allclose(np.asarray(o - o[0]), 0.0, atol=1e-5)
