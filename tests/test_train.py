"""Training substrate tests: optimizers, grad accumulation, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import compress, optimizer as opt_lib, train_loop


def _quadratic_loss(params, batch):
    # simple convex problem: ||W x - y||^2
    pred = batch["x"] @ params["w"] + params["b"]
    loss = jnp.mean((pred - batch["y"]) ** 2)
    return loss, {}


def _problem(key, n=64, din=8, dout=4):
    kw, kx, kn = jax.random.split(key, 3)
    w_true = jax.random.normal(kw, (din, dout))
    x = jax.random.normal(kx, (n, din))
    y = x @ w_true + 0.01 * jax.random.normal(kn, (n, dout))
    params = {"w": jnp.zeros((din, dout)), "b": jnp.zeros((dout,))}
    return params, {"x": x, "y": y}


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
    def test_converges_on_quadratic(self, name):
        params, batch = _problem(jax.random.PRNGKey(0))
        ocfg = opt_lib.OptConfig(name=name, lr=0.05 if name != "sgd" else 0.1,
                                 weight_decay=0.0)
        opt = opt_lib.init_opt_state(params, ocfg)
        step = jax.jit(train_loop.make_train_step(_quadratic_loss, ocfg))
        l0 = None
        for i in range(60):
            params, opt, m = step(params, opt, batch)
            if l0 is None:
                l0 = float(m["loss"])
        assert float(m["loss"]) < l0 * 0.05, (name, l0, float(m["loss"]))

    def test_adamw_matches_manual_step(self):
        """One AdamW update vs the textbook formula."""
        p = {"w": jnp.asarray([[1.0, -2.0]])}
        g = {"w": jnp.asarray([[0.5, 0.25]])}
        cfg = opt_lib.OptConfig(name="adamw", lr=0.1, b1=0.9, b2=0.99,
                                eps=1e-8, weight_decay=0.01, grad_clip=0.0)
        st = opt_lib.init_opt_state(p, cfg)
        p2, st2 = opt_lib._adamw_update(p, g, st, cfg)
        m = 0.1 * np.asarray(g["w"])
        v = 0.01 * np.asarray(g["w"]) ** 2
        mhat = m / (1 - 0.9)
        vhat = v / (1 - 0.99)
        want = np.asarray(p["w"]) - 0.1 * (
            mhat / (np.sqrt(vhat) + 1e-8) + 0.01 * np.asarray(p["w"]))
        np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)

    def test_adafactor_state_is_factored(self):
        params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((16,))}
        st = opt_lib.init_opt_state(params, opt_lib.OptConfig(name="adafactor"))
        assert st["vr"]["big"].shape == (256,)
        assert st["vc"]["big"].shape == (512,)
        assert st["vc"]["small"].shape == (16,)

    def test_grad_clip(self):
        g = {"w": jnp.full((4,), 100.0)}
        clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-4)


class TestGradAccum:
    def test_accum_equals_full_batch(self):
        params, batch = _problem(jax.random.PRNGKey(1), n=32)
        ocfg = opt_lib.OptConfig(name="sgd", lr=0.1, grad_clip=0.0)
        opt = opt_lib.init_opt_state(params, ocfg)
        step1 = jax.jit(train_loop.make_train_step(_quadratic_loss, ocfg))
        step4 = jax.jit(train_loop.make_train_step(_quadratic_loss, ocfg, accum_steps=4))
        p1, _, _ = step1(params, opt, batch)
        p4, _, _ = step4(params, opt, batch)
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]), rtol=1e-5, atol=1e-6)


class TestCompression:
    def test_roundtrip_small_error(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (128,))
        q, s, err = compress.compress(g, jnp.zeros_like(g))
        rec = compress.decompress(q, s)
        # per-step error bounded by scale/2; residual carries the rest
        assert float(jnp.max(jnp.abs(rec + err - g))) < 1e-6

    def test_error_feedback_unbiased_over_steps(self):
        """Error feedback: the cumulative applied update converges to the
        cumulative true gradient (1-bit-Adam family property)."""
        key = jax.random.PRNGKey(0)
        err = jnp.zeros((64,))
        applied = jnp.zeros((64,))
        total = jnp.zeros((64,))
        for i in range(50):
            g = jax.random.normal(jax.random.fold_in(key, i), (64,))
            q, s, err = compress.compress(g, err)
            applied += compress.decompress(q, s)
            total += g
        # relative deviation of the sums is tiny (residual is bounded)
        rel = float(jnp.linalg.norm(applied - total) / jnp.linalg.norm(total))
        assert rel < 0.05, rel
