"""Norm-cache suite: the graph-resident ``‖x‖²`` cache and the decomposed
distance formula it feeds (the PR-3 blocked MXU engine).

Three groups, matching the ISSUE's coverage list:

* decomposed-vs-direct — the ``‖q‖² + ‖x‖² − 2·q·x`` form (with and without
  the cache) against a float64 direct-difference oracle, swept over
  metrics x dims x dtypes.  This is a TOLERANCE suite by policy: the
  decomposition trades associativity for MXU shape, so agreement is float
  -level, never bitwise (the bitwise invariant lives in the fused-vs
  -reference parity suite, which keeps both sides on the SAME formula).
* cache consistency — the ``KNNGraph.sq_norms`` invariant (valid for every
  allocated alive row, 0 for unallocated/removed rows) through build,
  ``dynamic.insert`` and ``dynamic.remove`` round trips: nothing drifts and
  nothing stale survives a removal.
* block boundaries — the blocked engine pads candidate lists to whole
  (C_blk)-wide blocks; kernels and the fused expansion must agree with
  their references at C NOT a multiple of the block width (padding lanes
  live) as well as at exact multiples.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import brute, construct, dynamic, segments
from repro.core import graph as graph_lib
from repro.core import search as search_lib
from repro.kernels import expand as expand_lib
from repro.kernels import gather_dist as gather_kernel
from repro.kernels import ref


# ---------------------------------------------------------------------------
# decomposed vs direct
# ---------------------------------------------------------------------------


def _direct_oracle(q64, x64, idx, metric):
    """Float64 direct-formula distances (no decomposition anywhere)."""
    b, c = idx.shape
    out = np.zeros((b, c))
    for i in range(b):
        for j in range(c):
            v = x64[max(idx[i, j], 0)]
            if metric == "l2":
                out[i, j] = np.sum((q64[i] - v) ** 2)
            else:  # cosine
                qn = np.linalg.norm(q64[i])
                vn = np.linalg.norm(v)
                out[i, j] = 1.0 - np.dot(q64[i], v) / max(qn * vn, 1e-12)
    return out


class TestDecomposedVsDirect:
    """The decomposition is the only formula change the blocked engine makes;
    l2 and cosine are the metrics that consume the cached ``‖x‖²``."""

    @pytest.mark.parametrize("metric", ["l2", "cosine"])
    @pytest.mark.parametrize("d", [8, 96, 200])
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("cached", [True, False])
    def test_gather_distance_tolerance(self, metric, d, dtype, cached):
        rng = np.random.RandomState(0)
        n, b, c = 300, 7, 33
        x64 = rng.randn(n, d) * 2.0
        q64 = rng.randn(b, d) * 2.0
        idx = rng.randint(-1, n, size=(b, c)).astype(np.int32)
        x = jnp.asarray(x64, jnp.float32).astype(dtype)
        q = jnp.asarray(q64, jnp.float32).astype(dtype)
        # the cache is defined over the stored (possibly low-precision) rows
        sq = graph_lib.squared_norms(x) if cached else None
        want = _direct_oracle(
            np.asarray(q.astype(jnp.float32), np.float64),
            np.asarray(x.astype(jnp.float32), np.float64),
            idx, metric,
        )
        got = ref.gather_distance(q, x, jnp.asarray(idx), metric, sq_norms=sq)
        mask = idx >= 0
        # decomposed-vs-direct is tolerance-based BY POLICY: catastrophic
        # cancellation bounds error by ~eps·‖q‖‖x‖, so the bound is absolute
        # in the squared-norm scale, looser for bf16 storage
        tol = 0.25 if dtype == "bfloat16" else 2e-3
        np.testing.assert_allclose(
            np.asarray(got)[mask], want[mask], atol=tol * d, rtol=5e-2
            if dtype == "bfloat16" else 1e-3,
        )
        assert np.all(np.isinf(np.asarray(got)[~mask]))

    @pytest.mark.parametrize("metric", ["l2", "cosine"])
    @pytest.mark.parametrize("d", [8, 200])
    def test_kernel_matches_cached_reference(self, metric, d):
        """Pallas blocked kernel (interpret) vs the cached reference — both
        on the decomposed formula, so tight float32 tolerance."""
        rng = np.random.RandomState(1)
        n, b, c = 300, 5, 40
        x = jnp.asarray(rng.randn(n, d), jnp.float32)
        q = jnp.asarray(rng.randn(b, d), jnp.float32)
        idx = jnp.asarray(rng.randint(-1, n, size=(b, c)), jnp.int32)
        sq = graph_lib.squared_norms(x)
        got = gather_kernel.gather_distance(
            q, x, idx, metric=metric, sq_norms=sq, interpret=True
        )
        want = ref.gather_distance(q, x, idx, metric, sq_norms=sq)
        mask = np.asarray(idx) >= 0
        np.testing.assert_allclose(
            np.asarray(got)[mask], np.asarray(want)[mask],
            rtol=2e-4, atol=2e-3,
        )

    def test_pairwise_cached_matches_uncached(self):
        rng = np.random.RandomState(2)
        q = jnp.asarray(rng.randn(9, 48), jnp.float32)
        x = jnp.asarray(rng.randn(70, 48), jnp.float32)
        sq = graph_lib.squared_norms(x)
        got = ref.pairwise_distance(q, x, "l2", x_sq_norms=sq)
        want = ref.pairwise_distance(q, x, "l2")
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4
        )


# ---------------------------------------------------------------------------
# cache consistency through dynamic updates
# ---------------------------------------------------------------------------


def _check_invariant(g, x, msg):
    """sq_norms == ‖x_i‖² for allocated alive rows; 0 elsewhere."""
    n_valid = int(g.n_valid)
    cap = g.capacity
    sq = np.asarray(g.sq_norms)
    alive = np.asarray(g.alive)
    true_sq = np.asarray(graph_lib.squared_norms(jnp.asarray(x)))[:cap]
    live = np.arange(cap) < n_valid
    np.testing.assert_allclose(
        sq[live & alive], true_sq[live & alive], rtol=1e-6, atol=1e-5,
        err_msg=f"{msg}: stale/wrong cache on live rows",
    )
    assert np.all(sq[~live] == 0.0), f"{msg}: unallocated rows must cache 0"
    assert np.all(sq[live & ~alive] == 0.0), f"{msg}: removed rows must cache 0"


class TestCacheConsistency:
    N0, EXTRA, D, K = 300, 60, 12, 8

    def _build(self, seed=0):
        rng = np.random.RandomState(seed)
        x = rng.rand(self.N0 + self.EXTRA, self.D).astype(np.float32)
        cfg = construct.BuildConfig(
            k=self.K, metric="l2", wave=64, lgd=True, beam=16, n_seeds=4,
            hash_slots=512, max_iters=30, use_pallas=False,
        )
        g, _ = construct.build(
            jnp.asarray(x[: self.N0]), cfg, jax.random.PRNGKey(seed)
        )
        return g, x, cfg

    def test_build_populates_cache(self):
        g, x, _ = self._build()
        _check_invariant(g, x, "after build")

    def test_insert_remove_round_trip(self):
        g, x, cfg = self._build()
        grown = graph_lib.grow_graph(g, self.N0 + self.EXTRA)
        _check_invariant(grown, x, "after grow")
        g2, _ = dynamic.insert(
            grown, jnp.asarray(x), self.EXTRA, cfg, jax.random.PRNGKey(7)
        )
        assert int(g2.n_valid) == self.N0 + self.EXTRA
        _check_invariant(g2, x, "after insert")

        victims = jnp.asarray([3, 50, self.N0 + 5, self.N0 + 31], jnp.int32)
        g3 = dynamic.remove(g2, jnp.asarray(x), victims, "l2")
        _check_invariant(g3, x, "after remove")
        # a second wave of inserts on top of holes must not resurrect
        # stale entries elsewhere
        g4 = dynamic.remove(g3, jnp.asarray(x), jnp.asarray([0], jnp.int32), "l2")
        _check_invariant(g4, x, "after second remove")

    def test_attach_sq_norms_matches_builder(self):
        g, x, _ = self._build()
        detached = g._replace(sq_norms=jnp.zeros_like(g.sq_norms))
        reattached = graph_lib.attach_sq_norms(detached, jnp.asarray(x))
        np.testing.assert_allclose(
            np.asarray(reattached.sq_norms), np.asarray(g.sq_norms),
            rtol=1e-6, atol=1e-5,
        )


# ---------------------------------------------------------------------------
# block boundaries: non-multiple-of-block candidate counts
# ---------------------------------------------------------------------------


class TestBlockBoundaries:
    def test_block_helpers(self):
        assert gather_kernel.block_c(1) == 1
        assert gather_kernel.block_c(100) == 100
        assert gather_kernel.block_c(130) == 128
        assert gather_kernel.padded_c(100) == 100  # single exact block
        assert gather_kernel.padded_c(128) == 128
        assert gather_kernel.padded_c(130) == 256  # padding lanes live
        assert gather_kernel.padded_c(256) == 256
        assert gather_kernel.padded_c(300) == 384

    @pytest.mark.parametrize("c", [1, 127, 128, 129, 200, 256, 300])
    def test_gather_distance_at_block_edges(self, c):
        rng = np.random.RandomState(3)
        n, b, d = 400, 4, 16
        x = jnp.asarray(rng.randn(n, d), jnp.float32)
        q = jnp.asarray(rng.randn(b, d), jnp.float32)
        idx = jnp.asarray(rng.randint(-1, n, size=(b, c)), jnp.int32)
        sq = graph_lib.squared_norms(x)
        got = gather_kernel.gather_distance(
            q, x, idx, metric="l2", sq_norms=sq, interpret=True
        )
        assert got.shape == (b, c)
        want = ref.gather_distance(q, x, idx, "l2", sq_norms=sq)
        mask = np.asarray(idx) >= 0
        np.testing.assert_allclose(
            np.asarray(got)[mask], np.asarray(want)[mask],
            rtol=2e-4, atol=2e-3,
        )
        assert np.all(np.isinf(np.asarray(got)[~mask]))

    @pytest.mark.parametrize("c", [129, 130, 300])
    def test_fused_expand_parity_past_one_block(self, c):
        """Fused kernel vs reference-with-kernel-distances stays bit
        -identical when the candidate list spans multiple blocks with live
        padding lanes — the parity policy at the new block geometry."""
        rng = np.random.RandomState(4)
        n, d, b = 500, 8, 3
        data = jnp.asarray(rng.rand(n, d).astype(np.float32))
        g = brute.exact_seed_graph(data, n, 8, "l2")
        q = data[40 : 40 + b]
        cfg = search_lib.SearchConfig(
            k=8, beam=16, n_seeds=4, hash_slots=256, metric="l2",
            use_pallas=False,
        )
        st = search_lib.init_state(g, data, q, jax.random.PRNGKey(5), cfg)
        fields = ["beam_ids", "beam_dist", "beam_exp", "vis_ids", "vis_dist",
                  "comps"]
        for it in range(2):  # 2nd iteration sees a non-empty visited hash
            cands = jnp.asarray(
                rng.randint(-1, n, size=(b, c)), jnp.int32
            )
            # production semantics: candidate lists are row-deduped upstream
            cands = jnp.where(segments.mask_row_duplicates(cands), -1, cands)
            args = (
                q, data, cands, st.beam_ids, st.beam_dist, st.beam_exp,
                st.vis_ids, st.vis_dist,
            )
            want = expand_lib.expand_reference(
                *args, metric="l2", probes=cfg.hash_probes,
                sq_norms=g.sq_norms, pallas_distances=True, interpret=True,
            )
            got = expand_lib.fused_expand(
                *args, metric="l2", probes=cfg.hash_probes,
                sq_norms=g.sq_norms, interpret=True,
            )
            for name, a, bb in zip(fields, want, got):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(bb),
                    err_msg=f"iter {it}, C={c}, field {name}",
                )
            bi, bd, be, vi, vd, _ = want
            st = st._replace(
                beam_ids=bi, beam_dist=bd, beam_exp=be,
                vis_ids=vi, vis_dist=vd,
            )
