"""Parity suite: the fused Pallas expansion kernel (interpret mode) must be
bit-identical to the unfused ``_expand`` op chain.

The fused kernel and ``expand_reference`` share the probe/record/merge body
(``kernels.expand._probe_mask_record_merge``) and the per-row distance
formula (``kernels.gather_dist.row_distance``), so any drift between the two
execution paths is a bug, not a tolerance question.  The sweep covers the
metric x expansion-policy corners the ISSUE pins: {l2, ip} x ``use_reverse``
x ``use_lgd_mask`` (with non-trivial λ planted so the LGD mask actually
filters), chained over several EHC iterations so later steps see hash tables
and beams produced by earlier ones.

A second group checks the three-way ``use_pallas`` dispatch end-to-end: the
full search driven through the fused kernel agrees with the pure-JAX
reference path (tolerance-based — the reference computes l2 via the matmul
expansion, the kernels via the per-row difference form).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import brute
from repro.core import search as search_lib
from repro.kernels import expand as expand_lib

N, D, K = 500, 8, 8
FIELDS = ["beam_ids", "beam_dist", "beam_exp", "vis_ids", "vis_dist", "comps"]


@pytest.fixture(scope="module")
def data():
    rng = np.random.RandomState(0)
    return jnp.asarray(rng.rand(N, D).astype(np.float32))


def _graph(data, metric, seed=3):
    g = brute.exact_seed_graph(data, N, K, metric)
    # plant non-trivial occlusion factors so use_lgd_mask has teeth
    rng = np.random.RandomState(seed)
    lam = jnp.asarray(rng.randint(0, 3, g.nbr_lam.shape), jnp.int32)
    return g._replace(nbr_lam=lam)


class TestFusedBitIdentical:
    @pytest.mark.parametrize("metric", ["l2", "ip"])
    @pytest.mark.parametrize("use_reverse", [True, False])
    @pytest.mark.parametrize("use_lgd_mask", [True, False])
    def test_expand_matches_unfused(self, data, metric, use_reverse, use_lgd_mask):
        cfg = search_lib.SearchConfig(
            k=K, beam=16, n_seeds=4, hash_slots=256, max_iters=12,
            metric=metric, use_reverse=use_reverse, use_lgd_mask=use_lgd_mask,
            use_pallas=False,
        )
        g = _graph(data, metric)
        q = data[100:106]
        st = search_lib.init_state(g, data, q, jax.random.PRNGKey(1), cfg)
        for it in range(3):
            cands, beam_exp = search_lib._prepare_expansion(g, st, cfg)
            args = (
                q, data, cands, st.beam_ids, st.beam_dist, beam_exp,
                st.vis_ids, st.vis_dist,
            )
            # unfused op chain, with the gather-dist kernel supplying the
            # same per-row numerics the fused kernel uses
            ref = expand_lib.expand_reference(
                *args, metric=metric, probes=cfg.hash_probes,
                pallas_distances=True,
            )
            fused = expand_lib.fused_expand(
                *args, metric=metric, probes=cfg.hash_probes, interpret=True
            )
            for name, a, b in zip(FIELDS, ref, fused):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"iter {it}, field {name}",
                )
            bi, bd, be, vi, vd, _ = ref
            st = st._replace(
                beam_ids=bi, beam_dist=bd, beam_exp=be,
                vis_ids=vi, vis_dist=vd,
            )

    def test_hard_diversify_corner(self, data):
        """The DPG/FANNG-style λ>0 ablation rides the same kernel."""
        cfg = search_lib.SearchConfig(
            k=K, beam=16, n_seeds=4, hash_slots=256, max_iters=8,
            use_lgd_mask=True, hard_diversify=True, use_pallas=False,
        )
        g = _graph(data, "l2")
        q = data[:4]
        st = search_lib.init_state(g, data, q, jax.random.PRNGKey(2), cfg)
        cands, beam_exp = search_lib._prepare_expansion(g, st, cfg)
        args = (
            q, data, cands, st.beam_ids, st.beam_dist, beam_exp,
            st.vis_ids, st.vis_dist,
        )
        ref = expand_lib.expand_reference(*args, pallas_distances=True)
        fused = expand_lib.fused_expand(*args, interpret=True)
        for name, a, b in zip(FIELDS, ref, fused):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=name
            )


class TestDispatchEndToEnd:
    @pytest.mark.parametrize("metric", ["l2", "ip"])
    def test_search_fused_agrees_with_reference(self, data, metric):
        """use_pallas=True (fused kernel, interpret) vs use_pallas=False
        (pure-JAX) full searches find the same neighbors."""
        g = brute.exact_seed_graph(data, N, K, metric)
        q = data[:6]
        kw = dict(k=K, beam=16, n_seeds=4, hash_slots=256, max_iters=12,
                  metric=metric)
        r_ref = search_lib.search(
            g, data, q, jax.random.PRNGKey(0),
            search_lib.SearchConfig(use_pallas=False, **kw),
        )
        r_fused = search_lib.search(
            g, data, q, jax.random.PRNGKey(0),
            search_lib.SearchConfig(use_pallas=True, **kw),
        )
        # same seeds, same walk — orderings may differ only through float
        # formula differences in the distance computation
        agree = np.mean(np.asarray(r_ref.ids) == np.asarray(r_fused.ids))
        assert agree >= 0.95, agree
        np.testing.assert_allclose(
            np.asarray(r_ref.dists), np.asarray(r_fused.dists),
            rtol=1e-4, atol=1e-5,
        )
        np.testing.assert_array_equal(
            np.asarray(r_ref.n_comps), np.asarray(r_fused.n_comps)
        )
