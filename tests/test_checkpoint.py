"""Checkpoint/restore: roundtrip, manifest validation, graph state, elastic
restore under a different sharding (single-device here; the reshard path is
the same device_put-by-global-index code a multi-host restore uses)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import construct
from repro.train import checkpoint


def _state(key):
    return {
        "params": {"w": jax.random.normal(key, (8, 4)), "b": jnp.zeros((4,))},
        "opt": {"m": jnp.ones((8, 4)), "step": jnp.asarray(7, jnp.int32)},
    }


class TestRoundtrip:
    def test_save_restore(self, tmp_path):
        st = _state(jax.random.PRNGKey(0))
        checkpoint.save(str(tmp_path / "ck"), st, step=123, meta={"note": "t"})
        like = jax.tree.map(lambda x: jnp.zeros_like(x), st)
        got, step = checkpoint.restore(str(tmp_path / "ck"), like)
        assert step == 123
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_manifest_contents(self, tmp_path):
        st = _state(jax.random.PRNGKey(0))
        checkpoint.save(str(tmp_path / "ck"), st, step=5)
        man = checkpoint.load_manifest(str(tmp_path / "ck"))
        names = {r["name"] for r in man["leaves"]}
        assert "params/w" in names and "opt/step" in names

    def test_shape_mismatch_rejected(self, tmp_path):
        st = _state(jax.random.PRNGKey(0))
        checkpoint.save(str(tmp_path / "ck"), st)
        bad = {**st, "params": {"w": jnp.zeros((9, 4)), "b": jnp.zeros((4,))}}
        with pytest.raises(ValueError):
            checkpoint.restore(str(tmp_path / "ck"), bad)

    def test_missing_leaf_rejected(self, tmp_path):
        st = _state(jax.random.PRNGKey(0))
        checkpoint.save(str(tmp_path / "ck"), st)
        bigger = {**st, "extra": jnp.zeros((2,))}
        with pytest.raises(KeyError):
            checkpoint.restore(str(tmp_path / "ck"), bigger)

    def test_restore_with_shardings(self, tmp_path):
        """Elastic path: restore placing leaves under explicit shardings."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import mesh as mesh_lib

        st = _state(jax.random.PRNGKey(0))
        checkpoint.save(str(tmp_path / "ck"), st)
        mesh = mesh_lib.make_host_mesh((1, 1))
        sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), st)
        got, _ = checkpoint.restore(str(tmp_path / "ck"), st, shardings=sh)
        np.testing.assert_allclose(
            np.asarray(got["params"]["w"]), np.asarray(st["params"]["w"]))


class TestGraphCheckpoint:
    def test_wave_boundary_resume(self, tmp_path):
        """Build half, checkpoint, restore, finish — same-quality graph as a
        straight-through build (fault-tolerant construction)."""
        x = jax.random.uniform(jax.random.PRNGKey(0), (600, 8))
        cfg = construct.BuildConfig(k=8, wave=100, beam=16, n_seeds=4,
                                    hash_slots=512, max_iters=24)

        # straight-through
        g_full, _ = construct.build(x, cfg, jax.random.PRNGKey(1))

        # interrupted at wave 2 (after 256 seed + 200 inserted)
        saved = {}

        def cb(widx, g):
            if widx == 2:
                checkpoint.save_graph(str(tmp_path / "gck"), g, 456, {"k": 8})
                saved["done"] = True
                raise KeyboardInterrupt  # simulated preemption

        try:
            construct.build(x, cfg, jax.random.PRNGKey(1), wave_callback=cb)
        except KeyboardInterrupt:
            pass
        assert saved.get("done")

        from repro.core.graph import empty_graph
        like = empty_graph(600, 8, cfg.rev_cap or 16)
        g_res, row = checkpoint.restore_graph(str(tmp_path / "gck"), like)
        assert row == 456
        next_row = int(g_res.n_valid)
        g_done, _ = construct.build(
            x, cfg, jax.random.PRNGKey(2), initial=(g_res, next_row))
        assert int(g_done.n_valid) == 600

        from repro.core import brute
        tids, _ = brute.brute_force_knn(
            x, x, 8, "l2", exclude_ids=jnp.arange(600, dtype=jnp.int32))
        r_full = float(brute.recall_at_k(g_full.nbr_ids, tids, 8))
        r_resume = float(brute.recall_at_k(g_done.nbr_ids, tids, 8))
        assert r_resume > r_full - 0.05, (r_full, r_resume)
