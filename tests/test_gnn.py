"""MACE tests: E(3) equivariance properties, masking, data regimes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import graphs
from repro.models import mace


def _rot(axis: int, th: float) -> jnp.ndarray:
    c, s = np.cos(th), np.sin(th)
    m = np.eye(3)
    i, j = [(1, 2), (0, 2), (0, 1)][axis]
    m[i, i] = c; m[i, j] = -s; m[j, i] = s; m[j, j] = c
    return jnp.asarray(m, jnp.float32)


@pytest.fixture(scope="module")
def mol():
    key = jax.random.PRNGKey(0)
    cfg = mace.MACEConfig(n_layers=2, d_hidden=16, n_rbf=4, n_species=4, readout_hidden=8)
    params = mace.init_params(key, cfg)
    pos, spec = graphs.molecules(key, 1, 12)
    snd, rcv = graphs.knn_edges_from_positions(pos[0], 4)
    return cfg, params, pos[0], spec[0], snd, rcv


class TestEquivariance:
    @pytest.mark.parametrize("axis", [0, 1, 2])
    def test_energy_rotation_invariant(self, mol, axis):
        cfg, p, pos, spec, snd, rcv = mol
        R = _rot(axis, 0.83)
        e1 = mace.energy(p, pos, spec, snd, rcv, cfg)
        e2 = mace.energy(p, pos @ R.T, spec, snd, rcv, cfg)
        np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)

    def test_energy_translation_invariant(self, mol):
        cfg, p, pos, spec, snd, rcv = mol
        e1 = mace.energy(p, pos, spec, snd, rcv, cfg)
        e2 = mace.energy(p, pos + jnp.asarray([1.3, -2.0, 0.4]), spec, snd, rcv, cfg)
        np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)

    def test_forces_rotation_equivariant(self, mol):
        cfg, p, pos, spec, snd, rcv = mol
        R = _rot(1, 1.1)
        f1 = mace.forces(p, pos, spec, snd, rcv, cfg)
        f2 = mace.forces(p, pos @ R.T, spec, snd, rcv, cfg)
        np.testing.assert_allclose(np.asarray(f2), np.asarray(f1 @ R.T), rtol=1e-3, atol=1e-5)

    def test_energy_not_trivially_constant(self, mol):
        cfg, p, pos, spec, snd, rcv = mol
        e1 = mace.energy(p, pos, spec, snd, rcv, cfg)
        e2 = mace.energy(p, pos * 1.1, spec, snd, rcv, cfg)  # dilation ≠ isometry
        assert abs(float(e1) - float(e2)) > 1e-6


class TestMasking:
    def test_padded_edges_are_inert(self, mol):
        cfg, p, pos, spec, snd, rcv = mol
        e_base = mace.energy(p, pos, spec, snd, rcv, cfg)
        # append garbage edges under a False mask
        snd_p = jnp.concatenate([snd, jnp.zeros((8,), jnp.int32)])
        rcv_p = jnp.concatenate([rcv, jnp.ones((8,), jnp.int32)])
        mask = jnp.concatenate([jnp.ones_like(snd, bool), jnp.zeros((8,), bool)])
        e_pad = mace.energy(p, pos, spec, snd_p, rcv_p, cfg, edge_mask=mask)
        np.testing.assert_allclose(float(e_base), float(e_pad), rtol=1e-5)

    def test_node_mask_zeroes_readout(self):
        cfg = mace.MACEConfig(n_layers=1, d_hidden=8, n_rbf=4, n_species=2,
                              d_node_feat=6, n_classes=3, readout_hidden=8)
        p = mace.init_params(jax.random.PRNGKey(0), cfg)
        g = graphs.random_graph(jax.random.PRNGKey(1), 20, 60, 6, n_classes=3)
        mask = jnp.arange(20) < 10
        out = mace.forward(
            p, jnp.zeros((20, 3)), jnp.zeros((20,), jnp.int32),
            g.senders, g.receivers, cfg, node_feat=g.features, node_mask=mask,
        )
        np.testing.assert_allclose(np.asarray(out[10:]), 0.0, atol=1e-7)


class TestRegimes:
    def test_node_classification_trains(self):
        cfg = mace.MACEConfig(n_layers=2, d_hidden=16, n_rbf=4, n_species=1,
                              d_node_feat=16, n_classes=4, readout_hidden=8)
        params = mace.init_params(jax.random.PRNGKey(0), cfg)
        g = graphs.random_graph(jax.random.PRNGKey(1), 80, 400, 16, n_classes=4)
        batch = dict(
            positions=jnp.zeros((80, 3)), species=jnp.zeros((80,), jnp.int32),
            senders=g.senders, receivers=g.receivers, node_feat=g.features,
            labels=g.labels,
        )
        from repro.train import optimizer as opt_lib, train_loop
        ocfg = opt_lib.OptConfig(name="adamw", lr=3e-3)
        opt = opt_lib.init_opt_state(params, ocfg)
        step = jax.jit(train_loop.make_train_step(
            lambda p, b: mace.node_class_loss(p, b, cfg), ocfg))
        losses = []
        for _ in range(10):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses

    def test_sampler_shapes_and_membership(self):
        g = graphs.random_graph(jax.random.PRNGKey(0), 200, 2000, 8)
        seeds = jnp.arange(16, dtype=jnp.int32)
        fronts = graphs.khop_sample(jax.random.PRNGKey(1), g.indptr, g.indices, seeds, (5, 3))
        assert fronts[1].shape == (16, 5) and fronts[2].shape == (16, 5, 3)
        # sampled neighbors really are neighbors (or self for isolated nodes)
        ind = np.asarray(g.indices)
        ptr = np.asarray(g.indptr)
        f1 = np.asarray(fronts[1])
        for i, s in enumerate(np.asarray(seeds)):
            nbrs = set(ind[ptr[s]:ptr[s + 1]].tolist()) | {int(s)}
            assert set(f1[i].tolist()) <= nbrs

    def test_molecule_batch_loss(self):
        cfg = mace.MACEConfig(n_layers=1, d_hidden=8, n_rbf=4, n_species=4, readout_hidden=8)
        p = mace.init_params(jax.random.PRNGKey(0), cfg)
        pos, spec = graphs.molecules(jax.random.PRNGKey(1), 4, 10)
        snds, rcvs = jax.vmap(lambda x: graphs.knn_edges_from_positions(x, 3))(pos)
        batch = dict(positions=pos, species=spec, senders=snds, receivers=rcvs,
                     energy=jnp.zeros((4,)))
        loss, m = mace.energy_loss(p, batch, cfg)
        assert jnp.isfinite(loss) and jnp.isfinite(m["rmse"])
