"""Wave-pipeline equivalence tests.

Three claims the fused construction loop must keep honest:

1. the fused jitted ``wave_step`` (search + commit in one compiled call,
   device-side stats fold) produces **bit-identical** graphs to running the
   unfused search -> commit_wave path with the same inputs;
2. ``build(W=1)`` keeps the paper's sequential Alg. 2/3 semantics (one sample
   per wave, no intra-wave tile) and still reaches high recall;
3. the production wave width (W=64) holds recall@10 >= 0.90 on a 2k-point
   synthetic set.

Plus: the host-sync discipline — ``build`` returns device-side stats and
invokes ``wave_callback`` only at the configured stride.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import brute, construct
from repro.core import search as search_lib

N, D, K = 2000, 8, 10


@pytest.fixture(scope="module")
def data():
    rng = np.random.RandomState(0)
    return jnp.asarray(rng.rand(N, D).astype(np.float32))


@pytest.fixture(scope="module")
def truth(data):
    ids, _ = brute.brute_force_knn(
        data, data, K, "l2", exclude_ids=jnp.arange(N, dtype=jnp.int32)
    )
    return ids


class TestFusedEqualsUnfused:
    @pytest.mark.parametrize("lgd", [False, True])
    def test_wave_step_bit_identical_to_search_plus_commit(self, data, lgd):
        """Regression: fusing search+commit must not change a single bit."""
        cfg = construct.BuildConfig(
            k=K, wave=64, lgd=lgd, beam=16, n_seeds=4, hash_slots=512,
            max_iters=24,
        )
        g = brute.exact_seed_graph(data, 256, K, "l2")
        pos = jnp.asarray(256, jnp.int32)
        key = jax.random.PRNGKey(7)

        # unfused reference: standalone search, then standalone commit
        W = cfg.wave
        q = data[pos + jnp.arange(W)]
        res = search_lib.search(g, data, q, key, cfg.search_config())
        n_real = jnp.asarray(W, jnp.int32)
        g_ref, edges_ref = construct.commit_wave(g, data, pos, n_real, res, cfg)

        # fused path (donates g on accelerators — run it last)
        g_fused, stats = construct.wave_step(
            g, data, pos, key, construct.zero_stats(), cfg
        )

        for name, a, b in zip(g_ref._fields, g_ref, g_fused):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"field {name}"
            )
        assert int(stats.n_waves) == 1
        assert float(stats.n_inserted_edges) == float(edges_ref)

    def test_wave_step_stats_fold(self, data):
        """The stats carry accumulates across chained fused steps."""
        cfg = construct.BuildConfig(
            k=K, wave=32, lgd=False, beam=16, n_seeds=4, hash_slots=512,
            max_iters=16,
        )
        g = brute.exact_seed_graph(data, 128, K, "l2")
        stats = construct.zero_stats(5.0)
        pos = 128
        for i in range(3):
            g, stats = construct.wave_step(
                g, data, jnp.asarray(pos, jnp.int32), jax.random.PRNGKey(i),
                stats, cfg,
            )
            pos += cfg.wave
        assert int(stats.n_waves) == 3
        # seed charge + per-wave comps (searches + intra-wave tiles)
        min_intra = 3 * (32 * 31) / 2.0
        assert float(stats.n_comps) >= 5.0 + min_intra


class TestWaveSemantics:
    def test_w1_matches_sequential_semantics(self, data):
        """W=1 is the paper's sequential Alg. 2/3: each wave inserts exactly
        one sample against the graph so far, and the result is a high-quality
        graph (the sequential limit the batched waves must degenerate to)."""
        small = data[:400]
        tids, _ = brute.brute_force_knn(
            small, small, K, "l2", exclude_ids=jnp.arange(400, dtype=jnp.int32)
        )
        cfg = construct.BuildConfig(
            k=K, wave=1, lgd=True, beam=16, n_seeds=4, hash_slots=512,
            max_iters=32, intra_wave=False, n_seed_init=256,
        )
        waves = []
        g, stats = construct.build(
            small, cfg, jax.random.PRNGKey(0),
            wave_callback=lambda i, gg: waves.append(int(gg.n_valid)),
        )
        # one sample per wave, graph grows by exactly 1 each commit
        assert int(stats.n_waves) == 400 - 256
        assert waves == list(range(257, 401))
        rec = float(brute.recall_at_k(g.nbr_ids, tids, K))
        assert rec > 0.85, rec

    @pytest.mark.parametrize("lgd", [False, True])
    def test_w64_recall_at_10(self, data, truth, lgd):
        """Acceptance: build(W=64) recall@10 >= 0.90 on the 2k synthetic set."""
        cfg = construct.BuildConfig(
            k=K, wave=64, lgd=lgd, beam=24, n_seeds=4, hash_slots=1024,
            max_iters=40,
        )
        g, _ = construct.build(data, cfg, jax.random.PRNGKey(1))
        rec = float(brute.recall_at_k(g.nbr_ids, truth, 10))
        assert rec >= 0.90, (lgd, rec)


class TestCallbackStride:
    def test_stride_controls_sync_points(self, data):
        cfg = construct.BuildConfig(
            k=K, wave=128, lgd=False, beam=16, n_seeds=4, hash_slots=512,
            max_iters=16,
        )
        calls = []
        g, stats = construct.build(
            data[:1280], cfg, jax.random.PRNGKey(0),
            wave_callback=lambda i, gg: calls.append(i),
            callback_stride=4,
        )
        n_waves = int(stats.n_waves)
        assert calls == [i for i in range(1, n_waves + 1) if i % 4 == 0]

    def test_stride_validation(self, data):
        cfg = construct.BuildConfig(k=K, wave=64)
        with pytest.raises(ValueError):
            construct.build(data[:512], cfg, callback_stride=0)

    def test_stats_are_device_side(self, data):
        """No host round trip is forced on the caller: every stats pytree
        leaf is a jax Array (syncing is the caller's choice, once, at the
        end — ``Counter64`` fields sync only when read via int()/float())."""
        cfg = construct.BuildConfig(
            k=K, wave=128, lgd=False, beam=16, n_seeds=4, hash_slots=512,
            max_iters=16,
        )
        _, stats = construct.build(data[:640], cfg, jax.random.PRNGKey(0))
        for leaf in jax.tree.leaves(stats):
            assert isinstance(leaf, jax.Array), type(leaf)
