"""Shared case builders + checkers for the property test tier.

Two consumers:

  * ``tests/test_property.py`` — the Hypothesis suite (skipped when the
    package is absent; CI installs it).  Strategies there only draw small
    integers (seeds, shapes); everything data-shaped is built HERE from a
    ``np.random.RandomState(seed)``, so each example is a pure function of
    the drawn ints.
  * ``tests/test_property_fixed.py`` — the fixed-seed leg: the same checkers
    over a pinned case matrix, so the property logic itself is exercised by
    tier-1 even where Hypothesis is not installed.

Checkers raise ``AssertionError`` with context; they return nothing.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import dynamic, merge, segments
from repro.core.graph import (
    KNNGraph,
    attach_sq_norms,
    empty_graph,
    graph_invariants_ok,
    grow_graph,
    rebuild_reverse,
    row_scales,
    squared_norms,
    trim_graph,
)


# ---------------------------------------------------------------------------
# Case builders (pure NumPy — no jit specialization per Hypothesis example)
# ---------------------------------------------------------------------------


def make_points(seed: int, n: int, d: int) -> np.ndarray:
    return np.random.RandomState(seed).rand(n, d).astype(np.float32)


def exact_lists(x: np.ndarray, k: int):
    """NumPy-exact sorted k-NN lists over x (the oracle graph shape)."""
    n = x.shape[0]
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1).astype(np.float32)
    np.fill_diagonal(d2, np.inf)
    kk = min(k, n - 1)
    order = np.argsort(d2, axis=1, kind="stable")[:, :kk]
    ids = np.full((n, k), -1, np.int32)
    dist = np.full((n, k), np.inf, np.float32)
    ids[:, :kk] = order.astype(np.int32)
    dist[:, :kk] = np.take_along_axis(d2, order, axis=1)
    return ids, dist


def make_graph(seed: int, n: int, k: int, d: int = 4) -> tuple[KNNGraph, np.ndarray]:
    """A structurally valid, fully-alive KNNGraph over random points.

    Exact forward lists, canonical reverse side, exact norm cache — i.e. a
    graph every owner-maintained invariant holds on, which the ops under
    test must then *preserve*.
    """
    x = make_points(seed, n, d)
    ids, dist = exact_lists(x, k)
    g = empty_graph(n, k, rev_capacity=2 * k)
    g = g._replace(
        nbr_ids=jnp.asarray(ids),
        nbr_dist=jnp.asarray(dist),
        alive=jnp.ones((n,), bool),
        n_valid=jnp.asarray(n, jnp.int32),
    )
    g = attach_sq_norms(g, jnp.asarray(x))
    return rebuild_reverse(g), x


def assert_invariants(g: KNNGraph, context: str = "") -> None:
    inv = graph_invariants_ok(g)
    bad = [name for name, v in inv.items() if not bool(jnp.all(v))]
    assert not bad, f"graph invariants violated {bad} {context}"


def assert_norm_cache(g: KNNGraph, x: np.ndarray, context: str = "") -> None:
    """The PR-3 cache invariant: exact ‖x_i‖² for alive allocated rows, 0
    everywhere else."""
    sq = np.asarray(g.sq_norms)
    want = np.asarray(squared_norms(jnp.asarray(x[: g.capacity])))
    if want.shape[0] < g.capacity:  # grown graphs: unallocated tail rows
        want = np.pad(want, (0, g.capacity - want.shape[0]))
    rows = np.arange(g.capacity)
    live = (rows < int(g.n_valid)) & np.asarray(g.alive)
    np.testing.assert_allclose(
        sq[live], want[live], rtol=1e-6,
        err_msg=f"norm cache drifted on alive rows {context}",
    )
    assert np.all(sq[~live] == 0.0), f"norm cache nonzero on dead rows {context}"


def assert_scale_table(g: KNNGraph, x: np.ndarray, context: str = "") -> None:
    """The PR-7 scale-table invariant (mirrors the norm cache): exact
    ``max|x_i|/127`` for alive allocated rows, 0 everywhere else.  Zero
    scales dequantize through 1, so a stale nonzero entry on a dead row
    would silently corrupt int8 distances after the row is recycled."""
    sc = np.asarray(g.row_scale)
    want = np.asarray(row_scales(jnp.asarray(x[: g.capacity])))
    if want.shape[0] < g.capacity:  # grown graphs: unallocated tail rows
        want = np.pad(want, (0, g.capacity - want.shape[0]))
    rows = np.arange(g.capacity)
    live = (rows < int(g.n_valid)) & np.asarray(g.alive)
    np.testing.assert_allclose(
        sc[live], want[live], rtol=1e-6,
        err_msg=f"scale table drifted on alive rows {context}",
    )
    assert np.all(sc[~live] == 0.0), f"scale table nonzero on dead rows {context}"


# ---------------------------------------------------------------------------
# Checkers (one property each)
# ---------------------------------------------------------------------------


def check_generated_graph_invariants(seed: int, n: int, k: int) -> None:
    g, x = make_graph(seed, n, k)
    assert_invariants(g, "(freshly generated)")
    assert_norm_cache(g, x, "(freshly generated)")
    assert_scale_table(g, x, "(freshly generated)")


def check_remove_preserves_invariants(seed: int, n: int, k: int, n_rm: int) -> None:
    """dynamic.remove keeps every structural + cache invariant, for any
    victim set (including duplicates and out-of-range padding)."""
    g, x = make_graph(seed, n, k)
    rng = np.random.RandomState(seed ^ 0x5EED)
    victims = rng.randint(-1, n + 2, size=max(n_rm, 1)).astype(np.int32)
    g2 = dynamic.remove(g, jnp.asarray(x), jnp.asarray(victims), "l2")
    assert_invariants(g2, f"(after remove {victims.tolist()})")
    assert_norm_cache(g2, x, "(after remove)")
    assert_scale_table(g2, x, "(after remove)")
    dead = set(int(v) for v in victims if 0 <= v < n)
    alive = np.asarray(g2.alive)
    assert not any(alive[v] for v in dead)
    # no list (forward or reverse) still references a victim
    for v in dead:
        assert not np.any(np.asarray(g2.nbr_ids) == v)
        assert not np.any(np.asarray(g2.rev_ids) == v)


def check_grow_trim_cache_carry(seed: int, n: int, k: int, extra: int) -> None:
    """grow_graph carries the cache; trim_graph drops only unallocated tail."""
    g, x = make_graph(seed, n, k)
    g2 = grow_graph(g, n + extra)
    assert g2.capacity == n + extra
    assert_norm_cache(g2, x, "(after grow)")
    assert_invariants(g2, "(after grow)")
    g3 = trim_graph(g2, n)
    for field in KNNGraph._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(g3, field)), np.asarray(getattr(g, field)),
            err_msg=f"trim(grow(g)) != g on {field}",
        )


def check_scale_table_lifecycle(seed: int, n0: int, extra: int, k: int) -> None:
    """``KNNGraph.row_scale`` rides every lifecycle op exactly like the norm
    cache: build -> grow -> insert -> remove -> compact, with zeros on
    recycled rows at every stage."""
    import jax

    from repro.core import construct

    rng = np.random.RandomState(seed)
    x = rng.rand(n0 + extra, 8).astype(np.float32)
    cfg = construct.BuildConfig(
        k=k, metric="l2", wave=32, beam=16, n_seeds=4, max_iters=20,
        dispatch="reference",
    )
    g, _ = construct.build(jnp.asarray(x[:n0]), cfg, jax.random.PRNGKey(seed))
    assert_scale_table(g, x[:n0], "(after build)")
    g = grow_graph(g, n0 + extra)
    assert_scale_table(g, x, "(after grow)")
    g, _ = dynamic.insert(g, jnp.asarray(x), extra, cfg,
                          jax.random.PRNGKey(seed + 1))
    assert_scale_table(g, x, "(after insert)")
    victims = rng.choice(n0 + extra, size=min(3, n0), replace=False).astype(np.int32)
    g = dynamic.remove(g, jnp.asarray(x), jnp.asarray(victims), "l2")
    assert_scale_table(g, x, "(after remove)")
    g2, x2, _ = dynamic.compact(g, jnp.asarray(x))
    assert_scale_table(g2, np.asarray(x2), "(after compact)")


def check_reverse_structural_contract(seed: int, n: int, k: int) -> None:
    """rebuild_reverse: every stored reverse edge is a true forward edge's
    reverse, each member holds min(in_degree, R) owners, and rev_lam
    snapshots the forward twin's λ exactly."""
    g, _ = make_graph(seed, n, k)
    # give λ distinguishable values so the snapshot check bites
    rng = np.random.RandomState(seed ^ 0xABCD)
    lam = np.where(
        np.asarray(g.nbr_ids) >= 0, rng.randint(0, 7, size=(n, k)), 0
    ).astype(np.int32)
    g = rebuild_reverse(g._replace(nbr_lam=jnp.asarray(lam)))
    ids = np.asarray(g.nbr_ids)
    rev = np.asarray(g.rev_ids)
    rev_lam = np.asarray(g.rev_lam)
    R = g.rev_capacity
    owners = {j: [r for r in range(n) if j in ids[r].tolist()] for j in range(n)}
    for j in range(n):
        got = [int(o) for o in rev[j] if o >= 0]
        assert set(got) <= set(owners[j]), f"phantom reverse edge at {j}"
        assert len(got) == min(len(owners[j]), R)
        assert len(set(got)) == len(got), f"duplicate reverse owners at {j}"
        for slot, o in enumerate(rev[j]):
            if o >= 0:  # λ snapshot == λ of j inside G[o]
                twin = int(np.where(ids[o] == j)[0][0])
                assert rev_lam[j, slot] == lam[o, twin]
        assert int(g.rev_ptr[j]) == min(len(owners[j]), R)


def check_merge_candidates_invariants(case) -> None:
    cap, k, ids, dist, v, q, d = case
    res = merge.merge_candidates(
        jnp.asarray(ids), jnp.asarray(dist), jnp.asarray(np.zeros_like(ids)),
        jnp.asarray(v), jnp.asarray(q), jnp.asarray(d),
    )
    m_ids = np.asarray(res.nbr_ids)
    m_dist = np.asarray(res.nbr_dist)
    for r in range(cap):
        row = m_dist[r]
        assert np.all(np.diff(row[np.isfinite(row)]) >= 0), "row not sorted"
        real = m_ids[r][m_ids[r] >= 0]
        assert len(set(real.tolist())) == len(real), "duplicate ids in row"
        assert r not in real.tolist(), "self loop"


def check_merge_candidates_oracle(case) -> None:
    """Batched merge == per-row sequential top-k insertion (the paper's
    insertG semantics, final-content-exact)."""
    cap, k, ids, dist, v, q, d = case
    res = merge.merge_candidates(
        jnp.asarray(ids), jnp.asarray(dist), jnp.asarray(np.zeros_like(ids)),
        jnp.asarray(v), jnp.asarray(q), jnp.asarray(d),
    )
    m_ids = np.asarray(res.nbr_ids)
    m_dist = np.asarray(res.nbr_dist)
    for r in range(cap):
        pool = {}
        for j in range(k):
            if ids[r, j] >= 0:
                pool[int(ids[r, j])] = float(dist[r, j])
        for t in range(len(v)):
            if v[t] == r and q[t] != r and q[t] >= 0 and int(q[t]) not in pool:
                pool[int(q[t])] = float(d[t])
        want = sorted(pool.items(), key=lambda kv: kv[1])[:k]
        got = [(int(i), float(s)) for i, s in zip(m_ids[r], m_dist[r]) if i >= 0]
        assert len(got) == len(want), f"row {r}: kept {len(got)} != {len(want)}"
        np.testing.assert_allclose(
            [s for _, s in got], [s for _, s in want], rtol=1e-6,
            err_msg=f"row {r} distances diverge from sequential insertion",
        )


def make_merge_case(seed: int, cap: int, k: int, t: int):
    """Random partially-filled rows + a proposal stream whose distances are
    a deterministic function of the pair (as in reality)."""
    rng = np.random.RandomState(seed)
    ids = np.full((cap, k), -1, np.int32)
    dist = np.full((cap, k), np.inf, np.float32)
    for r in range(cap):
        nfill = rng.randint(0, k + 1)
        if nfill:
            cands = rng.choice(
                [i for i in range(cap) if i != r],
                size=min(nfill, cap - 1), replace=False,
            )
            ids[r, : len(cands)] = cands
            dist[r, : len(cands)] = np.sort(rng.rand(len(cands)).astype(np.float32))
    v = rng.randint(-1, cap, size=t).astype(np.int32)
    q = rng.randint(0, cap, size=t).astype(np.int32)
    pair_d = rng.rand(cap + 1, cap).astype(np.float32)
    d = pair_d[np.maximum(v, 0), q]
    return cap, k, ids, dist, v, q, d


def check_append_reverse_ring(seed: int, R: int, t: int) -> None:
    rng = np.random.RandomState(seed)
    cap = 8
    owner = rng.randint(0, cap, size=t).astype(np.int32)
    member = rng.randint(-1, cap, size=t).astype(np.int32)
    rev2, _, ptr2 = merge.append_reverse(
        jnp.full((cap, R), -1, jnp.int32),
        jnp.zeros((cap, R), jnp.int32),
        jnp.zeros((cap,), jnp.int32),
        jnp.asarray(owner), jnp.asarray(member),
    )
    rev2, ptr2 = np.asarray(rev2), np.asarray(ptr2)
    for m in range(cap):
        appends = owner[(member == m) & (owner >= 0)]
        assert ptr2[m] == len(appends), "rev_ptr must count every append"
        got = set(int(o) for o in rev2[m] if o >= 0)
        assert len(got) <= R
        # starting from an empty ring, EXACTLY the last min(R, n) appends
        # survive (FIFO overwrite drops the oldest, never the newest)
        expect = set(appends[-min(R, len(appends)):].tolist()) if len(appends) else set()
        assert got == expect, f"member {m}: ring holds {got}, want {expect}"


def check_search_comps_accounting(seed: int, n: int, k: int, B: int) -> None:
    """The scanning-rate ledger oracle (Eq. 2 numerator, per lane).

    EHC charges ``n_comps`` once per distance evaluation, and every evaluated
    vertex is recorded in the D array (vis_ids/vis_dist).  So whenever a lane
    did NOT saturate its hash (``hash_full`` False):

      * the recorded ids are unique — nothing was evaluated twice;
      * every recorded distance equals the exact NumPy distance;
      * ``n_comps`` == the number of recorded (= unique evaluated) vertices.

    A saturated lane may overcount (inserts dropped, later re-evaluations
    possible) — exactly what the flag is for — so there the ledger is only
    bounded below by the recorded count.  The seed-graph pre-charge
    (``construct.zero_stats``) is checked exactly: a build that is all seed
    graph scans n(n-1)/2 pairs, no more, no less.
    """
    import jax

    from repro.core import construct
    from repro.core import search as search_lib

    g, x = make_graph(seed, n, k)
    rng = np.random.RandomState(seed ^ 0xACC7)
    q = rng.rand(B, x.shape[1]).astype(np.float32)
    kk = min(k, 8)
    cfg = search_lib.SearchConfig(
        k=kk, beam=max(16, kk), n_seeds=4, metric="l2", max_iters=24,
        use_pallas=False,
    )
    res = search_lib.search(
        g, jnp.asarray(x), jnp.asarray(q), jax.random.PRNGKey(seed), cfg
    )
    vis_ids = np.asarray(res.vis_ids)
    vis_dist = np.asarray(res.vis_dist)
    n_comps = np.asarray(res.n_comps)
    full = np.asarray(res.hash_full)
    d2 = ((q[:, None, :] - x[None, :, :]) ** 2).sum(-1).astype(np.float32)
    for b in range(B):
        rec = vis_ids[b] >= 0
        ids_b = vis_ids[b][rec]
        assert len(set(ids_b.tolist())) == len(ids_b), (
            f"lane {b}: duplicate ids in the D array"
        )
        assert np.all(ids_b < n), f"lane {b}: out-of-range id recorded"
        # the blocked engine computes ||q||^2 + ||x||^2 - 2 q.x in f32; allow
        # the decomposition's last-ulp drift vs the direct NumPy difference
        np.testing.assert_allclose(
            vis_dist[b][rec], d2[b, ids_b], rtol=1e-4, atol=1e-5,
            err_msg=f"lane {b}: D array distance != exact distance",
        )
        if not full[b]:
            assert int(n_comps[b]) == int(rec.sum()), (
                f"lane {b}: n_comps {int(n_comps[b])} != unique evaluations "
                f"{int(rec.sum())} with hash not saturated"
            )
        else:  # saturated lanes may only overcount, never undercount
            assert int(n_comps[b]) >= int(rec.sum())
    # seed-graph pre-charge: zero_stats carries it verbatim, and a build that
    # is ALL seed graph (n <= n_seed_init) charges exactly n(n-1)/2
    assert int(construct.zero_stats(123.0).n_comps) == 123
    n0 = min(n, 24)
    bcfg = construct.BuildConfig(k=kk, metric="l2", wave=16, use_pallas=False)
    _, st = construct.build(
        jnp.asarray(x[:n0]), bcfg, jax.random.PRNGKey(0)
    )
    assert int(st.n_comps) == n0 * (n0 - 1) // 2, (
        "seed-graph pre-charge must equal the exhaustive pair count"
    )


def check_tracker_transparency(seed: int, n: int, k: int, B: int) -> None:
    """Telemetry is read-only: tracker on == tracker off, bitwise (fp32).

    Builds the same dataset twice through ``construct.build`` — once bare,
    once under an ``InMemoryTracker`` — and asserts the committed graphs and
    a subsequent B-query search are bit-identical.  The tracked run must
    also have actually produced telemetry (stride spans + cumulative build
    metrics whose final ``build/n_comps`` equals the returned counter), so
    a silently-disconnected tracker can't pass as "transparent".
    """
    import jax

    from repro.core import construct
    from repro.core import search as search_lib
    from repro.obs import InMemoryTracker

    x = jnp.asarray(make_points(seed, n, 4))
    # n_seed_init below n so the instrumented wave loop actually runs
    cfg = construct.BuildConfig(
        k=k, metric="l2", wave=8, n_seed_init=min(8, max(2, n - 1)),
        use_pallas=False,
    )
    key = jax.random.PRNGKey(seed)
    g0, st0 = construct.build(x, cfg, key)
    trk = InMemoryTracker()
    g1, st1 = construct.build(x, cfg, key, tracker=trk)
    for f in ("nbr_ids", "nbr_dist", "alive", "rev_ids", "rev_ptr"):
        np.testing.assert_array_equal(
            np.asarray(getattr(g0, f)), np.asarray(getattr(g1, f)),
            err_msg=f"graph field {f} changed under telemetry",
        )
    assert int(st0.n_comps) == int(st1.n_comps)

    stride_spans = trk.spans("build/stride")
    assert stride_spans and all(e["synced"] for e in stride_spans)
    build_metrics = [
        e for e in trk.metrics_events if "build/n_comps" in e["metrics"]
    ]
    assert build_metrics, "tracked build emitted no build metrics"
    assert build_metrics[-1]["metrics"]["build/n_comps"] == int(st1.n_comps)

    rng = np.random.RandomState(seed ^ 0x0B5)
    q = jnp.asarray(rng.rand(B, 4).astype(np.float32))
    scfg = search_lib.SearchConfig(
        k=min(k, 8), beam=16, n_seeds=4, metric="l2", max_iters=24,
        use_pallas=False,
    )
    r0 = search_lib.search(g0, x, q, jax.random.PRNGKey(seed), scfg)
    r1 = search_lib.search(g1, x, q, jax.random.PRNGKey(seed), scfg)
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
    np.testing.assert_array_equal(np.asarray(r0.dists), np.asarray(r1.dists))
    np.testing.assert_array_equal(
        np.asarray(r0.n_comps), np.asarray(r1.n_comps)
    )


def check_topk_smallest_matches_numpy(seed: int, m: int, c: int, k: int) -> None:
    """ref.topk_smallest == NumPy partial sort, ids consistent with dists."""
    from repro.kernels import ref

    rng = np.random.RandomState(seed)
    d = rng.rand(m, c).astype(np.float32)
    ids = rng.randint(0, 1000, size=(m, c)).astype(np.int32)
    kk = min(k, c)
    got_d, got_i = ref.topk_smallest(jnp.asarray(d), jnp.asarray(ids), kk)
    got_d, got_i = np.asarray(got_d), np.asarray(got_i)
    want = np.sort(d, axis=1)[:, :kk]
    np.testing.assert_allclose(got_d, want, rtol=1e-6)
    for r_ in range(m):
        for j in range(kk):
            # the id in slot j must name a column whose distance matches
            src = np.where(ids[r_] == got_i[r_, j])[0]
            assert src.size and d[r_][src].min() <= want[r_, j] + 1e-6


def check_grouped_top_r_matches_numpy(seed: int, num_segments: int, r: int, t: int) -> None:
    """segments.grouped_top_r == the per-segment first-r NumPy reference."""
    rng = np.random.RandomState(seed)
    keys = np.sort(rng.randint(0, num_segments + 2, size=t)).astype(np.int32)
    payload = rng.randint(0, 1000, size=t).astype(np.int32)
    (buf,), counts = segments.grouped_top_r(
        jnp.asarray(keys), [jnp.asarray(payload)], [-1], num_segments, r
    )
    buf, counts = np.asarray(buf), np.asarray(counts)
    for s in range(num_segments):
        vals = payload[keys == s]
        want = vals[:r].tolist()
        got = [int(x) for x in buf[s] if x >= 0]
        assert got == want, f"segment {s}: {got} != {want}"
        assert counts[s] == len(vals), "counts must be uncapped"


def check_merged_coarse_fold_invariants(seed: int, n_rm: int) -> None:
    """Folded coarse levels obey the hierarchy invariants through a 4-shard
    merge tree with pre-merge churn on shard 0.

    Four shard graphs build under seed_mode="coarse" (fixed shapes: one jit
    specialization across every drawn example); shard 0 then loses ``n_rm``
    rows (``dynamic.remove`` + ``hierarchy.purge_rows`` — capacity keeps its
    high-water mark, so the merge precondition holds).  The fold's root
    level must reference only live union rows, keep every member cell in
    range, and be exactly the offset-concatenation of the leaf levels
    (landmarks fold, they are never resampled).
    """
    import jax

    from repro.core import construct, hierarchy
    from repro.core import merge as merge_lib

    SHARD_N, D, K, L, M = 48, 6, 4, 12, 4
    assert 0 <= n_rm <= 8
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.rand(4 * SHARD_N, D).astype(np.float32))
    cfg = construct.BuildConfig(
        k=K, wave=16, lgd=True, beam=12, n_seeds=2, hash_slots=256,
        max_iters=16, n_seed_init=16, seed_mode="coarse",
        coarse_landmarks=L, coarse_members=M,
    )
    graphs, coarses = [], []
    for s in range(4):
        g, _, c = construct.build(
            x[s * SHARD_N : (s + 1) * SHARD_N], cfg,
            jax.random.fold_in(jax.random.PRNGKey(seed), s),
            return_coarse=True,
        )
        graphs.append(g)
        coarses.append(c)

    # churn shard 0 pre-merge: remove rows (padded to a fixed width so the
    # remove jit-cache hits across examples) and purge its level
    rm = np.full(8, -1, np.int32)
    rm[:n_rm] = rng.choice(SHARD_N, size=n_rm, replace=False)
    graphs[0] = dynamic.remove(
        graphs[0], x[:SHARD_N], jnp.asarray(rm), cfg.metric
    )
    coarses[0] = hierarchy.purge_rows(coarses[0], jnp.asarray(rm))

    merged, comps, root = merge_lib.merge_subgraphs(
        x=x, graphs=graphs, scfg=cfg.search_config(),
        key=jax.random.PRNGKey(seed + 99), coarses=coarses,
    )
    assert root is not None and comps > 0
    n_total = 4 * SHARD_N
    alive = np.asarray(merged.alive)
    removed_global = set(rm[rm >= 0].tolist())  # shard 0 is offset 0

    # landmark liveness: live landmark rows reference live union rows; no
    # removed row survives the fold
    lrows = np.asarray(root.landmark_rows)
    assert root.n_landmarks == 4 * L
    assert lrows.shape == (4 * L,)
    live_l = lrows[lrows >= 0]
    assert live_l.size, "fold must keep live landmarks"
    assert live_l.max() < n_total
    assert alive[live_l].all(), "dead landmark row escaped the fold"
    assert not (set(live_l.tolist()) & removed_global)

    # member-cell id ranges: every member in [-1, n_total), never dead
    mem = np.asarray(root.members)
    assert mem.shape == (4 * L, M)
    live_m = mem[mem >= 0]
    assert live_m.size == 0 or live_m.max() < n_total
    assert live_m.size == 0 or alive[live_m].all()
    assert not (set(live_m.tolist()) & removed_global)
    assert np.asarray(root.mem_ptr).shape == (4 * L,)
    assert (np.asarray(root.mem_ptr) >= 0).all()

    # structural oracle: the root is the offset-concatenation of the leaves
    # in shard order (points frozen; landmark graph re-merged, not resampled)
    assert np.array_equal(
        np.asarray(root.points),
        np.concatenate([np.asarray(c.points) for c in coarses]),
    )
    want_rows = np.concatenate(
        [
            np.where(
                np.asarray(c.landmark_rows) >= 0,
                np.asarray(c.landmark_rows) + s * SHARD_N,
                -1,
            )
            for s, c in enumerate(coarses)
        ]
    )
    assert np.array_equal(lrows, want_rows)
