"""ServingLoop semantics (repro.serve.loop).

The loop is a deterministic host-side state machine; these tests drive it
step by step and pin the contracts the benchmarks and the CI serving gate
stand on: exactly-once serving, pow2 wave coalescing, churn flushed at wave
boundaries (reads observe prior writes), the deterministic recall
reservoir, and the report/audit surface.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import construct
from repro.index.lifecycle import OnlineIndex
from repro.obs import InMemoryTracker
from repro.serve.loop import ServeLoopConfig, ServingLoop, _slice_result

D = 8


def _mk_index(n=192, seed=0, k=6):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.rand(n, D).astype(np.float32))
    return OnlineIndex.build(
        x, construct.BuildConfig(k=k, wave=64), key=jax.random.PRNGKey(1)
    )


def _queries(m, seed=100):
    return np.random.RandomState(seed).rand(m, D).astype(np.float32)


def _mk_loop(index=None, **cfg_kw):
    index = index or _mk_index()
    return ServingLoop(index, ServeLoopConfig(top_k=5, **cfg_kw))


# ---------------------------------------------------------------------------
# coalescing + exactly-once
# ---------------------------------------------------------------------------


def test_pow2_bucketing_and_drain_order():
    loop = _mk_loop(max_batch=8)
    assert loop.submit(_queries(5)) == 5
    assert loop.submit(_queries(6, seed=101)) == 11
    w1 = loop.step()  # drains 8 (the cap), bucket 8
    assert (w1["batch"], w1["bucket"]) == (8, 8)
    w2 = loop.step()  # drains the remaining 3, padded to 4
    assert (w2["batch"], w2["bucket"]) == (3, 4)
    assert loop.step() is None  # empty queue: no wave, no crash
    assert loop.served == 11 and loop.queue_depth == 0


@pytest.mark.parametrize("m,bucket", [(1, 1), (2, 2), (3, 4), (4, 4), (7, 8)])
def test_bucket_is_next_pow2(m, bucket):
    loop = _mk_loop(max_batch=8)
    loop.submit(_queries(m))
    assert loop.step()["bucket"] == bucket


def test_single_query_submit_is_a_row():
    loop = _mk_loop(max_batch=4)
    loop.submit(_queries(1)[0])  # 1-D submit
    w = loop.step()
    assert (w["batch"], w["bucket"]) == (1, 1) and loop.served == 1


def test_pump_drains_everything():
    loop = _mk_loop(max_batch=4)
    loop.submit(_queries(11))
    assert loop.pump() == 3  # 4 + 4 + 3
    assert loop.served == 11 and loop.queue_depth == 0
    assert loop.stats.n_queries == 11  # padding lanes not double-counted


def test_served_ids_are_alive_rows():
    idx = _mk_index()
    loop = ServingLoop(idx, ServeLoopConfig(top_k=5, max_batch=8,
                                            recall_sample_every=1))
    loop.submit(_queries(13))
    loop.pump()
    alive = np.asarray(idx.graph.alive)
    for ids in loop._res_ids:
        assert (ids >= 0).all() and (ids < idx.n_items).all()
        assert alive[ids].all()


# ---------------------------------------------------------------------------
# churn interleave: reads observe prior writes
# ---------------------------------------------------------------------------


def test_add_is_buffered_until_wave_boundary():
    idx = _mk_index()
    loop = _mk_loop(index=idx, max_batch=8)
    n0 = idx.n_items
    loop.add(_queries(3, seed=55), key=jax.random.PRNGKey(9))
    # buffered: the catalog counts them, the graph has not committed them
    assert idx.n_pending == 3 and int(idx.graph.n_valid) == n0
    loop.submit(_queries(2))
    loop.step()
    assert idx.n_pending == 0  # flushed at the wave boundary, pre-search
    assert int(idx.graph.n_valid) == n0 + 3 and idx.n_items == n0 + 3


def test_remove_lands_immediately_and_is_never_served():
    idx = _mk_index()
    victims = [3, 40, 77]
    loop = ServingLoop(idx, ServeLoopConfig(top_k=5, max_batch=8,
                                            recall_sample_every=1))
    loop.remove(jnp.asarray(victims))
    assert idx.n_items == 192 - 3
    loop.submit(_queries(16))
    loop.pump()
    for ids in loop._res_ids:
        assert not np.isin(ids, victims).any()


def test_inserted_row_is_findable_next_wave():
    idx = _mk_index()
    probe = _queries(1, seed=777)
    loop = ServingLoop(idx, ServeLoopConfig(top_k=5, beam=32, max_batch=4,
                                            recall_sample_every=1))
    new_id = idx.n_items  # lands in the first free slot
    loop.add(probe, key=jax.random.PRNGKey(4))
    loop.submit(probe)  # query == the just-inserted vector
    loop.step()
    assert new_id in loop._res_ids[0]  # its own (distance-0) neighbor


# ---------------------------------------------------------------------------
# recall reservoir + audit
# ---------------------------------------------------------------------------


def test_reservoir_stride_and_round_robin():
    loop = _mk_loop(max_batch=8, recall_sample_every=2, recall_reservoir=3)
    q = _queries(10)
    loop.submit(q)
    loop.pump()
    # sampled arrival indices: 0,2,4,6,8 -> slots 0,1,2,0,1 (round robin),
    # so the reservoir ends holding arrivals 6, 8, 4 in slots 0, 1, 2
    assert len(loop._res_q) == 3
    np.testing.assert_array_equal(loop._res_q[0], q[6])
    np.testing.assert_array_equal(loop._res_q[1], q[8])
    np.testing.assert_array_equal(loop._res_q[2], q[4])


def test_audit_reports_fresh_and_served_recall():
    idx = _mk_index()
    loop = ServingLoop(idx, ServeLoopConfig(top_k=5, max_batch=8,
                                            recall_sample_every=1,
                                            recall_reservoir=8))
    loop.submit(_queries(8))
    loop.pump()
    out = loop.audit_recall(k=5)
    assert out["n_audited"] == 8
    assert 0.0 <= out["recall_at_5"] <= 1.0
    assert 0.0 <= out["recall_at_5_served"] <= 1.0


def test_audit_recall_high_on_tiny_catalog():
    # a wide-beam walk over a 48-row catalog finds nearly everything; the
    # floor guards the audit's alive-aware ground truth plumbing (a wrong
    # n_valid/alive mask crashes recall toward 0), not EHC quality
    idx = _mk_index(n=48)
    loop = ServingLoop(idx, ServeLoopConfig(top_k=5, beam=48, max_batch=8,
                                            recall_sample_every=1))
    loop.submit(_queries(8))
    loop.pump()
    out = loop.audit_recall(k=5)
    assert out["recall_at_5"] >= 0.85


def test_empty_reservoir_audit():
    loop = _mk_loop(max_batch=4)
    assert loop.audit_recall() == {"n_audited": 0}


# ---------------------------------------------------------------------------
# report + measurement window
# ---------------------------------------------------------------------------


def test_report_surface_and_reset_window():
    idx = _mk_index()
    loop = ServingLoop(idx, ServeLoopConfig(top_k=5, max_batch=8))
    loop.submit(_queries(12))
    loop.pump()
    rec = loop.report(audit_k=5)
    for k in ("n_served", "n_waves", "qps", "p50_latency_ms",
              "p99_latency_ms", "mean_latency_ms", "comps_per_query",
              "scanning_rate", "hash_saturation_ratio", "capped_ratio",
              "recall_at_5", "recall_at_5_served"):
        assert k in rec, k
    assert rec["n_served"] == 12 and rec["n_waves"] == 2
    assert rec["qps"] > 0 and rec["p99_latency_ms"] >= rec["p50_latency_ms"]
    assert rec["comps_per_query"] > 0
    assert 0.0 < rec["scanning_rate"] < 1.0
    # warm-up exclusion: the window resets, the index does not
    loop.reset_window()
    assert loop.served == 0 and loop.stats.n_queries == 0
    assert loop._res_q == [] and loop._lat == []
    assert idx.n_items == 192
    loop.submit(_queries(3))
    loop.pump()
    assert loop.report()["n_served"] == 3


def test_latency_includes_queueing_delay():
    import time

    loop = _mk_loop(max_batch=8)
    loop.submit(_queries(2))
    time.sleep(0.05)  # queries wait in the queue before the wave fires
    loop.step()
    rec = loop.report()
    assert rec["p50_latency_ms"] >= 50.0  # enqueue->result, not search-only


# ---------------------------------------------------------------------------
# telemetry wiring
# ---------------------------------------------------------------------------


def test_tracker_sees_the_wave_skeleton():
    trk = InMemoryTracker()
    idx = _mk_index()
    loop = ServingLoop(idx, ServeLoopConfig(top_k=5, max_batch=8),
                       tracker=trk)
    assert idx.tracker is trk  # lifecycle spans share the trace
    loop.submit(_queries(9))
    loop.pump()
    assert len(trk.spans("serve/step")) == 2
    searches = trk.spans("serve/search")
    assert len(searches) == 2
    assert all(s["synced"] for s in searches)  # latency covered device work
    assert all(s["parent"] == "serve/step" for s in searches)
    per_wave = [e for e in trk.metrics_events
                if "serve/batch" in e["metrics"]]
    assert [e["metrics"]["serve/bucket"] for e in per_wave] == [8, 1]
    assert [e["step"] for e in per_wave] == [1, 2]


def test_slice_result_trims_every_field():
    idx = _mk_index()
    res = idx.search(jnp.asarray(_queries(4)), 5, key=jax.random.PRNGKey(0))
    cut = _slice_result(res, 2)
    for f in res._fields:
        assert getattr(cut, f).shape[0] == 2, f


def test_config_validation():
    with pytest.raises(AssertionError):
        ServeLoopConfig(max_batch=6)  # not a pow2
    with pytest.raises(AssertionError):
        ServeLoopConfig(recall_sample_every=0)
