"""Fixed-seed leg of the property suite (tests/prop_util.py checkers).

The Hypothesis suite (``test_property.py``) sweeps these same checkers over
drawn cases; this file pins a small deterministic case matrix so the
property logic itself — and the invariants it guards — stay exercised by
tier-1 even in environments without Hypothesis.
"""

import pytest

import prop_util


CASES = [(0, 8, 2), (1, 12, 3), (2, 16, 5), (3, 5, 4)]


@pytest.mark.parametrize("seed,n,k", CASES)
def test_generated_graph_invariants(seed, n, k):
    prop_util.check_generated_graph_invariants(seed, n, k)


@pytest.mark.parametrize("seed,n,k,n_rm", [(0, 8, 2, 1), (1, 12, 3, 4), (2, 14, 4, 3)])
def test_remove_preserves_invariants(seed, n, k, n_rm):
    prop_util.check_remove_preserves_invariants(seed, n, k, n_rm)


@pytest.mark.parametrize("seed,n,k,extra", [(0, 8, 2, 3), (1, 12, 3, 8)])
def test_grow_trim_cache_carry(seed, n, k, extra):
    prop_util.check_grow_trim_cache_carry(seed, n, k, extra)


@pytest.mark.parametrize("seed,n0,extra,k", [(0, 48, 12, 4), (1, 64, 16, 6)])
def test_scale_table_lifecycle(seed, n0, extra, k):
    prop_util.check_scale_table_lifecycle(seed, n0, extra, k)


@pytest.mark.parametrize("seed,n,k", CASES)
def test_reverse_structural_contract(seed, n, k):
    prop_util.check_reverse_structural_contract(seed, n, k)


@pytest.mark.parametrize("seed,cap,k,t", [(0, 6, 3, 20), (1, 12, 5, 40), (2, 4, 2, 1)])
def test_merge_candidates(seed, cap, k, t):
    case = prop_util.make_merge_case(seed, cap, k, t)
    prop_util.check_merge_candidates_invariants(case)
    prop_util.check_merge_candidates_oracle(case)


@pytest.mark.parametrize("seed,R,t", [(0, 2, 10), (1, 4, 30), (2, 6, 5)])
def test_append_reverse_ring(seed, R, t):
    prop_util.check_append_reverse_ring(seed, R, t)


@pytest.mark.parametrize("seed,n,k,B", [(0, 20, 4, 2), (1, 24, 6, 4)])
def test_search_comps_accounting(seed, n, k, B):
    prop_util.check_search_comps_accounting(seed, n, k, B)


@pytest.mark.parametrize("seed,n,k,B", [(0, 20, 4, 2), (1, 24, 6, 4)])
def test_tracker_transparency(seed, n, k, B):
    prop_util.check_tracker_transparency(seed, n, k, B)


@pytest.mark.parametrize("seed,m,c,k", [(0, 5, 16, 3), (1, 2, 20, 8), (2, 6, 1, 1)])
def test_topk_smallest(seed, m, c, k):
    prop_util.check_topk_smallest_matches_numpy(seed, m, c, k)


@pytest.mark.parametrize("seed,s,r,t", [(0, 4, 2, 30), (1, 8, 5, 60), (2, 2, 1, 0)])
def test_grouped_top_r(seed, s, r, t):
    prop_util.check_grouped_top_r_matches_numpy(seed, s, r, t)


@pytest.mark.parametrize("seed,n_rm", [(0, 3), (1, 0), (2, 8)])
def test_merged_coarse_fold_invariants(seed, n_rm):
    prop_util.check_merged_coarse_fold_invariants(seed, n_rm)
