"""RecSys tests: embedding-bag oracle, CIN vs naive reference, the four
models' training signal, retrieval-scorer consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import recsys_data
from repro.models import embedding, recsys
from repro.train import optimizer as opt_lib, train_loop


class TestEmbeddingBag:
    def test_sum_mean_vs_manual(self):
        tbl = jax.random.normal(jax.random.PRNGKey(0), (40, 6))
        ids = jnp.asarray([[1, 2, 3], [5, -1, -1], [-1, -1, -1]])
        got_sum = embedding.embedding_bag(tbl, ids, mode="sum")
        got_mean = embedding.embedding_bag(tbl, ids, mode="mean")
        np.testing.assert_allclose(np.asarray(got_sum[0]), np.asarray(tbl[1] + tbl[2] + tbl[3]), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(got_mean[1]), np.asarray(tbl[5]), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(got_sum[2]), 0.0, atol=1e-7)

    def test_weights(self):
        tbl = jax.random.normal(jax.random.PRNGKey(0), (10, 4))
        ids = jnp.asarray([[0, 1]])
        w = jnp.asarray([[2.0, 0.5]])
        got = embedding.embedding_bag(tbl, ids, weights=w)
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(2 * tbl[0] + 0.5 * tbl[1]), rtol=1e-6)

    def test_hash_rows_in_range(self):
        cfg = embedding.TableConfig(rows=10_000, dim=4, hash_rows=64)
        tbl = embedding.init_table(jax.random.PRNGKey(0), cfg)
        assert tbl.shape == (64, 4)
        out = embedding.lookup(tbl, jnp.asarray([0, 9_999, 1234]), cfg)
        assert out.shape == (3, 4) and bool(jnp.all(jnp.isfinite(out)))


class TestCIN:
    def test_matches_naive(self):
        """One CIN layer vs the explicit outer-product formula."""
        B, F, D, H = 3, 4, 5, 6
        key = jax.random.PRNGKey(0)
        emb = jax.random.normal(key, (B, F, D))
        w = jax.random.normal(jax.random.fold_in(key, 1), (H, F, F))
        got = recsys.cin(emb, {"w0": w}, (H,))
        # naive: x1[b,h,d] = sum_ij w[h,i,j] emb[b,i,d] emb[b,j,d]; pool over d
        naive = np.zeros((B, H))
        e = np.asarray(emb)
        wn = np.asarray(w)
        for b in range(B):
            for h in range(H):
                acc = 0.0
                for i in range(F):
                    for j in range(F):
                        acc += wn[h, i, j] * np.sum(e[b, i] * e[b, j])
                naive[b, h] = acc
        np.testing.assert_allclose(np.asarray(got), naive, rtol=1e-4)


class TestFM:
    def test_matches_naive(self):
        B, F, D = 4, 5, 3
        emb = jax.random.normal(jax.random.PRNGKey(0), (B, F, D))
        got = np.asarray(recsys.fm_second_order(emb))
        e = np.asarray(emb)
        naive = np.zeros(B)
        for b in range(B):
            for i in range(F):
                for j in range(i + 1, F):
                    naive[b] += float(np.dot(e[b, i], e[b, j]))
        np.testing.assert_allclose(got, naive, rtol=1e-4)


def _train(cfg, batch_fn, steps=12, lr=1e-2):
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt_lib.OptConfig(name="adamw", lr=lr)
    opt = opt_lib.init_opt_state(params, ocfg)
    step = jax.jit(train_loop.make_train_step(
        lambda p, b: recsys.loss_fn(p, b, cfg), ocfg))
    losses = []
    for i in range(steps):
        params, opt, m = step(params, opt, batch_fn(i))
        losses.append(float(m["loss"]))
    return params, losses


@pytest.mark.parametrize("name", ["deepfm", "xdeepfm", "bst", "mind"])
def test_models_learn(name):
    if name in ("deepfm", "xdeepfm"):
        cfg = recsys.RecsysConfig(
            name=name, n_sparse=6, vocab_per_field=200, embed_dim=8,
            mlp=(32, 16), cin_layers=(8, 8) if name == "xdeepfm" else (),
        )
        bf = lambda i: recsys_data.ctr_batch(jax.random.PRNGKey(i), 256, 6, 200)
    else:
        cfg = recsys.RecsysConfig(
            name=name, vocab_per_field=300, embed_dim=16, seq_len=8,
            n_heads=4, n_interests=2, capsule_iters=2, mlp=(32,),
        )
        bf = lambda i: recsys_data.behavior_batch(jax.random.PRNGKey(i), 256, 8, 300)
    _, losses = _train(cfg, bf)
    assert losses[-1] < losses[0], (name, losses)
    assert all(np.isfinite(losses)), (name, losses)


class TestRetrieval:
    def test_ctr_retrieval_matches_pointwise(self):
        """ctr_retrieval_scores == ctr_logits on the expanded batch."""
        cfg = recsys.RecsysConfig(name="deepfm", n_sparse=5, vocab_per_field=100,
                                  embed_dim=8, mlp=(16,))
        p = recsys.init_params(jax.random.PRNGKey(0), cfg)
        key = jax.random.PRNGKey(1)
        user = recsys_data.ctr_batch(key, 1, 5, 100)
        cand = jax.random.randint(jax.random.fold_in(key, 2), (32,), 0, 100)
        got = recsys.ctr_retrieval_scores(
            p, {"dense": user["dense"], "sparse": user["sparse"], "cand": cand}, cfg)
        # expand: batch of 32 with item field replaced
        sparse = jnp.tile(user["sparse"], (32, 1)).at[:, 0].set(cand)
        dense = jnp.tile(user["dense"], (32, 1))
        want = recsys.ctr_logits(p, {"dense": dense, "sparse": sparse}, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_bst_retrieval_matches_pointwise(self):
        cfg = recsys.RecsysConfig(name="bst", vocab_per_field=100, embed_dim=16,
                                  seq_len=6, n_heads=4, mlp=(16,))
        p = recsys.init_params(jax.random.PRNGKey(0), cfg)
        hist = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, 100)
        cand = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, 100)
        got = recsys.bst_retrieval_scores(p, {"hist": hist, "cand": cand}, cfg)
        want = recsys.bst_logits(
            p, {"hist": jnp.tile(hist, (16, 1)), "target": cand}, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_mind_retrieval_shapes(self):
        cfg = recsys.RecsysConfig(name="mind", vocab_per_field=100, embed_dim=8,
                                  n_interests=3, capsule_iters=2, mlp=(16,), seq_len=6)
        p = recsys.init_params(jax.random.PRNGKey(0), cfg)
        hist = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, 100)
        cands = jax.random.normal(jax.random.PRNGKey(2), (500, 8))
        s = recsys.retrieval_scores(p, hist, cands, cfg)
        assert s.shape == (500,) and bool(jnp.all(jnp.isfinite(s)))

    def test_capsule_routing_mask(self):
        """Padded history items must not contribute to interests."""
        cfg = recsys.RecsysConfig(name="mind", vocab_per_field=100, embed_dim=8,
                                  n_interests=2, capsule_iters=2, mlp=(16,), seq_len=6)
        p = recsys.init_params(jax.random.PRNGKey(0), cfg)
        h1 = jnp.asarray([[3, 7, 11, -1, -1, -1]])
        h2 = jnp.asarray([[3, 7, 11, 50, 60, 70]])
        i1 = recsys.mind_interests(p, h1, cfg)
        i2 = recsys.mind_interests(p, h2, cfg)
        i1b = recsys.mind_interests(p, jnp.asarray([[3, 7, 11, -1, -1, -1]]), cfg)
        np.testing.assert_allclose(np.asarray(i1), np.asarray(i1b), rtol=1e-6)
        assert not np.allclose(np.asarray(i1), np.asarray(i2))
