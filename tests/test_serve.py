"""Retrieval serving tests: LGD index vs brute, catalog churn (§IV-C)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import retrieval


@pytest.fixture(scope="module")
def bank():
    key = jax.random.PRNGKey(0)
    items = jax.random.normal(key, (2000, 16))
    items = items / jnp.linalg.norm(items, axis=1, keepdims=True)
    return items


@pytest.fixture(scope="module")
def index(bank):
    return retrieval.build_index(
        bank, k=10, metric="ip", wave=256, capacity=2300,
        key=jax.random.PRNGKey(1),
    )


class TestRetrieve:
    def test_recall_vs_brute(self, index, bank):
        q = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
        got_ids, got_scores = retrieval.retrieve(index, q, 10, beam=40)
        want_ids, _ = retrieval.retrieve_brute(index, q, 10)
        inter = len(set(np.asarray(got_ids).tolist()) & set(np.asarray(want_ids).tolist()))
        assert inter / 10 >= 0.7, (got_ids, want_ids)
        # scores descending (inner product: higher = better)
        s = np.asarray(got_scores)
        assert np.all(np.diff(s) <= 1e-5)

    def test_no_duplicates(self, index):
        q = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
        ids, _ = retrieval.retrieve(index, q, 20, beam=40)
        real = [int(i) for i in np.asarray(ids) if i >= 0]
        assert len(real) == len(set(real))


class TestScoreConvention:
    def test_cosine_scores_are_higher_is_better(self, bank):
        """Regression: scores used to be negated only for metric='ip', so
        cosine serving returned raw distances where callers expect
        higher = better.  Both similarity metrics now route through
        ``score_from_dist``."""
        idx = retrieval.build_index(
            bank[:500], k=10, metric="cosine", wave=256,
            key=jax.random.PRNGKey(4),
        )
        q = jax.random.normal(jax.random.PRNGKey(8), (4, 16))
        for ids, scores in (
            retrieval.retrieve(idx, q, 10, beam=40),
            retrieval.retrieve_brute(idx, q, 10),
        ):
            s = np.asarray(scores)
            assert np.all(np.diff(s) <= 1e-5), s  # descending: higher = better
        # brute top-1 is the true max-cosine-similarity item; the serving
        # score must rank it first, not last
        bids, bscores = retrieval.retrieve_brute(idx, q, 10)
        sims = np.asarray(
            (q @ bank[:500].T)
            / (np.linalg.norm(np.asarray(q), axis=1, keepdims=True)
               * np.linalg.norm(np.asarray(bank[:500]), axis=1)[None, :])
        )
        assert int(bids[0]) == int(np.argmax(sims.max(axis=0)))

    def test_l2_scores_stay_distances(self, bank):
        idx = retrieval.build_index(
            bank[:500], k=10, metric="l2", wave=256, key=jax.random.PRNGKey(4)
        )
        q = jax.random.normal(jax.random.PRNGKey(9), (2, 16))
        _, scores = retrieval.retrieve(idx, q, 10, beam=40)
        s = np.asarray(scores)
        assert np.all(s >= 0) and np.all(np.diff(s) >= -1e-5)  # ascending dist


class TestCatalogChurn:
    def test_add_items_found(self, index):
        new = jax.random.normal(jax.random.PRNGKey(5), (64, 16))
        new = new / jnp.linalg.norm(new, axis=1, keepdims=True)
        idx2 = retrieval.add_items(index, new, key=jax.random.PRNGKey(6))
        assert idx2.n_items == index.n_items + 64
        # querying exactly a new item should retrieve it
        ids, _ = retrieval.retrieve(idx2, new[:4], 5, beam=40)
        got = set(np.asarray(ids).tolist())
        expect = set(range(index.n_items, index.n_items + 4))
        assert got & expect, (got, expect)

    def test_remove_items_not_returned(self, index, bank):
        victims = jnp.arange(0, 100, dtype=jnp.int32)
        idx2 = retrieval.remove_items(index, victims)
        q = jax.random.normal(jax.random.PRNGKey(7), (8, 16))
        ids, _ = retrieval.retrieve(idx2, q, 10, beam=40)
        real = [int(i) for i in np.asarray(ids) if i >= 0]
        assert not (set(real) & set(range(100))), real
