"""Hierarchical entry-point seeding (core.hierarchy + seed_mode="coarse").

Pins the PR-6 tentpole contracts:
  * ``construct.build(seed_mode="coarse")`` returns a coarse level whose
    landmark rows / member cells reference real, alive full-graph rows, and
    charges the coarse machinery's comparisons to the scanning rate;
  * member cells fill for free as waves commit (``SearchResult.seed_cell``
    → ``hierarchy.note_inserted``) — no separate assignment pass;
  * coarse-seeded search matches random-seeded recall on the same graph;
  * the level survives the whole lifecycle: insert appends members, remove
    masks dead rows, compaction remaps, snapshots round-trip bit-exactly,
    and pre-v2 snapshots (no coarse payload) re-derive on load;
  * the parallel build and the sharded router thread the level through
    their merge paths.
"""

import dataclasses
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import brute, construct, hierarchy
from repro.core import search as search_lib
from repro.index import OnlineIndex, ShardedIndex, snapshot

N, D, K = 600, 8, 8
L = 48  # pinned landmark count (default_landmarks(600)=97 — smaller is faster)


@pytest.fixture(scope="module")
def data():
    rng = np.random.RandomState(7)
    return jnp.asarray(rng.rand(N, D).astype(np.float32))


@pytest.fixture(scope="module")
def queries():
    rng = np.random.RandomState(42)
    return jnp.asarray(rng.rand(16, D).astype(np.float32))


def _cfg(**kw):
    base = dict(k=K, metric="l2", wave=128, lgd=True, beam=24, n_seeds=4,
                hash_slots=512, max_iters=32, seed_mode="coarse",
                coarse_landmarks=L, coarse_members=4)
    base.update(kw)
    return construct.BuildConfig(**base)


@pytest.fixture(scope="module")
def built(data):
    g, stats, coarse = construct.build(
        data, _cfg(), jax.random.PRNGKey(1), return_coarse=True
    )
    return g, stats, coarse


def test_default_landmarks_clamps():
    assert hierarchy.default_landmarks(4) == 32  # floor
    assert hierarchy.default_landmarks(100_000) == int(4 * 100_000 ** 0.5)
    assert hierarchy.default_landmarks(10**8) == 4096  # ceiling


class TestCoarseBuild:
    def test_level_structure(self, built, data):
        g, _, coarse = built
        assert coarse is not None and coarse.n_landmarks == L
        lm = np.asarray(coarse.landmark_rows)
        assert np.all((lm >= 0) & (lm < N)) and len(set(lm.tolist())) == L
        np.testing.assert_array_equal(
            np.asarray(coarse.points), np.asarray(data)[lm]
        )
        assert int(coarse.graph.n_valid) == L
        mem = np.asarray(coarse.members)
        assert np.all((mem >= -1) & (mem < N))

    def test_members_fill_from_wave_commits(self, built):
        """Every row past the seed prefix is appended to its winning cell by
        the wave commit itself (seed_cell — the free assignment), on top of
        the brute-assigned seed prefix."""
        _, _, coarse = built
        n_seed = min(construct.BuildConfig().n_seed_init, N)
        total_appends = int(np.asarray(coarse.mem_ptr).sum())
        # seed prefix is brute-assigned; later rows via their own searches —
        # a lane whose coarse pass found no landmark (-1) may drop out, so
        # allow a small shortfall but require the mechanism clearly ran
        assert total_appends >= n_seed + int(0.9 * (N - n_seed))
        assert int((np.asarray(coarse.members) >= 0).sum()) > 0

    def test_coarse_comps_are_charged(self, built, data):
        """Eq. 2 honesty: the coarse machinery (landmark graph build, brute
        seed assignment, per-query coarse passes) must appear in n_comps."""
        _, stats_c, _ = built
        _, stats_r = construct.build(
            data, _cfg(seed_mode="random"), jax.random.PRNGKey(1)
        )
        # the landmark build alone adds >= L*(L-1)/2 over the random-mode
        # ledger's floor; uncharged coarse work would show up as equality
        assert float(stats_c.n_comps) > float(stats_r.n_comps)

    def test_graph_recall_matches_random_seeding(self, built, data):
        g_c, _, _ = built
        g_r, _ = construct.build(
            data, _cfg(seed_mode="random"), jax.random.PRNGKey(1)
        )
        true_ids, _ = brute.brute_force_knn(
            data, data, K, "l2",
            exclude_ids=jnp.arange(N, dtype=jnp.int32), use_pallas=False,
        )
        rec_c = float(brute.recall_at_k(g_c.nbr_ids, true_ids, K))
        rec_r = float(brute.recall_at_k(g_r.nbr_ids, true_ids, K))
        assert rec_c >= rec_r - 0.03, (rec_c, rec_r)
        assert rec_c >= 0.85, rec_c

    def test_parallel_build_threads_coarse(self, data):
        g, _ = construct.build_parallel(
            data, _cfg(), jax.random.PRNGKey(2), shards=2, refine_rounds=1
        )
        true_ids, _ = brute.brute_force_knn(
            data, data, K, "l2",
            exclude_ids=jnp.arange(N, dtype=jnp.int32), use_pallas=False,
        )
        assert float(brute.recall_at_k(g.nbr_ids, true_ids, K)) >= 0.85

    def test_parallel_build_return_coarse_parity(self, data, queries):
        """``build_parallel`` honors the same ``return_coarse=True`` contract
        as ``build``: a merged graph under seed_mode="coarse" comes back with
        a servable level (the merge fold's root, union id space)."""
        out = construct.build_parallel(
            data, _cfg(), jax.random.PRNGKey(3), shards=2, refine_rounds=1,
            return_coarse=True,
        )
        assert len(out) == 3
        g, stats, lvl = out
        assert lvl is not None
        rows = np.asarray(lvl.landmark_rows)
        assert rows.min() >= 0 and rows.max() < N
        # the level is directly servable — the parity ``build`` provides
        scfg = _cfg().search_config()
        res = search_lib.search(
            g, data, queries, jax.random.PRNGKey(4), scfg, coarse=lvl
        )
        true_ids, _ = brute.brute_force_knn(
            data, queries, K, "l2", use_pallas=False
        )
        assert float(brute.recall_at_k(res.ids, true_ids, K)) >= 0.85
        # shards=1 degenerates to build() with the contract intact
        out1 = construct.build_parallel(
            data, _cfg(), jax.random.PRNGKey(3), shards=1, return_coarse=True
        )
        assert len(out1) == 3 and out1[2] is not None


class TestCoarseSearch:
    def test_coarse_requires_level(self, built, data, queries):
        g, _, _ = built
        scfg = _cfg().search_config()
        assert scfg.seed_mode == "coarse"
        with pytest.raises(ValueError, match="coarse"):
            search_lib.search(g, data, queries, jax.random.PRNGKey(0), scfg)

    def test_seed_cell_and_recall(self, built, data, queries):
        g, _, coarse = built
        scfg = _cfg().search_config()
        res = search_lib.search(
            g, data, queries, jax.random.PRNGKey(3), scfg, coarse=coarse
        )
        cells = np.asarray(res.seed_cell)
        assert np.all((cells >= 0) & (cells < L)), cells
        true_ids, _ = brute.brute_force_knn(data, queries, 10, "l2")
        rec = float(brute.recall_at_k(res.ids[:, :10], true_ids, 10))
        # random-seeded search on the SAME graph is the fair baseline
        rres = search_lib.search(
            g, data, queries, jax.random.PRNGKey(3),
            dataclasses.replace(scfg, seed_mode="random"),
        )
        rrec = float(brute.recall_at_k(rres.ids[:, :10], true_ids, 10))
        assert rec >= rrec - 0.05, (rec, rrec)
        assert np.all(np.asarray(rres.seed_cell) == -1)


class TestLifecycleCoarse:
    @pytest.fixture()
    def index(self, data):
        return OnlineIndex.build(
            data, _cfg(), key=jax.random.PRNGKey(1), capacity=N + 64
        )

    def test_insert_appends_members(self, index):
        assert index.coarse is not None
        before = int(np.asarray(index.coarse.mem_ptr).sum())
        new = jnp.asarray(
            np.random.RandomState(9).rand(16, D).astype(np.float32)
        )
        index.add(new, key=jax.random.PRNGKey(2), flush=True)
        after = int(np.asarray(index.coarse.mem_ptr).sum())
        assert after > before
        # the appended members are the new rows
        fresh = set(range(N, N + 16))
        got = set(np.asarray(index.coarse.members).reshape(-1).tolist())
        assert got & fresh

    def test_remove_masks_landmark_and_members(self, index):
        victim = int(np.asarray(index.coarse.landmark_rows)[0])
        index.remove(jnp.asarray([victim], jnp.int32))
        lm = np.asarray(index.coarse.landmark_rows)
        assert lm[0] == -1
        assert victim not in np.asarray(index.coarse.members).reshape(-1)
        # routing vectors are frozen: the coarse walk still works
        res = index.search(index.items[:4], 5, key=jax.random.PRNGKey(4))
        assert np.all(np.asarray(res.seed_cell) >= 0)

    def test_compact_remaps_rows(self, index):
        index.remove(jnp.arange(0, 40, dtype=jnp.int32))
        index.compact()
        nv = int(index.graph.n_valid)
        for name in ("landmark_rows", "members"):
            a = np.asarray(getattr(index.coarse, name))
            live = a[a >= 0]
            assert np.all(live < nv), f"{name} references unallocated rows"
        res = index.search(index.items[:4], 5, key=jax.random.PRNGKey(4))
        assert res.ids.shape == (4, 5)

    def test_snapshot_round_trip_carries_coarse(self, index, queries, tmp_path):
        idx2 = OnlineIndex.load(index.save(str(tmp_path / "snap")))
        assert idx2.coarse is not None
        for name in ("landmark_rows", "points", "members", "mem_ptr"):
            np.testing.assert_array_equal(
                np.asarray(getattr(index.coarse, name)),
                np.asarray(getattr(idx2.coarse, name)),
                err_msg=f"coarse field {name} drifted",
            )
        np.testing.assert_array_equal(
            np.asarray(index.coarse.graph.nbr_ids),
            np.asarray(idx2.coarse.graph.nbr_ids),
        )
        r0 = index.search(queries[:4], 5, key=jax.random.PRNGKey(7))
        r1 = idx2.search(queries[:4], 5, key=jax.random.PRNGKey(7))
        np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))

    def test_pre_v2_snapshot_rederives_coarse(self, index, tmp_path):
        """A v1 snapshot (no coarse payload) must come back up serving
        coarsely: the level is re-derived on load."""
        path = index.save(str(tmp_path / "v1"))
        npz = os.path.join(path, snapshot.PAYLOAD_NAME)
        with np.load(npz) as z:
            arrays = {k: z[k] for k in z.files if not k.startswith("coarse_")}
        np.savez(npz, **arrays)
        man_path = os.path.join(path, snapshot.MANIFEST_NAME)
        with open(man_path) as f:
            man = json.load(f)
        man["format_version"] = 1
        with open(man_path, "w") as f:
            json.dump(man, f)
        idx2 = OnlineIndex.load(path)
        assert idx2.coarse is not None  # re-derived, not loaded
        res = idx2.search(index.items[:4], 5, key=jax.random.PRNGKey(8))
        assert np.all(np.asarray(res.seed_cell) >= 0)


class TestRouterCoarse:
    def test_merge_shards_carries_folded_coarse(self, data, queries):
        sh = ShardedIndex.build(data, 2, _cfg(), key=jax.random.PRNGKey(4))
        assert all(s.coarse is not None for s in sh.shards)
        n_lm = sum(s.coarse.n_landmarks for s in sh.shards)
        sh.merge_shards(key=jax.random.PRNGKey(5))
        merged = sh.shards[0]
        # the shard levels fold through the merge tree (offset-remapped into
        # the union id space), so the merged index serves coarse-seeded
        # searches without a lazy re-derive
        assert merged.coarse is not None
        assert merged.coarse.n_landmarks == n_lm
        rows = np.asarray(merged.coarse.landmark_rows)
        nv = int(merged.graph.n_valid)
        assert np.all(rows < nv) and np.any(rows >= 0)
        ids, _ = sh.retrieve(queries[:2], 5, key=jax.random.PRNGKey(6))
        assert merged.coarse is not None
        assert int((np.asarray(ids) >= 0).sum()) == 5
