"""System behaviour tests for the paper's core: search, construction, baseline.

Scaled-down versions of the paper's own validation: graph recall (Eq. 1)
against exact ground truth, scanning rate sanity (Eq. 2), dynamic updates.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    BuildConfig,
    SearchConfig,
    brute,
    build,
    construct,
    dynamic,
    graph as graph_lib,
    metrics,
    nndescent,
    search as search_lib,
)

N, D, K = 1500, 8, 10


@pytest.fixture(scope="module")
def data():
    rng = np.random.RandomState(0)
    return jnp.asarray(rng.rand(N, D).astype(np.float32))


@pytest.fixture(scope="module")
def truth(data):
    ids, dists = brute.brute_force_knn(
        data, data, K, "l2", exclude_ids=jnp.arange(N, dtype=jnp.int32)
    )
    return ids, dists


@pytest.fixture(scope="module")
def lgd_graph(data):
    cfg = BuildConfig(k=K, wave=128, lgd=True, beam=24, n_seeds=4, hash_slots=1024, max_iters=40)
    return build(data, cfg, jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def olg_graph(data):
    cfg = BuildConfig(k=K, wave=128, lgd=False, beam=24, n_seeds=4, hash_slots=1024, max_iters=40)
    return build(data, cfg, jax.random.PRNGKey(1))


def _all_invariants(g):
    inv = graph_lib.graph_invariants_ok(g)
    return {k: bool(jnp.all(v)) for k, v in inv.items()}


class TestBrute:
    def test_matches_naive(self, data):
        q = data[:32]
        ids, dists = brute.brute_force_knn(data, q, K, "l2", tile=256)
        full = metrics.pairwise("l2", q, data)
        want = np.argsort(np.asarray(full), axis=1)[:, :K]
        got_d = np.sort(np.asarray(full), axis=1)[:, :K]
        np.testing.assert_allclose(np.asarray(dists), got_d, rtol=1e-5, atol=1e-6)

    def test_exclude_self(self, data, truth):
        ids, _ = truth
        assert not np.any(np.asarray(ids) == np.arange(N)[:, None])


class TestSearchEHC:
    def test_high_recall_on_true_graph(self, data, truth):
        g = brute.exact_seed_graph(data, N, K, "l2")
        q = data[:200]
        cfg = SearchConfig(k=K, beam=32, n_seeds=8, hash_slots=1024, max_iters=64)
        res = search_lib.search(g, data, q, jax.random.PRNGKey(0), cfg)
        # searching dataset rows against the true graph must find themselves..
        # no—self rows are in the graph; recall vs truth-with-self
        tids, _ = brute.brute_force_knn(data, q, K, "l2")
        rec = brute.recall_at_k(res.ids, tids, K)
        assert float(rec) > 0.85, float(rec)

    def test_reverse_edges_help(self, data):
        """EHC (with Ḡ) vs plain HC (without) — Fig. 5's claim."""
        g = brute.exact_seed_graph(data, N, K, "l2")
        g_nore = g._replace(rev_ids=jnp.full_like(g.rev_ids, -1))
        q = data[:200]
        tids, _ = brute.brute_force_knn(data, q, 1, "l2")
        cfg = SearchConfig(k=K, beam=16, n_seeds=4, hash_slots=1024, max_iters=48)
        r_ehc = search_lib.search(g, data, q, jax.random.PRNGKey(0), cfg)
        r_hc = search_lib.search(g_nore, data, q, jax.random.PRNGKey(0), cfg)
        rec_ehc = float(brute.recall_at_k(r_ehc.ids[:, :1], tids, 1))
        rec_hc = float(brute.recall_at_k(r_hc.ids[:, :1], tids, 1))
        assert rec_ehc >= rec_hc - 0.02, (rec_ehc, rec_hc)

    def test_converges_before_cap(self, data):
        g = brute.exact_seed_graph(data, N, K, "l2")
        cfg = SearchConfig(k=K, beam=16, n_seeds=4, hash_slots=1024, max_iters=64)
        res = search_lib.search(g, data, data[:64], jax.random.PRNGKey(2), cfg)
        assert float(jnp.mean(res.converged)) > 0.95

    def test_results_sorted_and_unique(self, data):
        g = brute.exact_seed_graph(data, N, K, "l2")
        cfg = SearchConfig(k=K, beam=16, n_seeds=4, hash_slots=1024, max_iters=48)
        res = search_lib.search(g, data, data[50:100], jax.random.PRNGKey(3), cfg)
        d = np.asarray(res.dists)
        assert np.all(np.diff(d, axis=1) >= 0)
        ids = np.asarray(res.ids)
        for row in ids:
            real = row[row >= 0]
            assert len(set(real.tolist())) == len(real)


class TestConstruction:
    def test_olg_recall(self, olg_graph, truth):
        g, stats = olg_graph
        rec = float(brute.recall_at_k(g.nbr_ids, truth[0], K))
        assert rec > 0.85, rec

    def test_lgd_recall(self, lgd_graph, truth):
        g, stats = lgd_graph
        rec = float(brute.recall_at_k(g.nbr_ids, truth[0], K))
        assert rec > 0.80, rec

    def test_lgd_scans_less_than_olg(self, lgd_graph, olg_graph):
        """Table II/III claim: LGD's scanning rate <= OLG's (within noise)."""
        _, s_lgd = lgd_graph
        _, s_olg = olg_graph
        assert float(s_lgd.n_comps) <= float(s_olg.n_comps) * 1.05

    def test_invariants(self, lgd_graph, olg_graph):
        for g, _ in (lgd_graph, olg_graph):
            assert all(_all_invariants(g).values()), _all_invariants(g)

    def test_lambda_nonzero_somewhere(self, lgd_graph):
        g, _ = lgd_graph
        assert int(jnp.sum(g.nbr_lam)) > 0  # occlusion happens on uniform data

    def test_wave_one_equals_sequential_limit(self, data, truth):
        """W=1 is the paper's exact sequential algorithm — must still work."""
        small = data[:400]
        tids, _ = brute.brute_force_knn(
            small, small, K, "l2", exclude_ids=jnp.arange(400, dtype=jnp.int32)
        )
        cfg = BuildConfig(k=K, wave=1, lgd=True, beam=16, n_seeds=4,
                          hash_slots=512, max_iters=32, intra_wave=False)
        g, _ = build(small, cfg, jax.random.PRNGKey(0))
        rec = float(brute.recall_at_k(g.nbr_ids, tids, K))
        assert rec > 0.85, rec

    def test_search_on_built_graph(self, lgd_graph, data):
        g, _ = lgd_graph
        rng = np.random.RandomState(7)
        q = jnp.asarray(rng.rand(100, D).astype(np.float32))
        tids, _ = brute.brute_force_knn(data, q, 1, "l2")
        cfg = SearchConfig(k=K, beam=32, n_seeds=8, hash_slots=1024,
                           max_iters=48, use_lgd_mask=True)
        res = search_lib.search(g, data, q, jax.random.PRNGKey(5), cfg)
        rec = float(brute.recall_at_k(res.ids[:, :1], tids, 1))
        assert rec > 0.9, rec


class TestNNDescent:
    def test_recall(self, data, truth):
        cfg = nndescent.NNDescentConfig(k=K, max_iters=8, node_chunk=512)
        g, stats = nndescent.build(data, cfg, jax.random.PRNGKey(3))
        rec = float(brute.recall_at_k(g.nbr_ids, truth[0], K))
        assert rec > 0.80, rec
        assert stats["scanning_rate"] > 0

    def test_refine_improves(self, data, truth):
        # build a deliberately weak LGD graph, then refine (§IV-D)
        cfg = BuildConfig(k=K, wave=256, lgd=True, beam=12, n_seeds=2,
                          hash_slots=512, max_iters=10)
        g, _ = build(data, cfg, jax.random.PRNGKey(4))
        rec0 = float(brute.recall_at_k(g.nbr_ids, truth[0], K))
        g2, comps = nndescent.local_join_refine(g, data, "l2", node_chunk=512)
        rec1 = float(brute.recall_at_k(g2.nbr_ids, truth[0], K))
        assert rec1 >= rec0, (rec0, rec1)
        assert comps > 0

    def test_refine_rebuilds_canonical_lambda(self, data, truth):
        # regression (λ wipe): refine used to zero nbr_lam, and the reverse
        # rebuild then snapshotted zeros into rev_lam — degenerating the LGD
        # reverse filter on every refined graph.  Pin the refined λ table
        # (and the search behavior it drives) against a scratch NumPy oracle.
        cfg = BuildConfig(k=K, wave=256, lgd=True, beam=12, n_seeds=2,
                          hash_slots=512, max_iters=10)
        g, _ = build(data, cfg, jax.random.PRNGKey(4))
        g2, _ = nndescent.local_join_refine(g, data, "l2", node_chunk=512)

        # scratch oracle: λ(j_i) = #{l < i : m(j_l, j_i) < m(v, j_i)} on the
        # refined (sorted) lists — the one formula the commit path maintains
        x = np.asarray(data)
        ids = np.asarray(g2.nbr_ids)
        dist = np.asarray(g2.nbr_dist)
        sq = np.sum(x.astype(np.float32) ** 2, axis=1)
        lam_oracle = np.zeros_like(ids)
        for v in range(ids.shape[0]):
            for i in range(ids.shape[1]):
                if ids[v, i] < 0:
                    continue
                for ll in range(i):
                    if ids[v, ll] < 0:
                        continue
                    # same squared-l2 matmul expansion the engine computes
                    a, b = ids[v, ll], ids[v, i]
                    m = max(sq[a] + sq[b] - 2.0 * np.float32(x[a] @ x[b]), 0.0)
                    if m < dist[v, i]:
                        lam_oracle[v, i] += 1
        assert np.array_equal(np.asarray(g2.nbr_lam), lam_oracle)
        assert lam_oracle.any()  # a refined graph has real occlusion

        # the LGD-masked search must behave exactly as it does on a graph
        # whose λ was rebuilt from scratch (comps AND results)
        g_oracle = graph_lib.rebuild_reverse(
            g2._replace(nbr_lam=jnp.asarray(lam_oracle))
        )
        assert np.array_equal(np.asarray(g2.rev_lam), np.asarray(g_oracle.rev_lam))
        scfg = SearchConfig(k=K, beam=24, n_seeds=4, hash_slots=1024,
                            max_iters=40, use_lgd_mask=True)
        q = data[:64]
        r_fix = search_lib.search(g2, data, q, jax.random.PRNGKey(5), scfg)
        r_orc = search_lib.search(g_oracle, data, q, jax.random.PRNGKey(5), scfg)
        assert np.array_equal(np.asarray(r_fix.ids), np.asarray(r_orc.ids))
        assert int(jnp.sum(r_fix.n_comps)) == int(jnp.sum(r_orc.n_comps))
        rec = float(brute.recall_at_k(r_fix.ids, truth[0][:64], K))
        assert rec > 0.85, rec


class TestDynamic:
    def test_insert(self, data):
        n0 = 1000
        cfg = BuildConfig(k=K, wave=128, lgd=True, beam=24, n_seeds=4,
                          hash_slots=1024, max_iters=40)
        g, _ = build(data[:n0], cfg, jax.random.PRNGKey(0))
        # grow capacity to full dataset, then insert the remainder online
        # (grow_graph carries every field — incl. the norm cache — forward)
        full = graph_lib.grow_graph(g, N)
        g2, _ = dynamic.insert(full, data, N - n0, cfg, jax.random.PRNGKey(9))
        assert int(g2.n_valid) == N
        tids, _ = brute.brute_force_knn(
            data, data, K, "l2", exclude_ids=jnp.arange(N, dtype=jnp.int32)
        )
        rec = float(brute.recall_at_k(g2.nbr_ids, tids, K))
        assert rec > 0.8, rec

    def test_remove(self, lgd_graph, data):
        g, _ = lgd_graph
        victims = jnp.arange(0, 50, dtype=jnp.int32)
        g2 = dynamic.remove(g, data, victims, "l2")
        assert not bool(jnp.any(g2.alive[victims]))
        # no list references a removed id
        for vid in [0, 10, 49]:
            assert not bool(jnp.any(g2.nbr_ids == vid))
            assert not bool(jnp.any(g2.rev_ids == vid))
        # still searchable with decent recall, removed ids never returned
        rng = np.random.RandomState(11)
        q = jnp.asarray(rng.rand(50, D).astype(np.float32))
        cfg = SearchConfig(k=K, beam=32, n_seeds=8, hash_slots=1024, max_iters=48)
        res = search_lib.search(g2, data, q, jax.random.PRNGKey(1), cfg)
        assert not bool(jnp.any(res.ids[:, :1] < 50) & jnp.any(res.ids[:, :1] >= 0))


class TestMetrics:
    @pytest.mark.parametrize("metric", ["l2", "l1", "cosine", "chi2"])
    def test_identity_is_zero(self, metric, data):
        x = jnp.abs(data[:20]) if metric == "chi2" else data[:20]
        d = metrics.pairwise(metric, x, x)
        np.testing.assert_allclose(np.asarray(jnp.diagonal(d)), 0.0, atol=1e-4)

    @pytest.mark.parametrize("metric", ["l2", "l1", "chi2"])
    def test_symmetry(self, metric, data):
        a = jnp.abs(data[:16]) if metric == "chi2" else data[:16]
        b = jnp.abs(data[16:32]) if metric == "chi2" else data[16:32]
        d1 = metrics.pairwise(metric, a, b)
        d2 = metrics.pairwise(metric, b, a)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2).T, rtol=1e-5, atol=1e-6)

    def test_generic_metric_construction(self, data):
        """The paper's generic-metric claim: build under l1 and chi2 too."""
        small = jnp.abs(data[:600])
        for metric in ["l1", "chi2", "cosine"]:
            tids, _ = brute.brute_force_knn(
                small, small, K, metric, exclude_ids=jnp.arange(600, dtype=jnp.int32)
            )
            cfg = BuildConfig(k=K, metric=metric, wave=64, lgd=True, beam=16,
                              n_seeds=4, hash_slots=512, max_iters=32)
            g, _ = build(small, cfg, jax.random.PRNGKey(0))
            rec = float(brute.recall_at_k(g.nbr_ids, tids, K))
            assert rec > 0.75, (metric, rec)
