"""Index lifecycle subsystem (repro.index): snapshots, churn, sharding.

Pins the PR-4 acceptance contracts:
  * snapshot save→load round trip is BIT-exact on every persisted array and
    search-result-identical (the re-derived norm cache included);
  * ``compact()`` after removing 25% of rows recovers the freed capacity
    while keeping brute-force-checked recall@10 within 0.02, and restores
    the norm-cache / rev_lam invariants exactly;
  * over-capacity insert grows (amortized doubling) instead of raising —
    the old ``assert n0 + m <= capacity`` is unreachable;
  * steady-state churn (insert ≈ remove) recycles the free-slot ledger and
    never grows capacity;
  * the sharded router's merged top-k matches the single-index answer on a
    partitioned catalog (exactly, under per-shard brute force), and global
    ids survive shard-internal compaction.
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import brute, construct, dynamic
from repro.core import graph as graph_lib
from repro.index import OnlineIndex, ShardedIndex, snapshot
from repro.serve import retrieval

N, D, K = 600, 8, 8


@pytest.fixture(scope="module")
def data():
    rng = np.random.RandomState(0)
    return jnp.asarray(rng.rand(N, D).astype(np.float32))


@pytest.fixture(scope="module")
def queries():
    rng = np.random.RandomState(42)
    return jnp.asarray(rng.rand(32, D).astype(np.float32))


def _cfg(**kw):
    base = dict(k=K, metric="l2", wave=128, lgd=True, beam=24, n_seeds=4,
                hash_slots=512, max_iters=32)
    base.update(kw)
    return construct.BuildConfig(**base)


@pytest.fixture(scope="module")
def index(data):
    return OnlineIndex.build(data, _cfg(), key=jax.random.PRNGKey(1))


def _graph_fields_equal(a: graph_lib.KNNGraph, b: graph_lib.KNNGraph) -> dict:
    return {
        f: np.array_equal(np.asarray(getattr(a, f)), np.asarray(getattr(b, f)))
        for f in ("nbr_ids", "nbr_dist", "nbr_lam", "rev_ids", "rev_lam",
                  "rev_ptr", "alive", "sq_norms", "row_scale")
    }


class TestSnapshot:
    def test_round_trip_bit_exact(self, index, tmp_path):
        path = index.save(str(tmp_path / "snap"))
        idx2 = OnlineIndex.load(path)
        eq = _graph_fields_equal(index.graph, idx2.graph)
        assert all(eq.values()), eq
        assert int(idx2.graph.n_valid) == int(index.graph.n_valid)
        np.testing.assert_array_equal(
            np.asarray(index.items), np.asarray(idx2.items)
        )
        assert idx2.build_cfg == index.build_cfg

    def test_round_trip_search_identical(self, index, queries, tmp_path):
        idx2 = OnlineIndex.load(index.save(str(tmp_path / "snap")))
        key = jax.random.PRNGKey(7)
        ids0, s0 = retrieval.retrieve(index, queries[:4], 10, key=key)
        ids1, s1 = retrieval.retrieve(idx2, queries[:4], 10, key=key)
        np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))

    def test_round_trip_after_churn(self, index, data, tmp_path):
        """A churned index (dead rows, free ledger) snapshots faithfully."""
        idx = index.clone().remove(jnp.arange(0, 50, dtype=jnp.int32))
        idx2 = OnlineIndex.load(idx.save(str(tmp_path / "churned")))
        eq = _graph_fields_equal(idx.graph, idx2.graph)
        assert all(eq.values()), eq
        assert idx2.free_slots == idx.free_slots == 50

    def test_newer_format_version_rejected(self, index, tmp_path):
        path = index.save(str(tmp_path / "snap"))
        man_path = os.path.join(path, snapshot.MANIFEST_NAME)
        with open(man_path) as f:
            man = json.load(f)
        man["format_version"] = snapshot.FORMAT_VERSION + 1
        with open(man_path, "w") as f:
            json.dump(man, f)
        with pytest.raises(ValueError, match="format_version"):
            snapshot.load(path)

    def test_legacy_payload_without_reverse_rebuilds(self, index, tmp_path):
        """A payload that predates rev_lam restores via rebuild_reverse."""
        path = index.save(str(tmp_path / "snap"))
        npz = os.path.join(path, snapshot.PAYLOAD_NAME)
        with np.load(npz) as z:
            arrays = {k: z[k] for k in z.files
                      if k not in ("rev_ids", "rev_lam", "rev_ptr")}
        np.savez(npz, **arrays)
        g, _, _, _ = snapshot.load(path)
        want = graph_lib.rebuild_reverse(index.graph)
        np.testing.assert_array_equal(np.asarray(g.rev_ids),
                                      np.asarray(want.rev_ids))
        np.testing.assert_array_equal(np.asarray(g.rev_lam),
                                      np.asarray(want.rev_lam))

    def test_config_drift_tolerated(self, index, tmp_path):
        """Unknown config fields (from a future writer) are dropped."""
        path = index.save(str(tmp_path / "snap"))
        man_path = os.path.join(path, snapshot.MANIFEST_NAME)
        with open(man_path) as f:
            man = json.load(f)
        man["build_config"]["some_future_knob"] = 42
        with open(man_path, "w") as f:
            json.dump(man, f)
        _, _, cfg, _ = snapshot.load(path)
        assert cfg == index.build_cfg


def _recall_vs_brute(idx: OnlineIndex, queries, k=10) -> float:
    """Brute-force-checked recall@k of the graph search, alive-aware."""
    true_ids, _ = brute.brute_force_knn(
        idx.items, queries, k, idx.metric,
        n_valid=idx.graph.n_valid, alive=idx.graph.alive,
    )
    res = idx.search(queries, k, beam=48, key=jax.random.PRNGKey(5))
    return float(brute.recall_at_k(res.ids, true_ids, k))


class TestCompact:
    @pytest.fixture(scope="class")
    def removed(self, data):
        idx = OnlineIndex.build(data, _cfg(), key=jax.random.PRNGKey(1))
        victims = jnp.asarray(
            np.random.RandomState(3).choice(N, N // 4, replace=False),
            jnp.int32,
        )
        return idx.remove(victims)

    def test_recovers_capacity_and_recall(self, removed, queries):
        rec_before = _recall_vs_brute(removed, queries)
        idx = removed.clone()
        assert idx.free_slots == N // 4
        id_map = idx.compact()
        n_alive = N - N // 4
        assert int(idx.graph.n_valid) == n_alive
        assert idx.free_slots == 0
        assert idx.capacity - int(idx.graph.n_valid) == N // 4  # reclaimed
        assert int(jnp.sum(idx.graph.alive)) == n_alive
        # the id map moves every survivor and kills every victim
        assert (id_map >= 0).sum() == n_alive
        rec_after = _recall_vs_brute(idx, queries)
        assert rec_after >= rec_before - 0.02, (rec_before, rec_after)

    def test_items_follow_their_rows(self, removed, data):
        idx = removed.clone()
        id_map = idx.compact()
        old_items = np.asarray(data)
        new_items = np.asarray(idx.items)
        for old in range(0, N, 37):
            new = int(id_map[old])
            if new >= 0:
                np.testing.assert_array_equal(new_items[new], old_items[old])

    def test_norm_cache_and_rev_lam_invariants(self, removed):
        idx = removed.clone()
        idx.compact()
        g = idx.graph
        # norm cache: exact for alive allocated rows, 0 elsewhere
        want = graph_lib.attach_sq_norms(g, idx.items)
        np.testing.assert_array_equal(np.asarray(g.sq_norms),
                                      np.asarray(want.sq_norms))
        # reverse side: compaction rebuilds, so it must equal the canonical
        # rebuild exactly (rev_lam snapshot included)
        rebuilt = graph_lib.rebuild_reverse(g)
        np.testing.assert_array_equal(np.asarray(g.rev_ids),
                                      np.asarray(rebuilt.rev_ids))
        np.testing.assert_array_equal(np.asarray(g.rev_lam),
                                      np.asarray(rebuilt.rev_lam))
        inv = graph_lib.graph_invariants_ok(g)
        for name, ok in inv.items():
            assert bool(jnp.all(ok)), name


class TestAutoGrowth:
    def test_over_capacity_insert_grows(self, data):
        """Regression: the old serve path hard-asserted here."""
        idx = retrieval.build_index(
            data, k=K, metric="l2", wave=128, key=jax.random.PRNGKey(1)
        )
        assert idx.capacity == N  # no headroom at all
        new = jnp.asarray(
            np.random.RandomState(9).rand(64, D).astype(np.float32)
        )
        idx2 = retrieval.add_items(idx, new, key=jax.random.PRNGKey(2))
        assert idx2.capacity >= N + 64
        assert idx2.capacity == int(N * idx.growth_factor)  # doubled, not +64
        assert int(idx2.graph.n_valid) == N + 64
        # the argument index is untouched (functional contract)
        assert idx.capacity == N and int(idx.graph.n_valid) == N
        # the new items are immediately searchable
        ids, _ = retrieval.retrieve(idx2, new[:4], 5, beam=32)
        assert set(np.asarray(ids).tolist()) & set(range(N, N + 64))

    def test_steady_churn_never_grows(self, data, queries):
        """insert ≈ remove: the ledger + compaction recycle slots forever."""
        idx = OnlineIndex.build(data, _cfg(), key=jax.random.PRNGKey(1))
        rng = np.random.RandomState(11)
        for step in range(4):
            alive = np.flatnonzero(np.asarray(idx.graph.alive))
            victims = rng.choice(alive, 32, replace=False)
            idx.remove(jnp.asarray(victims, jnp.int32))
            idx.add(
                jnp.asarray(rng.rand(32, D).astype(np.float32)),
                key=jax.random.fold_in(jax.random.PRNGKey(2), step),
                flush=True,
            )
            assert idx.capacity == N, f"churn step {step} grew the index"
        assert idx.n_items == N
        assert _recall_vs_brute(idx, queries) > 0.7


class TestRemoveSanitization:
    def test_padding_ids_are_ignored(self, data):
        """Regression: dynamic.remove clips ids, so an unsanitized -1
        (search-result padding) used to kill row 0; cap used to kill the
        last row.  Neither may touch the graph or the ledger."""
        idx = OnlineIndex.build(data, _cfg(), key=jax.random.PRNGKey(1))
        idx.remove(jnp.asarray([-1, N, N + 7], jnp.int32))
        assert idx.free_slots == 0
        assert bool(idx.graph.alive[0]) and bool(idx.graph.alive[N - 1])
        assert idx.n_items == N
        # already-dead ids are no-ops too (no double-count in the ledger)
        idx.remove(jnp.asarray([3], jnp.int32))
        idx.remove(jnp.asarray([3, -1], jnp.int32))
        assert idx.free_slots == 1

    def test_remove_targets_preflush_rows_across_compaction(self, data):
        """Regression: remove() flushes pending adds first, and that flush
        can auto-compact (rows move).  The caller's victim ids name the
        PRE-flush layout and must be remapped — not applied verbatim to the
        compacted graph, which would kill the wrong items."""
        idx = OnlineIndex.build(data, _cfg(), key=jax.random.PRNGKey(1))
        idx.remove(jnp.asarray([5], jnp.int32))  # one hole below the victim
        idx.add(
            jnp.asarray(np.random.RandomState(23).rand(1, D).astype(np.float32)),
            flush=False,
        )  # buffered: the next remove's flush must compact (cap is full)
        victim_vec = np.asarray(idx.items[10]).copy()
        keep_vec = np.asarray(idx.items[11]).copy()
        idx.remove(jnp.asarray([10], jnp.int32))
        alive_vecs = np.asarray(idx.items)[np.asarray(idx.graph.alive)]
        assert not np.any(np.all(alive_vecs == victim_vec, axis=1))
        assert np.any(np.all(alive_vecs == keep_vec, axis=1))

    def test_ledger_reconciles_from_alive_mask(self, data, tmp_path):
        """A churned graph saved WITHOUT its lifecycle state (snapshot.save
        directly) still accounts its holes on load: the alive mask is the
        ground truth, the ledger only a cache of it."""
        idx = OnlineIndex.build(data, _cfg(), key=jax.random.PRNGKey(1))
        idx.remove(jnp.arange(10, 40, dtype=jnp.int32))
        path = str(tmp_path / "bare")
        snapshot.save(path, idx.graph, idx.items, idx.build_cfg)
        idx2 = OnlineIndex.load(path)
        assert idx2.free_slots == 30
        assert idx2.n_items == idx.n_items


class TestIngestBuffer:
    def test_small_adds_coalesce(self, data):
        idx = OnlineIndex.build(
            data, _cfg(), key=jax.random.PRNGKey(1), capacity=N + 128,
            ingest_batch=32,
        )
        rng = np.random.RandomState(13)
        n0 = int(idx.graph.n_valid)
        for _ in range(31):  # below threshold: buffered, no wave
            idx.add(jnp.asarray(rng.rand(1, D).astype(np.float32)))
        assert int(idx.graph.n_valid) == n0
        assert idx.n_pending == 31
        assert idx.n_items == N + 31  # buffered items count as live
        idx.add(jnp.asarray(rng.rand(1, D).astype(np.float32)))  # hits 32
        assert idx.n_pending == 0
        assert int(idx.graph.n_valid) == n0 + 32  # ONE coalesced wave

    def test_reads_observe_buffered_writes(self, data):
        idx = OnlineIndex.build(
            data, _cfg(), key=jax.random.PRNGKey(1), capacity=N + 128,
            ingest_batch=64,
        )
        new = jnp.asarray(
            np.random.RandomState(17).rand(4, D).astype(np.float32)
        )
        idx.add(new)  # stays buffered
        assert idx.n_pending == 4
        ids, _ = retrieval.retrieve(idx, new, 5, beam=32)  # flushes first
        assert idx.n_pending == 0
        assert set(np.asarray(ids).tolist()) & set(range(N, N + 4))


class TestPendingKeyDeterminism:
    """Regression (the pending_key leak): a PRNG key stashed by a buffered
    add must die with its batch.  Pre-fix, an EMPTY keyed add stashed its key
    anyway and flush()'s empty-buffer early return preserved it — so a later,
    unrelated coalescing flush picked up the stale key and two replicas fed
    the identical (items, key) sequence diverged on flush timing."""

    def test_empty_keyed_add_stashes_nothing(self, data):
        idx = OnlineIndex.build(
            data, _cfg(), key=jax.random.PRNGKey(1), capacity=N + 64,
            ingest_batch=64,
        )
        idx.add(jnp.zeros((0, D), jnp.float32), key=jax.random.PRNGKey(5))
        assert idx.pending == () and idx.pending_key is None
        # an empty-buffer flush clears any stale key too
        idx.pending_key = jax.random.PRNGKey(6)
        idx.flush()
        assert idx.pending_key is None

    def test_replicas_agree_across_flush_timing(self, data):
        """Replica A sees an extra empty keyed add (a no-op write, e.g. a
        drained upstream batch) before the real one; replica B only the real
        one.  The graphs must come out identical — pre-fix, A's flush ran
        under the leaked key and the insertion searches diverged."""
        batch = jnp.asarray(
            np.random.RandomState(29).rand(32, D).astype(np.float32)
        )
        a = OnlineIndex.build(
            data, _cfg(), key=jax.random.PRNGKey(1), capacity=N + 64,
            ingest_batch=16,
        )
        b = OnlineIndex.build(
            data, _cfg(), key=jax.random.PRNGKey(1), capacity=N + 64,
            ingest_batch=16,
        )
        a.add(jnp.zeros((0, D), jnp.float32), key=jax.random.PRNGKey(5))
        a.add(batch)  # trips the threshold; flush must run unkeyed
        b.add(batch)
        assert a.pending == () and b.pending == ()
        eq = _graph_fields_equal(a.graph, b.graph)
        assert all(eq.values()), (
            f"replicas diverged on {[f for f, ok in eq.items() if not ok]}"
        )


class TestServingConfigCarry:
    """Regression (serving-config determinism): OnlineIndex.search used to
    rebuild a SearchConfig from scratch, dropping every non-default
    build-time search parameter (hash_slots, n_seeds, max_iters, ...) — a
    saved replica served with different parameters than the index was built
    and validated with."""

    def test_search_config_carries_build_params(self, data, queries, tmp_path):
        cfg = _cfg(hash_slots=512, n_seeds=3, max_iters=7)
        idx = OnlineIndex.build(data, cfg, key=jax.random.PRNGKey(1))
        idx2 = OnlineIndex.load(idx.save(str(tmp_path / "snap")))
        for i in (idx, idx2):
            scfg = i.search_config(5)
            assert scfg.hash_slots == 512
            assert scfg.n_seeds == 3
            assert scfg.max_iters == 7
            assert scfg.use_lgd_mask == cfg.lgd
            assert scfg.k == 5 and scfg.beam == 10
            # the D array a real search allocates is the configured one —
            # the shape is the observable the old path silently changed
            res = i.search(queries[:4], 5, key=jax.random.PRNGKey(7))
            assert res.vis_ids.shape[1] == 512

    def test_save_load_search_identity(self, data, queries, tmp_path):
        """Same request, same key, before vs after the snapshot round trip:
        identical results — i.e. the replica serves under the same config."""
        cfg = _cfg(hash_slots=256, n_seeds=5, max_iters=9)
        idx = OnlineIndex.build(data, cfg, key=jax.random.PRNGKey(1))
        idx2 = OnlineIndex.load(idx.save(str(tmp_path / "snap")))
        assert idx2.search_config(7, beam=32) == idx.search_config(7, beam=32)
        r0 = idx.search(queries[:8], 7, key=jax.random.PRNGKey(3))
        r1 = idx2.search(queries[:8], 7, key=jax.random.PRNGKey(3))
        np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
        np.testing.assert_array_equal(
            np.asarray(r0.dists), np.asarray(r1.dists)
        )


class TestShardedRouter:
    @pytest.fixture(scope="class")
    def sharded(self, data):
        return ShardedIndex.build(
            data, 3, _cfg(), key=jax.random.PRNGKey(4)
        )

    def test_brute_merge_matches_single_index_exactly(
        self, sharded, index, queries
    ):
        """Per-shard brute + global merge == unsharded brute, id for id."""
        for i in range(0, 32, 8):
            q = queries[i : i + 4]
            gids, gsc = sharded.retrieve(q, 10, brute=True)
            sids, ssc = retrieval.retrieve_brute(index, q, 10)
            np.testing.assert_array_equal(gids, np.asarray(sids))
            np.testing.assert_allclose(
                np.asarray(gsc), np.asarray(ssc), rtol=1e-6
            )

    def test_graph_search_recall(self, sharded, index, queries):
        gids, _ = sharded.retrieve(queries[:4], 10, key=jax.random.PRNGKey(8))
        bids, _ = retrieval.retrieve_brute(index, queries[:4], 10)
        inter = set(gids.tolist()) & set(np.asarray(bids).tolist())
        assert len(inter) / 10 >= 0.6, (gids, bids)

    def test_insert_routes_by_fill_remove_by_ownership(self, data):
        sh = ShardedIndex.build(data, 3, _cfg(), key=jax.random.PRNGKey(4))
        fills = [s.n_items for s in sh.shards]
        target = int(np.argmin(fills))
        new = jnp.asarray(
            np.random.RandomState(19).rand(8, D).astype(np.float32)
        )
        gids = sh.add(new, key=jax.random.PRNGKey(5))
        assert sh.shards[target].n_items == fills[target] + 8
        assert sh.n_items == N + 8
        # the new items answer queries for themselves, under their global ids
        got, _ = sh.retrieve(new[:2], 3, brute=True)
        assert set(got.tolist()) & set(gids.tolist())
        # removal routes to the owner shard and the id disappears globally
        assert sh.remove(gids[:4]) == 4
        assert sh.n_items == N + 4
        got, _ = sh.retrieve(new[:2], 5, brute=True)
        assert not (set(got.tolist()) & set(gids[:4].tolist()))

    def test_remove_ignores_sentinel_ids(self, data):
        """Regression: -1 is the gid tables' free-slot sentinel; asking the
        router to remove -1 used to match every freed slot."""
        sh = ShardedIndex.build(data, 2, _cfg(), key=jax.random.PRNGKey(4))
        assert sh.remove(np.asarray([0, 1])) == 2  # leaves -1 holes
        n_before = sh.n_items
        assert sh.remove(np.asarray([-1])) == 0
        assert sh.n_items == n_before

    def test_global_ids_survive_shard_compaction(self, data, queries):
        sh = ShardedIndex.build(data, 2, _cfg(), key=jax.random.PRNGKey(4))
        before, _ = sh.retrieve(queries[:2], 5, brute=True)
        # kill rows in shard 0, then compact everywhere: local rows move,
        # global answers must not
        table0 = sh.gids[0]
        dead_gids = table0[table0 >= 0][5:25]
        survivors = [g for g in before.tolist() if g not in set(dead_gids.tolist())]
        sh.remove(dead_gids)
        sh.compact()
        assert all(s.free_slots == 0 for s in sh.shards)
        after, _ = sh.retrieve(queries[:2], 5, brute=True)
        for g in survivors:
            assert g in after.tolist(), (g, after)

    def test_router_save_load_round_trip(self, sharded, queries, tmp_path):
        path = sharded.save(str(tmp_path / "router"))
        sh2 = ShardedIndex.load(path)
        assert sh2.n_shards == sharded.n_shards
        assert sh2.n_items == sharded.n_items
        a, sa = sharded.retrieve(queries[:4], 10, key=jax.random.PRNGKey(9))
        b, sb = sh2.retrieve(queries[:4], 10, key=jax.random.PRNGKey(9))
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))
