"""Sharded-graph parallelism tests (core.distributed).

Multiple placeholder devices require XLA_FLAGS before jax init, so these run
in a subprocess — the same pattern the dry-run itself uses.
"""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.core import brute, construct, distributed
from repro.kernels import compat

mesh = compat.make_mesh((4, 2), ("data", "model"))
cfg = construct.BuildConfig(k=4, wave=16, n_seed_init=16, beam=8, n_seeds=4,
                            hash_slots=256, max_iters=10, use_pallas=False)
g, x = distributed.init_sharded_state(mesh, 8 * 64, 16, cfg)
step = jax.jit(distributed.make_distributed_build_step(mesh, cfg))
key = jax.random.PRNGKey(0)
pos = 16
while pos < 64:
    g, comps, edges = step(g, x, jnp.asarray(pos, jnp.int32),
                           jnp.asarray(min(16, 64 - pos), jnp.int32), key)
    pos += 16
assert int(g.n_valid) == 64, int(g.n_valid)
assert float(comps) > 0
assert float(edges) >= 0

search = jax.jit(distributed.make_distributed_search(mesh, cfg.search_config()))
q = jax.random.uniform(jax.random.PRNGKey(5), (16, 16))
ids, d = search(g, x, q, jax.random.PRNGKey(9))
xg = jnp.asarray(jax.device_get(x))
tid, td = brute.brute_force_knn(xg, q, 4, "l2", use_pallas=False)
rec = np.mean([len(set(map(int, ids[i][:4])) & set(map(int, tid[i])))
               for i in range(16)]) / 4

# degraded serving: blank one shard's rows (simulated node loss) —
# search still works, recall degrades gracefully
nl = 64
alive = g.alive.at[:nl].set(False)  # shard 0's rows
g2 = g._replace(alive=alive)
ids2, _ = search(g2, x, q, jax.random.PRNGKey(9))
assert not np.any((np.asarray(ids2) >= 0) & (np.asarray(ids2) < nl))
rec2 = np.mean([len(set(map(int, ids2[i][:4])) & set(map(int, tid[i])))
                for i in range(16)]) / 4

print(json.dumps({"recall": float(rec), "recall_degraded": float(rec2),
                  "sorted": bool(np.all(np.diff(np.asarray(d), axis=1) >= 0))}))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_distributed_build_and_search_recall(result):
    assert result["recall"] > 0.6, result


def test_results_sorted(result):
    assert result["sorted"]


def test_degraded_shard_graceful(result):
    # losing 1/8 of the data costs recall but must not break serving
    assert result["recall_degraded"] >= result["recall"] - 0.25, result


SUBGRAPH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.core import brute, construct, distributed
from repro.kernels import compat

mesh = compat.make_mesh((4,), ("data",))
n, d = 4 * 80, 12
x = jax.random.uniform(jax.random.PRNGKey(0), (n, d))
cfg = construct.BuildConfig(k=8, wave=32, n_seed_init=32, beam=16, n_seeds=4,
                            hash_slots=512, max_iters=20, use_pallas=False)

# shard_map sub-builds over real data: 4 local graphs in local id spaces
graphs, coarses, comps, waves, edges = distributed.build_subgraphs(
    mesh, x, cfg, jax.random.PRNGKey(1))
assert len(graphs) == 4 and all(int(g.n_valid) == 80 for g in graphs)
assert coarses == [None] * 4  # random seed mode: no shard levels
assert comps > 0 and waves > 0 and edges > 0

# the same shard graphs fold through the device-path of build_parallel —
# with a mesh, the merge-tree levels run mesh-resident (merge_pairs_mesh)
g, stats = construct.build_parallel(
    x, cfg, jax.random.PRNGKey(1), shards=4, refine_rounds=1, mesh=mesh)
tids, _ = brute.brute_force_knn(
    x, x, 8, "l2", exclude_ids=jnp.arange(n, dtype=jnp.int32),
    use_pallas=False)
rec = float(brute.recall_at_k(g.nbr_ids, tids, 8))
from repro.core.graph import graph_invariants_ok
inv = graph_invariants_ok(g)
bad = [k for k, v in inv.items() if not bool(jnp.all(v))]

# coarse seed mode: shard levels derive per device, fold through the mesh
# levels (stacked CoarseLevel operands), and the root level rides out
import dataclasses
cfg_c = dataclasses.replace(cfg, seed_mode="coarse", coarse_landmarks=32,
                            coarse_members=4)
g2, stats2, lvl = construct.build_parallel(
    x, cfg_c, jax.random.PRNGKey(2), shards=4, refine_rounds=1, mesh=mesh,
    return_coarse=True)
assert lvl is not None and lvl.n_landmarks == 4 * 32
rows = np.asarray(lvl.landmark_rows)
assert rows.min() >= 0 and rows.max() < n  # folded into the union id space
rec_c = float(brute.recall_at_k(g2.nbr_ids, tids, 8))
print(json.dumps({"recall": rec, "bad": bad, "comps": int(stats.n_comps),
                  "recall_coarse": rec_c}))
"""


@pytest.fixture(scope="module")
def subgraph_result():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SUBGRAPH_SCRIPT], capture_output=True,
        text=True, env=env, timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_device_parallel_build_merges_clean(subgraph_result):
    r = subgraph_result
    assert not r["bad"], r
    assert r["comps"] > 0


def test_device_parallel_build_recall(subgraph_result):
    # 4-way device build + symmetric merge + one refine round must land in
    # the same quality band as the single-graph build at this tiny scale
    assert subgraph_result["recall"] > 0.85, subgraph_result


def test_device_parallel_build_coarse_recall(subgraph_result):
    # coarse-seeded mesh fold (stacked CoarseLevel operands under shard_map)
    # must match the random-seeded fold's quality band
    assert subgraph_result["recall_coarse"] > 0.85, subgraph_result


COMPRESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.kernels import compat
from repro.train import optimizer as opt_lib, train_loop

mesh = compat.make_mesh((2, 4), ("pod", "data"))

def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2), {}

key = jax.random.PRNGKey(0)
w_true = jax.random.normal(key, (16, 4))
x = jax.random.normal(jax.random.fold_in(key, 1), (64, 16))
y = x @ w_true
params = {"w": jnp.zeros((16, 4))}
ocfg = opt_lib.OptConfig(name="sgd", lr=0.15, grad_clip=0.0)

def run(compress):
    p = {"w": jnp.zeros((16, 4))}
    opt = opt_lib.init_opt_state(p, ocfg)
    err = train_loop.init_pod_error_state(p, mesh)
    step = jax.jit(train_loop.make_sharded_train_step(
        loss_fn, ocfg, mesh, compress_pod=compress))
    with mesh:
        for i in range(150):
            p, opt, err, m = step(p, opt, err, {"x": x, "y": y})
    return float(m["loss"]), p

l_comp, p_comp = run(True)
l_ref, p_ref = run(False)
dw = float(jnp.max(jnp.abs(p_comp["w"] - p_ref["w"])))
print(json.dumps({"loss_compressed": l_comp, "loss_ref": l_ref, "max_dw": dw}))
"""


@pytest.fixture(scope="module")
def compress_result():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", COMPRESS_SCRIPT], capture_output=True, text=True,
        env=env, timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_compressed_dp_converges(compress_result):
    r = compress_result
    assert r["loss_compressed"] < 1e-2, r


@pytest.mark.slow
def test_compressed_tracks_uncompressed(compress_result):
    r = compress_result
    # int8 error feedback: same optimum, small transient deviation
    assert r["max_dw"] < 0.05, r
