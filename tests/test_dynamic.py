"""Dedicated coverage for core.dynamic — §IV-C online insert/remove.

Pins the two claims the paper makes for dynamic sets:
  * insertion is just more construction waves: an insert-then-remove round
    trip leaves a graph that searches as well as it did before the churn;
  * removal's λ repair (the undo of Rule 3, recomputed with ~k²/2 distances
    per affected row) is exact — checked against a NumPy oracle — and a
    repaired graph matches a from-scratch rebuild on the surviving points in
    search quality.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import brute, construct, dynamic, metrics
from repro.core import graph as graph_lib
from repro.core import search as search_lib

N0, N_EXTRA, D, K = 500, 100, 8, 8
N = N0 + N_EXTRA


@pytest.fixture(scope="module")
def data():
    rng = np.random.RandomState(0)
    return jnp.asarray(rng.rand(N, D).astype(np.float32))


@pytest.fixture(scope="module")
def queries():
    rng = np.random.RandomState(42)
    return jnp.asarray(rng.rand(64, D).astype(np.float32))


def _cfg(**kw):
    base = dict(k=K, wave=64, lgd=True, beam=16, n_seeds=4, hash_slots=512,
                max_iters=32)
    base.update(kw)
    return construct.BuildConfig(**base)


def _search_recall(g, x, q, true_ids, k=K):
    cfg = search_lib.SearchConfig(k=k, beam=32, n_seeds=8, hash_slots=1024,
                                  max_iters=48)
    res = search_lib.search(g, x, q, jax.random.PRNGKey(5), cfg)
    return float(brute.recall_at_k(res.ids, true_ids, k))


class TestInsertRemoveRoundTrip:
    def test_round_trip_preserves_recall(self, data, queries):
        base = data[:N0]
        truth_base, _ = brute.brute_force_knn(base, queries, K, "l2")
        cfg = _cfg()
        g0, _ = construct.build(base, cfg, jax.random.PRNGKey(0))
        rec_before = _search_recall(g0, base, queries, truth_base)

        # insert the extra rows online, then withdraw exactly those rows
        g_grown = graph_lib.grow_graph(g0, N)
        g1, stats = dynamic.insert(g_grown, data, N_EXTRA, cfg,
                                   jax.random.PRNGKey(1))
        assert int(g1.n_valid) == N
        assert int(stats.n_waves) == (N_EXTRA + cfg.wave - 1) // cfg.wave
        victims = jnp.arange(N0, N, dtype=jnp.int32)
        g2 = dynamic.remove(g1, data, victims, "l2")

        # structure: the removed rows are gone from every list
        assert not bool(jnp.any(g2.alive[victims]))
        assert not bool(jnp.any(g2.nbr_ids >= N0))
        assert not bool(jnp.any(g2.rev_ids >= N0))
        # liveness invariant: no alive row references a dead neighbor,
        # forward or reverse (graph_invariants_ok's live_* checks)
        inv = graph_lib.graph_invariants_ok(g2)
        for name, ok in inv.items():
            assert bool(jnp.all(ok)), name

        rec_after = _search_recall(g2, data, queries, truth_base)
        assert rec_after >= rec_before - 0.05, (rec_before, rec_after)

    def test_inserted_rows_are_searchable(self, data, queries):
        base = data[:N0]
        cfg = _cfg()
        g0, _ = construct.build(base, cfg, jax.random.PRNGKey(0))
        g1, _ = dynamic.insert(
            graph_lib.grow_graph(g0, N), data, N_EXTRA, cfg,
            jax.random.PRNGKey(1),
        )
        truth_full, _ = brute.brute_force_knn(data, queries, K, "l2")
        rec = _search_recall(g1, data, queries, truth_full)
        assert rec > 0.80, rec
        # at least some results come from the inserted region
        cfg_s = search_lib.SearchConfig(k=K, beam=32, n_seeds=8,
                                        hash_slots=1024, max_iters=48)
        res = search_lib.search(g1, data, queries, jax.random.PRNGKey(2), cfg_s)
        assert bool(jnp.any(res.ids >= N0))


def _lambda_repair_oracle(g, x, removed_ids, metric="l2"):
    """NumPy re-derivation of the Rule-3 undo in dynamic.remove.

    For each row r with removed member m at slot s: every valid, surviving
    member j at a later slot loses one λ count iff m(x_j, x_m) < m(x_m, x_r).
    Returns the expected λ decrement matrix (cap, k) BEFORE re-packing.
    """
    nbr_ids = np.asarray(g.nbr_ids)
    nbr_dist = np.asarray(g.nbr_dist)
    xs = np.asarray(x)
    cap, k = nbr_ids.shape
    removed = np.zeros(cap, bool)
    removed[np.asarray(removed_ids)] = True
    dec = np.zeros((cap, k), np.int64)
    for r in range(cap):
        ids = nbr_ids[r]
        valid = ids >= 0
        hit = valid & removed[np.maximum(ids, 0)]
        if not hit.any():
            continue
        vecs = xs[np.maximum(ids, 0)]
        dm = np.asarray(metrics.pairwise(metric, jnp.asarray(vecs),
                                         jnp.asarray(vecs)))
        for s in np.nonzero(hit)[0]:
            for j in range(s + 1, k):
                if valid[j] and not hit[j] and dm[s, j] < nbr_dist[r, s]:
                    dec[r, j] += 1
    return dec


class TestLambdaRepair:
    @pytest.fixture(scope="class")
    def small(self, data):
        small = data[:300]
        cfg = _cfg(wave=32)
        g, _ = construct.build(small, cfg, jax.random.PRNGKey(3))
        return small, g

    def test_repair_matches_numpy_oracle(self, small):
        x, g = small
        victims = jnp.asarray([7, 31, 100], jnp.int32)
        g2 = dynamic.remove(g, x, victims, "l2", repair_lambda=True)

        dec = _lambda_repair_oracle(g, x, victims)
        want_lam = np.maximum(np.asarray(g.nbr_lam) - dec, 0)
        # compare per (row, member) pair — remove() re-packs rows
        nbr_ids0 = np.asarray(g.nbr_ids)
        got_ids = np.asarray(g2.nbr_ids)
        got_lam = np.asarray(g2.nbr_lam)
        removed = set(int(v) for v in np.asarray(victims))
        for r in range(300):
            if r in removed:
                assert np.all(got_ids[r] == -1)
                continue
            want = {
                int(m): int(want_lam[r, s])
                for s, m in enumerate(nbr_ids0[r])
                if m >= 0 and int(m) not in removed
            }
            got = {
                int(m): int(got_lam[r, s])
                for s, m in enumerate(got_ids[r]) if m >= 0
            }
            assert got == want, f"row {r}: {got} != {want}"

    def test_repair_changes_only_lambda(self, small):
        x, g = small
        victims = jnp.asarray([7, 31, 100], jnp.int32)
        g_on = dynamic.remove(g, x, victims, "l2", repair_lambda=True)
        g_off = dynamic.remove(g, x, victims, "l2", repair_lambda=False)
        np.testing.assert_array_equal(np.asarray(g_on.nbr_ids),
                                      np.asarray(g_off.nbr_ids))
        np.testing.assert_array_equal(np.asarray(g_on.nbr_dist),
                                      np.asarray(g_off.nbr_dist))
        # and the repair actually decremented something on this data
        assert int(jnp.sum(g_off.nbr_lam)) >= int(jnp.sum(g_on.nbr_lam))

    def test_repaired_graph_matches_scratch_rebuild(self, small, queries):
        """Removal + λ repair ≈ building from scratch on the survivors: the
        LGD-masked search quality of the two graphs must agree on small n."""
        x, g = small
        n_keep = 270
        victims = jnp.arange(n_keep, 300, dtype=jnp.int32)
        g_rm = dynamic.remove(g, x, victims, "l2", repair_lambda=True)

        cfg = _cfg(wave=32)
        g_scratch, _ = construct.build(x[:n_keep], cfg, jax.random.PRNGKey(4))

        truth, _ = brute.brute_force_knn(x[:n_keep], queries, K, "l2")
        scfg = search_lib.SearchConfig(k=K, beam=32, n_seeds=8,
                                       hash_slots=1024, max_iters=48,
                                       use_lgd_mask=True)
        rec_rm = float(brute.recall_at_k(
            search_lib.search(g_rm, x, queries, jax.random.PRNGKey(6),
                              scfg).ids,
            truth, K))
        rec_scratch = float(brute.recall_at_k(
            search_lib.search(g_scratch, x[:n_keep], queries,
                              jax.random.PRNGKey(6), scfg).ids,
            truth, K))
        assert rec_rm >= rec_scratch - 0.10, (rec_rm, rec_scratch)
        assert rec_rm > 0.75, rec_rm
