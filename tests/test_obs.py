"""Telemetry subsystem tests (repro.obs).

Three contracts pinned here:

  * **span machinery** — nesting depth/parent stamps, close ordering
    (inner spans emit before their enclosing span), the ``synced`` flag,
    and the Noop tracker's no-sync/no-alloc behaviour;
  * **JSONL crash safety** — append mode, flush-per-event (events are
    readable while the tracker is still open), round-trip through
    ``load_events`` with a torn tail skipped rather than fatal;
  * **transparency** — attaching a tracker changes no search result
    bitwise (fp32): construct, lifecycle and the serving loop produce
    identical arrays with telemetry on and off.  (The property tier
    sweeps the construct/search leg over drawn cases via
    ``prop_util.check_tracker_transparency``; here it is pinned once at a
    serving-shaped size.)
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import construct
from repro.index.lifecycle import OnlineIndex
from repro.obs import (
    NOOP,
    InMemoryTracker,
    JsonlTracker,
    NoopTracker,
    SearchStats,
    load_events,
    span_tree,
)
from repro.serve.loop import ServeLoopConfig, ServingLoop


# ---------------------------------------------------------------------------
# span machinery (InMemoryTracker)
# ---------------------------------------------------------------------------


def test_span_nesting_depth_parent_and_order():
    trk = InMemoryTracker()
    with trk.span("outer"):
        with trk.span("inner") as sp:
            sp.synced = True
        with trk.span("inner2"):
            pass
    spans = trk.span_events
    # close order: inner spans emit before the enclosing span
    assert [e["name"] for e in spans] == ["inner", "inner2", "outer"]
    by_name = {e["name"]: e for e in spans}
    assert by_name["outer"]["depth"] == 0 and "parent" not in by_name["outer"]
    assert by_name["inner"]["depth"] == 1
    assert by_name["inner"]["parent"] == "outer"
    assert by_name["inner2"]["parent"] == "outer"
    assert by_name["inner"]["synced"] is True
    assert by_name["inner2"]["synced"] is False
    # wall-clock sanity: the outer span contains both inner spans
    assert by_name["outer"]["dur_s"] >= by_name["inner"]["dur_s"]
    assert all(e["dur_s"] >= 0.0 for e in spans)


def test_span_sync_returns_tree_and_marks():
    trk = InMemoryTracker()
    x = jnp.arange(4.0)
    with trk.span("s") as sp:
        out = sp.sync({"a": x})
    assert out["a"] is x  # passthrough: call sites write res = sp.sync(res)
    assert trk.spans("s")[0]["synced"] is True


def test_metrics_carry_step_and_enclosing_span():
    trk = InMemoryTracker()
    with trk.span("wave"):
        trk.log_metrics({"a": 1, "b": 2.5}, step=7)
    trk.log_metrics({"c": np.int64(3)})  # numpy scalar -> host scalar
    evs = trk.metrics_events
    assert evs[0]["span"] == "wave" and evs[0]["step"] == 7
    assert evs[0]["metrics"] == {"a": 1, "b": 2.5}
    assert "span" not in evs[1] and evs[1]["metrics"]["c"] == 3
    assert isinstance(evs[1]["metrics"]["c"], int)


def test_span_stack_unwinds_on_exception():
    trk = InMemoryTracker()
    with pytest.raises(RuntimeError):
        with trk.span("boom"):
            raise RuntimeError("x")
    # the span still emitted and the stack fully unwound
    assert [e["name"] for e in trk.span_events] == ["boom"]
    trk.log_metrics({"after": 1})
    assert "span" not in trk.metrics_events[-1]


def test_noop_tracker_is_inert_and_allocation_free():
    trk = NoopTracker()
    ctx1, ctx2 = trk.span("a"), trk.span("b")
    assert ctx1 is ctx2  # shared singleton: no per-span allocation
    x = jnp.arange(3.0)
    with trk.span("a") as sp:
        assert sp.sync(x) is x  # passthrough — no block_until_ready
        sp.synced = True  # annotation writes are discarded, not errors
        assert sp.synced is False
    trk.log_metrics({"k": 1}, step=0)
    trk.finish()
    assert isinstance(NOOP, NoopTracker)


# ---------------------------------------------------------------------------
# JsonlTracker: crash-safe append + round trip
# ---------------------------------------------------------------------------


def test_jsonl_round_trip_and_header(tmp_path):
    p = str(tmp_path / "trace.jsonl")
    trk = JsonlTracker(p, run_meta={"bench": "unit", "n": 8})
    with trk.span("outer"):
        trk.log_metrics({"x": 1.5}, step=0)
    trk.finish()
    evs = load_events(p)
    assert [e["event"] for e in evs] == ["run", "metrics", "span"]
    assert evs[0]["meta"] == {"bench": "unit", "n": 8}
    assert "wall_time_utc" in evs[0] and "pid" in evs[0]
    assert evs[1]["metrics"] == {"x": 1.5} and evs[1]["span"] == "outer"
    assert evs[2]["name"] == "outer" and evs[2]["depth"] == 0


def test_jsonl_flush_per_event_readable_before_finish(tmp_path):
    p = str(tmp_path / "live.jsonl")
    trk = JsonlTracker(p)
    trk.log_metrics({"early": 1})
    # crash-safety contract: every event is flushed as written, so a
    # reader (or a post-crash inspection) sees it without finish()
    assert [e["event"] for e in load_events(p)] == ["run", "metrics"]
    trk.finish()


def test_jsonl_append_mode_multiple_runs(tmp_path):
    p = str(tmp_path / "multi.jsonl")
    for i in range(2):
        trk = JsonlTracker(p, run_meta={"run": i})
        trk.log_metrics({"i": i})
        trk.finish()
    evs = load_events(p)
    runs = [e for e in evs if e["event"] == "run"]
    assert [r["meta"]["run"] for r in runs] == [0, 1]
    assert len(evs) == 4  # 2 x (header + metrics), nothing clobbered


def test_jsonl_torn_tail_skipped(tmp_path):
    p = str(tmp_path / "torn.jsonl")
    trk = JsonlTracker(p)
    trk.log_metrics({"ok": 1})
    trk.finish()
    with open(p, "a", encoding="utf-8") as f:
        f.write('{"event": "metrics", "metrics": {"to')  # crash mid-write
    evs = load_events(p)
    assert [e["event"] for e in evs] == ["run", "metrics"]
    assert evs[1]["metrics"] == {"ok": 1}


def test_jsonl_post_finish_emit_dropped_not_fatal(tmp_path):
    p = str(tmp_path / "closed.jsonl")
    trk = JsonlTracker(p)
    trk.finish()
    trk.log_metrics({"late": 1})  # dropped, must not raise
    assert [e["event"] for e in load_events(p)] == ["run"]


def test_jsonl_lines_are_valid_json_objects(tmp_path):
    p = str(tmp_path / "schema.jsonl")
    trk = JsonlTracker(p)
    with trk.span("a"):
        with trk.span("b") as sp:
            sp.synced = True
    trk.finish()
    with open(p, encoding="utf-8") as f:
        for line in f:
            ev = json.loads(line)
            assert isinstance(ev, dict) and "event" in ev


def test_span_tree_renders_nesting(tmp_path):
    trk = InMemoryTracker()
    with trk.span("outer"):
        with trk.span("inner") as sp:
            sp.synced = True
    lines = list(span_tree(trk.events))
    assert lines[0].startswith("  inner:") and "[dispatch-only]" not in lines[0]
    assert lines[1].startswith("outer:") and "[dispatch-only]" in lines[1]


# ---------------------------------------------------------------------------
# SearchStats aggregation math
# ---------------------------------------------------------------------------


class _FakeRes:
    """Duck-typed SearchResult accounting surface."""

    def __init__(self, comps, full, iters, conv):
        self.n_comps = np.asarray(comps, np.int32)
        self.hash_full = np.asarray(full, bool)
        self.n_iters = np.asarray(iters, np.int32)
        self.converged = np.asarray(conv, bool)


def test_search_stats_totals_and_ratios():
    st = SearchStats()
    st.update(
        _FakeRes([4, 9, 16, 0], [True, False, False, False],
                 [2, 3, 4, 1], [True, True, False, True]),
        n_items=100,
    )
    assert st.n_queries == 4
    assert st.total_comps == 29
    assert st.comps_per_query == pytest.approx(29 / 4)
    assert st.hash_saturation_ratio == pytest.approx(1 / 4)
    assert st.capped_ratio == pytest.approx(1 / 4)
    assert st.max_comps == 16
    assert st.scanning_rate == pytest.approx(29 / (4 * 100))
    # pow2 histogram: 4 -> bucket 2, 9 -> 3, 16 -> 4, 0 -> 0
    want = np.zeros(32, np.int64)
    want[[2, 3, 4, 0]] += 1
    np.testing.assert_array_equal(st.hist, want)


def test_search_stats_churn_weighted_scanning_rate():
    # the denominator is the catalog size each query actually saw
    st = SearchStats()
    st.update(_FakeRes([10], [False], [1], [True]), n_items=100)
    st.update(_FakeRes([10], [False], [1], [True]), n_items=300)
    assert st.scanning_rate == pytest.approx(20 / (100 + 300))


def test_search_stats_merge_and_reset():
    a = SearchStats(n_items=50)
    a.update(_FakeRes([8], [True], [2], [False]))
    b = SearchStats()
    b.update(_FakeRes([2, 2], [False, False], [1, 1], [True, True]), n_items=10)
    a.merge(b)
    assert a.n_queries == 3 and a.total_comps == 12
    assert a.hash_full_queries == 1 and a.capped_queries == 1
    assert a._n_items_weighted == 50 + 20
    a.reset()
    assert a.n_queries == 0 and a.total_comps == 0
    assert a.default_n_items == 50  # the pinned default survives reset
    assert not a.hist.any()


def test_search_stats_percentile_brackets_true_value():
    st = SearchStats()
    comps = [3] * 50 + [40] * 50
    st.update(_FakeRes(comps, [False] * 100, [1] * 100, [True] * 100),
              n_items=None)
    # histogram percentile reports the upper bucket edge: <= 2x overestimate
    assert 3 <= st.comps_percentile(25) <= 6
    assert 40 <= st.comps_percentile(99) <= 80
    m = st.as_metrics("s")
    assert m["s/n_queries"] == 100
    assert m["s/comps_per_query"] == pytest.approx(21.5)
    for k in ("s/comps_p50", "s/comps_p99", "s/scanning_rate",
              "s/hash_saturation_ratio", "s/capped_ratio"):
        assert k in m


# ---------------------------------------------------------------------------
# transparency: tracker on == tracker off, bitwise (fp32)
# ---------------------------------------------------------------------------


def _mk_items(n=192, d=8, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.rand(n, d).astype(np.float32))


def test_build_bitwise_identical_with_tracker():
    x = _mk_items()
    # n_seed_init below n so the wave loop (the instrumented path) runs
    cfg = construct.BuildConfig(k=6, wave=64, n_seed_init=64)
    key = jax.random.PRNGKey(3)
    g0, s0 = construct.build(x, cfg, key)
    trk = InMemoryTracker()
    g1, s1 = construct.build(x, cfg, key, tracker=trk)
    np.testing.assert_array_equal(np.asarray(g0.nbr_ids), np.asarray(g1.nbr_ids))
    np.testing.assert_array_equal(np.asarray(g0.nbr_dist), np.asarray(g1.nbr_dist))
    assert int(s0.n_comps) == int(s1.n_comps)
    # and the tracker actually saw the build
    assert trk.spans("build/stride")
    assert any("build/n_comps" in e["metrics"] for e in trk.metrics_events)


def test_serving_loop_bitwise_identical_with_tracker():
    """The full serving surface — churn flushes, waves, padding — serves
    bit-identical ids with telemetry on and off (same seeds throughout)."""
    x = _mk_items()
    rng = np.random.RandomState(7)
    bursts = [rng.rand(m, 8).astype(np.float32) for m in (5, 3, 8, 1)]
    adds = rng.rand(4, 8).astype(np.float32)

    def run(tracker):
        idx = OnlineIndex.build(
            x, construct.BuildConfig(k=6, wave=64), key=jax.random.PRNGKey(1)
        )
        loop = ServingLoop(
            idx, ServeLoopConfig(top_k=5, max_batch=8,
                                 recall_sample_every=3, recall_reservoir=4),
            tracker=tracker, seed=11,
        )
        served = []
        loop.submit(bursts[0])
        loop.step()
        loop.add(adds, key=jax.random.PRNGKey(2))
        loop.remove(jnp.asarray([0, 17]))
        for b in bursts[1:]:
            loop.submit(b)
        while loop.queue_depth:
            w = loop.step()
            served.append(w["bucket"])
        # capture everything that was served via the audit reservoir
        return loop, served

    loop0, buckets0 = run(None)
    trk = InMemoryTracker()
    loop1, buckets1 = run(trk)
    assert buckets0 == buckets1
    assert loop0.served == loop1.served == sum(b.shape[0] for b in bursts)
    for a, b in zip(loop0._res_ids, loop1._res_ids):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(loop0._res_q, loop1._res_q):
        np.testing.assert_array_equal(a, b)
    # lifecycle state equally untouched by telemetry
    np.testing.assert_array_equal(
        np.asarray(loop0.index.graph.nbr_ids), np.asarray(loop1.index.graph.nbr_ids)
    )
    np.testing.assert_array_equal(
        np.asarray(loop0.index.graph.alive), np.asarray(loop1.index.graph.alive)
    )
    # the tracked run produced the expected span skeleton (+1: the first
    # burst's wave is served before the churn, outside the bucket list)
    assert len(trk.spans("serve/step")) == len(buckets1) + 1
    assert len(trk.spans("serve/search")) == len(buckets1) + 1
    assert trk.spans("serve/remove")[0]["synced"] is True
    assert trk.spans("index/flush")  # churn flush nested under the loop
